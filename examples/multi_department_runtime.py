"""End-to-end N-department runtime demo (ROADMAP item): 2 elastic trainers
+ 1 serving pool consolidated on ONE host DevicePool, driven by
``MultiTenantOrchestrator`` under the ``slo_headroom`` reclaim engine.

A WS load spike makes the serving department claim devices; the phase-1
reclaim planner orders victims by live ``TenantSignals`` (the predicted
latency headroom fed back by ``latency_tick_slo``, trainer preemption
costs), shrinking trainers by whole DP groups; when the spike passes, idle
devices reflow and the trainers grow back — no training work lost.

    PYTHONPATH=src python examples/multi_department_runtime.py

With a budget-constrained market engine the serving department pays the
trainers' per-node bids for every device it preempts (beyond its floor);
watch its remaining budget drain across the spike until it can no longer
afford the replicas its SLO wants — the department throttles ITSELF
(at --budget 3 the peak gets 3 replicas instead of 4 and the latency
headroom collapses from +0.80s to +0.21s; once fully broke it falls back
to its floor):

    PYTHONPATH=src python examples/multi_department_runtime.py \\
        --policy budget_auction --budget 3
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import sys
import tempfile

import jax
import numpy as np

from repro.configs import ARCHS, reduced_config
from repro.configs.base import TrainConfig
from repro.core.types import SLOConfig
from repro.models import model as M
from repro.runtime.elastic import ElasticTrainer
from repro.runtime.orchestrator import MultiTenantOrchestrator
from repro.runtime.serving_pool import ServingPool
from repro.serving.batching import ServiceTimeModel
from repro.workloads.autoscaler import SLOAutoscaler


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--policy", default="slo_headroom")
    ap.add_argument("--intervals", type=int, default=8)
    ap.add_argument("--budget", type=float, default=0.0,
                    help="serving department's market budget (tokens; "
                         "0 = unlimited) for the budget engines")
    args = ap.parse_args(argv)
    budget = args.budget if args.budget > 0 else None

    cfg = reduced_config(ARCHS[args.arch])
    params = M.init_params(jax.random.PRNGKey(0), cfg)

    def trainer():
        return ElasticTrainer(cfg, TrainConfig(learning_rate=1e-3),
                              global_batch=4, seq_len=32,
                              ckpt_dir=tempfile.mkdtemp(prefix="phx_"),
                              model_size=1)

    slo = SLOConfig(latency_target_s=2.0)
    scaler = SLOAutoscaler(ServiceTimeModel(), slo, n_min=1, n_max=6)
    pool = ServingPool(cfg, params, capacity_tokens_per_replica=200.0)

    orch = MultiTenantOrchestrator(policy=args.policy)
    orch.add_latency("serve", pool, priority=0, slo_autoscaler=scaler,
                     floor=1, budget=budget,
                     bid_policy="slo_elastic" if budget else "linear")
    ta, tb = trainer(), trainer()
    orch.add_batch("train-a", ta, priority=1, weight=2.0, min_devices=1)
    orch.add_batch("train-b", tb, priority=2, weight=1.0, min_devices=1)
    orch.start()

    # WS request rate (req/s): trough -> spike -> trough
    rates = np.interp(np.arange(args.intervals),
                      [0, 2, 4, args.intervals - 1], [0.2, 0.2, 30.0, 0.2])
    mean_s, scv = 0.35, 1.0
    for i, rate in enumerate(rates):
        orch.latency_tick_slo("serve", float(rate), mean_s, scv)
        ma = orch.train_steps("train-a", 1)
        mb = orch.train_steps("train-b", 1)
        sig = orch.svc.tenants["serve"].signals()
        market = orch.market_state()
        wallet = ""
        if market is not None and budget is not None:
            wallet = (f"  budget={market['remaining']['serve']:6.1f}/"
                      f"{budget:g} left")
        print(f"interval {i}: rate={rate:5.1f} req/s  "
              f"replicas={len(pool.replicas)}  "
              f"headroom={sig.latency_headroom_s:+6.2f}s  "
              f"train-a devs={ma['devices']} step={ma['step']}  "
              f"train-b devs={mb['devices']} step={mb['step']}{wallet}")

    print("\nper-department benefit summary")
    print("------------------------------")
    shrinks = [e for e in orch.events if e["kind"] == "shrink"]
    state = orch.svc.policy.state_snapshot()
    for name, dept in orch.batch.items():
        t = dept.trainer
        drained = state["victim_nodes"].get(name, 0)
        print(f"  {name:8s} batch   steps={t.step:3d}  "
              f"resizes={t.resizes}  devices={len(orch.devs.groups[name])}  "
              f"devices_reclaimed_from_it={drained} "
              f"(no work lost across resizes)")
    rec = orch.svc.tenants["serve"]
    print(f"  serve    latency replicas={len(pool.replicas)}  "
          f"alloc={rec.alloc}  floor={rec.floor}  "
          f"slo_target={slo.latency_target_s}s")
    print(f"  engine={state['engine']}  reclaim_plans="
          f"{state['reclaim_plans']}  last_plan={state['last_plan']}  "
          f"trainer_shrinks={len(shrinks)}")
    market = orch.market_state()
    if market is not None:
        spend = {n: round(v, 1) for n, v in market["spend"].items()}
        print(f"  market   spend={spend}  clearing_prices="
              f"{[round(p, 2) for p in market['clearing_prices'][:8]]}  "
              f"transactions={market['transactions']}")
    orch.devs.check()
    orch.svc.check()
    return 0


if __name__ == "__main__":
    sys.exit(main())
