"""Paper experiment end-to-end: SC vs DC consolidation (Fig. 5/7/8).

Default runs the request-level WS workload (``repro.workloads``): requests
arrive via a flash-crowd process, an SLO autoscaler turns latency targets
into node demand, and each DC row reports p99 latency + SLO-violation rate
alongside the paper's benefit metrics. ``--ws timeseries`` reproduces the
paper's original instance-demand curve instead.

``--mix``/``--policy`` run an N-department consolidation instead of the
paper's two: e.g. ``--mix 2hpc2ws1be --policy proportional_share``
consolidates 2 HPC + 2 request-level WS + 1 best-effort batch department
under weighted proportional idle sharing, reporting per-department benefit
metrics for each DC size.

    PYTHONPATH=src python examples/consolidation_sim.py
    PYTHONPATH=src python examples/consolidation_sim.py --ws timeseries
    PYTHONPATH=src python examples/consolidation_sim.py --preempt checkpoint
    PYTHONPATH=src python examples/consolidation_sim.py --arrival mmpp --slo 20
    PYTHONPATH=src python examples/consolidation_sim.py \
        --mix 2hpc2ws1be --policy demand_capped
"""
import argparse
import sys

from repro.core.experiment import (DC_SIZES, SC_TOTAL, run_experiment,
                                   validate_claims)
from repro.core.policies import POLICIES
from repro.core.simulator import ConsolidationSim
from repro.core.traces import TWO_WEEKS_S, synthetic_sdsc_blue
from repro.core.types import SimConfig, SLOConfig
from repro.serving.batching import ServiceTimeModel
from repro.workloads import RequestWorkload, make_trace
from repro.workloads.arrivals import GENERATORS
from repro.workloads.campaign import MIXES, ScenarioCell, make_tenants

WS_DEDICATED = 64           # SC: the WS department's own machine


def run_mix(args, cfg, sizes):
    """N-department consolidation sweep with per-department benefits."""
    horizon = args.days * 86400.0
    print(f"\n== N-department consolidation: mix={args.mix} "
          f"policy={args.policy} preempt={args.preempt} ==")
    for size in sizes:
        cell = ScenarioCell(preempt=args.preempt, scheduler=args.scheduler,
                            arrival=args.arrival, total_nodes=size,
                            slo_target_s=args.slo, rate_rps=args.rate,
                            horizon_s=horizon,
                            n_jobs=max(40, int(2672 * horizon / TWO_WEEKS_S)),
                            policy=args.policy, mix=args.mix, seed=args.seed)
        sim = ConsolidationSim(
            SimConfig(total_nodes=size, preempt_mode=args.preempt,
                      scheduler=args.scheduler, seed=args.seed),
            horizon=horizon, tenants=make_tenants(cell), policy=args.policy)
        res = sim.run()
        print(f"\n-- total_nodes={size} "
              f"(cost {100.0 * size / SC_TOTAL:.1f}% of SC {SC_TOTAL}) --")
        print(f"{'department':>12} {'kind':>8} {'prio':>5} {'avg_alloc':>10} "
              f"{'benefit':<48}")
        for name, t in res.tenants.items():
            ben = "  ".join(f"{k}={v:.4g}" for k, v in t.benefit.items())
            print(f"{name:>12} {t.kind:>8} {t.priority:>5} "
                  f"{t.avg_alloc:>10.1f} {ben:<48}")
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--preempt", default="kill",
                    choices=["kill", "checkpoint"])
    ap.add_argument("--scheduler", default="first_fit",
                    choices=["first_fit", "fcfs", "easy_backfill"])
    ap.add_argument("--sizes", default=",".join(map(str, DC_SIZES)))
    ap.add_argument("--ws", default="requests",
                    choices=["requests", "timeseries"],
                    help="WS model: request-level + SLO autoscaler (new) "
                         "or the paper's instance-demand timeseries")
    ap.add_argument("--arrival", default="flash_crowd",
                    choices=sorted(GENERATORS))
    ap.add_argument("--rate", type=float, default=3.0,
                    help="mean WS request rate (req/s, requests mode)")
    ap.add_argument("--slo", type=float, default=30.0,
                    help="p99 latency target in seconds (requests mode)")
    ap.add_argument("--days", type=float, default=2.0,
                    help="horizon in days for requests mode (timeseries "
                         "mode always runs the paper's 14 days)")
    ap.add_argument("--mix", default="paper2", choices=sorted(MIXES),
                    help="department mix; paper2 = the paper's 1 HPC + 1 WS")
    ap.add_argument("--policy", default="paper", choices=sorted(POLICIES),
                    help="cooperative policy for the N-department mix")
    args = ap.parse_args(argv)

    cfg = SimConfig(preempt_mode=args.preempt, scheduler=args.scheduler,
                    seed=args.seed)
    sizes = tuple(int(s) for s in args.sizes.split(","))

    if args.mix != "paper2" or args.policy != "paper":
        return run_mix(args, cfg, sizes)

    workload = None
    if args.ws == "requests":
        horizon = args.days * 86400.0
        jobs = synthetic_sdsc_blue(
            args.seed, n_jobs=max(40, int(2672 * horizon / TWO_WEEKS_S)),
            horizon=horizon)
        trace = make_trace(args.arrival, args.rate, horizon, args.seed)
        workload = RequestWorkload(trace=trace, model=ServiceTimeModel(),
                                   slo=SLOConfig(latency_target_s=args.slo))
        res = run_experiment(seed=args.seed, cfg=cfg, sizes=sizes,
                             horizon=horizon, jobs=jobs, ws_demand=workload)
    else:
        res = run_experiment(seed=args.seed, cfg=cfg, sizes=sizes)

    sc = res["SC"]
    print(f"\n== Static configuration (SC): {SC_TOTAL} nodes "
          f"(144 HPC + {WS_DEDICATED} WS) ==")
    print(f"  completed={sc.completed}/{sc.submitted}  "
          f"avg_turnaround={sc.avg_turnaround:.0f}s  "
          f"benefit_user={sc.benefit_user:.2e}")
    if workload is not None:
        sc_lat = workload.realized_metrics([(0.0, WS_DEDICATED)],
                                           horizon=horizon)
        print(f"  WS on dedicated {WS_DEDICATED} nodes: "
              f"{len(workload.trace)} requests, "
              f"p99={sc_lat['p99_s']:.1f}s  "
              f"slo_violation={100 * sc_lat['violation_rate']:.2f}%")

    print(f"\n== Dynamic configuration (DC), policy={args.preempt}/"
          f"{args.scheduler}, ws={args.ws} ==")
    lat_hdr = f" {'ws_p99':>8} {'viol%':>6}" if workload is not None else ""
    print(f"{'size':>6} {'cost%':>6} {'completed':>10} {'killed':>7} "
          f"{'preempt':>8} {'turnaround':>11} {'ws_unmet':>9}{lat_hdr}")
    for size in sorted(res['DC'], reverse=True):
        r = res["DC"][size]
        lat = ""
        if r.ws_latency is not None:
            lat = (f" {r.ws_latency['p99_s']:>7.1f}s "
                   f"{100 * r.ws_latency['violation_rate']:>5.2f}%")
        print(f"{size:>6} {100.0*size/SC_TOTAL:>5.1f}% {r.completed:>10} "
              f"{r.killed:>7} {r.preemptions:>8} "
              f"{r.avg_turnaround:>10.0f}s {r.ws_unmet_node_seconds:>9.0f}"
              f"{lat}")
    if args.ws == "timeseries" and 160 in res["DC"]:
        print("\npaper-claim validation:", validate_claims(res))
    else:
        print("\n(paper-claim validation needs the calibrated 14-day "
              "trace: run with --ws timeseries)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
