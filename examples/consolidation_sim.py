"""Paper experiment end-to-end: SC vs DC consolidation (Fig. 5/7/8).

    PYTHONPATH=src python examples/consolidation_sim.py
    PYTHONPATH=src python examples/consolidation_sim.py --preempt checkpoint
    PYTHONPATH=src python examples/consolidation_sim.py --scheduler easy_backfill
"""
import argparse
import sys

from repro.core.experiment import (DC_SIZES, SC_TOTAL, run_experiment,
                                   validate_claims)
from repro.core.types import SimConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--preempt", default="kill",
                    choices=["kill", "checkpoint"])
    ap.add_argument("--scheduler", default="first_fit",
                    choices=["first_fit", "fcfs", "easy_backfill"])
    ap.add_argument("--sizes", default=",".join(map(str, DC_SIZES)))
    args = ap.parse_args(argv)

    cfg = SimConfig(preempt_mode=args.preempt, scheduler=args.scheduler,
                    seed=args.seed)
    sizes = tuple(int(s) for s in args.sizes.split(","))
    res = run_experiment(seed=args.seed, cfg=cfg, sizes=sizes)

    sc = res["SC"]
    print(f"\n== Static configuration (SC): {SC_TOTAL} nodes "
          f"(144 HPC + 64 WS) ==")
    print(f"  completed={sc.completed}/{sc.submitted}  "
          f"avg_turnaround={sc.avg_turnaround:.0f}s  "
          f"benefit_user={sc.benefit_user:.2e}")
    print(f"\n== Dynamic configuration (DC), policy={args.preempt}/"
          f"{args.scheduler} ==")
    print(f"{'size':>6} {'cost%':>6} {'completed':>10} {'killed':>7} "
          f"{'preempt':>8} {'turnaround':>11} {'ws_unmet':>9}")
    for size in sorted(res['DC'], reverse=True):
        r = res["DC"][size]
        print(f"{size:>6} {100.0*size/SC_TOTAL:>5.1f}% {r.completed:>10} "
              f"{r.killed:>7} {r.preemptions:>8} "
              f"{r.avg_turnaround:>10.0f}s {r.ws_unmet_node_seconds:>9.0f}")
    claims = validate_claims(res) if 160 in res["DC"] else {}
    print("\npaper-claim validation:", claims)
    return 0


if __name__ == "__main__":
    sys.exit(main())
