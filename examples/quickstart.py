"""Quickstart: train a small LM with the repro stack on CPU.

    PYTHONPATH=src python examples/quickstart.py --arch deepseek-7b --steps 5

Uses the reduced per-family config (the full configs are exercised by the
512-device dry-run: `python -m repro.launch.dryrun`).
"""
import argparse
import sys
import time

import jax

from repro.configs import ARCHS, reduced_config
from repro.configs.base import TrainConfig
from repro.data.pipeline import SyntheticLM
from repro.training.train_step import init_state, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b", choices=sorted(ARCHS))
    ap.add_argument("--steps", type=int, default=5)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args(argv)

    cfg = reduced_config(ARCHS[args.arch])
    tcfg = TrainConfig(learning_rate=1e-3, z_loss=0.0)
    state = init_state(jax.random.PRNGKey(0), cfg)
    step_fn = jax.jit(make_train_step(cfg, tcfg, moe_groups=2),
                      donate_argnums=(0,))
    data = SyntheticLM(cfg, seed=0)
    print(f"arch={cfg.name} (reduced) params="
          f"{sum(x.size for x in jax.tree.leaves(state.params)):,}")
    for step in range(args.steps):
        t0 = time.time()
        batch = data.batch(step, args.batch, args.seq)
        state, metrics = step_fn(state, batch)
        print(f"step {step}: loss={float(metrics['loss']):.4f} "
              f"nll={float(metrics['nll']):.4f} "
              f"gnorm={float(metrics['grad_norm']):.3f} "
              f"({time.time()-t0:.2f}s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
