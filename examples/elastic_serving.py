"""Serving a small model with batched requests through the WS CMS stack:
continuous batcher + least-outstanding balancer + utilization autoscaler.

    PYTHONPATH=src python examples/elastic_serving.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import argparse
import sys
import time

import jax
import numpy as np

from repro.configs import ARCHS, reduced_config
from repro.models import model as M
from repro.runtime.serving_pool import ServingPool
from repro.serving.batching import ContinuousBatcher, Request


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--requests", type=int, default=24)
    args = ap.parse_args(argv)

    cfg = reduced_config(ARCHS[args.arch])
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    pool = ServingPool(cfg, params, capacity_tokens_per_replica=400.0)
    pool.scale_to(jax.devices()[:1])
    batcher = ContinuousBatcher(max_batch=8, bucket=64)

    rng = np.random.default_rng(0)
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size, size=(8,), dtype=np.int32)
        batcher.submit(Request(req_id=i, prompt=prompt, max_new=8,
                               arrival=i * 0.01))

    t0 = time.time()
    rounds = 0
    while batcher.queue:
        reqs = batcher.next_round()
        # autoscale against the queue's offered load
        offered = sum(len(r.prompt) + r.max_new for r in list(batcher.queue)
                      + reqs)
        want = pool.desired_replicas(float(offered))
        pool.scale_to(jax.devices()[:min(want, 4)])
        batcher.run_round(reqs, pool.submit, now=time.time() - t0)
        rounds += 1
        print(f"round {rounds}: batch={len(reqs)} replicas="
              f"{len(pool.replicas)} queued={len(batcher.queue)}")
    done = batcher.completed
    print(f"\nserved {len(done)} requests in {rounds} rounds, "
          f"{time.time()-t0:.2f}s wall")
    print("throughput:",
          f"{sum(r.max_new for r in done)/(time.time()-t0):.1f} tok/s")
    assert all(r.done is not None and len(r.done) == r.max_new for r in done)
    return 0


if __name__ == "__main__":
    sys.exit(main())
