"""End-to-end training driver: a ~100M-param LM for a few hundred steps.

    PYTHONPATH=src python examples/train_100m.py --preset 100m --steps 300
    PYTHONPATH=src python examples/train_100m.py --preset 10m  --steps 200

Uses the full stack: config -> data pipeline -> train step (AdamW, remat,
z-loss) -> async checkpointing -> metrics log. On this CPU container the
`10m` preset finishes a 200-step run in minutes; `100m` is the same driver
at deepseek-family dimensions d=768/L=12 (~124M params).
"""
import argparse
import json
import os
import sys
import time

import jax

from repro.checkpoint.checkpointer import AsyncCheckpointer, latest_step, restore
from repro.configs import ARCHS
from repro.configs.base import TrainConfig
from repro.data.pipeline import SyntheticLM
from repro.training.train_step import init_state, make_train_step

PRESETS = {
    "10m": dict(num_layers=4, d_model=256, num_heads=4, num_kv_heads=4,
                head_dim=64, d_ff=1024, vocab_size=8192),
    "30m": dict(num_layers=6, d_model=512, num_heads=8, num_kv_heads=8,
                head_dim=64, d_ff=2048, vocab_size=16384),
    "100m": dict(num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
                 head_dim=64, d_ff=3072, vocab_size=32768),
}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="10m", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--log", default="")
    args = ap.parse_args(argv)

    cfg = ARCHS["deepseek-7b"].with_(param_dtype="float32",
                                     compute_dtype="float32",
                                     **PRESETS[args.preset])
    tcfg = TrainConfig(learning_rate=args.lr, z_loss=1e-4, grad_clip=1.0)
    data = SyntheticLM(cfg, seed=0)
    state = init_state(jax.random.PRNGKey(0), cfg)
    n_params = sum(x.size for x in jax.tree.leaves(state.params))
    print(f"preset={args.preset} params={n_params:,} "
          f"tokens/step={args.batch * args.seq}")

    ckpt = None
    if args.ckpt_dir:
        os.makedirs(args.ckpt_dir, exist_ok=True)
        ckpt = AsyncCheckpointer(args.ckpt_dir)
        if latest_step(args.ckpt_dir) is not None:
            shapes = jax.eval_shape(lambda: state)
            state = restore(args.ckpt_dir, shapes)
            print("resumed from step", latest_step(args.ckpt_dir))

    step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0,))
    log = []
    t0 = time.time()
    for step in range(args.steps):
        batch = data.batch(step, args.batch, args.seq)
        state, metrics = step_fn(state, batch)
        if step % 10 == 0 or step == args.steps - 1:
            row = {"step": step, "loss": float(metrics["loss"]),
                   "nll": float(metrics["nll"]),
                   "grad_norm": float(metrics["grad_norm"]),
                   "elapsed_s": round(time.time() - t0, 1)}
            log.append(row)
            print(f"step {step:4d} loss={row['loss']:.4f} "
                  f"gnorm={row['grad_norm']:.3f} ({row['elapsed_s']}s)")
        if ckpt and step and step % args.ckpt_every == 0:
            ckpt.save(state, step=step)
    if ckpt:
        ckpt.save(state, step=args.steps)
        ckpt.close()
    if args.log:
        json.dump(log, open(args.log, "w"), indent=1)
    first, last = log[0]["nll"], log[-1]["nll"]
    print(f"\nnll {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")
    return 0 if last < first else 1


if __name__ == "__main__":
    sys.exit(main())
