"""Sharded, resumable campaign walkthrough (API form of the CLI flow).

Runs the tiny grid as two shards spooling into JSONL files, kills-and-
resumes one shard to show crash durability, merges the spools, and checks
the merged reductions against a single-shot run — then prints the
throughput section the campaign artifact now carries.

    PYTHONPATH=src python examples/sharded_campaign.py [--grid tiny]
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile

from repro.workloads.campaign import (make_grid, merge_spools, run_campaign,
                                      shard_cells, spool_load)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--grid", default="tiny",
                    choices=["tiny", "small", "mix_tiny"])
    ap.add_argument("--workers", type=int, default=2)
    args = ap.parse_args()

    cells = make_grid(args.grid)
    print(f"grid={args.grid}: {len(cells)} cells, "
          f"{len(shard_cells(cells, '0/2'))}+{len(shard_cells(cells, '1/2'))}"
          f" across 2 shards")

    with tempfile.TemporaryDirectory() as td:
        spools = [os.path.join(td, f"shard{i}.jsonl") for i in range(2)]

        # shard 0 runs to completion
        run_campaign(cells, workers=args.workers, grid_name=args.grid,
                     spool_path=spools[0], shard="0/2")

        # shard 1 is "interrupted" after half its cells...
        half = shard_cells(cells, "1/2")
        run_campaign(half[: len(half) // 2], workers=args.workers,
                     grid_name=args.grid, spool_path=spools[1])
        print(f"shard 1 interrupted with "
              f"{len(spool_load(spools[1]))}/{len(half)} cells spooled")

        # ...and resumed: only the missing cells re-execute
        art1 = run_campaign(cells, workers=args.workers,
                            grid_name=args.grid, spool_path=spools[1],
                            resume=True, shard="1/2")
        tp = art1["throughput"]
        print(f"resume executed={tp['executed']} skipped={tp['skipped']}")

        merged, missing = merge_spools(spools, grid_cells=cells,
                                       grid_name=args.grid)
        assert not missing, missing

        single = run_campaign(cells, workers=args.workers,
                              grid_name=args.grid)
        assert merged["reductions"] == single["reductions"], \
            "merge must reproduce the single-shot reductions exactly"
        print("merged reductions == single-shot reductions")
        print("throughput:",
              json.dumps(single["throughput"], indent=1, default=float))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
