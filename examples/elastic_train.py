"""Elastic training under the Phoenix policies — the runtime showcase.

Runs on 8 host devices: an ElasticTrainer (the "ST job") trains while a
synthetic WS load trace drives the §III-C autoscaler; the provision service
reclaims devices from / returns devices to the trainer live. Demonstrates
checkpoint-resize-resume with no lost work (vs the paper's kill policy).

    PYTHONPATH=src python examples/elastic_train.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import sys
import tempfile

import jax
import numpy as np

from repro.configs import ARCHS, reduced_config
from repro.configs.base import TrainConfig
from repro.models import model as M
from repro.runtime.elastic import ElasticTrainer
from repro.runtime.orchestrator import PhoenixOrchestrator
from repro.runtime.serving_pool import ServingPool


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--intervals", type=int, default=6)
    args = ap.parse_args(argv)

    cfg = reduced_config(ARCHS[args.arch])
    ckpt_dir = tempfile.mkdtemp(prefix="phoenix_ckpt_")
    trainer = ElasticTrainer(cfg, TrainConfig(learning_rate=1e-3),
                             global_batch=8, seq_len=32,
                             ckpt_dir=ckpt_dir, model_size=1)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    pool = ServingPool(cfg, params, capacity_tokens_per_replica=200.0)
    orch = PhoenixOrchestrator(trainer, pool, min_st_devices=2)
    orch.start()

    # WS offered load (tokens/interval): trough -> spike -> trough
    loads = np.interp(np.arange(args.intervals),
                      [0, 2, 3, args.intervals - 1], [0, 0, 900, 0])
    for i, load in enumerate(loads):
        orch.ws_tick(float(load))
        m = orch.train_steps(2)
        print(f"interval {i}: ws_load={load:6.0f} "
              f"replicas={len(pool.replicas)} "
              f"train_devices={m['devices']} step={m['step']} "
              f"loss={m['loss']:.4f}")
        if pool.replicas:
            out = pool.submit(np.array([[5, 6, 7, 8]], dtype=np.int32), 4)
            print(f"            served 1 request -> tokens {out[0].tolist()}")
    print(f"resizes: {trainer.resizes}; ST events: "
          f"{[e for e in orch.events if e['kind'] == 'st_shrink']}")
    print("final step:", trainer.step, "(no work lost across resizes)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
