"""Train step: loss, grads (optionally microbatched), AdamW update.

The returned step function is pure and pjit-able; all distribution comes from
in/out shardings plus the ``constrain`` hook threaded into the model.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.models import model as M
from repro.training.optimizer import OptState, adamw_update, init_opt_state

MOE_LB_COEF = 0.01
MOE_Z_COEF = 0.001


class TrainState(NamedTuple):
    params: Any
    opt: OptState


def init_state(key, cfg: ModelConfig) -> TrainState:
    params = M.init_params(key, cfg)
    return TrainState(params, init_opt_state(params))


def _input_of(batch: Dict[str, jnp.ndarray], cfg: ModelConfig):
    return batch["embeds"] if cfg.input_mode == "embeddings" else batch["tokens"]


def cross_entropy(logits, labels, z_loss_coef: float):
    """logits [..., V] f32; labels [...] int. Mean NLL + z-loss."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = jnp.mean(lse - gold)
    zl = jnp.mean(jnp.square(lse))
    return nll + z_loss_coef * zl, nll


def make_loss_fn(cfg: ModelConfig, tcfg: TrainConfig, *, constrain=M._ident,
                 moe_groups: int = 1) -> Callable:
    def loss_fn(params, batch):
        logits, aux = M.forward(params, _input_of(batch, cfg), cfg,
                                constrain=constrain, remat=tcfg.remat,
                                moe_groups=moe_groups)
        loss, nll = cross_entropy(logits, batch["labels"], tcfg.z_loss)
        if cfg.moe is not None:
            loss = loss + MOE_LB_COEF * aux.get("moe_lb", 0.0) \
                + MOE_Z_COEF * aux.get("moe_z", 0.0)
        return loss, {"nll": nll}
    return loss_fn


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig, *, constrain=M._ident,
                    moe_groups: int = 1) -> Callable:
    loss_fn = make_loss_fn(cfg, tcfg, constrain=constrain,
                           moe_groups=moe_groups)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        k = tcfg.microbatch
        if k and k > 1:
            def resh(x):
                return x.reshape((k, x.shape[0] // k) + x.shape[1:])
            mb = jax.tree.map(resh, batch)

            def body(carry, mbatch):
                acc, loss_acc, nll_acc = carry
                (loss, aux), g = grad_fn(params, mbatch)
                acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), acc, g)
                return (acc, loss_acc + loss, nll_acc + aux["nll"]), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, loss, nll), _ = jax.lax.scan(
                body, (zeros, jnp.float32(0), jnp.float32(0)), mb)
            inv = 1.0 / k
            return loss * inv, {"nll": nll * inv}, \
                jax.tree.map(lambda g: g * inv, gsum)
        (loss, aux), g = grad_fn(params, batch)
        return loss, aux, g

    def train_step(state: TrainState, batch):
        loss, aux, grads = compute_grads(state.params, batch)
        new_params, new_opt, om = adamw_update(state.opt, grads, state.params,
                                               tcfg)
        metrics = {"loss": loss, "nll": aux["nll"], **om}
        return TrainState(new_params, new_opt), metrics

    return train_step
