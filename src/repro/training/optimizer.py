"""AdamW with f32 master weights, ZeRO-1-shardable state, optional int8
gradient quantize-dequantize (models a compressed DP all-reduce; see
DESIGN.md §7 for the SPMD caveat).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


class OptState(NamedTuple):
    step: jnp.ndarray          # i32 scalar
    m: Any                     # f32 tree
    v: Any                     # f32 tree
    master: Any                # f32 tree (master copy of params)


def init_opt_state(params) -> OptState:
    f32 = lambda t: jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), t)
    # explicit copy: if params are already f32, astype would alias the same
    # buffer and break donation (same buffer donated twice in train_step)
    master = jax.tree.map(
        lambda x: jnp.array(x, dtype=jnp.float32, copy=True), params)
    return OptState(jnp.zeros((), jnp.int32), f32(params), f32(params), master)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def quantize_int8(g):
    """Per-tensor symmetric int8 quantize-dequantize."""
    def one(x):
        xf = x.astype(jnp.float32)
        scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
        return q.astype(jnp.float32) * scale
    return jax.tree.map(one, g)


def adamw_update(opt: OptState, grads, params, tcfg: TrainConfig):
    """Returns (new_params, new_opt, metrics)."""
    if tcfg.grad_compression == "int8":
        grads = quantize_int8(grads)
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, tcfg.grad_clip / jnp.maximum(gnorm, 1e-12)) \
        if tcfg.grad_clip > 0 else 1.0
    step = opt.step + 1
    b1, b2 = tcfg.beta1, tcfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, w):
        g = g.astype(jnp.float32) * clip
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m2 / bc1
        vhat = v2 / bc2
        delta = mhat / (jnp.sqrt(vhat) + tcfg.eps) + tcfg.weight_decay * w
        w2 = w - tcfg.learning_rate * delta
        return m2, v2, w2

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(opt.m)
    flat_v = treedef.flatten_up_to(opt.v)
    flat_w = treedef.flatten_up_to(opt.master)
    out = [upd(g, m, v, w) for g, m, v, w in zip(flat_g, flat_m, flat_v, flat_w)]
    new_m = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_master = jax.tree.unflatten(treedef, [o[2] for o in out])
    new_params = jax.tree.map(lambda w, p: w.astype(p.dtype),
                              new_master, params)
    return new_params, OptState(step, new_m, new_v, new_master), \
        {"grad_norm": gnorm}
