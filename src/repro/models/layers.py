"""Basic building blocks: dense, norms, RoPE, embeddings, gated MLP.

Pure-functional: parameters are nested dicts of jnp arrays; every block has an
``init_*`` and an apply function. No framework dependency — this keeps full
control over scan-stacking and sharding annotations.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def _dtype(name: str):
    return jnp.dtype(name)


# ---------------------------------------------------------------- dense


def init_dense(key, in_dim: int, out_dim: int, *, bias: bool = False,
               dtype=jnp.bfloat16, scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(in_dim)
    p = {"kernel": (jax.random.normal(key, (in_dim, out_dim), jnp.float32)
                    * scale).astype(dtype)}
    if bias:
        p["bias"] = jnp.zeros((out_dim,), dtype)
    return p


def dense(p, x):
    y = x @ p["kernel"].astype(x.dtype)
    if "bias" in p:
        y = y + p["bias"].astype(x.dtype)
    return y


# ---------------------------------------------------------------- norms


def init_norm(kind: str, dim: int, dtype=jnp.float32):
    if kind == "rmsnorm":
        return {"scale": jnp.zeros((dim,), dtype)}  # gemma-style (1 + scale)
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def apply_norm(p, x, *, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if "bias" in p:  # layernorm
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"] + p["bias"]
    else:  # rmsnorm, (1 + scale) parameterization
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * (1.0 + p["scale"])
    return y.astype(x.dtype)


# ---------------------------------------------------------------- rope


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [..., seq, heads, head_dim]; positions: [..., seq] (int)."""
    half = x.shape[-1] // 2
    freqs = rope_frequencies(x.shape[-1], theta)                  # [half]
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, half]
    cos = jnp.cos(angles)[..., :, None, :]                        # [..., S, 1, half]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- activations


def activation(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return lambda x: jax.nn.gelu(x, approximate=True)
    raise ValueError(name)


# ---------------------------------------------------------------- gated MLP


def init_mlp(key, cfg: ModelConfig, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    d, ff = cfg.d_model, cfg.d_ff
    return {
        "wi_gate": init_dense(k1, d, ff, dtype=dtype),
        "wi_up": init_dense(k2, d, ff, dtype=dtype),
        "wo": init_dense(k3, ff, d, dtype=dtype),
    }


def mlp(p, x, act_name: str):
    act = activation(act_name)
    h = act(dense(p["wi_gate"], x)) * dense(p["wi_up"], x)
    return dense(p["wo"], h)


# ---------------------------------------------------------------- embeddings


def init_embedding(key, vocab: int, dim: int, dtype):
    return {"table": (jax.random.normal(key, (vocab, dim), jnp.float32)
                      * 0.02).astype(dtype)}


def embed(p, tokens, compute_dtype):
    return jnp.take(p["table"], tokens, axis=0).astype(compute_dtype)


def unembed(p, x):
    """Logits against the embedding table (tied head)."""
    return x @ p["table"].astype(x.dtype).T


def softcap(x, cap: float):
    if not cap:
        return x
    return jnp.tanh(x / cap) * cap
