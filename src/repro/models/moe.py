"""Sort-based top-k Mixture-of-Experts (dropping, capacity-bounded).

Dispatch is *sort-based*, not one-hot-einsum based: GShard-style dispatch
einsums cost O(tokens x experts x capacity x d_model) HLO FLOPs — at
qwen3-moe's 128 experts that is ~20x the useful expert FLOPs, which would
poison the roofline's MODEL_FLOPS/HLO_FLOPS ratio. Here dispatch/combine are
pure data movement (argsort + scatter/gather), so HLO FLOPs stay ~= active
expert FLOPs.

Sharding: tokens are grouped into `num_groups` groups laid out on the data
axis (dispatch is group-local => no cross-shard communication); expert weights
are sharded over the `model` axis on the ffn dimension ("expert-TP"), so the
expert matmuls behave exactly like a dense TP FFN (reduce over `model`).
An expert-parallel all-to-all variant is explored in the perf hillclimb.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import activation, dense, init_dense


def init_moe(key, cfg: ModelConfig, dtype):
    m = cfg.moe
    kr, kg, ku, ko = jax.random.split(key, 4)
    d, e, f = cfg.d_model, m.num_experts, m.d_ff_expert
    scale = 1.0 / math.sqrt(d)
    return {
        "router": init_dense(kr, d, e, dtype=jnp.float32),
        "wi_gate": (jax.random.normal(kg, (e, d, f), jnp.float32) * scale).astype(dtype),
        "wi_up": (jax.random.normal(ku, (e, d, f), jnp.float32) * scale).astype(dtype),
        "wo": (jax.random.normal(ko, (e, f, d), jnp.float32)
               / math.sqrt(f)).astype(dtype),
    }


def _capacity(tokens_per_group: int, m) -> int:
    cap = int(math.ceil(tokens_per_group * m.top_k * m.capacity_factor
                        / m.num_experts))
    return max(8, ((cap + 7) // 8) * 8)  # MXU-friendly multiple of 8


def _dispatch_group(xg, probs, eidx, num_experts: int, cap: int):
    """Group-local sort-based dispatch.

    xg: [n, d]; probs/eidx: [n, k]. Returns (buf [E, cap, d],
    scatter coords for combine: token [n*k], expert [n*k], pos [n*k],
    keep [n*k], flat probs [n*k]).
    """
    n, k = eidx.shape
    flat_e = eidx.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(num_experts))
    pos_sorted = jnp.arange(n * k, dtype=jnp.int32) - starts[sorted_e]
    keep = pos_sorted < cap
    token_sorted = (order // k).astype(jnp.int32)
    pos_safe = jnp.where(keep, pos_sorted, cap)  # cap == OOB -> dropped
    src = jnp.take(xg, token_sorted, axis=0)
    buf = jnp.zeros((num_experts, cap, xg.shape[-1]), xg.dtype)
    buf = buf.at[sorted_e, pos_safe].set(src, mode="drop")
    probs_sorted = probs.reshape(-1)[order]
    return buf, (token_sorted, sorted_e, pos_safe, keep, probs_sorted)


def _combine_group(yb, coords, n: int):
    token_sorted, sorted_e, pos_safe, keep, probs_sorted = coords
    gathered = yb.at[sorted_e, pos_safe].get(mode="fill", fill_value=0.0)
    gathered = gathered * (keep[:, None] * probs_sorted[:, None]).astype(yb.dtype)
    out = jnp.zeros((n, yb.shape[-1]), yb.dtype)
    return out.at[token_sorted].add(gathered)


def moe_forward(p, x, cfg: ModelConfig, *, num_groups: int = 0,
                constrain=lambda x, kind: x):
    """x: [B, S, D] -> (y [B, S, D], aux losses dict)."""
    m = cfg.moe
    B, S, D = x.shape
    N = B * S
    G = num_groups or m.num_groups or 1
    G = max(1, min(G, N))
    while N % G:
        G -= 1
    n = N // G
    cap = _capacity(n, m)

    xf = constrain(x.reshape(G, n, D), "moe_local")
    router_logits = (xf.astype(jnp.float32)
                     @ p["router"]["kernel"])                    # [G, n, E]
    router_probs = jax.nn.softmax(router_logits, axis=-1)
    top_p, top_i = jax.lax.top_k(router_probs, m.top_k)          # [G, n, k]
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)       # renormalize
    top_p = constrain(top_p, "moe_local")
    top_i = constrain(top_i, "moe_local")

    buf, coords = jax.vmap(
        lambda xg, pg, ig: _dispatch_group(xg, pg, ig, m.num_experts, cap)
    )(xf, top_p, top_i)                                           # buf [G,E,cap,D]
    ep = m.expert_parallel
    buf = constrain(buf, "moe_ep_buf" if ep else "moe_local")
    coords = tuple(constrain(c, "moe_local") for c in coords)

    act = activation(cfg.act)
    wg, wu, wo = (p["wi_gate"].astype(x.dtype), p["wi_up"].astype(x.dtype),
                  p["wo"].astype(x.dtype))
    h = act(jnp.einsum("gecd,edf->gecf", buf, wg)) \
        * jnp.einsum("gecd,edf->gecf", buf, wu)
    h = constrain(h, "moe_ep_ff" if ep else "moe_ff")
    yb = constrain(jnp.einsum("gecf,efd->gecd", h, wo), "moe_local")

    y = jax.vmap(lambda b, c: _combine_group(b, c, n))(yb, coords)
    y = constrain(y, "moe_local")
    y = y.reshape(B, S, D)

    # aux: load-balance loss (Switch) + router z-loss
    me = jnp.mean(router_probs, axis=(0, 1))                      # [E]
    ce = jnp.mean(
        (jax.nn.one_hot(top_i, m.num_experts).sum(axis=2)), axis=(0, 1))
    lb = m.num_experts * jnp.sum(me * ce) / m.top_k
    zl = jnp.mean(jnp.square(jax.nn.logsumexp(router_logits, axis=-1)))
    return y, {"moe_lb": lb, "moe_z": zl}
