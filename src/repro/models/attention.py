"""Causal self-attention: global + sliding-window, GQA, KV caches.

Training / prefill use a *chunked* streaming-softmax implementation (flash
attention expressed in pure JAX): an outer python loop over query chunks and an
inner ``lax.scan`` over the key/value chunks visible to that query chunk. This
keeps peak activation memory at O(S·c) instead of O(S²) — a 32k-token prefill
would otherwise materialize a 128 GB logit tensor per device — while keeping
HLO FLOPs *exactly* causal (we never visit kv chunks above the diagonal).

On TPU the Pallas kernels in ``repro.kernels`` implement the same math; this
module is the XLA path used by the CPU dry-run and as the oracle-level
reference for integration tests.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import apply_norm, apply_rope, dense, init_dense, init_norm

NEG_INF = -1e30


def init_attention(key, cfg: ModelConfig, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d = cfg.d_model
    p = {
        "wq": init_dense(k1, d, cfg.q_dim, bias=cfg.qkv_bias, dtype=dtype),
        "wk": init_dense(k2, d, cfg.kv_dim, bias=cfg.qkv_bias, dtype=dtype),
        "wv": init_dense(k3, d, cfg.kv_dim, bias=cfg.qkv_bias, dtype=dtype),
        "wo": init_dense(k4, cfg.q_dim, d, dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_norm("rmsnorm", cfg.head_dim)
        p["k_norm"] = init_norm("rmsnorm", cfg.head_dim)
    return p


def _project_qkv(p, x, cfg: ModelConfig, positions, theta: float):
    """x: [B, S, D] -> q [B,S,H,hd], k/v [B,S,K,hd] (rope applied)."""
    B, S, _ = x.shape
    q = dense(p["wq"], x).reshape(B, S, cfg.num_heads, cfg.head_dim)
    k = dense(p["wk"], x).reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    v = dense(p["wv"], x).reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = apply_norm(p["q_norm"], q)
        k = apply_norm(p["k_norm"], k)
    q = apply_rope(q, positions, theta)
    k = apply_rope(k, positions, theta)
    return q, k, v


def _chunk_attend(q, k, v, q_pos, k_pos, scale):
    """One (q-chunk, kv-chunk) streaming-softmax step.

    q: [B, qc, K, G, hd]   (kv head-grouped query)
    k/v: [B, kc, K, hd]
    returns unnormalized (acc, m, l) update terms.
    """
    logits = jnp.einsum("bqkgh,bskh->bkgqs", q, k).astype(jnp.float32) * scale
    mask = (k_pos[None, :] <= q_pos[:, None])  # [qc, kc] causal
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    m_new = jnp.max(logits, axis=-1)                     # [B,K,G,qc]
    p = jnp.exp(logits - m_new[..., None])
    l_new = jnp.sum(p, axis=-1)                          # [B,K,G,qc]
    acc_new = jnp.einsum("bkgqs,bskh->bkgqh", p.astype(v.dtype), v)
    return acc_new, m_new, l_new


def _merge(acc, m, l, acc2, m2, l2):
    m12 = jnp.maximum(m, m2)
    a1 = jnp.exp(m - m12)
    a2 = jnp.exp(m2 - m12)
    acc12 = acc * a1[..., None].astype(acc.dtype) + acc2 * a2[..., None].astype(acc.dtype)
    l12 = l * a1 + l2 * a2
    return acc12, m12, l12


def chunked_causal_attention(q, k, v, positions, *, window: int = 0,
                             q_chunk: int = 0) -> jnp.ndarray:
    """Flash-style causal attention in pure JAX.

    q: [B,S,H,hd], k/v: [B,S,K,hd] (GQA: H = K*G), positions: [S].
    window > 0: sliding-window (each query sees the last `window` keys).
    Returns [B,S,H,hd].
    """
    B, S, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    scale = 1.0 / math.sqrt(hd)
    qc = q_chunk or (2048 if S >= 8192 else min(S, 1024))
    qc = min(qc, S)
    assert S % qc == 0, (S, qc)
    nq = S // qc
    qg = q.reshape(B, S, K, G, hd)

    outs = []
    if window:
        # pad keys in front with `wpad` so every q chunk slices [wpad + qc].
        wpad = ((window + qc - 1) // qc) * qc
        kp = jnp.pad(k, ((0, 0), (wpad, 0), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (wpad, 0), (0, 0), (0, 0)))
        kpos = jnp.pad(positions, (wpad, 0), constant_values=-10**9)
        for i in range(nq):
            qi = jax.lax.dynamic_slice_in_dim(qg, i * qc, qc, axis=1)
            qp = jax.lax.dynamic_slice_in_dim(positions, i * qc, qc, axis=0)
            ki = jax.lax.dynamic_slice_in_dim(kp, i * qc, wpad + qc, axis=1)
            vi = jax.lax.dynamic_slice_in_dim(vp, i * qc, wpad + qc, axis=1)
            kposi = jax.lax.dynamic_slice_in_dim(kpos, i * qc, wpad + qc, axis=0)
            logits = jnp.einsum("bqkgh,bskh->bkgqs", qi, ki).astype(jnp.float32) * scale
            mask = (kposi[None, :] <= qp[:, None]) & \
                   (kposi[None, :] > qp[:, None] - window)
            logits = jnp.where(mask[None, None, None], logits, NEG_INF)
            w = jax.nn.softmax(logits, axis=-1)
            oi = jnp.einsum("bkgqs,bskh->bkgqh", w.astype(vi.dtype), vi)
            outs.append(oi)
    else:
        kc = qc
        for i in range(nq):
            qi = jax.lax.dynamic_slice_in_dim(qg, i * qc, qc, axis=1)
            qp = jax.lax.dynamic_slice_in_dim(positions, i * qc, qc, axis=0)

            def kv_step(carry, idx):
                acc, m, l = carry
                kj = jax.lax.dynamic_slice_in_dim(k, idx * kc, kc, axis=1)
                vj = jax.lax.dynamic_slice_in_dim(v, idx * kc, kc, axis=1)
                kposj = jax.lax.dynamic_slice_in_dim(positions, idx * kc, kc, axis=0)
                acc2, m2, l2 = _chunk_attend(qi, kj, vj, qp, kposj, scale)
                return _merge(acc, m, l, acc2, m2, l2), None

            acc0 = jnp.zeros((B, K, G, qc, hd), v.dtype)
            m0 = jnp.full((B, K, G, qc), NEG_INF, jnp.float32)
            l0 = jnp.zeros((B, K, G, qc), jnp.float32)
            (acc, m, l), _ = jax.lax.scan(
                kv_step, (acc0, m0, l0), jnp.arange(i + 1))
            oi = acc / jnp.maximum(l, 1e-30)[..., None].astype(acc.dtype)
            outs.append(oi)

    out = jnp.concatenate(outs, axis=3)  # [B,K,G,S,hd] concat on q dim
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, S, H, hd)
    return out


def attention_forward(p, x, cfg: ModelConfig, positions, *, window: int = 0,
                      theta: float = 10_000.0) -> jnp.ndarray:
    """Full-sequence attention block ([B,S,D] -> [B,S,D])."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg, positions[None, :].repeat(B, 0)
                           if positions.ndim == 1 else positions, theta)
    out = chunked_causal_attention(q, k, v, positions if positions.ndim == 1
                                   else positions[0], window=window)
    return dense(p["wo"], out.reshape(B, S, cfg.q_dim))


# ------------------------------------------------------------------ caches


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int, *, window: int = 0,
                  dtype=jnp.bfloat16, abstract: bool = False):
    """KV cache for one attention layer.

    Layout: k/v [B, L, K, hd]; pos [L] slot→global-position (-1 empty).
    Sliding-window layers use a ring buffer of size `window`.
    """
    L = min(window, max_len) if window else max_len
    shape_kv = (batch, L, cfg.num_kv_heads, cfg.head_dim)
    if abstract:
        return {
            "k": jax.ShapeDtypeStruct(shape_kv, dtype),
            "v": jax.ShapeDtypeStruct(shape_kv, dtype),
            "pos": jax.ShapeDtypeStruct((L,), jnp.int32),
        }
    return {
        "k": jnp.zeros(shape_kv, dtype),
        "v": jnp.zeros(shape_kv, dtype),
        "pos": jnp.full((L,), -1, jnp.int32),
    }


def attention_prefill(p, x, cfg: ModelConfig, positions, *, window: int = 0,
                      theta: float = 10_000.0, max_len: int = 0):
    """Prefill: full-seq attention AND the populated cache.

    The cache is allocated at ``max_len`` (>= S) slots so subsequent decode
    steps can append; sliding-window layers use a ring buffer whose slot for
    position p is ``p % L`` — consistent with ``attention_decode``.
    """
    B, S, _ = x.shape
    pos1d = positions if positions.ndim == 1 else positions[0]
    q, k, v = _project_qkv(p, x, cfg, pos1d[None, :].repeat(B, 0), theta)
    out = chunked_causal_attention(q, k, v, pos1d, window=window)
    y = dense(p["wo"], out.reshape(B, S, cfg.q_dim))
    max_len = max(max_len or S, S)
    L = min(window, max_len) if window else max_len
    keep = min(L, S)
    kv_pos = pos1d[S - keep:].astype(jnp.int32)
    if keep == L:
        # slots (pos % L) are a cyclic rotation of 0..L-1 — use roll, not
        # scatter: GSPMD partitions rolls cleanly but replicates scattered
        # caches ("involuntary full rematerialization"), a 20x collective
        # regression on 32k prefills (EXPERIMENTS.md §Perf i1).
        shift = int((S - L) % L) if L else 0
        ck = jnp.roll(k[:, S - keep:], shift, axis=1)
        cv = jnp.roll(v[:, S - keep:], shift, axis=1)
        cpos = jnp.roll(kv_pos, shift, axis=0)
        return y, {"k": ck, "v": cv, "pos": cpos}
    slots = kv_pos % L
    ck = jnp.zeros((B, L) + k.shape[2:], k.dtype).at[:, slots].set(k[:, S - keep:])
    cv = jnp.zeros((B, L) + v.shape[2:], v.dtype).at[:, slots].set(v[:, S - keep:])
    cpos = jnp.full((L,), -1, jnp.int32).at[slots].set(kv_pos)
    return y, {"k": ck, "v": cv, "pos": cpos}


def attention_decode(p, x, cache, cfg: ModelConfig, cur_pos, *, window: int = 0,
                     theta: float = 10_000.0):
    """One-token decode. x: [B, 1, D]; cur_pos: scalar int (current position).

    Returns ([B,1,D], new_cache). Ring-buffer update for window layers.
    """
    B = x.shape[0]
    pos_b = jnp.full((B, 1), cur_pos, jnp.int32)
    q, k, v = _project_qkv(p, x, cfg, pos_b, theta)   # q [B,1,H,hd]
    L = cache["k"].shape[1]
    # ring slot; for global caches cur_pos < L always, so this is identity.
    slot = (jnp.asarray(cur_pos) % L).astype(jnp.int32)
    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
    cpos = jax.lax.dynamic_update_slice_in_dim(
        cache["pos"], jnp.full((1,), cur_pos, jnp.int32), slot, axis=0)

    K, G = cfg.num_kv_heads, cfg.num_heads // cfg.num_kv_heads
    hd = cfg.head_dim
    qg = q.reshape(B, 1, K, G, hd)
    scale = 1.0 / math.sqrt(hd)
    logits = jnp.einsum("bqkgh,bskh->bkgqs", qg, ck).astype(jnp.float32) * scale
    valid = cpos >= 0
    if window:
        valid = valid & (cpos > cur_pos - window)
    logits = jnp.where(valid[None, None, None, None, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bkgqh", w.astype(cv.dtype), cv)
    o = o.transpose(0, 3, 1, 2, 4).reshape(B, 1, cfg.q_dim)
    y = dense(p["wo"], o)
    return y, {"k": ck, "v": cv, "pos": cpos}
