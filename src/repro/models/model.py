"""Unified decoder-only CausalLM covering all 10 assigned architectures.

Layer stacking uses ``jax.lax.scan`` over *pattern repeats*: the per-layer
block kinds are ``cfg.block_pattern`` tiled over depth, parameters for each
pattern position are stacked along a leading ``repeat`` axis, and one scan
body applies a whole pattern instance. This keeps HLO size O(pattern) instead
of O(depth) — a hard requirement for 512-way SPMD compiles of 88-layer models
on this host. A non-divisible depth remainder (e.g. recurrentgemma's 26 = 3x8
+ 2) is applied as unstacked "tail" layers after the scan.

Modes:
  train   — full-seq forward, logits (+ MoE aux losses)
  prefill — full-seq forward + populated caches
  decode  — single-token step against caches
"""
from __future__ import annotations

import functools
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import rglru as rg
from repro.models import xlstm as xl
from repro.models.layers import (activation, apply_norm, dense, embed,
                                 init_dense, init_embedding, init_norm, mlp,
                                 init_mlp, softcap, unembed)

Constrain = Callable[[jnp.ndarray, str], jnp.ndarray]
_ident: Constrain = lambda x, kind: x


def _pattern_split(cfg: ModelConfig) -> Tuple[int, Tuple[str, ...]]:
    p = cfg.block_pattern
    reps = cfg.num_layers // len(p)
    tail = cfg.layer_kinds()[reps * len(p):]
    return reps, tail


# ------------------------------------------------------------------ blocks


def init_block(kind: str, key, cfg: ModelConfig, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    p: Dict[str, Any] = {"pre_norm": init_norm(cfg.norm, cfg.d_model)}
    if kind in ("attn", "local"):
        p["mixer"] = attn.init_attention(k1, cfg, dtype)
        p["mlp_norm"] = init_norm(cfg.norm, cfg.d_model)
        if cfg.moe is not None:
            p["moe"] = init_moe_lazy(k2, cfg, dtype)
        else:
            p["mlp"] = init_mlp(k2, cfg, dtype)
    elif kind == "rglru":
        p["mixer"] = rg.init_rglru_block(k1, cfg, dtype)
        p["mlp_norm"] = init_norm(cfg.norm, cfg.d_model)
        p["mlp"] = init_mlp(k2, cfg, dtype)
    elif kind == "mlstm":
        p["mixer"] = xl.init_mlstm_block(k1, cfg, dtype)
    elif kind == "slstm":
        p["mixer"] = xl.init_slstm_block(k1, cfg, dtype)
    else:
        raise ValueError(kind)
    return p


def init_moe_lazy(key, cfg, dtype):
    from repro.models.moe import init_moe
    return init_moe(key, cfg, dtype)


def _theta(cfg: ModelConfig, kind: str) -> float:
    if kind == "local" and cfg.rope_theta_local:
        return cfg.rope_theta_local
    return cfg.rope_theta


def apply_block(kind: str, p, x, cfg: ModelConfig, *, mode: str,
                positions=None, cache=None, cur_pos=None,
                constrain: Constrain = _ident, moe_groups: int = 1,
                max_len: int = 0):
    """Returns (x, aux, new_cache)."""
    act = activation(cfg.act)
    aux: Dict[str, jnp.ndarray] = {}
    new_cache = None
    h = apply_norm(p["pre_norm"], x)
    window = cfg.window_size if kind == "local" else 0

    if kind in ("attn", "local"):
        theta = _theta(cfg, kind)
        if mode == "train":
            y = attn.attention_forward(p["mixer"], h, cfg, positions,
                                       window=window, theta=theta)
        elif mode == "prefill":
            y, new_cache = attn.attention_prefill(p["mixer"], h, cfg, positions,
                                                  window=window, theta=theta,
                                                  max_len=max_len)
        else:
            y, new_cache = attn.attention_decode(p["mixer"], h, cache, cfg,
                                                 cur_pos, window=window,
                                                 theta=theta)
        x = x + y
        x = constrain(x, "residual")
        h2 = apply_norm(p["mlp_norm"], x)
        if cfg.moe is not None:
            from repro.models.moe import moe_forward
            y2, aux = moe_forward(p["moe"], h2, cfg, num_groups=moe_groups,
                                  constrain=constrain)
        else:
            y2 = mlp(p["mlp"], h2, cfg.act)
        x = x + y2
    elif kind == "rglru":
        if mode == "train":
            y = rg.rglru_block_forward(p["mixer"], h, cfg, act)
        elif mode == "prefill":
            y, new_cache = rg.rglru_block_prefill(p["mixer"], h, cfg, act)
        else:
            y, new_cache = rg.rglru_block_decode(p["mixer"], h, cache, cfg, act)
        x = x + y
        x = constrain(x, "residual")
        h2 = apply_norm(p["mlp_norm"], x)
        x = x + mlp(p["mlp"], h2, cfg.act)
    elif kind == "mlstm":
        if mode == "train":
            y = xl.mlstm_block_forward(p["mixer"], h, cfg)
        elif mode == "prefill":
            y, new_cache = xl.mlstm_block_prefill(p["mixer"], h, cfg)
        else:
            y, new_cache = xl.mlstm_block_decode(p["mixer"], h, cache, cfg)
        x = x + y
    elif kind == "slstm":
        if mode == "train":
            y = xl.slstm_block_forward(p["mixer"], h, cfg, act)
        elif mode == "prefill":
            y, st = xl.slstm_block_forward(p["mixer"], h, cfg, act,
                                           return_state=True)
            new_cache = st
        else:
            y, new_cache = xl.slstm_block_decode(p["mixer"], h, cache, cfg, act)
        x = x + y
    else:
        raise ValueError(kind)
    x = constrain(x, "residual")
    return x, aux, new_cache


def init_block_cache(kind: str, cfg: ModelConfig, batch: int, max_len: int,
                     dtype=jnp.bfloat16, abstract: bool = False):
    if kind == "attn":
        return attn.init_kv_cache(cfg, batch, max_len, window=0, dtype=dtype,
                                  abstract=abstract)
    if kind == "local":
        return attn.init_kv_cache(cfg, batch, max_len, window=cfg.window_size,
                                  dtype=dtype, abstract=abstract)
    if kind == "rglru":
        return rg.init_rglru_cache(cfg, batch, dtype=dtype, abstract=abstract)
    if kind == "mlstm":
        return xl.init_mlstm_cache(cfg, batch, dtype=dtype, abstract=abstract)
    if kind == "slstm":
        return xl.init_slstm_cache(cfg, batch, abstract=abstract)
    raise ValueError(kind)


# ------------------------------------------------------------------ model


def init_params(key, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.param_dtype)
    reps, tail = _pattern_split(cfg)
    keys = jax.random.split(key, 4)
    params: Dict[str, Any] = {
        "embed": init_embedding(keys[0], cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": init_norm(cfg.norm, cfg.d_model),
    }
    if cfg.num_codebooks > 0:
        params["head"] = init_dense(keys[1], cfg.d_model,
                                    cfg.num_codebooks * cfg.vocab_size,
                                    dtype=dtype)
    elif not cfg.tie_embeddings:
        params["head"] = init_dense(keys[1], cfg.d_model, cfg.vocab_size,
                                    dtype=dtype)

    bkeys = jax.random.split(keys[2], max(reps, 1) * len(cfg.block_pattern))
    repeats: Dict[str, Any] = {}
    for j, kind in enumerate(cfg.block_pattern):
        per_rep = [init_block(kind, bkeys[r * len(cfg.block_pattern) + j],
                              cfg, dtype) for r in range(reps)]
        repeats[f"b{j}"] = jax.tree.map(lambda *xs: jnp.stack(xs), *per_rep) \
            if reps > 1 else jax.tree.map(lambda v: v[None], per_rep[0])
    params["repeats"] = repeats
    tkeys = jax.random.split(keys[3], max(len(tail), 1))
    params["tail"] = {f"t{j}": init_block(kind, tkeys[j], cfg, dtype)
                      for j, kind in enumerate(tail)}
    return params


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16, abstract: bool = False):
    reps, tail = _pattern_split(cfg)
    cache: Dict[str, Any] = {"repeats": {}, "tail": {}}
    for j, kind in enumerate(cfg.block_pattern):
        one = init_block_cache(kind, cfg, batch, max_len, dtype, abstract)
        if abstract:
            cache["repeats"][f"b{j}"] = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((reps,) + s.shape, s.dtype), one)
        else:
            cache["repeats"][f"b{j}"] = jax.tree.map(
                lambda v: jnp.broadcast_to(v[None], (reps,) + v.shape).copy(), one)
    for j, kind in enumerate(tail):
        cache["tail"][f"t{j}"] = init_block_cache(kind, cfg, batch, max_len,
                                                  dtype, abstract)
    return cache


def _embed_in(params, batch_in, cfg: ModelConfig, compute_dtype):
    if cfg.input_mode == "embeddings":
        x = batch_in.astype(compute_dtype)
    else:
        x = embed(params["embed"], batch_in, compute_dtype)
    if cfg.tie_embeddings:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), compute_dtype)
    return x


def _head_out(params, x, cfg: ModelConfig):
    B, S, _ = x.shape
    if cfg.num_codebooks > 0:
        logits = dense(params["head"], x).reshape(
            B, S, cfg.num_codebooks, cfg.vocab_size)
    elif cfg.tie_embeddings:
        logits = unembed(params["embed"], x)
    else:
        logits = dense(params["head"], x)
    logits = softcap(logits.astype(jnp.float32), cfg.logit_softcap)
    return logits


def _sum_aux(a: Dict, b: Dict) -> Dict:
    out = dict(a)
    for k, v in b.items():
        out[k] = out.get(k, 0.0) + v
    return out


def forward(params, batch_in, cfg: ModelConfig, *, constrain: Constrain = _ident,
            remat: str = "none", moe_groups: int = 1):
    """Train-mode forward: logits [B,S,V] (or [B,S,C,V]), aux losses."""
    compute_dtype = jnp.dtype(cfg.compute_dtype)
    x = _embed_in(params, batch_in, cfg, compute_dtype)
    B, S = x.shape[:2]
    positions = jnp.arange(S, dtype=jnp.int32)
    reps, tail = _pattern_split(cfg)
    pattern = cfg.block_pattern

    def rep_body(xc, rep_params):
        aux = {}
        for j, kind in enumerate(pattern):
            xc, a, _ = apply_block(kind, rep_params[f"b{j}"], xc, cfg,
                                   mode="train", positions=positions,
                                   constrain=constrain, moe_groups=moe_groups)
            aux = _sum_aux(aux, a)
        # fixed key-set for scan ys
        return xc, {k: aux.get(k, jnp.float32(0.0))
                    for k in ("moe_lb", "moe_z")}

    body = rep_body
    if remat != "none":
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if remat == "dots" else None)
        body = jax.checkpoint(rep_body, policy=policy, prevent_cse=False)

    x, auxs = jax.lax.scan(body, x, params["repeats"])
    aux = {k: jnp.sum(v) for k, v in auxs.items()}
    for j, kind in enumerate(tail):
        x, a, _ = apply_block(kind, params["tail"][f"t{j}"], x, cfg,
                              mode="train", positions=positions,
                              constrain=constrain, moe_groups=moe_groups)
        aux = _sum_aux(aux, a)
    x = apply_norm(params["final_norm"], x)
    return _head_out(params, x, cfg), aux


def prefill(params, batch_in, cfg: ModelConfig, *, constrain: Constrain = _ident,
            moe_groups: int = 1, max_len: int = 0):
    """Prefill: returns (logits of last position [B,V...], cache)."""
    compute_dtype = jnp.dtype(cfg.compute_dtype)
    x = _embed_in(params, batch_in, cfg, compute_dtype)
    B, S = x.shape[:2]
    positions = jnp.arange(S, dtype=jnp.int32)
    reps, tail = _pattern_split(cfg)
    pattern = cfg.block_pattern

    def rep_body(xc, rep_params):
        caches = {}
        for j, kind in enumerate(pattern):
            xc, _, c = apply_block(kind, rep_params[f"b{j}"], xc, cfg,
                                   mode="prefill", positions=positions,
                                   constrain=constrain, moe_groups=moe_groups,
                                   max_len=max_len)
            caches[f"b{j}"] = c
        return xc, caches

    x, rep_caches = jax.lax.scan(rep_body, x, params["repeats"])
    cache = {"repeats": rep_caches, "tail": {}}
    for j, kind in enumerate(tail):
        x, _, c = apply_block(kind, params["tail"][f"t{j}"], x, cfg,
                              mode="prefill", positions=positions,
                              constrain=constrain, moe_groups=moe_groups,
                              max_len=max_len)
        cache["tail"][f"t{j}"] = c
    x = apply_norm(params["final_norm"], x)
    logits = _head_out(params, x[:, -1:], cfg)
    return logits[:, 0], cache


def decode_step(params, cache, tokens, cur_pos, cfg: ModelConfig, *,
                constrain: Constrain = _ident, moe_groups: int = 1):
    """One decode step.

    tokens: [B, 1] token ids (or [B, 1, D] embeddings for embedding-input
    archs); cur_pos: scalar int32 (current position, uniform across batch).
    Returns (logits [B, V...], new_cache).
    """
    compute_dtype = jnp.dtype(cfg.compute_dtype)
    x = _embed_in(params, tokens, cfg, compute_dtype)
    reps, tail = _pattern_split(cfg)
    pattern = cfg.block_pattern

    def rep_body(xc, inp):
        rep_params, rep_cache = inp
        new_caches = {}
        for j, kind in enumerate(pattern):
            xc, _, c = apply_block(kind, rep_params[f"b{j}"], xc, cfg,
                                   mode="decode", cache=rep_cache[f"b{j}"],
                                   cur_pos=cur_pos, constrain=constrain,
                                   moe_groups=moe_groups)
            new_caches[f"b{j}"] = c
        return xc, new_caches

    x, rep_caches = jax.lax.scan(rep_body, x,
                                 (params["repeats"], cache["repeats"]))
    new_cache = {"repeats": rep_caches, "tail": {}}
    for j, kind in enumerate(tail):
        x, _, c = apply_block(kind, params["tail"][f"t{j}"], x, cfg,
                              mode="decode", cache=cache["tail"][f"t{j}"],
                              cur_pos=cur_pos, constrain=constrain,
                              moe_groups=moe_groups)
        new_cache["tail"][f"t{j}"] = c
    x = apply_norm(params["final_norm"], x)
    logits = _head_out(params, x, cfg)
    return logits[:, 0], new_cache
