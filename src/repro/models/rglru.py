"""RG-LRU recurrent block (Griffin / RecurrentGemma).

Recurrence (per channel):
    r_t = sigmoid(W_r u_t + b_r)              (recurrence gate)
    i_t = sigmoid(W_i u_t + b_i)              (input gate)
    log a_t = -c * softplus(Lambda) * r_t     (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)

First-order linear recurrence with input-dependent decay => parallelizable
via ``jax.lax.associative_scan`` for train/prefill; O(1)-state single step for
decode. The block wraps the RG-LRU in Griffin's gated branch structure with a
width-4 causal temporal conv.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense, init_dense

_C = 8.0


def init_rglru_block(key, cfg: ModelConfig, dtype):
    w = cfg.lru_width
    d = cfg.d_model
    ks = jax.random.split(key, 7)
    return {
        "w_gate_in": init_dense(ks[0], d, w, dtype=dtype),      # GeLU branch
        "w_rnn_in": init_dense(ks[1], d, w, dtype=dtype),       # recurrent branch
        "rg_conv_w": (jax.random.normal(ks[2], (cfg.conv_width, w), jnp.float32)
                   / math.sqrt(cfg.conv_width)).astype(dtype),
        "rg_conv_b": jnp.zeros((w,), dtype),
        "w_rg": init_dense(ks[3], w, w, bias=True, dtype=dtype),
        "w_ig": init_dense(ks[4], w, w, bias=True, dtype=dtype),
        # Lambda parameterized so a ~ U(0.9, 0.999) at init
        "lam": jnp.asarray(
            jnp.log(jnp.expm1(-jnp.log(
                jax.random.uniform(ks[5], (w,), jnp.float32, 0.9, 0.999)) / _C)),
            jnp.float32),
        "w_out": init_dense(ks[6], w, d, dtype=dtype),
    }


def _causal_conv(u, w, b):
    """u: [B, S, W]; width-K per-channel causal conv."""
    K = w.shape[0]
    out = u * w[K - 1].astype(u.dtype)
    for j in range(1, K):
        shifted = jnp.pad(u, ((0, 0), (j, 0), (0, 0)))[:, :-j]
        out = out + shifted * w[K - 1 - j].astype(u.dtype)
    return out + b.astype(u.dtype)


def _gates(p, u):
    r = jax.nn.sigmoid(dense(p["w_rg"], u).astype(jnp.float32))
    i = jax.nn.sigmoid(dense(p["w_ig"], u).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) computed in log space for stability
    multiplier = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, multiplier * i * u.astype(jnp.float32)


def rglru_scan(p, u):
    """u: [B, S, W] -> h: [B, S, W] via associative scan over S."""
    a, bterm = _gates(p, u)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    aa, hh = jax.lax.associative_scan(combine, (a, bterm), axis=1)
    return hh.astype(u.dtype)


def rglru_step(p, u_t, h_prev):
    """u_t: [B, W]; h_prev: [B, W] (f32) -> (h_t_cast, h_t_f32)."""
    a, bterm = _gates(p, u_t)
    h = a * h_prev + bterm
    return h.astype(u_t.dtype), h


def init_rglru_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16,
                     abstract: bool = False):
    w = cfg.lru_width
    shapes = {
        "h": ((batch, w), jnp.float32),
        "conv": ((batch, cfg.conv_width - 1, w), dtype),
    }
    if abstract:
        return {k: jax.ShapeDtypeStruct(s, d) for k, (s, d) in shapes.items()}
    return {k: jnp.zeros(s, d) for k, (s, d) in shapes.items()}


def rglru_block_forward(p, x, cfg: ModelConfig, act):
    """Full-sequence Griffin recurrent block: [B,S,D] -> [B,S,D]."""
    gate = act(dense(p["w_gate_in"], x))
    u = dense(p["w_rnn_in"], x)
    u = _causal_conv(u, p["rg_conv_w"], p["rg_conv_b"])
    h = rglru_scan(p, u)
    return dense(p["w_out"], gate * h)


def rglru_block_prefill(p, x, cfg: ModelConfig, act):
    gate = act(dense(p["w_gate_in"], x))
    u0 = dense(p["w_rnn_in"], x)
    u = _causal_conv(u0, p["rg_conv_w"], p["rg_conv_b"])
    a, bterm = _gates(p, u)

    def combine(xc, yc):
        a1, b1 = xc
        a2, b2 = yc
        return a1 * a2, a2 * b1 + b2

    _, hh = jax.lax.associative_scan(combine, (a, bterm), axis=1)
    y = dense(p["w_out"], gate * hh.astype(x.dtype))
    cw = cfg.conv_width
    cache = {"h": hh[:, -1], "conv": u0[:, -(cw - 1):]}
    return y, cache


def rglru_block_decode(p, x, cache, cfg: ModelConfig, act):
    """x: [B, 1, D] -> ([B, 1, D], cache)."""
    xt = x[:, 0]
    gate = act(dense(p["w_gate_in"], xt))
    u_t = dense(p["w_rnn_in"], xt)
    hist = jnp.concatenate([cache["conv"], u_t[:, None]], axis=1)  # [B, cw, W]
    w = p["rg_conv_w"]
    conv_out = jnp.einsum("bkw,kw->bw", hist.astype(jnp.float32),
                          w.astype(jnp.float32)).astype(xt.dtype) \
        + p["rg_conv_b"].astype(xt.dtype)
    h_cast, h_f32 = rglru_step(p, conv_out, cache["h"])
    y = dense(p["w_out"], gate * h_cast)
    return y[:, None], {"h": h_f32, "conv": hist[:, 1:]}
