"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM
(scalar memory, strictly sequential — xLSTM paper §2.4 notes it is not
parallelizable; on TPU we express it as a ``lax.scan`` over time).

Stabilized exponential gating follows the xLSTM paper (arXiv:2405.04517):
running max-state m keeps exp() arguments bounded; the stored state is the
rescaled (C·e^{-m}, n·e^{-m}) pair so decode and chunkwise train agree.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense, init_dense

NEG_INF = -1e30


def _mlstm_dims(cfg: ModelConfig):
    inner = int(cfg.d_model * cfg.mlstm_proj_factor)
    H = cfg.num_heads
    dv = inner // H
    dqk = dv // 2
    return inner, H, dqk, dv


# ====================================================================== mLSTM


def init_mlstm_block(key, cfg: ModelConfig, dtype):
    inner, H, dqk, dv = _mlstm_dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 9)
    return {
        "w_up": init_dense(ks[0], d, inner, dtype=dtype),
        "w_gate": init_dense(ks[1], d, inner, dtype=dtype),
        "conv_w": (jax.random.normal(ks[2], (cfg.conv_width, inner), jnp.float32)
                   / math.sqrt(cfg.conv_width)).astype(dtype),
        "conv_b": jnp.zeros((inner,), dtype),
        "w_q": init_dense(ks[3], inner, H * dqk, dtype=dtype),
        "w_k": init_dense(ks[4], inner, H * dqk, dtype=dtype),
        "w_v": init_dense(ks[5], inner, H * dv, dtype=dtype),
        "w_i": init_dense(ks[6], inner, H, bias=True, dtype=jnp.float32),
        "w_f": init_dense(ks[7], inner, H, bias=True, dtype=jnp.float32),
        "out_scale": jnp.ones((H, dv), jnp.float32),
        "w_down": init_dense(ks[8], inner, d, dtype=dtype),
    }


def _causal_conv(u, w, b):
    K = w.shape[0]
    out = u * w[K - 1].astype(u.dtype)
    for j in range(1, K):
        shifted = jnp.pad(u, ((0, 0), (j, 0), (0, 0)))[:, :-j]
        out = out + shifted * w[K - 1 - j].astype(u.dtype)
    return out + b.astype(u.dtype)


def _headnorm(h, scale, eps=1e-6):
    """Per-head RMS norm. h: [..., H, dv]."""
    hf = h.astype(jnp.float32)
    var = jnp.mean(jnp.square(hf), axis=-1, keepdims=True)
    return (hf * jax.lax.rsqrt(var + eps) * scale).astype(h.dtype)


def _mlstm_qkvif(p, x, cfg: ModelConfig):
    inner, H, dqk, dv = _mlstm_dims(cfg)
    B, S, _ = x.shape
    xu = dense(p["w_up"], x)
    g = dense(p["w_gate"], x)
    xc = jax.nn.silu(_causal_conv(xu, p["conv_w"], p["conv_b"]))
    q = dense(p["w_q"], xc).reshape(B, S, H, dqk)
    k = dense(p["w_k"], xc).reshape(B, S, H, dqk) / math.sqrt(dqk)
    v = dense(p["w_v"], xu).reshape(B, S, H, dv)
    i_log = dense(p["w_i"], xc.astype(jnp.float32))               # [B,S,H]
    f_log = jax.nn.log_sigmoid(dense(p["w_f"], xc.astype(jnp.float32)))
    return xu, g, q, k, v, i_log, f_log


def mlstm_chunkwise(q, k, v, i_log, f_log, *, chunk: int = 256,
                    initial_state=None, return_state: bool = False):
    """Chunkwise-parallel stabilized mLSTM.

    q,k: [B,S,H,dqk]; v: [B,S,H,dv]; i_log,f_log: [B,S,H].
    Returns h: [B,S,H,dv] (and final (C,n,m) state if requested).
    """
    B, S, H, dqk = q.shape
    dv = v.shape[-1]
    c = min(chunk, S)
    while S % c:
        c -= 1
    T = S // c

    def resh(x, tail):
        return x.reshape((B, T, c) + tail)

    qs = resh(q, (H, dqk)).transpose(0, 1, 3, 2, 4)   # [B,T,H,c,dqk]
    ks = resh(k, (H, dqk)).transpose(0, 1, 3, 2, 4)
    vs = resh(v, (H, dv)).transpose(0, 1, 3, 2, 4)
    il = resh(i_log, (H,)).transpose(0, 1, 3, 2)       # [B,T,H,c]
    fl = resh(f_log, (H,)).transpose(0, 1, 3, 2)

    if initial_state is None:
        C0 = jnp.zeros((B, H, dqk, dv), jnp.float32)
        n0 = jnp.zeros((B, H, dqk), jnp.float32)
        m0 = jnp.full((B, H), 0.0, jnp.float32)
    else:
        C0, n0, m0 = initial_state

    causal = jnp.tril(jnp.ones((c, c), bool))

    def chunk_step(carry, inp):
        C, n, m = carry                                   # [B,H,dqk,dv] ...
        qc, kc, vc, ic, fc = inp                          # [B,H,c,*]
        b = jnp.cumsum(fc, axis=-1)                       # [B,H,c]
        Btot = b[..., -1:]                                # [B,H,1]
        # intra-chunk log weights: D[j,l] = b_j - b_l + i_l  (l <= j)
        logD = b[..., :, None] - b[..., None, :] + ic[..., None, :]
        logD = jnp.where(causal[None, None], logD, NEG_INF)
        m_intra = jnp.max(logD, axis=-1)                  # [B,H,c]
        m_inter = b + m[..., None]                        # [B,H,c]
        m_j = jnp.maximum(m_intra, m_inter)
        Dmat = jnp.exp(logD - m_j[..., None])
        scores = jnp.einsum("bhjd,bhld->bhjl",
                            qc.astype(jnp.float32), kc.astype(jnp.float32))
        w_intra = scores * Dmat
        h_intra = jnp.einsum("bhjl,bhld->bhjd", w_intra, vc.astype(jnp.float32))
        n_intra = jnp.einsum("bhjl,bhld->bhjd", w_intra, kc.astype(jnp.float32))
        dec_q = jnp.exp(m_inter - m_j)                    # [B,H,c]
        h_inter = jnp.einsum("bhjd,bhde->bhje", qc.astype(jnp.float32), C) \
            * dec_q[..., None]
        n_inter = jnp.einsum("bhjd,bhd->bhj", qc.astype(jnp.float32), n) * dec_q
        num = h_intra + h_inter                           # [B,H,c,dv]
        den = jnp.abs(jnp.einsum("bhjd,bhjd->bhj", qc.astype(jnp.float32),
                                 n_intra) + n_inter)
        h = num / jnp.maximum(den, jnp.exp(-m_j))[..., None]
        # ---- state update ----
        m_state = jnp.maximum((Btot + m[..., None])[..., 0],
                              jnp.max(Btot - b + ic, axis=-1))    # [B,H]
        dec_k = jnp.exp(Btot - b + ic - m_state[..., None])        # [B,H,c]
        C_new = C * jnp.exp(Btot[..., 0] + m - m_state)[..., None, None] \
            + jnp.einsum("bhl,bhld,bhle->bhde", dec_k,
                         kc.astype(jnp.float32), vc.astype(jnp.float32))
        n_new = n * jnp.exp(Btot[..., 0] + m - m_state)[..., None] \
            + jnp.einsum("bhl,bhld->bhd", dec_k, kc.astype(jnp.float32))
        return (C_new, n_new, m_state), h

    xs = (qs.transpose(1, 0, 2, 3, 4), ks.transpose(1, 0, 2, 3, 4),
          vs.transpose(1, 0, 2, 3, 4), il.transpose(1, 0, 2, 3),
          fl.transpose(1, 0, 2, 3))
    (Cf, nf, mf), hs = jax.lax.scan(chunk_step, (C0, n0, m0), xs)
    h = hs.transpose(1, 0, 3, 2, 4).reshape(B, S, H, dv).astype(v.dtype)
    if return_state:
        return h, (Cf, nf, mf)
    return h


def mlstm_block_forward(p, x, cfg: ModelConfig, *, chunk: int = 256):
    inner, H, dqk, dv = _mlstm_dims(cfg)
    B, S, _ = x.shape
    xu, g, q, k, v, i_log, f_log = _mlstm_qkvif(p, x, cfg)
    h = mlstm_chunkwise(q, k, v, i_log, f_log, chunk=chunk)
    h = _headnorm(h, p["out_scale"])
    h = (h * jax.nn.silu(g).reshape(B, S, H, dv)).reshape(B, S, inner)
    return dense(p["w_down"], h)


def init_mlstm_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16,
                     abstract: bool = False):
    inner, H, dqk, dv = _mlstm_dims(cfg)
    shapes = {
        "C": ((batch, H, dqk, dv), jnp.float32),
        "n": ((batch, H, dqk), jnp.float32),
        "m": ((batch, H), jnp.float32),
        "conv": ((batch, cfg.conv_width - 1, inner), dtype),
    }
    if abstract:
        return {k: jax.ShapeDtypeStruct(s, d) for k, (s, d) in shapes.items()}
    return {k: jnp.zeros(s, d) for k, (s, d) in shapes.items()}


def mlstm_block_prefill(p, x, cfg: ModelConfig, *, chunk: int = 256):
    inner, H, dqk, dv = _mlstm_dims(cfg)
    B, S, _ = x.shape
    xu, g, q, k, v, i_log, f_log = _mlstm_qkvif(p, x, cfg)
    h, (C, n, m) = mlstm_chunkwise(q, k, v, i_log, f_log, chunk=chunk,
                                   return_state=True)
    h = _headnorm(h, p["out_scale"])
    h = (h * jax.nn.silu(g).reshape(B, S, H, dv)).reshape(B, S, inner)
    y = dense(p["w_down"], h)
    cache = {"C": C, "n": n, "m": m, "conv": xu[:, -(cfg.conv_width - 1):]}
    return y, cache


def mlstm_block_decode(p, x, cache, cfg: ModelConfig):
    """x: [B, 1, D] single-token decode."""
    inner, H, dqk, dv = _mlstm_dims(cfg)
    B = x.shape[0]
    xt = x[:, 0]
    xu = dense(p["w_up"], xt)                               # [B, inner]
    g = dense(p["w_gate"], xt)
    hist = jnp.concatenate([cache["conv"], xu[:, None]], axis=1)
    w = p["conv_w"]
    conv = jnp.einsum("bki,ki->bi", hist.astype(jnp.float32),
                      w.astype(jnp.float32)) + p["conv_b"].astype(jnp.float32)
    xc = jax.nn.silu(conv).astype(xt.dtype)
    q = dense(p["w_q"], xc).reshape(B, H, dqk).astype(jnp.float32)
    k = (dense(p["w_k"], xc).reshape(B, H, dqk)
         / math.sqrt(dqk)).astype(jnp.float32)
    v = dense(p["w_v"], xu).reshape(B, H, dv).astype(jnp.float32)
    i_log = dense(p["w_i"], xc.astype(jnp.float32))          # [B,H]
    f_log = jax.nn.log_sigmoid(dense(p["w_f"], xc.astype(jnp.float32)))
    C, n, m = cache["C"], cache["n"], cache["m"]
    m_new = jnp.maximum(f_log + m, i_log)
    fbar = jnp.exp(f_log + m - m_new)
    ibar = jnp.exp(i_log - m_new)
    C_new = C * fbar[..., None, None] + ibar[..., None, None] \
        * jnp.einsum("bhd,bhe->bhde", k, v)
    n_new = n * fbar[..., None] + ibar[..., None] * k
    num = jnp.einsum("bhd,bhde->bhe", q, C_new)
    den = jnp.abs(jnp.einsum("bhd,bhd->bh", q, n_new))
    h = num / jnp.maximum(den, jnp.exp(-m_new))[..., None]
    h = _headnorm(h.astype(x.dtype), p["out_scale"])
    h = (h * jax.nn.silu(g).reshape(B, H, dv)).reshape(B, inner)
    y = dense(p["w_down"], h)
    return y[:, None], {"C": C_new, "n": n_new, "m": m_new,
                        "conv": hist[:, 1:]}


# ====================================================================== sLSTM


def init_slstm_block(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    H = cfg.num_heads
    dh = d // H
    inner = int(d * cfg.slstm_proj_factor)
    ks = jax.random.split(key, 7)
    gate_in = {}
    for name, kk in zip(("z", "i", "f", "o"), jax.random.split(ks[0], 4)):
        gate_in[f"w_{name}"] = init_dense(kk, d, d, bias=True, dtype=dtype)
    rec = (jax.random.normal(ks[1], (4, H, dh, dh), jnp.float32)
           / math.sqrt(dh)).astype(jnp.float32)
    return {
        **gate_in,
        "rec": rec,                                     # [4(z,i,f,o), H, dh, dh]
        "out_scale": jnp.ones((H, dh), jnp.float32),
        "w_ff_up": init_dense(ks[2], d, inner, dtype=dtype),
        "w_ff_down": init_dense(ks[3], inner, d, dtype=dtype),
    }


def init_slstm_cache(cfg: ModelConfig, batch: int, abstract: bool = False):
    H = cfg.num_heads
    dh = cfg.d_model // H
    sh = (batch, H, dh)
    names = ("h", "c", "n", "m")
    if abstract:
        return {k: jax.ShapeDtypeStruct(sh if k != "m" else (batch, H),
                                        jnp.float32) for k in names}
    return {k: jnp.zeros(sh if k != "m" else (batch, H), jnp.float32)
            for k in names}


def _slstm_cell(rec, xz, xi, xf, xo, state):
    """One step. x*: [B,H,dh] (precomputed input projections, f32)."""
    h, c, n, m = state["h"], state["c"], state["n"], state["m"]
    rz = jnp.einsum("bhd,hde->bhe", h, rec[0])
    ri = jnp.einsum("bhd,hde->bhe", h, rec[1])
    rf = jnp.einsum("bhd,hde->bhe", h, rec[2])
    ro = jnp.einsum("bhd,hde->bhe", h, rec[3])
    z = jnp.tanh(xz + rz)
    i_log = (xi + ri).mean(axis=-1)                     # per-head scalar gates
    f_log = jax.nn.log_sigmoid((xf + rf).mean(axis=-1))
    o = jax.nn.sigmoid(xo + ro)
    m_new = jnp.maximum(f_log + m, i_log)
    ibar = jnp.exp(i_log - m_new)[..., None]
    fbar = jnp.exp(f_log + m - m_new)[..., None]
    c_new = fbar * c + ibar * z
    n_new = fbar * n + ibar
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    return {"h": h_new, "c": c_new, "n": n_new, "m": m_new}


def slstm_block_forward(p, x, cfg: ModelConfig, act, *, initial_state=None,
                        return_state: bool = False):
    """[B,S,D] -> [B,S,D] via sequential scan over S."""
    B, S, D = x.shape
    H = cfg.num_heads
    dh = D // H
    xz = dense(p["w_z"], x).astype(jnp.float32).reshape(B, S, H, dh)
    xi = dense(p["w_i"], x).astype(jnp.float32).reshape(B, S, H, dh)
    xf = dense(p["w_f"], x).astype(jnp.float32).reshape(B, S, H, dh)
    xo = dense(p["w_o"], x).astype(jnp.float32).reshape(B, S, H, dh)
    state = initial_state or init_slstm_cache(cfg, B)
    rec = p["rec"]

    def step(st, inp):
        st = _slstm_cell(rec, *inp, st)
        return st, st["h"]

    xs = tuple(a.transpose(1, 0, 2, 3) for a in (xz, xi, xf, xo))
    state, hs = jax.lax.scan(step, state, xs)
    h = hs.transpose(1, 0, 2, 3)                         # [B,S,H,dh]
    h = _headnorm(h, p["out_scale"]).reshape(B, S, D).astype(x.dtype)
    y = dense(p["w_ff_down"], act(dense(p["w_ff_up"], h)))
    if return_state:
        return y, state
    return y


def slstm_block_decode(p, x, cache, cfg: ModelConfig, act):
    B = x.shape[0]
    H = cfg.num_heads
    dh = cfg.d_model // H
    xt = x[:, 0]
    xz = dense(p["w_z"], xt).astype(jnp.float32).reshape(B, H, dh)
    xi = dense(p["w_i"], xt).astype(jnp.float32).reshape(B, H, dh)
    xf = dense(p["w_f"], xt).astype(jnp.float32).reshape(B, H, dh)
    xo = dense(p["w_o"], xt).astype(jnp.float32).reshape(B, H, dh)
    state = _slstm_cell(p["rec"], xz, xi, xf, xo, cache)
    h = _headnorm(state["h"][:, None], p["out_scale"])
    h = h.reshape(B, 1, cfg.d_model).astype(x.dtype)
    y = dense(p["w_ff_down"], act(dense(p["w_ff_up"], h)))
    return y, state
