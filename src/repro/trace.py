"""Trace analyzer CLI for control-plane telemetry (core/telemetry.py).

    python -m repro.trace summarize CELL.trace.jsonl
    python -m repro.trace diff A.trace.jsonl B.trace.jsonl
    python -m repro.trace causality CELL.trace.jsonl --tenant ws-0
    python -m repro.trace validate CELL.trace.jsonl
    python -m repro.trace perfetto CELL.trace.jsonl --out cell.perfetto.json

``summarize`` prints per-tenant reclaim-latency and SLO-violation-duration
distributions, spend attribution and the fault ledger (failures/repairs
by cause, suppressions, drain deliveries); ``diff`` compares two summaries
(e.g. the same cell under two engines); ``causality`` walks every forced
claim's ``claim -> reclaim plan -> drains -> SLO recovery`` chain;
``validate`` schema-checks the trace and verifies causal-chain integrity
— including every ``node_fail -> node_repair`` pairing and every
``reclaim_step -> drain_complete`` delivery — (non-zero exit on any
problem — CI gates on it); ``perfetto`` exports
Chrome trace-event JSON loadable in https://ui.perfetto.dev or
chrome://tracing. All subcommands take ``--json`` for machine output.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.core.telemetry import (causality_report, check_causal_chains,
                                  diff_summaries, load_events,
                                  summarize_events, to_perfetto,
                                  validate_events)


def _fmt_dist(d: dict) -> str:
    return (f"n={d['n']} p50={d['p50']:.1f}s p99={d['p99']:.1f}s "
            f"max={d['max']:.1f}s")


def _print_summary(s: dict) -> None:
    print(f"events: {s['events']}")
    for t, n in s["by_type"].items():
        print(f"  {t:<16} {n}")
    rl = s["reclaim_latency_s"]
    print(f"reclaim latency (overall): {_fmt_dist(rl['overall'])}")
    for name, d in rl["by_tenant"].items():
        print(f"  {name:<16} {_fmt_dist(d)}")
    for name, n in rl["unrecovered"].items():
        print(f"  {name:<16} {n} claim(s) never recovered")
    if s["slo_violations"]:
        print("slo violations:")
        for name, v in s["slo_violations"].items():
            print(f"  {name:<16} count={v['count']} open={v['open']} "
                  f"{_fmt_dist(v['duration_s'])}")
    if s["spend"]:
        print("spend attribution:")
        for name, d in s["spend"].items():
            print(f"  {name:<16} idle={d.get('idle', 0.0):.2f} "
                  f"reclaim={d.get('reclaim', 0.0):.2f}")
    if s["auction"]["clearings"]:
        print(f"auction clearings: {s['auction']['clearings']} "
              f"price {_fmt_dist(s['auction']['clearing_price'])}")
    f = s.get("faults", {})
    if f.get("failures") or f.get("suppressed"):
        by_cause = " ".join(f"{c}={n}" for c, n in
                            sorted(f.get("by_cause", {}).items()))
        print(f"faults: failures={f['failures']} repairs={f['repairs']} "
              f"unrepaired={f['unrepaired']} suppressed={f['suppressed']} "
              f"({by_cause})")
        if f.get("drain_completes"):
            print(f"  drains: {f['drain_completes']} window(s), "
                  f"{f['drained_nodes']} node(s) delivered after drain")


def _cmd_summarize(args) -> int:
    s = summarize_events(load_events(args.trace))
    if args.json:
        json.dump(s, sys.stdout, indent=1)
        print()
    else:
        _print_summary(s)
    return 0


def _cmd_diff(args) -> int:
    d = diff_summaries(summarize_events(load_events(args.a)),
                       summarize_events(load_events(args.b)))
    if args.json:
        json.dump(d, sys.stdout, indent=1)
        print()
        return 0
    print(f"events: {d['events']['a']} -> {d['events']['b']} "
          f"({d['events']['delta']:+d})")
    for t, v in d["by_type"].items():
        if v["delta"]:
            print(f"  {t:<16} {v['a']} -> {v['b']} ({v['delta']:+d})")
    rl = d["reclaim_latency_s"]
    print("reclaim latency: " + "  ".join(
        f"{k}={rl[k]['a']:.1f}->{rl[k]['b']:.1f}"
        for k in ("n", "p50", "p99", "max")))
    for name, v in d["slo_violations"].items():
        print(f"  slo {name}: count {v['count']['a']}->{v['count']['b']} "
              f"p99_dur {v['p99_duration_s']['a']:.1f}s->"
              f"{v['p99_duration_s']['b']:.1f}s")
    for name, v in d["spend"].items():
        print(f"  spend {name}: idle {v['idle']['a']:.1f}->"
              f"{v['idle']['b']:.1f} reclaim {v['reclaim']['a']:.1f}->"
              f"{v['reclaim']['b']:.1f}")
    return 0


def _cmd_causality(args) -> int:
    rep = causality_report(load_events(args.trace), tenant=args.tenant)
    if args.json:
        json.dump(rep, sys.stdout, indent=1)
        print()
        return 0 if not rep["broken_chains"] else 1
    who = args.tenant or "all tenants"
    print(f"forced-reclaim claims ({who}): {rep['forced_claims']}")
    for c in rep["chains"]:
        print(f"[t={c['ts']:.1f}s] {c['tenant']} requested {c['requested']} "
              f"(free={c['from_free']}, granted={c['granted']}, "
              f"short={c['short']}) engine={c['engine']}")
        print(f"    plan: {c['planned_victims']}")
        for dr in c["drains"]:
            print(f"    drain {dr['victim']}: released {dr['released']}, "
                  f"claimant got {dr['granted']}")
        ep = c.get("shortfall_episode")
        if ep is not None:
            if ep["recovered"]:
                print(f"    shortfall episode: recovered after "
                      f"{ep['duration_s']:.1f}s")
            else:
                print("    shortfall episode: NEVER recovered")
    if rep["broken_chains"]:
        print(f"BROKEN causal chains: {len(rep['broken_chains'])}")
        for p in rep["broken_chains"][:10]:
            print(f"  {p}")
        return 1
    print("causal chains intact")
    return 0


def _cmd_validate(args) -> int:
    events = load_events(args.trace)
    problems = validate_events(events) + check_causal_chains(events)
    if args.json:
        json.dump({"events": len(events), "problems": problems},
                  sys.stdout, indent=1)
        print()
    elif problems:
        for p in problems:
            print(p)
    else:
        print(f"ok: {len(events)} events, schema valid, "
              f"causal chains intact")
    return 1 if problems else 0


def _cmd_perfetto(args) -> int:
    doc = to_perfetto(load_events(args.trace))
    with open(args.out, "w") as f:
        json.dump(doc, f)
    print(f"{len(doc['traceEvents'])} trace events -> {args.out} "
          f"(open in https://ui.perfetto.dev)")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.trace",
        description=__doc__.splitlines()[0])
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("summarize", help="per-tenant latency/SLO/spend "
                                         "distributions")
    p.add_argument("trace")
    p.add_argument("--json", action="store_true")
    p.set_defaults(fn=_cmd_summarize)

    p = sub.add_parser("diff", help="compare two trace summaries")
    p.add_argument("a")
    p.add_argument("b")
    p.add_argument("--json", action="store_true")
    p.set_defaults(fn=_cmd_diff)

    p = sub.add_parser("causality", help="walk claim -> reclaim -> "
                                         "recovery chains")
    p.add_argument("trace")
    p.add_argument("--tenant", default=None)
    p.add_argument("--json", action="store_true")
    p.set_defaults(fn=_cmd_causality)

    p = sub.add_parser("validate", help="schema + causal-integrity check "
                                        "(non-zero exit on problems)")
    p.add_argument("trace")
    p.add_argument("--json", action="store_true")
    p.set_defaults(fn=_cmd_validate)

    p = sub.add_parser("perfetto", help="export Chrome trace-event JSON")
    p.add_argument("trace")
    p.add_argument("--out", required=True)
    p.set_defaults(fn=_cmd_perfetto)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
