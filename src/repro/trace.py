"""Trace analyzer CLI for control-plane telemetry (core/telemetry.py).

    python -m repro.trace summarize CELL.trace.jsonl
    python -m repro.trace diff A.trace.jsonl B.trace.jsonl
    python -m repro.trace causality CELL.trace.jsonl --tenant ws-0
    python -m repro.trace validate CELL.trace.jsonl
    python -m repro.trace replay CELL.trace.jsonl
    python -m repro.trace bisect A.trace.jsonl B.trace.jsonl
    python -m repro.trace regress goldens/mix_tiny_traces NEW_TRACE_DIR
    python -m repro.trace perfetto CELL.trace.jsonl --out cell.perfetto.json

``summarize`` prints per-tenant reclaim-latency and SLO-violation-duration
distributions, spend attribution and the fault ledger (failures/repairs
by cause, suppressions, drain deliveries); ``diff`` compares two summaries
(e.g. the same cell under two engines) including fault-ledger and
never-recovered deltas; ``causality`` walks every forced claim's
``claim -> reclaim plan -> drains -> SLO recovery`` chain;
``validate`` schema-checks the trace and verifies causal-chain integrity
— including every ``node_fail -> node_repair`` pairing and every
``reclaim_step -> drain_complete`` delivery — (non-zero exit on any
problem — CI gates on it); ``replay`` reconstructs the run's decision
sequence from the trace and re-applies it against fresh count books,
verifying every ``metrics`` checkpoint (core/replay.py) — non-zero exit
proves the trace is NOT a complete causal record; ``bisect`` walks two
traces of the same scenario under different engines and localizes the
first divergent decision (sim-time, tenant, planned vs taken step);
``regress`` pairs every golden cell trace with its counterpart in a new
trace dir and gates on drift thresholds (reclaim p99, SLO episode
count/duration, spend, fault ledger, never-recovered claims — all
default 0: same-seed traces are deterministic), non-zero exit on breach
— the CI regression gate; ``perfetto`` exports Chrome trace-event JSON
loadable in https://ui.perfetto.dev or chrome://tracing. All subcommands
take ``--json`` for machine output.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys

from repro.core.replay import bisect_traces, replay_events
from repro.core.telemetry import (causality_report, check_causal_chains,
                                  diff_summaries, load_events,
                                  summarize_events, to_perfetto,
                                  validate_events)


def _fmt_dist(d: dict) -> str:
    return (f"n={d['n']} p50={d['p50']:.1f}s p99={d['p99']:.1f}s "
            f"max={d['max']:.1f}s")


def _print_summary(s: dict) -> None:
    print(f"events: {s['events']}")
    for t, n in s["by_type"].items():
        print(f"  {t:<16} {n}")
    rl = s["reclaim_latency_s"]
    print(f"reclaim latency (overall): {_fmt_dist(rl['overall'])}")
    for name, d in rl["by_tenant"].items():
        print(f"  {name:<16} {_fmt_dist(d)}")
    for name, n in rl["unrecovered"].items():
        print(f"  {name:<16} {n} claim(s) never recovered")
    if s["slo_violations"]:
        print("slo violations:")
        for name, v in s["slo_violations"].items():
            print(f"  {name:<16} count={v['count']} open={v['open']} "
                  f"{_fmt_dist(v['duration_s'])}")
    if s["spend"]:
        print("spend attribution:")
        for name, d in s["spend"].items():
            print(f"  {name:<16} idle={d.get('idle', 0.0):.2f} "
                  f"reclaim={d.get('reclaim', 0.0):.2f}")
    if s["auction"]["clearings"]:
        print(f"auction clearings: {s['auction']['clearings']} "
              f"price {_fmt_dist(s['auction']['clearing_price'])}")
    f = s.get("faults", {})
    if f.get("failures") or f.get("suppressed"):
        by_cause = " ".join(f"{c}={n}" for c, n in
                            sorted(f.get("by_cause", {}).items()))
        print(f"faults: failures={f['failures']} repairs={f['repairs']} "
              f"unrepaired={f['unrepaired']} suppressed={f['suppressed']} "
              f"({by_cause})")
        if f.get("drain_completes"):
            print(f"  drains: {f['drain_completes']} window(s), "
                  f"{f['drained_nodes']} node(s) delivered after drain")


def _cmd_summarize(args) -> int:
    s = summarize_events(load_events(args.trace))
    if args.json:
        json.dump(s, sys.stdout, indent=1)
        print()
    else:
        _print_summary(s)
    return 0


def _cmd_diff(args) -> int:
    d = diff_summaries(summarize_events(load_events(args.a)),
                       summarize_events(load_events(args.b)))
    if args.json:
        json.dump(d, sys.stdout, indent=1)
        print()
        return 0
    print(f"events: {d['events']['a']} -> {d['events']['b']} "
          f"({d['events']['delta']:+d})")
    for t, v in d["by_type"].items():
        if v["delta"]:
            print(f"  {t:<16} {v['a']} -> {v['b']} ({v['delta']:+d})")
    rl = d["reclaim_latency_s"]
    print(f"reclaim latency: n={rl['n']['a']}->{rl['n']['b']}  " + "  ".join(
        f"{k}={rl[k]['a']:.1f}->{rl[k]['b']:.1f}"
        for k in ("p50", "p99", "max")))
    for name, v in d["slo_violations"].items():
        print(f"  slo {name}: count {v['count']['a']}->{v['count']['b']} "
              f"p99_dur {v['p99_duration_s']['a']:.1f}s->"
              f"{v['p99_duration_s']['b']:.1f}s")
    for name, v in d["spend"].items():
        print(f"  spend {name}: idle {v['idle']['a']:.1f}->"
              f"{v['idle']['b']:.1f} reclaim {v['reclaim']['a']:.1f}->"
              f"{v['reclaim']['b']:.1f}")
    for name, v in d["unrecovered"].items():
        if v["a"] or v["b"]:
            print(f"  unrecovered {name}: {v['a']}->{v['b']} "
                  f"({v['delta']:+d})")
    f = d["faults"]
    if any(f[k]["a"] or f[k]["b"] for k in f if k != "by_cause"):
        print("faults: " + "  ".join(
            f"{k}={f[k]['a']}->{f[k]['b']}"
            for k in ("failures", "repairs", "unrepaired", "suppressed",
                      "drain_completes", "drained_nodes")))
        for c, v in f["by_cause"].items():
            if v["delta"]:
                print(f"  cause {c}: {v['a']}->{v['b']} ({v['delta']:+d})")
    return 0


def _cmd_causality(args) -> int:
    rep = causality_report(load_events(args.trace), tenant=args.tenant)
    if args.json:
        json.dump(rep, sys.stdout, indent=1)
        print()
        return 0 if not rep["broken_chains"] else 1
    who = args.tenant or "all tenants"
    print(f"forced-reclaim claims ({who}): {rep['forced_claims']}")
    for c in rep["chains"]:
        print(f"[t={c['ts']:.1f}s] {c['tenant']} requested {c['requested']} "
              f"(free={c['from_free']}, granted={c['granted']}, "
              f"short={c['short']}) engine={c['engine']}")
        print(f"    plan: {c['planned_victims']}")
        for dr in c["drains"]:
            print(f"    drain {dr['victim']}: released {dr['released']}, "
                  f"claimant got {dr['granted']}")
        ep = c.get("shortfall_episode")
        if ep is not None:
            if ep["recovered"]:
                print(f"    shortfall episode: recovered after "
                      f"{ep['duration_s']:.1f}s")
            else:
                print("    shortfall episode: NEVER recovered")
    if rep["broken_chains"]:
        print(f"BROKEN causal chains: {len(rep['broken_chains'])}")
        for p in rep["broken_chains"][:10]:
            print(f"  {p}")
        return 1
    print("causal chains intact")
    return 0


def _cmd_validate(args) -> int:
    events = load_events(args.trace)
    problems = validate_events(events) + check_causal_chains(events)
    if args.json:
        json.dump({"events": len(events), "problems": problems},
                  sys.stdout, indent=1)
        print()
    elif problems:
        for p in problems:
            print(p)
    else:
        print(f"ok: {len(events)} events, schema valid, "
              f"causal chains intact")
    return 1 if problems else 0


def _cmd_replay(args) -> int:
    res = replay_events(load_events(args.trace))
    if args.json:
        json.dump({"events": res.events, "decisions": res.decisions,
                   "checkpoints": res.checkpoints, "books": res.books(),
                   "problems": res.problems}, sys.stdout, indent=1)
        print()
        return 0 if res.ok else 1
    if res.problems:
        print(f"REPLAY DIVERGED: {len(res.problems)} problem(s)")
        for p in res.problems[:20]:
            print(f"  {p}")
        return 1
    b = res.books()
    print(f"ok: replayed {res.decisions} decision(s) from {res.events} "
          f"event(s); {res.checkpoints} checkpoint(s) matched the live "
          f"run's count books exactly")
    print(f"final books: total={b['total']} free={b['free']} "
          f"draining={b['draining']}")
    for name, n in b["alloc"].items():
        extra = ""
        if b["spend"].get(name):
            extra = f" spend={b['spend'][name]:.2f}"
        print(f"  {name:<16} alloc={n}{extra}")
    return 0


def _cmd_bisect(args) -> int:
    rep = bisect_traces(load_events(args.a), load_events(args.b))
    if args.json:
        json.dump(rep or {"identical": True}, sys.stdout, indent=1)
        print()
        return 0 if rep is None else 1
    if rep is None:
        print("decision streams are behaviorally identical")
        return 0
    print(f"first divergent decision: #{rep['decision_index']} "
          f"({rep['common_decisions']} common decision(s) before it)")
    for label in ("a", "b"):
        s = rep[label]
        if s["exhausted"]:
            print(f"  {label}: trace ends (no decision #"
                  f"{rep['decision_index']})")
        else:
            print(f"  {label}: [t={s['ts']:.1f}s] {s['type']} "
                  f"tenant={s['tenant']}")
            print(f"     {json.dumps(s['event'], sort_keys=True)}")
    for label in ("plan_a", "plan_b"):
        plan = rep.get(label)
        if plan:
            steps = " ".join(f"{st['victim']}:{st['take']}"
                             for st in plan["steps"])
            print(f"  {label}: [t={plan['ts']:.1f}s] "
                  f"engine={plan['engine']} planned [{steps}]")
    if rep["context"]:
        print("  last common decisions:")
        for ev in rep["context"]:
            print(f"    [t={ev.get('ts', 0.0):.1f}s] {ev.get('type')} "
                  f"tenant={ev.get('tenant')}")
    return 1


# --------------------------------------------------------- regress gate


@dataclasses.dataclass(frozen=True)
class RegressThresholds:
    """Max tolerated |delta| per drift axis. All default to zero: a
    same-seed rerun emits a byte-identical trace (no wall clock in the
    control plane; queue metrics are post-hoc jax evaluations that never
    feed back into consolidation), so ANY drift is a behavior change."""
    reclaim_p99_s: float = 0.0
    reclaim_n: int = 0
    slo_count: int = 0
    slo_p99_duration_s: float = 0.0
    spend: float = 0.0
    faults: int = 0
    unrecovered: int = 0


def check_regression(diff: dict, thr: RegressThresholds) -> list:
    """Breaches in a ``diff_summaries`` output under ``thr`` (empty list
    == within tolerance)."""
    breaches = []

    def gate(axis, delta, limit):
        if abs(delta) > limit:
            breaches.append(f"{axis}: |{delta:+g}| > {limit:g}")

    rl = diff["reclaim_latency_s"]
    gate("reclaim_latency_s.n", rl["n"]["delta"], thr.reclaim_n)
    gate("reclaim_latency_s.p99", rl["p99"]["delta"], thr.reclaim_p99_s)
    for name, v in diff["slo_violations"].items():
        gate(f"slo_violations[{name}].count", v["count"]["delta"],
             thr.slo_count)
        gate(f"slo_violations[{name}].p99_duration_s",
             v["p99_duration_s"]["delta"], thr.slo_p99_duration_s)
    for name, v in diff["spend"].items():
        for kind in ("idle", "reclaim"):
            gate(f"spend[{name}].{kind}", v[kind]["delta"], thr.spend)
    for name, v in diff["unrecovered"].items():
        gate(f"unrecovered[{name}]", v["delta"], thr.unrecovered)
    for k, v in diff["faults"].items():
        if k == "by_cause":
            for c, cv in v.items():
                gate(f"faults.by_cause[{c}]", cv["delta"], thr.faults)
        else:
            gate(f"faults.{k}", v["delta"], thr.faults)
    return breaches


def _trace_cells(trace_dir: str) -> dict:
    """Map cell identity -> trace path for every ``*.trace.jsonl`` in a
    dir. Identity is the header's ``cell_id`` (human-readable, stable
    across the cell_key hash-schema) with the filename stem as
    fallback."""
    cells = {}
    for fn in sorted(os.listdir(trace_dir)):
        if not fn.endswith(".trace.jsonl"):
            continue
        path = os.path.join(trace_dir, fn)
        ident = fn[:-len(".trace.jsonl")]
        with open(path) as f:
            first = f.readline()
        if first:
            header = json.loads(first)
            ident = header.get("cell_id", ident)
        cells[ident] = path
    return cells


def _cmd_regress(args) -> int:
    thr = RegressThresholds(
        reclaim_p99_s=args.reclaim_p99_s, reclaim_n=args.reclaim_n,
        slo_count=args.slo_count,
        slo_p99_duration_s=args.slo_p99_duration_s, spend=args.spend,
        faults=args.faults, unrecovered=args.unrecovered)
    golden = _trace_cells(args.golden_dir)
    fresh = _trace_cells(args.new_dir)
    if not golden:
        print(f"no *.trace.jsonl files in golden dir {args.golden_dir}",
              file=sys.stderr)
        return 2
    report = {"cells": {}, "missing": [], "extra": [], "breaches": 0}
    for ident in sorted(set(golden) - set(fresh)):
        report["missing"].append(ident)
    for ident in sorted(set(fresh) - set(golden)):
        report["extra"].append(ident)
    for ident in sorted(set(golden) & set(fresh)):
        d = diff_summaries(summarize_events(load_events(golden[ident])),
                           summarize_events(load_events(fresh[ident])))
        breaches = check_regression(d, thr)
        report["cells"][ident] = {"breaches": breaches, "diff": d}
        report["breaches"] += len(breaches)
    failed = bool(report["missing"] or report["breaches"])
    if args.json:
        json.dump(report, sys.stdout, indent=1)
        print()
        return 1 if failed else 0
    for ident in report["missing"]:
        print(f"MISSING: golden cell '{ident}' has no counterpart in "
              f"{args.new_dir}")
    for ident in report["extra"]:
        print(f"note: new cell '{ident}' has no golden baseline "
              f"(not gated)")
    for ident, cell in report["cells"].items():
        if cell["breaches"]:
            print(f"DRIFT {ident}:")
            for br in cell["breaches"]:
                print(f"  {br}")
        else:
            print(f"ok {ident}")
    n = len(report["cells"])
    if failed:
        print(f"regress: FAIL — {report['breaches']} breach(es) across "
              f"{n} paired cell(s), {len(report['missing'])} missing")
        return 1
    print(f"regress: pass — {n} cell(s) within thresholds")
    return 0


def _cmd_perfetto(args) -> int:
    doc = to_perfetto(load_events(args.trace))
    with open(args.out, "w") as f:
        json.dump(doc, f)
    print(f"{len(doc['traceEvents'])} trace events -> {args.out} "
          f"(open in https://ui.perfetto.dev)")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.trace",
        description=__doc__.splitlines()[0])
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("summarize", help="per-tenant latency/SLO/spend "
                                         "distributions")
    p.add_argument("trace")
    p.add_argument("--json", action="store_true")
    p.set_defaults(fn=_cmd_summarize)

    p = sub.add_parser("diff", help="compare two trace summaries")
    p.add_argument("a")
    p.add_argument("b")
    p.add_argument("--json", action="store_true")
    p.set_defaults(fn=_cmd_diff)

    p = sub.add_parser("causality", help="walk claim -> reclaim -> "
                                         "recovery chains")
    p.add_argument("trace")
    p.add_argument("--tenant", default=None)
    p.add_argument("--json", action="store_true")
    p.set_defaults(fn=_cmd_causality)

    p = sub.add_parser("validate", help="schema + causal-integrity check "
                                        "(non-zero exit on problems)")
    p.add_argument("trace")
    p.add_argument("--json", action="store_true")
    p.set_defaults(fn=_cmd_validate)

    p = sub.add_parser("replay", help="re-apply the decision sequence "
                                      "against count books (non-zero "
                                      "exit on divergence)")
    p.add_argument("trace")
    p.add_argument("--json", action="store_true")
    p.set_defaults(fn=_cmd_replay)

    p = sub.add_parser("bisect", help="first divergent decision between "
                                      "two traces of the same scenario")
    p.add_argument("a")
    p.add_argument("b")
    p.add_argument("--json", action="store_true")
    p.set_defaults(fn=_cmd_bisect)

    p = sub.add_parser("regress", help="gate a new trace dir against a "
                                       "golden baseline (non-zero exit "
                                       "on drift)")
    p.add_argument("golden_dir")
    p.add_argument("new_dir")
    p.add_argument("--json", action="store_true")
    t = RegressThresholds()
    p.add_argument("--reclaim-p99-s", type=float, default=t.reclaim_p99_s,
                   help="max |delta| in overall reclaim-latency p99 "
                        "seconds (default %(default)s)")
    p.add_argument("--reclaim-n", type=int, default=t.reclaim_n,
                   help="max |delta| in reclaim count")
    p.add_argument("--slo-count", type=int, default=t.slo_count,
                   help="max |delta| in per-tenant SLO episode count")
    p.add_argument("--slo-p99-duration-s", type=float,
                   default=t.slo_p99_duration_s,
                   help="max |delta| in SLO episode p99 duration seconds")
    p.add_argument("--spend", type=float, default=t.spend,
                   help="max |delta| in per-tenant spend attribution")
    p.add_argument("--faults", type=int, default=t.faults,
                   help="max |delta| in any fault-ledger counter")
    p.add_argument("--unrecovered", type=int, default=t.unrecovered,
                   help="max |delta| in never-recovered claim counts")
    p.set_defaults(fn=_cmd_regress)

    p = sub.add_parser("perfetto", help="export Chrome trace-event JSON")
    p.add_argument("trace")
    p.add_argument("--out", required=True)
    p.set_defaults(fn=_cmd_perfetto)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
