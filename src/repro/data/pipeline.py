"""Deterministic synthetic data pipeline with resumable iterator state.

Step-indexed: batch(step) is a pure function of (seed, step, shape), so a
restarted or resized job regenerates exactly the batches it would have seen
— no iterator state needs checkpointing beyond the step counter, and every
data-parallel host can slice its shard without coordination (per-host
sharded loading: each host materializes only rows hash-assigned to it).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig


class SyntheticLM:
    """Zipf-ish token stream + next-token labels."""

    def __init__(self, cfg: ModelConfig, *, seed: int = 0):
        self.cfg = cfg
        self.seed = seed

    def batch(self, step: int, global_batch: int, seq_len: int,
              *, host_id: int = 0, host_count: int = 1) -> Dict:
        assert global_batch % host_count == 0
        rows = global_batch // host_count
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 7919 + host_id)
        # zipf-like marginal over the vocab (clipped)
        z = rng.zipf(1.3, size=(rows, seq_len + 1))
        toks = np.minimum(z - 1, self.cfg.vocab_size - 1).astype(np.int32)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if self.cfg.num_codebooks:
            batch["labels"] = np.stack(
                [batch["labels"]] * self.cfg.num_codebooks, axis=-1)
        if self.cfg.input_mode == "embeddings":
            emb = rng.standard_normal(
                (rows, seq_len, self.cfg.d_model)).astype(np.float32)
            batch = {"embeds": emb, "labels": batch["labels"]}
        return {k: jax.numpy.asarray(v) for k, v in batch.items()}

    def data_fn(self, step: int, global_batch: int, seq_len: int) -> Dict:
        return self.batch(step, global_batch, seq_len)
