"""Request-arrival processes for the WS department (request-level model).

Four generators cover the evaluation axes of the PhoenixCloud follow-up
(arXiv:1006.1401) and the HPC-cloud taxonomy's hybrid scenarios
(arXiv:1710.08731):

  * ``poisson``      — homogeneous Poisson (the M/G/k baseline);
  * ``mmpp``         — 2-state Markov-modulated Poisson (bursty traffic);
  * ``diurnal``      — nonhomogeneous Poisson with a day/night cycle, the
                       request-level analogue of the World-Cup trace shape;
  * ``flash_crowd``  — diurnal base plus sudden short spikes (the "varying
                       load" case the paper's WS department must survive).

All generators are vectorized numpy and deterministic in ``seed``. Token
counts per request (prompt + decode) come from ``sample_token_counts`` so
service times can be derived via ``serving.batching.ServiceTimeModel``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.core.types import Request

# token-count calibration: long-form generation (decode-dominated), the
# regime where a replica serves ~0.3 req/s/slot and queueing matters.
# Decode lengths are gamma(shape=4) — CV 0.5, p99/mean ~2.5 — so the p99
# *service* time stays under a ~30 s latency target and the SLO is
# feasible; the latency tail then comes from queueing, which is the thing
# the autoscaler controls.
PROMPT_TOK_MEAN = 600.0
PROMPT_TOK_SIGMA = 0.8
DECODE_TOK_MEAN = 1000.0
DECODE_GAMMA_SHAPE = 4.0


@dataclasses.dataclass
class RequestTrace:
    """Columnar request trace: arrays, not objects, so every downstream
    consumer (queue sim, autoscaler windows, campaign reductions) stays
    vectorized."""
    t: np.ndarray               # [N] float64, sorted arrival seconds
    prompt_tokens: np.ndarray   # [N] int64
    decode_tokens: np.ndarray   # [N] int64
    kind: str = "poisson"

    def __len__(self) -> int:
        return len(self.t)

    def to_requests(self) -> List[Request]:
        return [Request(req_id=i, arrival=float(self.t[i]),
                        prompt_tokens=int(self.prompt_tokens[i]),
                        decode_tokens=int(self.decode_tokens[i]))
                for i in range(len(self.t))]

    def rate_in(self, t0: float, t1: float) -> float:
        n = int(np.searchsorted(self.t, t1) - np.searchsorted(self.t, t0))
        return n / max(t1 - t0, 1e-9)


def sample_token_counts(n: int, rng: np.random.Generator,
                        prompt_mean: float = PROMPT_TOK_MEAN,
                        decode_mean: float = DECODE_TOK_MEAN):
    """Log-normal prompts (heavy tail), gamma decode lengths (CV 0.5)."""
    mu = np.log(prompt_mean) - 0.5 * PROMPT_TOK_SIGMA ** 2
    prompt = np.maximum(
        8, rng.lognormal(mu, PROMPT_TOK_SIGMA, n)).astype(np.int64)
    decode = np.maximum(16, rng.gamma(
        DECODE_GAMMA_SHAPE, decode_mean / DECODE_GAMMA_SHAPE, n)
    ).astype(np.int64)
    return prompt, decode


# ------------------------------------------------------------- generators


def poisson_arrivals(rate: float, horizon: float, seed: int = 0
                     ) -> RequestTrace:
    """Homogeneous Poisson at `rate` req/s over [0, horizon)."""
    rng = np.random.default_rng(seed)
    n_est = int(rate * horizon * 1.2) + 64
    gaps = rng.exponential(1.0 / rate, n_est)
    t = np.cumsum(gaps)
    while t[-1] < horizon:                       # rare under-draw
        more = np.cumsum(rng.exponential(1.0 / rate, n_est)) + t[-1]
        t = np.concatenate([t, more])
    t = t[t < horizon]
    prompt, decode = sample_token_counts(len(t), rng)
    return RequestTrace(t, prompt, decode, kind="poisson")


def _thin(t_max_rate: np.ndarray, rate_at, max_rate: float,
          rng: np.random.Generator) -> np.ndarray:
    """Vectorized thinning of a max-rate Poisson stream."""
    keep = rng.random(len(t_max_rate)) < rate_at(t_max_rate) / max_rate
    return t_max_rate[keep]


def diurnal_arrivals(base_rate: float, horizon: float, seed: int = 0,
                     peak_ratio: float = 4.0) -> RequestTrace:
    """Nonhomogeneous Poisson with a sinusoidal day/night cycle.

    Mean rate == base_rate; instantaneous rate swings between
    base_rate * 2/(1 + peak_ratio) and base_rate * 2*peak_ratio/(1+peak_ratio).
    """
    rng = np.random.default_rng(seed)
    amp = (peak_ratio - 1.0) / (peak_ratio + 1.0)

    def rate_at(t):
        hour = (t / 3600.0) % 24.0
        return base_rate * (1.0 + amp * np.sin((hour - 9.0) / 24.0
                                               * 2 * np.pi))

    max_rate = base_rate * (1.0 + amp)
    base = poisson_arrivals(max_rate, horizon, seed)
    t = _thin(base.t, rate_at, max_rate, rng)
    prompt, decode = sample_token_counts(len(t), rng)
    return RequestTrace(t, prompt, decode, kind="diurnal")


def mmpp_arrivals(rate_lo: float, rate_hi: float, horizon: float,
                  seed: int = 0, mean_sojourn_s: float = 600.0
                  ) -> RequestTrace:
    """2-state Markov-modulated Poisson process (bursty arrivals).

    The modulating chain alternates lo/hi states with exponential sojourns
    of mean `mean_sojourn_s`; within a state arrivals are Poisson. Index of
    dispersion > 1 — burstier than Poisson at every timescale above the
    sojourn scale.
    """
    rng = np.random.default_rng(seed)
    # state sojourn boundaries covering the horizon
    n_soj = int(horizon / mean_sojourn_s * 2.5) + 8
    sojourns = rng.exponential(mean_sojourn_s, n_soj)
    bounds = np.concatenate([[0.0], np.cumsum(sojourns)])
    while bounds[-1] < horizon:
        extra = rng.exponential(mean_sojourn_s, n_soj)
        bounds = np.concatenate([bounds, bounds[-1] + np.cumsum(extra)])
    times: List[np.ndarray] = []
    state_hi = bool(rng.integers(0, 2))
    for i in range(len(bounds) - 1):
        t0, t1 = float(bounds[i]), float(min(bounds[i + 1], horizon))
        if t0 >= horizon:
            break
        rate = rate_hi if state_hi else rate_lo
        n = rng.poisson(rate * (t1 - t0))
        if n > 0:
            times.append(np.sort(rng.uniform(t0, t1, n)))
        state_hi = not state_hi
    t = np.sort(np.concatenate(times)) if times else np.empty(0)
    prompt, decode = sample_token_counts(len(t), rng)
    return RequestTrace(t, prompt, decode, kind="mmpp")


def flash_crowd_arrivals(base_rate: float, horizon: float, seed: int = 0,
                         spike_ratio: float = 6.0,
                         n_spikes: int = 2,
                         spike_duration_s: float = 900.0) -> RequestTrace:
    """Diurnal base + `n_spikes` sudden flash crowds at `spike_ratio` x base.

    Spike start times are seeded-deterministic, placed away from the horizon
    edges so the ramp and drain are both inside the window.
    """
    rng = np.random.default_rng(seed + 7)
    base = diurnal_arrivals(base_rate, horizon, seed, peak_ratio=3.0)
    lo = 0.1 * horizon
    # short horizons: numpy draws from an inverted interval without error,
    # which would place spikes before t=0 — clamp so lo <= hi always
    hi = max(lo, 0.9 * horizon - spike_duration_s)
    starts = np.sort(rng.uniform(lo, hi, n_spikes))
    extra: List[np.ndarray] = []
    for s0 in starts:
        n = rng.poisson(base_rate * (spike_ratio - 1.0) * spike_duration_s)
        if n > 0:
            # sharp onset, exponential tail-off inside the spike window
            offs = rng.exponential(spike_duration_s / 3.0, n)
            offs = offs[offs < spike_duration_s]
            extra.append(s0 + offs)
    t = np.sort(np.concatenate([base.t] + extra)) if extra else base.t
    t = t[t < horizon]
    prompt, decode = sample_token_counts(len(t), rng)
    return RequestTrace(t, prompt, decode, kind="flash_crowd")


GENERATORS = {
    "poisson": lambda rate, horizon, seed: poisson_arrivals(
        rate, horizon, seed),
    # lo/hi chosen so the stationary mean (equal sojourns) equals `rate`
    "mmpp": lambda rate, horizon, seed: mmpp_arrivals(
        0.4 * rate, 1.6 * rate, horizon, seed),
    "diurnal": lambda rate, horizon, seed: diurnal_arrivals(
        rate, horizon, seed),
    "flash_crowd": lambda rate, horizon, seed: flash_crowd_arrivals(
        rate, horizon, seed),
}


def make_trace(kind: str, rate: float, horizon: float, seed: int = 0
               ) -> RequestTrace:
    """Uniform entry point: mean rate `rate` req/s, process shape `kind`."""
    if kind not in GENERATORS:
        raise ValueError(f"unknown arrival process {kind!r}; "
                         f"have {sorted(GENERATORS)}")
    return GENERATORS[kind](rate, horizon, seed)


def burstiness_index(trace: RequestTrace, window_s: float = 60.0) -> float:
    """Index of dispersion of counts: Var(N_w)/E(N_w) over fixed windows.

    == 1 for Poisson, > 1 for MMPP / flash crowds. Used by tests to verify
    the generators actually produce the burstiness they claim.
    """
    if len(trace) == 0:
        return 0.0
    horizon = float(trace.t[-1]) + 1e-9
    edges = np.arange(0.0, horizon + window_s, window_s)
    counts, _ = np.histogram(trace.t, bins=edges)
    m = counts.mean()
    return float(counts.var() / m) if m > 0 else 0.0
