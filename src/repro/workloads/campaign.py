"""Vectorized scenario campaign runner.

Sweeps (policy x department-mix x arrival process x cluster size x SLO)
grids over the consolidation simulator: each cell runs the full Phoenix
pipeline — arrival trace -> SLO autoscaler -> ConsolidationSim under the
chosen cooperative policy and department mix -> realized request latency —
then per-cell metric vectors are stacked into numpy arrays for batched
reduction (marginal means over every axis). One JSON artifact comes out,
consumed by ``benchmarks/paper_figs.py`` and CI's smoke campaigns.

    PYTHONPATH=src python -m repro.workloads.campaign --grid tiny \
        --out campaign.json --workers 2
    PYTHONPATH=src python -m repro.workloads.campaign --grid mix_tiny

Department mixes (``--grid mix*``): ``paper2`` is the paper's 1 HPC + 1 WS
wiring (the degenerate case); ``2hpc2ws`` consolidates 2 HPC + 2
request-level WS departments; ``2hpc2ws1be`` adds a best-effort batch
tenant. Cells are independent; ``--workers N`` fans them out over
processes (fork), falling back to in-process execution if a pool cannot
start.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time
from concurrent.futures.process import BrokenProcessPool
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.policies import POLICIES
from repro.core.simulator import ConsolidationSim
from repro.core.traces import synthetic_sdsc_blue
from repro.core.types import SimConfig, SLOConfig, TenantSpec
from repro.serving.batching import ServiceTimeModel
from repro.workloads.arrivals import GENERATORS, make_trace
from repro.workloads.autoscaler import RequestWorkload

# department mixes: name -> (n_hpc, n_ws, n_best_effort)
MIXES: Dict[str, tuple] = {
    "paper2": (1, 1, 0),        # the paper's wiring (degenerate 2-tenant)
    "2hpc2ws": (2, 2, 0),
    "2hpc2ws1be": (2, 2, 1),
}


@dataclasses.dataclass(frozen=True)
class ScenarioCell:
    """One point of the campaign grid (fully picklable)."""
    preempt: str                 # kill | checkpoint
    scheduler: str               # first_fit | fcfs | easy_backfill
    arrival: str                 # key into workloads.arrivals.GENERATORS
    total_nodes: int
    slo_target_s: float
    rate_rps: float = 2.0        # mean WS arrival rate (split across WS depts)
    horizon_s: float = 7200.0
    n_jobs: int = 80             # total HPC jobs (split across HPC depts)
    st_max_nodes: int = 32       # batch-trace size calibration
    policy: str = "paper"        # key into core.policies.POLICIES
    mix: str = "paper2"          # key into MIXES
    seed: int = 0

    def cell_id(self) -> str:
        base = (f"{self.preempt}-{self.scheduler}-{self.arrival}"
                f"-n{self.total_nodes}-slo{self.slo_target_s:g}"
                f"-s{self.seed}")
        if self.policy != "paper" or self.mix != "paper2":
            base += f"-{self.policy}-{self.mix}"
        return base


# metric columns extracted per cell, in a fixed order so the reduction is
# one stacked [n_cells, n_metrics] array
METRIC_KEYS = ("completed", "killed", "preemptions", "avg_turnaround_s",
               "ws_p50_s", "ws_p95_s", "ws_p99_s", "ws_violation_rate",
               "ws_unserved", "ws_unmet_node_seconds", "ws_peak_nodes",
               "st_avg_alloc", "ws_avg_alloc", "wall_s")
# axes a reduction marginalizes over
AXIS_KEYS = ("preempt", "scheduler", "arrival", "total_nodes",
             "slo_target_s", "policy", "mix")


def make_grid(name: str, seed: int = 0) -> List[ScenarioCell]:
    """Named grids. `tiny` is the CI smoke grid (8 cells, < 60 s serial);
    `mix_tiny` smokes the policy x department-mix matrix."""
    if name == "tiny":
        return [ScenarioCell(preempt=p, scheduler="first_fit", arrival=a,
                             total_nodes=n, slo_target_s=30.0, seed=seed)
                for p in ("kill", "checkpoint")
                for a in ("poisson", "flash_crowd")
                for n in (48, 64)]
    if name == "small":
        return [ScenarioCell(preempt=p, scheduler=s, arrival=a,
                             total_nodes=n, slo_target_s=slo, seed=seed)
                for p in ("kill", "checkpoint")
                for s in ("first_fit", "easy_backfill")
                for a in ("poisson", "mmpp", "flash_crowd")
                for n in (48, 64)
                for slo in (30.0,)]
    if name == "mix_tiny":
        return [ScenarioCell(preempt="kill", scheduler="first_fit",
                             arrival="poisson", total_nodes=96,
                             slo_target_s=30.0, policy=pol, mix="2hpc2ws",
                             seed=seed)
                for pol in sorted(POLICIES)]
    if name == "mix":
        return [ScenarioCell(preempt=p, scheduler="first_fit",
                             arrival="flash_crowd", total_nodes=n,
                             slo_target_s=30.0, policy=pol, mix=m, seed=seed)
                for p in ("kill", "checkpoint")
                for pol in sorted(POLICIES)
                for m in ("2hpc2ws", "2hpc2ws1be")
                for n in (96, 128)]
    if name == "full":
        return [ScenarioCell(preempt=p, scheduler=s, arrival=a,
                             total_nodes=n, slo_target_s=slo,
                             horizon_s=14400.0, n_jobs=160, policy=pol,
                             mix=m, seed=seed)
                for p in ("kill", "checkpoint")
                for s in ("first_fit", "fcfs", "easy_backfill")
                for a in sorted(GENERATORS)
                for n in (40, 48, 64, 96)
                for slo in (20.0, 30.0, 60.0)
                for pol in sorted(POLICIES)
                for m in sorted(MIXES)]
    raise ValueError(f"unknown grid {name!r}; "
                     f"have tiny/small/mix_tiny/mix/full")


def make_tenants(cell: ScenarioCell) -> List[TenantSpec]:
    """Build the department mix for one cell: HPC departments split the job
    trace, WS departments split the request rate, an optional best-effort
    batch tenant rides at the lowest priority."""
    n_hpc, n_ws, n_be = MIXES[cell.mix]
    specs: List[TenantSpec] = []
    for i in range(n_ws):
        trace = make_trace(cell.arrival, cell.rate_rps / n_ws,
                           cell.horizon_s, cell.seed + 101 * i)
        specs.append(TenantSpec(
            f"ws-{i}", "latency", priority=i,
            slo=SLOConfig(latency_target_s=cell.slo_target_s),
            demand=RequestWorkload(
                trace=trace, model=ServiceTimeModel(),
                slo=SLOConfig(latency_target_s=cell.slo_target_s))))
    for i in range(n_hpc):
        jobs = synthetic_sdsc_blue(seed=cell.seed + 31 * i,
                                   n_jobs=max(1, cell.n_jobs // n_hpc),
                                   horizon=cell.horizon_s,
                                   max_nodes=cell.st_max_nodes)
        specs.append(TenantSpec(
            f"hpc-{i}", "batch", priority=n_ws + i,
            weight=float(n_hpc - i), jobs=jobs))
    for i in range(n_be):
        jobs = synthetic_sdsc_blue(seed=cell.seed + 997 + i,
                                   n_jobs=max(1, cell.n_jobs // 4),
                                   horizon=cell.horizon_s,
                                   max_nodes=max(4, cell.st_max_nodes // 4))
        specs.append(TenantSpec(
            f"be-{i}", "batch", priority=100 + i, weight=0.5, jobs=jobs))
    return specs


def run_cell(cell: ScenarioCell) -> Dict:
    """Run one scenario end-to-end; returns axes + metrics as a flat dict."""
    t0 = time.time()
    cfg = SimConfig(total_nodes=cell.total_nodes,
                    preempt_mode=cell.preempt,
                    scheduler=cell.scheduler, seed=cell.seed)
    if cell.mix == "paper2" and cell.policy == "paper":
        # the degenerate 2-tenant path (bit-identical to the seed pipeline)
        jobs = synthetic_sdsc_blue(seed=cell.seed, n_jobs=cell.n_jobs,
                                   horizon=cell.horizon_s,
                                   max_nodes=cell.st_max_nodes)
        trace = make_trace(cell.arrival, cell.rate_rps, cell.horizon_s,
                           cell.seed)
        workload = RequestWorkload(
            trace=trace, model=ServiceTimeModel(),
            slo=SLOConfig(latency_target_s=cell.slo_target_s))
        sim = ConsolidationSim(cfg, jobs, workload, horizon=cell.horizon_s)
        ws_requests = len(trace)
        peak = max((n for _, n in workload.demand_events(cell.horizon_s)),
                   default=0)
    else:
        tenants = make_tenants(cell)
        sim = ConsolidationSim(cfg, horizon=cell.horizon_s, tenants=tenants,
                               policy=cell.policy)
        ws_requests = sum(len(s.demand.trace) for s in tenants
                          if s.kind == "latency")
        peak = sum(max((n for _, n in s.demand.demand_events(cell.horizon_s)),
                       default=0)
                   for s in tenants if s.kind == "latency")
    res = sim.run()

    latency_res = [t for t in res.tenants.values() if t.kind == "latency"]
    lats = [t.latency or {} for t in latency_res]
    slo_met = all(bool(lat.get("slo_met", False)) for lat in lats) \
        if lats else False

    def worst(key):     # headline latency metrics are worst-department
        return max((float(lat.get(key, 0.0)) for lat in lats), default=0.0)

    out = {k: getattr(cell, k) for k in AXIS_KEYS}
    out["cell_id"] = cell.cell_id()
    out["seed"] = cell.seed
    out["metrics"] = {
        "completed": res.completed,
        "killed": res.killed,
        "preemptions": res.preemptions,
        "avg_turnaround_s": res.avg_turnaround,
        "ws_p50_s": worst("p50_s"),
        "ws_p95_s": worst("p95_s"),
        "ws_p99_s": worst("p99_s"),
        "ws_violation_rate": worst("violation_rate"),
        "ws_unserved": sum(int(lat.get("unserved", 0)) for lat in lats),
        "ws_unmet_node_seconds": res.ws_unmet_node_seconds,
        "ws_peak_nodes": peak,
        "st_avg_alloc": res.st_avg_alloc,
        "ws_avg_alloc": res.ws_avg_alloc,
        "wall_s": time.time() - t0,
    }
    out["ws_requests"] = ws_requests
    out["slo_met"] = slo_met
    out["tenant_metrics"] = {
        name: {"kind": t.kind, "priority": t.priority,
               "avg_alloc": t.avg_alloc, **t.benefit}
        for name, t in res.tenants.items()}
    return out


def _run_cells(cells: Sequence[ScenarioCell], workers: int) -> List[Dict]:
    if workers > 1 and len(cells) > 1:
        try:
            from concurrent.futures import ProcessPoolExecutor
            with ProcessPoolExecutor(max_workers=workers) as pool:
                return list(pool.map(run_cell, cells))
        except (OSError, ImportError, BrokenProcessPool) as e:
            # no fork / restricted env / workers died on first submission
            print(f"[campaign] process pool unavailable ({e!r}); "
                  f"running serial", file=sys.stderr)
    return [run_cell(c) for c in cells]


def reduce_metrics(results: List[Dict]) -> Dict:
    """Numpy-batched reduction: stack all cells, marginalize per axis.

    Returns {"overall": {...}, "by_<axis>": {level: {...}}} with mean of
    every metric column — the campaign's answer to "which policy holds the
    SLO as the cluster shrinks" without re-reading per-cell rows.
    """
    if not results:
        return {}
    mat = np.array([[float(r["metrics"][k]) for k in METRIC_KEYS]
                    for r in results])                 # [cells, metrics]
    slo_met = np.array([r["slo_met"] for r in results], dtype=bool)

    def stats(mask: np.ndarray) -> Dict:
        sub = mat[mask]
        d = {k: float(v) for k, v in zip(METRIC_KEYS, sub.mean(axis=0))}
        d["cells"] = int(mask.sum())
        d["slo_met_rate"] = float(slo_met[mask].mean())
        return d

    red = {"overall": stats(np.ones(len(results), dtype=bool))}
    for axis in AXIS_KEYS:
        levels = sorted({r[axis] for r in results}, key=str)
        if len(levels) < 2:
            continue
        vals = np.array([str(r[axis]) for r in results])
        red[f"by_{axis}"] = {str(lv): stats(vals == str(lv))
                             for lv in levels}
    return red


def run_campaign(cells: Sequence[ScenarioCell], *, workers: int = 1,
                 out_path: Optional[str] = None,
                 grid_name: str = "custom") -> Dict:
    t0 = time.time()
    results = _run_cells(cells, workers)
    artifact = {
        "schema": "phoenix-campaign-v2",
        "grid": grid_name,
        "n_cells": len(results),
        "workers": workers,
        "wall_s": time.time() - t0,
        "metric_keys": list(METRIC_KEYS),
        "cells": results,
        "reductions": reduce_metrics(results),
    }
    if out_path:
        with open(out_path, "w") as f:
            json.dump(artifact, f, indent=1, default=float)
    return artifact


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--grid", default="tiny",
                    choices=["tiny", "small", "mix_tiny", "mix", "full"])
    ap.add_argument("--workers", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="campaign.json")
    args = ap.parse_args(argv)

    cells = make_grid(args.grid, seed=args.seed)
    art = run_campaign(cells, workers=args.workers, out_path=args.out,
                       grid_name=args.grid)
    ov = art["reductions"]["overall"]
    print(f"campaign grid={args.grid} cells={art['n_cells']} "
          f"wall={art['wall_s']:.1f}s -> {args.out}")
    print(f"  slo_met_rate={ov['slo_met_rate']:.2f}  "
          f"mean ws_p99={ov['ws_p99_s']:.1f}s  "
          f"mean violation_rate={ov['ws_violation_rate']:.4f}  "
          f"mean completed={ov['completed']:.1f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
