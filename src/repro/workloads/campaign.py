"""Vectorized, sharded, resumable scenario campaign runner.

Sweeps (policy x department-mix x arrival process x cluster size x SLO)
grids over the consolidation simulator: each cell runs the full Phoenix
pipeline — arrival trace -> SLO autoscaler -> ConsolidationSim under the
chosen cooperative policy and department mix -> realized request latency —
then per-cell metric vectors are stacked into numpy arrays for batched
reduction (marginal means over every axis). One JSON artifact comes out,
consumed by ``benchmarks/paper_figs.py`` and CI's smoke campaigns.

    PYTHONPATH=src python -m repro.workloads.campaign --grid tiny \
        --out campaign.json --workers 2
    PYTHONPATH=src python -m repro.workloads.campaign --grid mix_tiny

Sharded / resumable execution for the big grids (``full`` is ~4k cells):
every finished cell is streamed as one JSON line to a *spool* file, keyed
by a content hash of the entire ``ScenarioCell``; ``--resume`` skips cells
already spooled and the ``merge`` subcommand folds shard spools into the
final artifact (reductions are recomputed from the spooled rows, never
from in-memory state, so a merge of N shards is bit-identical to a
single-shot run):

    campaign --grid full --shard 0/8 --spool s0.jsonl   # one per host
    campaign --grid full --shard 1/8 --spool s1.jsonl --resume
    campaign merge --grid full --out full.json s*.jsonl

Department mixes (``--grid mix*``): ``paper2`` is the paper's 1 HPC + 1 WS
wiring (the degenerate case); ``2hpc2ws`` consolidates 2 HPC + 2
request-level WS departments; ``2hpc2ws1be`` adds a best-effort batch
tenant. Cells are independent; ``--workers N`` fans them out over
processes (fork), falling back to in-process execution if a pool cannot
start.

WS request queues (v6): cells run in chunks and each chunk's queues —
every tenant's realized allocation, constant and piecewise capacity alike
— flush as ONE shape-bucketed ``jit(vmap(scan))`` device dispatch
(``queue_impl='batched'``, float32, golden tolerance vs the exact paths;
the per-impl split lands in the artifact's ``throughput.queue_impls``).
``--queue-impl exact`` keeps the inline per-tenant float64 numpy sweep.
Batched metrics are composition-independent — bucket shapes are pure
per-cell functions — so chunking/sharding never changes a row.

Fault profiles (v7): ``--fault-profile`` / the ``fault_profile`` cell
axis injects node failures from ``core.faults.FAULT_PROFILES`` (``none``
keeps cells fault-free; ``independent`` | ``rack_corr`` | ``flapping``).
The fault stream is seeded independently of the policy/budget axes, so
robustness frontiers — completions and WS p99 vs fault severity, per
policy engine — are apples-to-apples across every other axis. The
``faults_tiny`` grid is mix_tiny x every profile.
"""
from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json
import os
import sys
import time
from concurrent.futures.process import BrokenProcessPool
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.faults import FAULT_PROFILES, get_fault_spec
from repro.core.policies import POLICIES
from repro.core.simulator import ConsolidationSim
from repro.core.telemetry import Tracer, summarize_events
from repro.core.traces import synthetic_sdsc_blue
from repro.core.types import SimConfig, SLOConfig, TenantSpec
from repro.serving.batching import ServiceTimeModel
from repro.workloads.arrivals import GENERATORS, make_trace
from repro.workloads.autoscaler import RequestWorkload
from repro.workloads.queueing import (QueueJob, SIM_COUNTERS, counters_delta,
                                      simulate_queue_batch,
                                      snapshot_counters)

SCHEMA = "phoenix-campaign-v7"

# cells dispatched per batched queue flush: every WS tenant queue from a
# chunk of sims rides one shape-bucketed device program (bigger chunks
# amortize better; smaller chunks keep spool streaming fine-grained)
QUEUE_CHUNK = 8

# department mixes: name -> (n_hpc, n_ws, n_best_effort)
MIXES: Dict[str, tuple] = {
    "paper2": (1, 1, 0),        # the paper's wiring (degenerate 2-tenant)
    "2hpc2ws": (2, 2, 0),
    "2hpc2ws1be": (2, 2, 1),
}


@dataclasses.dataclass(frozen=True)
class ScenarioCell:
    """One point of the campaign grid (fully picklable)."""
    preempt: str                 # kill | checkpoint
    scheduler: str               # first_fit | fcfs | easy_backfill
    arrival: str                 # key into workloads.arrivals.GENERATORS
    total_nodes: int
    slo_target_s: float
    rate_rps: float = 2.0        # mean WS arrival rate (split across WS depts)
    horizon_s: float = 7200.0
    n_jobs: int = 80             # total HPC jobs (split across HPC depts)
    st_max_nodes: int = 32       # batch-trace size calibration
    policy: str = "paper"        # key into core.policies.POLICIES
    mix: str = "paper2"          # key into MIXES
    # per-department market budget (tokens over the horizon); 0 = unlimited.
    # When set, latency departments bid slo_elastic (v5 market axis).
    budget: float = 0.0
    # WS request-queue backend (v6): "batched" defers every tenant queue to
    # the shape-bucketed jit(vmap(scan)) device cores (float32, golden
    # tolerance); "exact" keeps the inline per-tenant float64 numpy sweep.
    queue_impl: str = "batched"
    # fault-injection profile (v7): key into core.faults.FAULT_PROFILES;
    # "none" keeps the cell fault-free (the pre-v7 behaviour)
    fault_profile: str = "none"
    seed: int = 0

    def cell_id(self) -> str:
        """Human-readable id. Non-default load knobs are appended so custom
        grids varying them don't collide (the spool/resume key is the full
        content hash from ``cell_key`` regardless)."""
        base = (f"{self.preempt}-{self.scheduler}-{self.arrival}"
                f"-n{self.total_nodes}-slo{self.slo_target_s:g}"
                f"-s{self.seed}")
        if self.policy != "paper" or self.mix != "paper2":
            base += f"-{self.policy}-{self.mix}"
        defaults = {f.name: f.default for f in dataclasses.fields(self)}
        extra = [(tag, getattr(self, name))
                 for tag, name in (("r", "rate_rps"), ("h", "horizon_s"),
                                   ("j", "n_jobs"), ("x", "st_max_nodes"),
                                   ("b", "budget"), ("q", "queue_impl"),
                                   ("f", "fault_profile"))
                 if getattr(self, name) != defaults[name]]
        if extra:
            base += "".join(f"-{tag}{v:g}" if isinstance(v, float)
                            else f"-{tag}{v}" for tag, v in extra)
        return base

    def cell_key(self) -> str:
        """Content hash of every field AND the artifact schema — the
        spool/resume/cache key. Including the schema means spools written
        by an older row format can never be silently reused in a
        newer-schema artifact (their rows would lack the new columns)."""
        blob = json.dumps({"schema": SCHEMA, **dataclasses.asdict(self)},
                          sort_keys=True)
        return hashlib.sha256(blob.encode()).hexdigest()[:16]


# metric columns extracted per cell, in a fixed order so the reduction is
# one stacked [n_cells, n_metrics] array
METRIC_KEYS = ("completed", "killed", "preemptions", "avg_turnaround_s",
               "ws_p50_s", "ws_p95_s", "ws_p99_s", "ws_violation_rate",
               "ws_unserved", "ws_unmet_node_seconds", "ws_peak_nodes",
               "st_avg_alloc", "ws_avg_alloc", "queue_sim_s", "wall_s")
# the subset reductions marginalize over: deterministic simulation outcomes
# only, so a merge of shard spools is bit-identical to a single-shot run
# (timing lives per-cell and in the artifact's `throughput` section)
REDUCE_KEYS = tuple(k for k in METRIC_KEYS
                    if k not in ("queue_sim_s", "wall_s"))
# axes a reduction marginalizes over
AXIS_KEYS = ("preempt", "scheduler", "arrival", "total_nodes",
             "slo_target_s", "policy", "mix", "budget", "fault_profile")


def _policy_axis(policies: Optional[Sequence[str]],
                 default: Sequence[str]) -> List[str]:
    """Validate an explicit ``--policy`` subset against the registry."""
    if policies is None:
        return list(default)
    unknown = [p for p in policies if p not in POLICIES]
    if unknown:
        raise ValueError(f"unknown policies {unknown}; "
                         f"have {sorted(POLICIES)}")
    return list(policies)


def make_grid(name: str, seed: int = 0,
              policies: Optional[Sequence[str]] = None,
              budget: float = 0.0,
              queue_impl: Optional[str] = None,
              fault_profile: Optional[str] = None) -> List[ScenarioCell]:
    """Named grids. `tiny` is the CI smoke grid (8 cells, < 60 s serial);
    `mix_tiny` smokes the policy x department-mix matrix; `faults_tiny`
    crosses mix_tiny with every fault profile. ``policies`` overrides
    each grid's policy axis (CLI ``--policy a,b,c``); ``budget`` sets
    every cell's per-department market budget (CLI ``--budget``, 0 =
    unlimited); ``queue_impl`` overrides every cell's WS queue backend
    (CLI ``--queue-impl batched|exact``); ``fault_profile`` overrides
    every cell's fault-injection profile (CLI ``--fault-profile``, a key
    of ``core.faults.FAULT_PROFILES``)."""
    cells = _make_grid_cells(name, seed, policies)
    if budget:
        cells = [dataclasses.replace(c, budget=budget) for c in cells]
    if queue_impl is not None:
        if queue_impl not in ("batched", "exact"):
            raise ValueError(f"unknown queue_impl {queue_impl!r}; "
                             "have batched/exact")
        cells = [dataclasses.replace(c, queue_impl=queue_impl)
                 for c in cells]
    if fault_profile is not None:
        get_fault_spec(fault_profile)       # raises on unknown profile
        cells = [dataclasses.replace(c, fault_profile=fault_profile)
                 for c in cells]
    return cells


def _make_grid_cells(name: str, seed: int,
                     policies: Optional[Sequence[str]]) -> List[ScenarioCell]:
    if name == "tiny":
        pols = _policy_axis(policies, ["paper"])
        return [ScenarioCell(preempt=p, scheduler="first_fit", arrival=a,
                             total_nodes=n, slo_target_s=30.0, policy=pol,
                             seed=seed)
                for p in ("kill", "checkpoint")
                for a in ("poisson", "flash_crowd")
                for n in (48, 64)
                for pol in pols]
    if name == "small":
        pols = _policy_axis(policies, ["paper"])
        return [ScenarioCell(preempt=p, scheduler=s, arrival=a,
                             total_nodes=n, slo_target_s=slo, policy=pol,
                             seed=seed)
                for p in ("kill", "checkpoint")
                for s in ("first_fit", "easy_backfill")
                for a in ("poisson", "mmpp", "flash_crowd")
                for n in (48, 64)
                for slo in (30.0,)
                for pol in pols]
    if name == "mix_tiny":
        return [ScenarioCell(preempt="kill", scheduler="first_fit",
                             arrival="poisson", total_nodes=96,
                             slo_target_s=30.0, policy=pol, mix="2hpc2ws",
                             seed=seed)
                for pol in _policy_axis(policies, sorted(POLICIES))]
    if name == "faults_tiny":
        # robustness frontier: mix_tiny's policy axis x every fault
        # profile (the "none" column is the fault-free baseline)
        return [ScenarioCell(preempt="kill", scheduler="first_fit",
                             arrival="poisson", total_nodes=96,
                             slo_target_s=30.0, policy=pol, mix="2hpc2ws",
                             fault_profile=fp, seed=seed)
                for pol in _policy_axis(policies, sorted(POLICIES))
                for fp in sorted(FAULT_PROFILES)]
    if name == "mix":
        return [ScenarioCell(preempt=p, scheduler="first_fit",
                             arrival="flash_crowd", total_nodes=n,
                             slo_target_s=30.0, policy=pol, mix=m, seed=seed)
                for p in ("kill", "checkpoint")
                for pol in _policy_axis(policies, sorted(POLICIES))
                for m in ("2hpc2ws", "2hpc2ws1be")
                for n in (96, 128)]
    if name == "full":
        return [ScenarioCell(preempt=p, scheduler=s, arrival=a,
                             total_nodes=n, slo_target_s=slo,
                             horizon_s=14400.0, n_jobs=160, policy=pol,
                             mix=m, seed=seed)
                for p in ("kill", "checkpoint")
                for s in ("first_fit", "fcfs", "easy_backfill")
                for a in sorted(GENERATORS)
                for n in (40, 48, 64, 96)
                for slo in (20.0, 30.0, 60.0)
                for pol in _policy_axis(policies, sorted(POLICIES))
                for m in sorted(MIXES)]
    raise ValueError(f"unknown grid {name!r}; "
                     f"have tiny/small/mix_tiny/faults_tiny/mix/full")


def shard_cells(cells: Sequence[ScenarioCell],
                shard: Optional[str]) -> List[ScenarioCell]:
    """Deterministic round-robin partition: ``--shard i/N`` keeps cells at
    grid index i, i+N, i+2N, ... so every shard sees a representative slice
    of the axes (not a contiguous block of one policy)."""
    if not shard:
        return list(cells)
    try:
        idx_s, n_s = shard.split("/")
        idx, n = int(idx_s), int(n_s)
    except ValueError as e:
        raise ValueError(f"bad --shard {shard!r}; expected i/N") from e
    if not (n >= 1 and 0 <= idx < n):
        raise ValueError(f"bad --shard {shard!r}; need 0 <= i < N")
    return [c for j, c in enumerate(cells) if j % n == idx]


def make_tenants(cell: ScenarioCell) -> List[TenantSpec]:
    """Build the department mix for one cell: HPC departments split the job
    trace, WS departments split the request rate, an optional best-effort
    batch tenant rides at the lowest priority."""
    n_hpc, n_ws, n_be = MIXES[cell.mix]
    # market axis (v5): a finite budget makes every department pay for
    # nodes under the budget engines; latency departments then also bid
    # slo_elastic so urgency shapes the clearing prices
    budget = cell.budget if cell.budget > 0 else None
    bid_policy = "slo_elastic" if budget is not None else "linear"
    specs: List[TenantSpec] = []
    for i in range(n_ws):
        trace = make_trace(cell.arrival, cell.rate_rps / n_ws,
                           cell.horizon_s, cell.seed + 101 * i)
        specs.append(TenantSpec(
            f"ws-{i}", "latency", priority=i,
            budget=budget, bid_policy=bid_policy,
            slo=SLOConfig(latency_target_s=cell.slo_target_s),
            demand=RequestWorkload(
                trace=trace, model=ServiceTimeModel(),
                slo=SLOConfig(latency_target_s=cell.slo_target_s))))
    for i in range(n_hpc):
        jobs = synthetic_sdsc_blue(seed=cell.seed + 31 * i,
                                   n_jobs=max(1, cell.n_jobs // n_hpc),
                                   horizon=cell.horizon_s,
                                   max_nodes=cell.st_max_nodes)
        specs.append(TenantSpec(
            f"hpc-{i}", "batch", priority=n_ws + i,
            weight=float(n_hpc - i), budget=budget, jobs=jobs))
    for i in range(n_be):
        jobs = synthetic_sdsc_blue(seed=cell.seed + 997 + i,
                                   n_jobs=max(1, cell.n_jobs // 4),
                                   horizon=cell.horizon_s,
                                   max_nodes=max(4, cell.st_max_nodes // 4))
        specs.append(TenantSpec(
            f"be-{i}", "batch", priority=100 + i, weight=0.5,
            budget=budget, jobs=jobs))
    return specs


class _PendingCell:
    """A cell whose consolidation sim has run but whose WS request queues
    are still waiting for the chunk's batched device dispatch."""

    __slots__ = ("cell", "tracer", "res", "names", "jobs", "ws_requests",
                 "peak", "queue_acct", "wall_start_s")

    def __init__(self, cell, tracer, res, names, jobs, ws_requests, peak,
                 queue_acct, wall_start_s):
        self.cell = cell
        self.tracer = tracer
        self.res = res
        self.names = names          # tenant name per deferred job
        self.jobs = jobs            # List[QueueJob], same order
        self.ws_requests = ws_requests
        self.peak = peak
        self.queue_acct = queue_acct    # counters delta of the start phase
        self.wall_start_s = wall_start_s


def _cell_start(cell: ScenarioCell,
                trace_dir: Optional[str] = None) -> _PendingCell:
    """Run one scenario's consolidation sim, deferring the WS request-queue
    sims (``queue_impl='batched'``) so a chunk of cells can flush them as
    one shape-bucketed device program."""
    t0 = time.time()
    q0 = snapshot_counters()
    defer = cell.queue_impl == "batched"
    tracer = None
    if trace_dir is not None:
        tracer = Tracer(meta={"cell_id": cell.cell_id(),
                              "cell_key": cell.cell_key(),
                              "schema": SCHEMA})
    if tracer is not None and cell.fault_profile != "none":
        tracer.meta["fault_profile"] = cell.fault_profile
    cfg = SimConfig(total_nodes=cell.total_nodes,
                    preempt_mode=cell.preempt,
                    scheduler=cell.scheduler, seed=cell.seed,
                    faults=get_fault_spec(cell.fault_profile))
    if cell.mix == "paper2" and cell.policy == "paper":
        # the degenerate 2-tenant path (bit-identical to the seed pipeline)
        jobs = synthetic_sdsc_blue(seed=cell.seed, n_jobs=cell.n_jobs,
                                   horizon=cell.horizon_s,
                                   max_nodes=cell.st_max_nodes)
        trace = make_trace(cell.arrival, cell.rate_rps, cell.horizon_s,
                           cell.seed)
        workload = RequestWorkload(
            trace=trace, model=ServiceTimeModel(),
            slo=SLOConfig(latency_target_s=cell.slo_target_s))
        sim = ConsolidationSim(cfg, jobs, workload, horizon=cell.horizon_s,
                               tracer=tracer, defer_queue=defer)
        ws_requests = len(trace)
        peak = max((n for _, n in workload.demand_events(cell.horizon_s)),
                   default=0)
    else:
        tenants = make_tenants(cell)
        sim = ConsolidationSim(cfg, horizon=cell.horizon_s, tenants=tenants,
                               policy=cell.policy, tracer=tracer,
                               defer_queue=defer)
        ws_requests = sum(len(s.demand.trace) for s in tenants
                          if s.kind == "latency")
        peak = sum(max((n for _, n in s.demand.demand_events(cell.horizon_s)),
                       default=0)
                   for s in tenants if s.kind == "latency")
    res = sim.run()

    names: List[str] = []
    qjobs: List[QueueJob] = []
    for name, provider, alloc_events in sim.deferred_queue:
        if not all(hasattr(provider, a) for a in ("trace", "model", "slo")):
            # unknown provider: honor the deferral contract inline
            res.tenants[name].latency = provider.realized_metrics(
                alloc_events, horizon=cell.horizon_s)
            continue
        names.append(name)
        qjobs.append(QueueJob(trace=provider.trace,
                              capacity_events=tuple(alloc_events),
                              model=provider.model, slo=provider.slo,
                              horizon=cell.horizon_s))
    return _PendingCell(cell, tracer, res, names, qjobs, ws_requests, peak,
                        counters_delta(q0), time.time() - t0)


def _cell_finish(p: _PendingCell, metrics: Sequence, tags: Sequence[str],
                 queue_wall_s: float,
                 trace_dir: Optional[str] = None) -> Dict:
    """Attach the batch results for a pending cell's deferred queue jobs
    (metrics/tags/queue_wall_s cover exactly ``p.jobs``) and build its row."""
    cell, res = p.cell, p.res
    for name, m in zip(p.names, metrics):
        res.tenants[name].latency = m.as_dict()

    latency_res = [t for t in res.tenants.values() if t.kind == "latency"]
    lats = [t.latency or {} for t in latency_res]
    slo_met = all(bool(lat.get("slo_met", False)) for lat in lats) \
        if lats else False

    def worst(key):     # headline latency metrics are worst-department
        return max((float(lat.get(key, 0.0)) for lat in lats), default=0.0)

    # queue accounting: inline sims from the start phase (counter deltas)
    # plus this cell's share of the chunk's batched dispatch
    qd = p.queue_acct
    q_calls = int(qd["calls"]) + len(p.jobs)
    q_requests = int(qd["requests"]) + sum(len(j.trace) for j in p.jobs)
    q_seconds = float(qd["seconds"]) + queue_wall_s
    impls = {k: int(qd[k]) for k in SIM_COUNTERS
             if k not in ("calls", "requests", "seconds") and qd[k]}
    for tag in tags:
        impls[tag] = impls.get(tag, 0) + 1
    wall_s = p.wall_start_s + queue_wall_s

    out = {k: getattr(cell, k) for k in AXIS_KEYS}
    out["cell_id"] = cell.cell_id()
    out["cell_key"] = cell.cell_key()
    out["seed"] = cell.seed
    out["queue_impl"] = cell.queue_impl
    out["metrics"] = {
        "completed": res.completed,
        "killed": res.killed,
        "preemptions": res.preemptions,
        "avg_turnaround_s": res.avg_turnaround,
        "ws_p50_s": worst("p50_s"),
        "ws_p95_s": worst("p95_s"),
        "ws_p99_s": worst("p99_s"),
        "ws_violation_rate": worst("violation_rate"),
        "ws_unserved": sum(int(lat.get("unserved", 0)) for lat in lats),
        "ws_unmet_node_seconds": res.ws_unmet_node_seconds,
        "ws_peak_nodes": p.peak,
        "st_avg_alloc": res.st_avg_alloc,
        "ws_avg_alloc": res.ws_avg_alloc,
        "queue_sim_s": q_seconds,
        "wall_s": wall_s,
    }
    out["ws_requests"] = p.ws_requests
    out["slo_met"] = slo_met
    out["queue_sim"] = {"calls": q_calls,
                        "requests": q_requests,
                        "seconds": q_seconds,
                        "impls": impls}
    out["tenant_metrics"] = {
        name: {"kind": t.kind, "priority": t.priority,
               "avg_alloc": t.avg_alloc,
               "reclaimed_events": t.reclaimed_events,
               "reclaimed_nodes": t.reclaimed_nodes,
               "last_bid": t.last_bid,
               "spend": t.spend,
               "budget_remaining": t.budget_remaining, **t.benefit}
        for name, t in res.tenants.items()}
    # v4+: per-cell engine state — reclaim orderings taken and (auction)
    # clearing prices; v5 adds the market ledger (budgets, remaining,
    # spend, clearing prices) for the budget engines
    out["policy_state"] = res.policy_state
    if p.tracer is not None:
        # optional keys only — absent with tracing off, excluded from
        # REDUCE_KEYS, so reductions and untraced artifacts are unchanged
        # filename is cell_key — the collision-proof spool/resume/merge
        # identity — matching the documented contract; the human-readable
        # cell_id stays available in the tracer header meta
        trace_file = os.path.join(trace_dir,
                                  f"{cell.cell_key()}.trace.jsonl")
        p.tracer.to_jsonl(trace_file)
        out["trace_file"] = trace_file
        out["trace_summary"] = summarize_events(
            [p.tracer.header()] + p.tracer.events)
    return out


def _flush_pending(pending: Sequence[_PendingCell],
                   trace_dir: Optional[str] = None) -> List[Dict]:
    """Dispatch every pending cell's deferred queue jobs as ONE batched
    call, then finish all rows. The batch wall clock is apportioned to
    cells by their request share (timing is reporting-only — it never
    enters reductions, which stay independent of chunking)."""
    all_jobs: List[QueueJob] = []
    for p in pending:
        all_jobs.extend(p.jobs)
    tags: List[str] = []
    t0 = time.time()
    metrics = simulate_queue_batch(all_jobs, stats_out=tags) \
        if all_jobs else []
    queue_wall = time.time() - t0
    total_req = sum(len(j.trace) for j in all_jobs) or 1
    rows: List[Dict] = []
    off = 0
    for p in pending:
        k = len(p.jobs)
        share = queue_wall * sum(len(j.trace) for j in p.jobs) / total_req
        rows.append(_cell_finish(p, metrics[off:off + k],
                                 tags[off:off + k], share, trace_dir))
        off += k
    return rows


def run_cell(cell: ScenarioCell, trace_dir: Optional[str] = None) -> Dict:
    """Run one scenario end-to-end; returns axes + metrics as a flat dict.

    ``trace_dir`` (the runner's ``--trace``) enables control-plane
    telemetry for the cell: the full causal trace is spooled to
    ``<trace_dir>/<cell_key>.trace.jsonl`` (the collision-proof content
    hash; the human-readable cell_id is in the trace header's meta) and
    a compact summary
    (reclaim-latency p50/p99, SLO-violation durations, spend attribution)
    is folded into the row under ``trace_summary``. Tracing is a RUNNER
    flag, not a cell field: cell_key — the spool/resume/merge identity —
    is unchanged, and with tracing off the row is bit-identical to an
    untraced run.

    Equivalent to ``run_cell_chunk([cell])[0]``: the batched queue path is
    composition-independent (bucket shapes are pure per-cell functions of
    n; e/k padding is value-invariant), so a cell's metrics are bitwise
    the same whether its queues flush alone or with a chunk.
    """
    return _flush_pending([_cell_start(cell, trace_dir)], trace_dir)[0]


def run_cell_chunk(cells: Sequence[ScenarioCell],
                   trace_dir: Optional[str] = None) -> List[Dict]:
    """Run a chunk of cells, flushing all their WS request queues as one
    batched device dispatch. Row order matches ``cells``."""
    pending = [_cell_start(c, trace_dir) for c in cells]
    return _flush_pending(pending, trace_dir)


# ------------------------------------------------------------- spooling


def spool_append(path: str, row: Dict) -> None:
    """Append one finished cell to the JSONL spool (crash-durable: each
    line is self-contained and keyed by the cell's content hash)."""
    with open(path, "a") as f:
        f.write(json.dumps(row, default=float) + "\n")
        f.flush()


def spool_load(path: str) -> Dict[str, Dict]:
    """Load spooled rows keyed by cell_key; later duplicates win, truncated
    trailing lines (killed mid-write) are skipped."""
    rows: Dict[str, Dict] = {}
    if not os.path.exists(path):
        return rows
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                row = json.loads(line)
            except json.JSONDecodeError:
                continue                        # torn write at kill time
            key = row.get("cell_key")
            if key:
                rows[key] = row
    return rows


# ------------------------------------------------------------ reduction


def reduce_metrics(results: List[Dict]) -> Dict:
    """Numpy-batched reduction: stack all cells, marginalize per axis.

    Returns {"overall": {...}, "by_<axis>": {level: {...}}} with the
    finite-masked mean of every metric column — a single cell with
    unserved requests has inf percentiles, which must not poison every
    marginal mean containing it — plus an explicit ``inf_rate`` column
    (fraction of cells with any non-finite metric). Rows are re-ordered by
    cell_key before stacking so shard merges reduce bit-identically to
    single-shot runs regardless of completion order.
    """
    if not results:
        return {}
    results = sorted(results,
                     key=lambda r: r.get("cell_key", r.get("cell_id", "")))
    mat = np.array([[float(r["metrics"][k]) for k in REDUCE_KEYS]
                    for r in results])                 # [cells, metrics]
    slo_met = np.array([r["slo_met"] for r in results], dtype=bool)
    finite = np.isfinite(mat)

    def stats(mask: np.ndarray) -> Dict:
        sub = mat[mask]
        fin = finite[mask]
        cnt = fin.sum(axis=0)
        sums = np.where(fin, sub, 0.0).sum(axis=0)
        means = np.where(cnt > 0, sums / np.maximum(cnt, 1), np.inf)
        d = {k: float(v) for k, v in zip(REDUCE_KEYS, means)}
        d["cells"] = int(mask.sum())
        d["slo_met_rate"] = float(slo_met[mask].mean())
        d["inf_rate"] = float((~fin.all(axis=1)).mean())
        return d

    red = {"overall": stats(np.ones(len(results), dtype=bool))}
    for axis in AXIS_KEYS:
        # .get(): hand-built rows may predate a newly added axis column —
        # a single (absent) level is skipped like any non-varying axis
        levels = sorted({r.get(axis) for r in results}, key=str)
        if len(levels) < 2:
            continue
        vals = np.array([str(r.get(axis)) for r in results])
        red[f"by_{axis}"] = {str(lv): stats(vals == str(lv))
                             for lv in levels}
    return red


def _throughput(rows: Sequence[Dict], executed: int, skipped: int,
                run_wall: float) -> Dict:
    """Cells/sec + queue-sim requests/sec over the rows' own accounting
    (works identically for live runs and spool merges). ``queue_impls``
    counts queue-sim calls per implementation (v6), so BENCH numbers say
    which path — ``jax_batched`` device cores vs the numpy sweeps —
    actually served the campaign's queues."""
    q_req = sum(int(r.get("queue_sim", {}).get("requests", 0)) for r in rows)
    q_s = sum(float(r.get("queue_sim", {}).get("seconds", 0.0))
              for r in rows)
    cell_s = sum(float(r["metrics"].get("wall_s", 0.0)) for r in rows)
    impls: Dict[str, int] = {}
    for r in rows:
        for k, v in r.get("queue_sim", {}).get("impls", {}).items():
            impls[k] = impls.get(k, 0) + int(v)
    return {
        "executed": executed,
        "skipped": skipped,
        "run_wall_s": run_wall,
        "cells_per_s": executed / run_wall if run_wall > 0 else 0.0,
        "serial_cells_per_s": len(rows) / cell_s if cell_s > 0 else 0.0,
        "queue_requests": q_req,
        "queue_sim_s": q_s,
        "queue_requests_per_s": q_req / q_s if q_s > 0 else 0.0,
        "queue_impls": impls,
    }


# ------------------------------------------------------------ execution


def _run_cells_streaming(cells: Sequence[ScenarioCell], workers: int,
                         spool_path: Optional[str],
                         trace_dir: Optional[str] = None) -> List[Dict]:
    """Run cells in QUEUE_CHUNK-sized chunks — each chunk flushes all its
    WS request queues as one batched device dispatch — appending each
    finished row to the spool immediately so an interrupted run loses at
    most the in-flight chunk."""
    rows: List[Dict] = []

    def emit(chunk_rows: Sequence[Dict]) -> None:
        for row in chunk_rows:
            rows.append(row)
            if spool_path:
                spool_append(spool_path, row)

    chunks = [list(cells[i:i + QUEUE_CHUNK])
              for i in range(0, len(cells), QUEUE_CHUNK)]
    if workers > 1 and len(chunks) > 1:
        try:
            from concurrent.futures import (ProcessPoolExecutor,
                                            as_completed)
            with ProcessPoolExecutor(max_workers=workers) as pool:
                futs = {pool.submit(run_cell_chunk, ch, trace_dir): ch
                        for ch in chunks}
                for fut in as_completed(futs):
                    emit(fut.result())
            return rows
        except (OSError, ImportError, BrokenProcessPool) as e:
            # no fork / restricted env / workers died on first submission
            print(f"[campaign] process pool unavailable ({e!r}); "
                  f"running serial", file=sys.stderr)
            rows = []
    for ch in chunks:
        emit(run_cell_chunk(ch, trace_dir))
    return rows


def _assemble(rows_by_key: Dict[str, Dict],
              ordered_keys: Sequence[str]) -> List[Dict]:
    return [rows_by_key[k] for k in ordered_keys if k in rows_by_key]


def run_campaign(cells: Sequence[ScenarioCell], *, workers: int = 1,
                 out_path: Optional[str] = None,
                 grid_name: str = "custom",
                 spool_path: Optional[str] = None,
                 resume: bool = False,
                 shard: Optional[str] = None,
                 trace_dir: Optional[str] = None) -> Dict:
    """Run (a shard of) a campaign grid, optionally resuming from a spool.

    The artifact's ``cells`` keep the grid order and its ``reductions``
    are order-independent, so sharded spools merged later reproduce a
    single-shot artifact's reductions exactly. ``trace_dir`` enables
    per-cell control-plane traces (see ``run_cell``); it changes neither
    cell keys nor any reduced column, so traced and untraced runs of the
    same grid stay merge-compatible. A traced ``--resume`` re-runs any
    spooled cell whose ``<cell_key>.trace.jsonl`` is missing from
    ``trace_dir`` — a cell spooled by an earlier UNTRACED run would
    otherwise be skipped, leaving the trace set silently incomplete and
    the artifact with a mix of rows with/without ``trace_summary``.
    """
    t0 = time.time()
    if trace_dir is not None:
        os.makedirs(trace_dir, exist_ok=True)
    cells = shard_cells(cells, shard)
    keys = [c.cell_key() for c in cells]
    done: Dict[str, Dict] = {}
    if resume and spool_path:
        spooled = spool_load(spool_path)
        done = {k: spooled[k] for k in keys if k in spooled}
        if trace_dir is not None:
            untraced = [k for k in done if not os.path.exists(
                os.path.join(trace_dir, f"{k}.trace.jsonl"))]
            for k in untraced:
                del done[k]
            if untraced:
                print(f"resume: re-running {len(untraced)} spooled "
                      f"cell(s) with no trace in {trace_dir}",
                      file=sys.stderr)
    todo = [c for c, k in zip(cells, keys) if k not in done]
    new_rows = _run_cells_streaming(todo, workers, spool_path, trace_dir)
    by_key = dict(done)
    by_key.update({r["cell_key"]: r for r in new_rows})
    results = _assemble(by_key, keys)
    wall = time.time() - t0
    artifact = {
        "schema": SCHEMA,
        "grid": grid_name,
        "shard": shard,
        "n_cells": len(results),
        "workers": workers,
        "wall_s": wall,
        "metric_keys": list(METRIC_KEYS),
        "throughput": _throughput(results, executed=len(new_rows),
                                  skipped=len(done), run_wall=wall),
        "cells": results,
        "reductions": reduce_metrics(results),
    }
    if out_path:
        with open(out_path, "w") as f:
            json.dump(artifact, f, indent=1, default=float)
    return artifact


def merge_spools(spool_paths: Sequence[str],
                 grid_cells: Optional[Sequence[ScenarioCell]] = None,
                 grid_name: str = "merged"
                 ) -> Tuple[Dict, List[str]]:
    """Fold shard spools into one artifact; reductions are recomputed from
    the spooled rows. Returns (artifact, missing_cell_ids): when
    ``grid_cells`` is given, rows are ordered by the grid and cells absent
    from every spool are reported (their ids) instead of silently dropped.
    """
    by_key: Dict[str, Dict] = {}
    for p in spool_paths:
        by_key.update(spool_load(p))
    missing: List[str] = []
    if grid_cells is not None:
        keys = [c.cell_key() for c in grid_cells]
        missing = [c.cell_id() for c, k in zip(grid_cells, keys)
                   if k not in by_key]
        results = _assemble(by_key, keys)
    else:
        results = [by_key[k] for k in sorted(by_key)]
    cell_wall = sum(float(r["metrics"].get("wall_s", 0.0)) for r in results)
    artifact = {
        "schema": SCHEMA,
        "grid": grid_name,
        "shard": None,
        "n_cells": len(results),
        "workers": 0,
        "wall_s": cell_wall,
        "metric_keys": list(METRIC_KEYS),
        "throughput": _throughput(results, executed=len(results), skipped=0,
                                  run_wall=cell_wall),
        "cells": results,
        "reductions": reduce_metrics(results),
    }
    return artifact, missing


# ------------------------------------------------------------------ CLI


def _print_summary(art: Dict, out: str) -> None:
    ov = art["reductions"].get("overall", {})
    tp = art.get("throughput", {})
    print(f"campaign grid={art['grid']} cells={art['n_cells']} "
          f"wall={art['wall_s']:.1f}s -> {out}")
    if ov:
        print(f"  slo_met_rate={ov['slo_met_rate']:.2f}  "
              f"mean ws_p99={ov['ws_p99_s']:.1f}s  "
              f"mean violation_rate={ov['ws_violation_rate']:.4f}  "
              f"mean completed={ov['completed']:.1f}  "
              f"inf_rate={ov.get('inf_rate', 0.0):.3f}")
    if tp:
        print(f"  executed={tp['executed']} skipped={tp['skipped']}  "
              f"cells/s={tp['cells_per_s']:.2f}  "
              f"queue req/s={tp['queue_requests_per_s']:.0f}")


def _main_run(argv) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--grid", default="tiny",
                    choices=["tiny", "small", "mix_tiny", "faults_tiny",
                             "mix", "full"])
    ap.add_argument("--policy", default=None, metavar="P1,P2,...",
                    help="override the grid's policy axis with this "
                         f"comma-separated subset of {sorted(POLICIES)}")
    ap.add_argument("--budget", type=float, default=0.0,
                    help="per-department market budget (tokens over the "
                         "horizon) for the budget engines; 0 = unlimited")
    ap.add_argument("--queue-impl", default=None,
                    choices=["batched", "exact"],
                    help="WS request-queue backend: 'batched' (default) "
                         "flushes each chunk's queues through the jit(vmap"
                         "(scan)) device cores; 'exact' keeps the inline "
                         "float64 numpy sweep per tenant")
    ap.add_argument("--fault-profile", default=None,
                    choices=sorted(FAULT_PROFILES),
                    help="override every cell's fault-injection profile "
                         "(core.faults.FAULT_PROFILES); 'none' = fault-"
                         "free (default for all grids except faults_tiny)")
    ap.add_argument("--workers", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="campaign.json")
    ap.add_argument("--shard", default=None, metavar="i/N",
                    help="run only cells with grid_index %% N == i")
    ap.add_argument("--spool", default=None,
                    help="JSONL spool path (default derived from --out "
                         "when --shard/--resume is used)")
    ap.add_argument("--resume", action="store_true",
                    help="skip cells already present in the spool")
    ap.add_argument("--trace", nargs="?", const="", default=None,
                    metavar="DIR",
                    help="spool a control-plane trace per cell (JSONL, "
                         "analyzable with `python -m repro.trace`) into "
                         "DIR (default: <out>.traces/) and fold a "
                         "trace_summary into each row")
    args = ap.parse_args(argv)

    spool = args.spool
    if spool is None and (args.shard or args.resume):
        tag = f".shard{args.shard.replace('/', 'of')}" if args.shard else ""
        spool = f"{args.out}{tag}.spool.jsonl"

    trace_dir = None
    if args.trace is not None:
        trace_dir = args.trace or f"{args.out}.traces"

    policies = args.policy.split(",") if args.policy else None
    cells = make_grid(args.grid, seed=args.seed, policies=policies,
                      budget=args.budget, queue_impl=args.queue_impl,
                      fault_profile=args.fault_profile)
    art = run_campaign(cells, workers=args.workers, out_path=args.out,
                       grid_name=args.grid, spool_path=spool,
                       resume=args.resume, shard=args.shard,
                       trace_dir=trace_dir)
    _print_summary(art, args.out)
    if trace_dir is not None:
        print(f"  traces -> {trace_dir}/")
    return 0


def _main_merge(argv) -> int:
    ap = argparse.ArgumentParser(
        prog="campaign merge",
        description="Fold shard spools into one campaign artifact")
    ap.add_argument("spools", nargs="+", help="JSONL spool files")
    ap.add_argument("--out", default="campaign.json")
    ap.add_argument("--grid", default=None,
                    choices=["tiny", "small", "mix_tiny", "faults_tiny",
                             "mix", "full"],
                    help="order/verify rows against this named grid")
    ap.add_argument("--policy", default=None, metavar="P1,P2,...",
                    help="the --policy subset the shards ran with")
    ap.add_argument("--budget", type=float, default=0.0,
                    help="the --budget the shards ran with")
    ap.add_argument("--queue-impl", default=None,
                    choices=["batched", "exact"],
                    help="the --queue-impl the shards ran with")
    ap.add_argument("--fault-profile", default=None,
                    choices=sorted(FAULT_PROFILES),
                    help="the --fault-profile the shards ran with")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--allow-partial", action="store_true",
                    help="merge even if grid cells are missing")
    args = ap.parse_args(argv)

    policies = args.policy.split(",") if args.policy else None
    grid_cells = make_grid(args.grid, seed=args.seed, policies=policies,
                           budget=args.budget,
                           queue_impl=args.queue_impl,
                           fault_profile=args.fault_profile) \
        if args.grid else None
    art, missing = merge_spools(args.spools, grid_cells=grid_cells,
                                grid_name=args.grid or "merged")
    if missing:
        print(f"[merge] {len(missing)} grid cells missing from spools: "
              + ", ".join(missing[:5])
              + (" ..." if len(missing) > 5 else ""), file=sys.stderr)
        if not args.allow_partial:
            return 2
    with open(args.out, "w") as f:
        json.dump(art, f, indent=1, default=float)
    _print_summary(art, args.out)
    return 0


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if argv and argv[0] == "merge":
        return _main_merge(argv[1:])
    return _main_run(argv)


if __name__ == "__main__":
    sys.exit(main())
