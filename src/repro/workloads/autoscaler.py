"""SLO-aware autoscaling: latency targets -> node demand.

The paper's §III-C rule scales on a utilization threshold; it knows nothing
about latency. ``SLOAutoscaler`` replaces it for request-level workloads:
per control window it estimates the arrival rate and the service-time
distribution (from token counts via ``ServiceTimeModel``), then picks the
smallest replica count whose *predicted* latency percentile (Sakasegawa
G/G/k wait + exponential tail) meets the SLO, with square-root-staffing
headroom and scale-down hysteresis so the demand curve doesn't flap.

``RequestWorkload`` packages a trace + model + SLO into the
``WSDemandProvider`` protocol consumed by ``ConsolidationSim`` and
``PhoenixOrchestrator``: planned demand events in, realized latency metrics
out.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.types import SLOConfig
from repro.core.ws_cms import demand_events
from repro.serving.batching import ServiceTimeModel
from repro.workloads.arrivals import RequestTrace
from repro.workloads.queueing import (QueueMetrics,
                                      predicted_percentile_latency,
                                      simulate_queue)


class SLOAutoscaler:
    """Converts a latency SLO into per-window node demand."""

    def __init__(self, model: ServiceTimeModel, slo: SLOConfig, *,
                 window_s: float = 60.0,
                 n_min: int = 1, n_max: int = 10_000,
                 headroom: float = 0.5,
                 scale_down_margin: float = 0.8):
        self.model = model
        self.slo = slo
        self.window_s = window_s
        self.n_min = n_min
        self.n_max = n_max
        # square-root staffing: k_slots >= offered + headroom*sqrt(offered)
        self.headroom = headroom
        # only scale down if the smaller size would still meet the target
        # at `scale_down_margin` of it (hysteresis)
        self.scale_down_margin = scale_down_margin

    # ------------------------------------------------------------ per-window
    def predicted_latency_s(self, rate_rps: float, mean_s: float,
                            scv_s: float, p99_service_s: float,
                            n: int) -> float:
        """Predicted SLO-percentile latency at ``n`` nodes for this load —
        the runtime orchestrator feeds ``target - predicted`` into the
        ``TenantSignals`` latency-headroom channel each control interval."""
        if rate_rps <= 0 or mean_s <= 0:
            return 0.0
        return float(predicted_percentile_latency(
            rate_rps, mean_s, scv_s, p99_service_s,
            max(1, n) * self.model.slots_per_replica, self.slo.percentile))

    def desired_nodes(self, rate_rps: float, mean_s: float, scv_s: float,
                      p99_service_s: float, current: int = 0) -> int:
        """Smallest node count meeting the SLO at the given offered load."""
        slots = self.model.slots_per_replica
        offered = rate_rps * mean_s                       # slots of work
        if offered <= 0:
            return self.n_min
        k_floor = offered + self.headroom * np.sqrt(offered)
        n_base = max(self.n_min, int(np.ceil(k_floor / slots)))
        if p99_service_s >= self.slo.latency_target_s:
            # SLO infeasible at any scale (service alone exceeds the
            # target): provision for near-zero queueing and let the
            # violation rate report the miss
            return min(self.n_max, int(np.ceil(n_base * 1.3)))

        def ok(n: int) -> bool:
            return predicted_percentile_latency(
                rate_rps, mean_s, scv_s, p99_service_s, n * slots,
                self.slo.percentile) <= self.slo.latency_target_s

        # geometric expansion + binary search for the smallest feasible n
        lo, hi = n_base, n_base
        while hi < self.n_max and not ok(hi):
            lo, hi = hi + 1, min(self.n_max, hi * 2)
        while lo < hi:
            mid = (lo + hi) // 2
            if ok(mid):
                hi = mid
            else:
                lo = mid + 1
        n = lo
        if current > n:
            # hysteresis: keep the larger size unless the smaller one has
            # comfortable margin
            lat = predicted_percentile_latency(
                rate_rps, mean_s, scv_s, p99_service_s, n * slots,
                self.slo.percentile)
            if lat > self.scale_down_margin * self.slo.latency_target_s:
                n = min(current, n + 1)
        return n

    # ------------------------------------------------------------ full plan
    def plan(self, trace: RequestTrace, horizon: float) -> np.ndarray:
        """Node demand sampled every window_s over [0, horizon)."""
        n_win = max(1, int(np.ceil(horizon / self.window_s)))
        edges = np.arange(n_win + 1) * self.window_s
        counts, _ = np.histogram(trace.t, bins=edges)
        svc = self.model.service_times(trace.prompt_tokens,
                                       trace.decode_tokens)
        # global service-shape statistics (windows share the token mix);
        # rates vary per window
        mean_s = float(svc.mean()) if len(svc) else 0.0
        var_s = float(svc.var()) if len(svc) else 0.0
        scv_s = var_s / (mean_s ** 2) if mean_s > 0 else 0.0
        p99_s = float(np.percentile(svc, 99)) if len(svc) else 0.0

        out = np.empty(n_win, dtype=np.int64)
        cur = self.n_min
        for w in range(n_win):
            rate = counts[w] / self.window_s
            cur = self.desired_nodes(rate, mean_s, scv_s, p99_s, cur)
            out[w] = cur
        return out

    def plan_events(self, trace: RequestTrace, horizon: float
                    ) -> List[Tuple[float, int]]:
        return demand_events(self.plan(trace, horizon), self.window_s)


@dataclasses.dataclass
class RequestWorkload:
    """WSDemandProvider backed by a request trace + SLO autoscaler.

    This object replaces the raw ``ws_demand`` timeseries: the simulator
    asks it for planned demand events, runs the consolidation policies, and
    hands back the realized WS allocation so request latency can be
    measured against what was actually granted.
    """
    trace: RequestTrace
    model: ServiceTimeModel
    slo: SLOConfig
    autoscaler: Optional[SLOAutoscaler] = None
    horizon: Optional[float] = None
    planned: Optional[List[Tuple[float, int]]] = None

    def __post_init__(self):
        if self.autoscaler is None:
            self.autoscaler = SLOAutoscaler(self.model, self.slo)

    # ------------------------------------------------- WSDemandProvider API
    def demand_events(self, horizon: float) -> List[Tuple[float, int]]:
        if self.planned is None or self.horizon != horizon:
            self.horizon = horizon
            self.planned = self.autoscaler.plan_events(self.trace, horizon)
        return self.planned

    def realized_metrics(self, alloc_events: Sequence[Tuple[float, int]],
                         horizon: Optional[float] = None
                         ) -> Dict[str, float]:
        """Latency under the allocation the cluster actually granted."""
        m = simulate_queue(self.trace, alloc_events, self.model, self.slo,
                           horizon=horizon)
        return m.as_dict()

    def planned_metrics(self, horizon: float) -> Dict[str, float]:
        """Latency if the planned demand were always granted in full."""
        ev = self.demand_events(horizon)
        m = simulate_queue(self.trace, ev, self.model, self.slo,
                           horizon=horizon)
        return m.as_dict()

    def peak_nodes(self, horizon: float) -> int:
        ev = self.demand_events(horizon)
        return max((n for _, n in ev), default=0)
