"""Request-level WS workload subsystem.

Layers: arrival processes (``arrivals``) -> replica queue + SLO metrics
(``queueing``) -> SLO-aware autoscaling / demand provider (``autoscaler``)
-> scenario campaign runner (``campaign``).
"""
from repro.workloads.arrivals import (GENERATORS, RequestTrace,
                                      burstiness_index, diurnal_arrivals,
                                      flash_crowd_arrivals, make_trace,
                                      mmpp_arrivals, poisson_arrivals)
from repro.workloads.autoscaler import RequestWorkload, SLOAutoscaler
from repro.workloads.queueing import (QueueJob, QueueMetrics,
                                      capacity_steps, plan_queue_buckets,
                                      predicted_percentile_latency,
                                      sakasegawa_wait, simulate_queue,
                                      simulate_queue_batch,
                                      simulate_queue_many,
                                      simulate_queue_reference)

__all__ = [
    "GENERATORS", "RequestTrace", "burstiness_index", "diurnal_arrivals",
    "flash_crowd_arrivals", "make_trace", "mmpp_arrivals",
    "poisson_arrivals", "RequestWorkload", "SLOAutoscaler", "QueueJob",
    "QueueMetrics", "capacity_steps", "plan_queue_buckets",
    "predicted_percentile_latency", "sakasegawa_wait", "simulate_queue",
    "simulate_queue_batch", "simulate_queue_many",
    "simulate_queue_reference",
]
