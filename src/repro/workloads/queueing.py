"""M/G/k-style replica queue with continuous-batching service times.

Each WS node runs one serving replica with ``ServiceTimeModel.max_batch``
concurrent slots (the same knob as ``ContinuousBatcher``); the cluster is a
FIFO queue over ``k(t) = nodes(t) * slots_per_replica`` slots. Capacity is
piecewise-constant in time, so the same simulator measures both the
autoscaler's *planned* latency and the latency *realized* under whatever the
Resource Provision Service actually granted (they differ exactly when WS
demand went unmet — the tail the paper's node-demand timeseries can't see).

Capacity drops do not kill in-flight requests (nodes drain, matching the WS
CMS's release-idle-nodes policy); they only gate new starts.

The per-request loop is O(N log N); service times, percentiles and SLO
reductions are vectorized numpy.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.types import SLOConfig
from repro.serving.batching import ServiceTimeModel
from repro.workloads.arrivals import RequestTrace


@dataclasses.dataclass
class QueueMetrics:
    n_requests: int
    n_served: int
    p50_s: float
    p95_s: float
    p99_s: float
    mean_s: float
    max_s: float
    mean_wait_s: float
    violation_rate: float          # frac(latency > slo.latency_target_s)
    slo_met: bool                  # violation_rate <= slo.max_violation_rate
    unserved: int                  # never started before horizon

    def as_dict(self) -> Dict[str, float]:
        return dataclasses.asdict(self)


def capacity_steps(events: Sequence[Tuple[float, int]],
                   slots_per_node: int = 1
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """Normalize (time, nodes) change events into step arrays (times, slots).

    Events need not be sorted or deduplicated; the last level at a given
    time wins. Capacity before the first event is 0.
    """
    if not events:
        return np.array([0.0]), np.array([0], dtype=np.int64)
    # stable sort on time only: among same-time events the last logged wins
    ev = sorted(events, key=lambda e: e[0])
    times, levels = [0.0], [0]
    for t, n in ev:
        lvl = int(n) * slots_per_node
        if t == times[-1]:
            levels[-1] = lvl
        else:
            times.append(float(t))
            levels.append(lvl)
    return np.asarray(times), np.asarray(levels, dtype=np.int64)


def simulate_queue(trace: RequestTrace,
                   capacity_events: Sequence[Tuple[float, int]],
                   model: ServiceTimeModel,
                   slo: SLOConfig,
                   horizon: Optional[float] = None) -> QueueMetrics:
    """FIFO M/G/k(t) simulation; returns latency + SLO metrics.

    capacity_events: (time, n_nodes) change events (each node contributes
    ``model.slots_per_replica`` slots). Requests that cannot start before
    `horizon` (capacity starvation) count as unserved AND as violations —
    an unserved request is the worst possible latency.
    """
    n = len(trace)
    if horizon is None:
        horizon = float(trace.t[-1]) + 1e9 if n else 0.0
    if n == 0:
        return QueueMetrics(0, 0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0,
                            True, 0)

    svc = model.service_times(trace.prompt_tokens, trace.decode_tokens)
    cap_t, cap_k = capacity_steps(capacity_events, model.slots_per_replica)

    busy: List[float] = []          # completion-time heap of in-flight slots
    lat = np.empty(n)
    wait = np.empty(n)
    unserved = 0
    nc = len(cap_t)
    prev_start = 0.0                # FIFO discipline: a request never starts
    #                                 before the one queued ahead of it

    for i in range(n):
        t0 = float(trace.t[i])
        start = max(t0, prev_start)
        while True:
            # capacity level AT `start` (looked up per request — a global
            # monotone pointer would apply a later capacity step to this
            # request whenever an earlier one blocked past it)
            ci = int(np.searchsorted(cap_t, start, side="right")) - 1
            k = int(cap_k[ci])
            while busy and busy[0] <= start:
                heapq.heappop(busy)
            if len(busy) < k:
                break
            # blocked: wait for a slot to free or capacity to rise
            nxt = []
            if busy:
                nxt.append(busy[0])
            j = ci + 1
            while j < nc:
                if cap_k[j] > k:
                    nxt.append(float(cap_t[j]))
                    break
                j += 1
            if not nxt:
                start = np.inf
                break
            start = max(start, min(nxt))
            if start >= horizon:
                start = np.inf
                break
        if not np.isfinite(start) or start >= horizon:
            unserved += 1
            lat[i] = np.inf
            wait[i] = np.inf
            continue
        prev_start = start
        fin = start + float(svc[i])
        heapq.heappush(busy, fin)
        wait[i] = start - t0
        lat[i] = fin - t0

    served = np.isfinite(lat)
    n_served = int(served.sum())
    viol = float(np.mean(~served | (lat > slo.latency_target_s)))
    if n_served == 0:
        return QueueMetrics(n, 0, np.inf, np.inf, np.inf, np.inf, np.inf,
                            np.inf, 1.0, False, unserved)
    sl = lat[served]
    return QueueMetrics(
        n_requests=n,
        n_served=n_served,
        p50_s=float(np.percentile(sl, 50)),
        p95_s=float(np.percentile(sl, 95)),
        p99_s=float(np.percentile(sl, 99)),
        mean_s=float(sl.mean()),
        max_s=float(sl.max()),
        mean_wait_s=float(wait[served].mean()),
        violation_rate=viol,
        slo_met=viol <= slo.max_violation_rate,
        unserved=unserved,
    )


# ------------------------------------------------- analytic approximation


def sakasegawa_wait(rate: float, mean_s: float, scv_s: float,
                    k_slots: int, scv_a: float = 1.0) -> float:
    """Allen–Cunneen / Sakasegawa mean-wait approximation for G/G/k.

    Wq ~= (Ca^2 + Cs^2)/2 * rho^(sqrt(2(k+1)) - 1) / (k (1 - rho)) * E[s].
    Returns inf when rho >= 1. The autoscaler inverts this numerically to
    pick the smallest k meeting the latency target.
    """
    if k_slots <= 0:
        return np.inf
    rho = rate * mean_s / k_slots
    if rho >= 1.0:
        return np.inf
    if rho <= 0.0:
        return 0.0
    return ((scv_a + scv_s) / 2.0
            * rho ** (np.sqrt(2.0 * (k_slots + 1)) - 1.0)
            / (k_slots * (1.0 - rho)) * mean_s)


def predicted_percentile_latency(rate: float, mean_s: float, scv_s: float,
                                 p99_service_s: float, k_slots: int,
                                 percentile: float = 99.0,
                                 scv_a: float = 1.0) -> float:
    """Predicted latency percentile: service tail + exponential wait tail.

    With mean wait Wq, the waiting-time tail is approximated exponential, so
    the p-th percentile of wait is -ln(1 - p/100) * Wq (4.6x Wq at p99).
    """
    wq = sakasegawa_wait(rate, mean_s, scv_s, k_slots, scv_a)
    if not np.isfinite(wq):
        return np.inf
    tail = -np.log(max(1e-12, 1.0 - percentile / 100.0))
    return p99_service_s + tail * wq
