"""M/G/k-style replica queue with continuous-batching service times.

Each WS node runs one serving replica with ``ServiceTimeModel.max_batch``
concurrent slots (the same knob as ``ContinuousBatcher``); the cluster is a
FIFO queue over ``k(t) = nodes(t) * slots_per_replica`` slots. Capacity is
piecewise-constant in time, so the same simulator measures both the
autoscaler's *planned* latency and the latency *realized* under whatever the
Resource Provision Service actually granted (they differ exactly when WS
demand went unmet — the tail the paper's node-demand timeseries can't see).

Capacity drops do not kill in-flight requests (nodes drain, matching the WS
CMS's release-idle-nodes policy); they only gate new starts.

Implementations (all agree bit-for-bit on float64, enforced by
tests/test_queueing_equivalence.py):

  * ``no_wait``   — vectorized numpy O(N log N): when no request ever
                    queues (checked exactly), latency == service time.
  * ``constant``  — constant capacity k: FIFO M/G/k reduces to the
                    Kiefer–Wolfowitz k-slot rolling-finish recurrence
                    (replace the earliest-free slot), O(N log k).
  * ``event``     — piecewise capacity: two-pointer event-merged sweep,
                    O((N + E) log k) with an O(E) next-capacity-rise
                    table instead of a searchsorted per retry.
  * ``reference`` — the original per-request loop with a binary-search
                    capacity lookup inside a retry loop; kept as the
                    golden oracle and the benchmark baseline.

``simulate_queue_many`` batches constant-capacity cells through one
``jax.lax.scan``/``vmap`` core (float32 — golden-tolerance, not
bit-identical), falling back to the exact numpy paths per cell when JAX is
unavailable or capacity is piecewise.
"""
from __future__ import annotations

import dataclasses
import heapq
import time
from math import inf as _INF
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.types import SLOConfig
from repro.serving.batching import ServiceTimeModel
from repro.workloads.arrivals import RequestTrace

# running totals across simulate_queue calls: the campaign snapshots these
# around each cell to report queue-sim requests/sec in its artifact (one
# dict per process; cells return deltas, so process pools stay correct)
SIM_COUNTERS: Dict[str, float] = {
    "calls": 0, "requests": 0, "seconds": 0.0,
    "no_wait": 0, "constant": 0, "event": 0, "reference": 0,
}


def snapshot_counters() -> Dict[str, float]:
    return dict(SIM_COUNTERS)


def counters_delta(before: Dict[str, float]) -> Dict[str, float]:
    return {k: SIM_COUNTERS[k] - before.get(k, 0) for k in SIM_COUNTERS}


@dataclasses.dataclass
class QueueMetrics:
    n_requests: int
    n_served: int
    p50_s: float
    p95_s: float
    p99_s: float
    mean_s: float
    max_s: float
    mean_wait_s: float
    violation_rate: float          # frac(latency > slo.latency_target_s)
    slo_met: bool                  # violation_rate <= slo.max_violation_rate
    unserved: int                  # never started before horizon

    def as_dict(self) -> Dict[str, float]:
        return dataclasses.asdict(self)


def capacity_steps(events: Sequence[Tuple[float, int]],
                   slots_per_node: int = 1
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """Normalize (time, nodes) change events into step arrays (times, slots).

    Events need not be sorted or deduplicated; the last level at a given
    time wins. Capacity before the first event is 0.
    """
    if not events:
        return np.array([0.0]), np.array([0], dtype=np.int64)
    # stable sort on time only: among same-time events the last logged wins
    ev = sorted(events, key=lambda e: e[0])
    times, levels = [0.0], [0]
    for t, n in ev:
        lvl = int(n) * slots_per_node
        if t == times[-1]:
            levels[-1] = lvl
        else:
            times.append(float(t))
            levels.append(lvl)
    return np.asarray(times), np.asarray(levels, dtype=np.int64)


# ----------------------------------------------------------- metric fold


def _metrics(n: int, lat: np.ndarray, wait: np.ndarray, unserved: int,
             slo: SLOConfig) -> QueueMetrics:
    """Fold per-request latency/wait arrays into QueueMetrics (shared by
    every implementation, so they can only disagree on the arrays)."""
    served = np.isfinite(lat)
    n_served = int(served.sum())
    viol = float(np.mean(~served | (lat > slo.latency_target_s)))
    if n_served == 0:
        return QueueMetrics(n, 0, np.inf, np.inf, np.inf, np.inf, np.inf,
                            np.inf, 1.0, False, unserved)
    sl = lat[served]
    p50, p95, p99 = np.percentile(sl, [50.0, 95.0, 99.0])
    return QueueMetrics(
        n_requests=n,
        n_served=n_served,
        p50_s=float(p50),
        p95_s=float(p95),
        p99_s=float(p99),
        mean_s=float(sl.mean()),
        max_s=float(sl.max()),
        mean_wait_s=float(wait[served].mean()),
        violation_rate=viol,
        slo_met=viol <= slo.max_violation_rate,
        unserved=unserved,
    )


# ------------------------------------------------------- implementations


def _try_no_wait(t: np.ndarray, svc: np.ndarray, cap_t: np.ndarray,
                 cap_k: np.ndarray, horizon: float
                 ) -> Optional[Tuple[np.ndarray, np.ndarray]]:
    """Fully vectorized fast path: if no request would ever queue, latency
    is exactly the service time. Returns None when any request waits.

    With FIFO starts at the arrival instants, request i finds
    ``#{j < i : t_j + svc_j > t_i}`` slots busy; since arrivals are sorted
    and service times positive, that count is a single global searchsorted
    over the optimistic finish times. The check is exact, so the arrays
    returned are bit-identical to what the reference loop would produce.
    """
    n = len(t)
    if n == 0 or float(svc.min()) <= 0.0 or float(t[-1]) >= horizon:
        return None
    fin = t + svc
    # cheap prefix probe: queueing in the first block rejects congested
    # cells without paying the full-array sort
    probe = 2048
    if n > probe:
        tp = t[:probe]
        kp = cap_k[np.searchsorted(cap_t, tp, side="right") - 1]
        infl_p = (np.arange(probe)
                  - np.searchsorted(np.sort(fin[:probe]), tp, side="right"))
        if not np.all(infl_p < kp):
            return None
    k_at = cap_k[np.searchsorted(cap_t, t, side="right") - 1]
    inflight = np.arange(n) - np.searchsorted(np.sort(fin), t, side="right")
    if not np.all(inflight < k_at):
        return None
    return fin - t, np.zeros(n)


def _simulate_constant(t: np.ndarray, svc: np.ndarray, k: int,
                       horizon: float
                       ) -> Tuple[np.ndarray, np.ndarray, int]:
    """Constant-capacity FIFO M/G/k: Kiefer–Wolfowitz rolling-finish
    recurrence over a k-slot heap of slot-free times, O(N log k).

    A request starts at max(arrival, earliest slot-free time) and replaces
    that slot's finish — no capacity lookups, no retry loop. Bit-identical
    to the reference loop (same max/add float64 arithmetic).
    """
    n = len(t)
    lat = [_INF] * n
    wait = [_INF] * n
    if k <= 0:
        return np.asarray(lat), np.asarray(wait), n
    sl = svc.tolist()
    heapreplace = heapq.heapreplace
    heappush = heapq.heappush
    busy: List[float] = []          # slot free times, at most k entries
    unserved = 0
    for i, t0 in enumerate(t.tolist()):
        if len(busy) < k:
            if t0 >= horizon:
                unserved += 1
                continue
            fin = t0 + sl[i]
            heappush(busy, fin)
            lat[i] = fin - t0
            wait[i] = 0.0
            continue
        m = busy[0]
        start = t0 if t0 > m else m
        if start >= horizon:
            unserved += 1
            continue
        fin = start + sl[i]
        heapreplace(busy, fin)
        wait[i] = start - t0
        lat[i] = fin - t0
    return np.asarray(lat), np.asarray(wait), unserved


def _next_rise(cap_k: Sequence[int]) -> List[int]:
    """next_rise[j] = smallest j' > j with cap_k[j'] > cap_k[j], else nc.

    Monotonic-stack precompute so the event-merged sweep finds "when does
    capacity next exceed the current level" in O(1) instead of scanning."""
    nc = len(cap_k)
    out = [nc] * nc
    stack: List[int] = []
    for j in range(nc):
        kj = cap_k[j]
        while stack and cap_k[stack[-1]] < kj:
            out[stack.pop()] = j
        stack.append(j)
    return out


def _simulate_event(t: np.ndarray, svc: np.ndarray, cap_t: np.ndarray,
                    cap_k: np.ndarray, horizon: float
                    ) -> Tuple[np.ndarray, np.ndarray, int]:
    """Piecewise-capacity FIFO sweep: two pointers (requests, capacity
    events) merged in time, O((N + E) log k).

    The capacity interval of every *arrival* is precomputed in one
    vectorized searchsorted; the scalar pointer only walks events for the
    requests whose start was pushed past their arrival by the FIFO queue.
    It advances monotonically with the committed start time (which is
    nondecreasing across *served* requests); a request that turns out
    unserved searches with a local copy so future capacity never leaks
    back to earlier arrivals. Blocked requests jump straight to
    min(earliest finish, next capacity rise) via the ``_next_rise`` table
    instead of rescanning events per retry. Bit-identical to the
    reference loop.
    """
    n = len(t)
    sl = svc.tolist()
    ct = cap_t.tolist()
    ck = cap_k.tolist()
    nc = len(ct)
    ngr = _next_rise(ck)
    heappush = heapq.heappush
    heappop = heapq.heappop
    lat = [_INF] * n
    wait = [_INF] * n
    ci_of_t = (np.searchsorted(cap_t, t, side="right") - 1).tolist()
    busy: List[float] = []          # completion-time heap of in-flight slots
    blen = 0                        # len(busy), tracked to skip len() calls
    unserved = 0
    prev_start = 0.0                # FIFO discipline: a request never starts
    ci_done = 0                     # capacity interval at prev_start
    for i, t0 in enumerate(t.tolist()):
        if t0 >= prev_start:        # common case: arrival interval known
            start = t0
            ci = ci_of_t[i]
        else:
            start = prev_start
            ci = ci_done
            while ci + 1 < nc and ct[ci + 1] <= start:
                ci += 1
        while True:
            k = ck[ci]
            while blen and busy[0] <= start:
                heappop(busy)
                blen -= 1
            if blen < k:
                break
            # blocked: wait for a slot to free or capacity to rise
            cand = busy[0] if blen else _INF
            jn = ngr[ci]
            if jn < nc and ct[jn] < cand:
                cand = ct[jn]
            if cand == _INF:
                start = _INF
                break
            if cand > start:
                start = cand
            if start >= horizon:
                start = _INF
                break
            while ci + 1 < nc and ct[ci + 1] <= start:
                ci += 1
        if start >= horizon:            # also catches start == inf
            unserved += 1
            continue
        prev_start = start
        ci_done = ci
        fin = start + sl[i]
        heappush(busy, fin)
        blen += 1
        wait[i] = start - t0
        lat[i] = fin - t0
    return np.asarray(lat), np.asarray(wait), unserved


def _simulate_reference(t: np.ndarray, svc: np.ndarray, cap_t: np.ndarray,
                        cap_k: np.ndarray, horizon: float
                        ) -> Tuple[np.ndarray, np.ndarray, int]:
    """The original per-request loop (searchsorted capacity lookup inside a
    retry loop). Kept verbatim as the golden oracle and bench baseline."""
    n = len(t)
    busy: List[float] = []          # completion-time heap of in-flight slots
    lat = np.empty(n)
    wait = np.empty(n)
    unserved = 0
    nc = len(cap_t)
    prev_start = 0.0                # FIFO discipline: a request never starts
    #                                 before the one queued ahead of it

    for i in range(n):
        t0 = float(t[i])
        start = max(t0, prev_start)
        while True:
            # capacity level AT `start` (looked up per request — a global
            # monotone pointer would apply a later capacity step to this
            # request whenever an earlier one blocked past it)
            ci = int(np.searchsorted(cap_t, start, side="right")) - 1
            k = int(cap_k[ci])
            while busy and busy[0] <= start:
                heapq.heappop(busy)
            if len(busy) < k:
                break
            # blocked: wait for a slot to free or capacity to rise
            nxt = []
            if busy:
                nxt.append(busy[0])
            j = ci + 1
            while j < nc:
                if cap_k[j] > k:
                    nxt.append(float(cap_t[j]))
                    break
                j += 1
            if not nxt:
                start = np.inf
                break
            start = max(start, min(nxt))
            if start >= horizon:
                start = np.inf
                break
        if not np.isfinite(start) or start >= horizon:
            unserved += 1
            lat[i] = np.inf
            wait[i] = np.inf
            continue
        prev_start = start
        fin = start + float(svc[i])
        heapq.heappush(busy, fin)
        wait[i] = start - t0
        lat[i] = fin - t0
    return lat, wait, unserved


IMPLS = ("auto", "fast", "event", "reference")


def simulate_queue(trace: RequestTrace,
                   capacity_events: Sequence[Tuple[float, int]],
                   model: ServiceTimeModel,
                   slo: SLOConfig,
                   horizon: Optional[float] = None,
                   impl: str = "auto") -> QueueMetrics:
    """FIFO M/G/k(t) simulation; returns latency + SLO metrics.

    capacity_events: (time, n_nodes) change events (each node contributes
    ``model.slots_per_replica`` slots). Requests that cannot start before
    `horizon` (capacity starvation) count as unserved AND as violations —
    an unserved request is the worst possible latency.

    impl: ``auto`` picks the fastest exact path (vectorized no-wait ->
    constant-capacity recurrence -> event-merged sweep); ``fast`` forces
    the vectorized family (raises on piecewise capacity with queueing);
    ``event`` and ``reference`` force those loops. All paths produce
    bit-identical float64 metrics.
    """
    if impl not in IMPLS:
        raise ValueError(f"unknown impl {impl!r}; have {IMPLS}")
    n = len(trace)
    if horizon is None:
        horizon = float(trace.t[-1]) + 1e9 if n else 0.0
    if n == 0:
        return QueueMetrics(0, 0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0,
                            True, 0)

    t0_wall = time.perf_counter()
    svc = model.service_times(trace.prompt_tokens, trace.decode_tokens)
    cap_t, cap_k = capacity_steps(capacity_events, model.slots_per_replica)
    t = np.asarray(trace.t, dtype=np.float64)
    horizon = float(horizon)
    constant = bool(np.all(cap_k == cap_k[0]))

    used = impl
    if impl == "reference":
        lat, wait, unserved = _simulate_reference(t, svc, cap_t, cap_k,
                                                  horizon)
    elif impl == "event":
        lat, wait, unserved = _simulate_event(t, svc, cap_t, cap_k, horizon)
    else:
        nw = _try_no_wait(t, svc, cap_t, cap_k, horizon)
        if nw is not None:
            lat, wait = nw
            unserved = 0
            used = "no_wait"
        elif constant:
            lat, wait, unserved = _simulate_constant(t, svc, int(cap_k[0]),
                                                     horizon)
            used = "constant"
        elif impl == "fast":
            raise ValueError("impl='fast' needs constant capacity or a "
                             "contention-free trace; use 'auto' or 'event'")
        else:
            lat, wait, unserved = _simulate_event(t, svc, cap_t, cap_k,
                                                  horizon)
            used = "event"

    SIM_COUNTERS["calls"] += 1
    SIM_COUNTERS["requests"] += n
    SIM_COUNTERS["seconds"] += time.perf_counter() - t0_wall
    SIM_COUNTERS[used] += 1
    return _metrics(n, lat, wait, unserved, slo)


def simulate_queue_reference(trace: RequestTrace,
                             capacity_events: Sequence[Tuple[float, int]],
                             model: ServiceTimeModel,
                             slo: SLOConfig,
                             horizon: Optional[float] = None
                             ) -> QueueMetrics:
    """The pre-vectorization implementation (golden oracle / baseline)."""
    return simulate_queue(trace, capacity_events, model, slo,
                          horizon=horizon, impl="reference")


# ------------------------------------------------------- batched (JAX)


_JAX_CORES: Dict[Tuple[int, int], object] = {}


def _jax_modules():
    try:
        import jax
        import jax.numpy as jnp
        return jax, jnp
    except Exception:                                    # pragma: no cover
        return None


def _kw_batched_core(n_pad: int, k_pad: int):
    """jit(vmap(scan)) Kiefer–Wolfowitz core for [B, n_pad] traces with
    [B, k_pad] slot vectors; cached per padded shape bucket so a grid of
    same-shape cells compiles once."""
    key = (n_pad, k_pad)
    core = _JAX_CORES.get(key)
    if core is not None:
        return core
    mods = _jax_modules()
    if mods is None:
        return None
    jax, jnp = mods

    def one(t, s, free0, horizon):
        def step(free, ts):
            t_i, s_i = ts
            m = jnp.min(free)
            start = jnp.maximum(t_i, m)
            ok = start < horizon
            fin = start + s_i
            free2 = free.at[jnp.argmin(free)].set(fin)
            free = jnp.where(ok, free2, free)
            lat = jnp.where(ok, fin - t_i, jnp.inf)
            wait = jnp.where(ok, start - t_i, jnp.inf)
            return free, (lat, wait)

        _, (lat, wait) = jax.lax.scan(step, free0, (t, s))
        return lat, wait

    core = jax.jit(jax.vmap(one))
    _JAX_CORES[key] = core
    return core


def _pad_pow2(n: int, floor: int = 256) -> int:
    p = floor
    while p < n:
        p *= 2
    return p


def simulate_queue_many(traces: Sequence[RequestTrace],
                        capacities: Sequence[Sequence[Tuple[float, int]]],
                        model: ServiceTimeModel,
                        slo: SLOConfig,
                        horizon: Optional[float] = None,
                        backend: str = "auto") -> List[QueueMetrics]:
    """Batched FIFO queue simulation over many grid cells.

    Constant-capacity cells are padded to shared [B, N] blocks and run
    through one ``jax.lax.scan``/``vmap`` Kiefer–Wolfowitz core (float32:
    metrics agree with the exact paths to golden tolerance, not bitwise).
    Piecewise-capacity cells — and everything when JAX is unavailable or
    ``backend='numpy'`` — fall back to the exact per-cell ``simulate_queue``
    dispatch. Results come back in input order.
    """
    if backend not in ("auto", "jax", "numpy"):
        raise ValueError(f"unknown backend {backend!r}")
    if len(traces) != len(capacities):
        raise ValueError("traces and capacities must align")
    out: List[Optional[QueueMetrics]] = [None] * len(traces)

    batch: List[int] = []
    ks: List[int] = []              # constant slot count per batched cell
    if backend != "numpy" and _jax_modules() is not None:
        for i, ev in enumerate(capacities):
            _, cap_k = capacity_steps(ev, model.slots_per_replica)
            if len(traces[i]) and np.all(cap_k == cap_k[0]):
                batch.append(i)
                ks.append(int(cap_k[0]))
    batched = set(batch)
    for i in range(len(traces)):
        if i not in batched:
            out[i] = simulate_queue(traces[i], capacities[i], model, slo,
                                    horizon=horizon)
    if not batch:
        return out  # type: ignore[return-value]

    t0_wall = time.perf_counter()
    _, jnp = _jax_modules()
    n_pad = _pad_pow2(max(len(traces[i]) for i in batch))
    k_pad = max(1, max(ks))
    core = _kw_batched_core(n_pad, k_pad)

    B = len(batch)
    t_b = np.full((B, n_pad), np.inf, dtype=np.float32)
    s_b = np.zeros((B, n_pad), dtype=np.float32)
    free0 = np.zeros((B, k_pad), dtype=np.float32)
    hz = np.empty(B, dtype=np.float32)
    for row, i in enumerate(batch):
        tr = traces[i]
        n = len(tr)
        svc = model.service_times(tr.prompt_tokens, tr.decode_tokens)
        t_b[row, :n] = tr.t
        s_b[row, :n] = svc
        free0[row, ks[row]:] = np.inf          # slots beyond k never free
        h = horizon
        if h is None:
            h = float(tr.t[-1]) + 1e9 if n else 0.0
        hz[row] = h
    lat_b, wait_b = core(jnp.asarray(t_b), jnp.asarray(s_b),
                         jnp.asarray(free0), jnp.asarray(hz))
    lat_b = np.asarray(lat_b, dtype=np.float64)
    wait_b = np.asarray(wait_b, dtype=np.float64)
    for row, i in enumerate(batch):
        n = len(traces[i])
        lat = lat_b[row, :n]
        unserved = int((~np.isfinite(lat)).sum())
        out[i] = _metrics(n, lat, wait_b[row, :n], unserved, slo)
    n_req = sum(len(traces[i]) for i in batch)
    SIM_COUNTERS["calls"] += len(batch)
    SIM_COUNTERS["requests"] += n_req
    SIM_COUNTERS["seconds"] += time.perf_counter() - t0_wall
    SIM_COUNTERS["constant"] += len(batch)
    return out  # type: ignore[return-value]


# ------------------------------------------------- analytic approximation


def sakasegawa_wait(rate: float, mean_s: float, scv_s: float,
                    k_slots: int, scv_a: float = 1.0) -> float:
    """Allen–Cunneen / Sakasegawa mean-wait approximation for G/G/k.

    Wq ~= (Ca^2 + Cs^2)/2 * rho^(sqrt(2(k+1)) - 1) / (k (1 - rho)) * E[s].
    Returns inf when rho >= 1. The autoscaler inverts this numerically to
    pick the smallest k meeting the latency target.
    """
    if k_slots <= 0:
        return np.inf
    rho = rate * mean_s / k_slots
    if rho >= 1.0:
        return np.inf
    if rho <= 0.0:
        return 0.0
    return ((scv_a + scv_s) / 2.0
            * rho ** (np.sqrt(2.0 * (k_slots + 1)) - 1.0)
            / (k_slots * (1.0 - rho)) * mean_s)


def predicted_percentile_latency(rate: float, mean_s: float, scv_s: float,
                                 p99_service_s: float, k_slots: int,
                                 percentile: float = 99.0,
                                 scv_a: float = 1.0) -> float:
    """Predicted latency percentile: service tail + exponential wait tail.

    With mean wait Wq, the waiting-time tail is approximated exponential, so
    the p-th percentile of wait is -ln(1 - p/100) * Wq (4.6x Wq at p99).
    """
    wq = sakasegawa_wait(rate, mean_s, scv_s, k_slots, scv_a)
    if not np.isfinite(wq):
        return np.inf
    tail = -np.log(max(1e-12, 1.0 - percentile / 100.0))
    return p99_service_s + tail * wq
