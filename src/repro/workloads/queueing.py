"""M/G/k-style replica queue with continuous-batching service times.

Each WS node runs one serving replica with ``ServiceTimeModel.max_batch``
concurrent slots (the same knob as ``ContinuousBatcher``); the cluster is a
FIFO queue over ``k(t) = nodes(t) * slots_per_replica`` slots. Capacity is
piecewise-constant in time, so the same simulator measures both the
autoscaler's *planned* latency and the latency *realized* under whatever the
Resource Provision Service actually granted (they differ exactly when WS
demand went unmet — the tail the paper's node-demand timeseries can't see).

Capacity drops do not kill in-flight requests (nodes drain, matching the WS
CMS's release-idle-nodes policy); they only gate new starts.

Implementations (all agree bit-for-bit on float64, enforced by
tests/test_queueing_equivalence.py):

  * ``no_wait``   — vectorized numpy O(N log N): when no request ever
                    queues (checked exactly), latency == service time.
  * ``constant``  — constant capacity k: FIFO M/G/k reduces to the
                    Kiefer–Wolfowitz k-slot rolling-finish recurrence
                    (replace the earliest-free slot), O(N log k).
  * ``event``     — piecewise capacity: two-pointer event-merged sweep,
                    O((N + E) log k) with an O(E) next-capacity-rise
                    table instead of a searchsorted per retry.
  * ``reference`` — the original per-request loop with a binary-search
                    capacity lookup inside a retry loop; kept as the
                    golden oracle and the benchmark baseline.

``simulate_queue_batch`` (and its ``simulate_queue_many`` wrapper) batches
heterogeneous cells through shape-bucketed ``jit(vmap(lax.scan))`` device
programs — a Kiefer–Wolfowitz core for constant capacity and a k(t)-aware
sorted-slot core for piecewise capacity — with the metric fold fused on
device (float32 — golden-tolerance, not bit-identical), falling back to the
exact numpy paths per cell when JAX is unavailable.
"""
from __future__ import annotations

import dataclasses
import heapq
import time
from collections import OrderedDict
from math import inf as _INF
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.types import SLOConfig
from repro.serving.batching import ServiceTimeModel
from repro.workloads.arrivals import RequestTrace

# running totals across simulate_queue calls: the campaign snapshots these
# around each cell to report queue-sim requests/sec in its artifact (one
# dict per process; cells return deltas, so process pools stay correct)
SIM_COUNTERS: Dict[str, float] = {
    "calls": 0, "requests": 0, "seconds": 0.0,
    "no_wait": 0, "constant": 0, "event": 0, "reference": 0,
    "jax_batched": 0,
}


def snapshot_counters() -> Dict[str, float]:
    return dict(SIM_COUNTERS)


def counters_delta(before: Dict[str, float]) -> Dict[str, float]:
    return {k: SIM_COUNTERS[k] - before.get(k, 0) for k in SIM_COUNTERS}


@dataclasses.dataclass
class QueueMetrics:
    n_requests: int
    n_served: int
    p50_s: float
    p95_s: float
    p99_s: float
    mean_s: float
    max_s: float
    mean_wait_s: float
    violation_rate: float          # frac(latency > slo.latency_target_s)
    slo_met: bool                  # violation_rate <= slo.max_violation_rate
    unserved: int                  # never started before horizon

    def as_dict(self) -> Dict[str, float]:
        return dataclasses.asdict(self)


def capacity_steps(events: Sequence[Tuple[float, int]],
                   slots_per_node: int = 1
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """Normalize (time, nodes) change events into step arrays (times, slots).

    Events need not be sorted or deduplicated; the last level at a given
    time wins. Capacity before the first event is 0.
    """
    if not events:
        return np.array([0.0]), np.array([0], dtype=np.int64)
    # stable sort on time only: among same-time events the last logged wins
    ev = sorted(events, key=lambda e: e[0])
    times, levels = [0.0], [0]
    for t, n in ev:
        lvl = int(n) * slots_per_node
        if t == times[-1]:
            levels[-1] = lvl
        else:
            times.append(float(t))
            levels.append(lvl)
    return np.asarray(times), np.asarray(levels, dtype=np.int64)


# ----------------------------------------------------------- metric fold


def _metrics(n: int, lat: np.ndarray, wait: np.ndarray, unserved: int,
             slo: SLOConfig) -> QueueMetrics:
    """Fold per-request latency/wait arrays into QueueMetrics (shared by
    every implementation, so they can only disagree on the arrays)."""
    served = np.isfinite(lat)
    n_served = int(served.sum())
    viol = float(np.mean(~served | (lat > slo.latency_target_s)))
    if n_served == 0:
        return QueueMetrics(n, 0, np.inf, np.inf, np.inf, np.inf, np.inf,
                            np.inf, 1.0, False, unserved)
    sl = lat[served]
    p50, p95, p99 = np.percentile(sl, [50.0, 95.0, 99.0])
    return QueueMetrics(
        n_requests=n,
        n_served=n_served,
        p50_s=float(p50),
        p95_s=float(p95),
        p99_s=float(p99),
        mean_s=float(sl.mean()),
        max_s=float(sl.max()),
        mean_wait_s=float(wait[served].mean()),
        violation_rate=viol,
        slo_met=viol <= slo.max_violation_rate,
        unserved=unserved,
    )


# ------------------------------------------------------- implementations


def _try_no_wait(t: np.ndarray, svc: np.ndarray, cap_t: np.ndarray,
                 cap_k: np.ndarray, horizon: float
                 ) -> Optional[Tuple[np.ndarray, np.ndarray]]:
    """Fully vectorized fast path: if no request would ever queue, latency
    is exactly the service time. Returns None when any request waits.

    With FIFO starts at the arrival instants, request i finds
    ``#{j < i : t_j + svc_j > t_i}`` slots busy; since arrivals are sorted
    and service times positive, that count is a single global searchsorted
    over the optimistic finish times. The check is exact, so the arrays
    returned are bit-identical to what the reference loop would produce.
    """
    n = len(t)
    if n == 0 or float(svc.min()) <= 0.0 or float(t[-1]) >= horizon:
        return None
    fin = t + svc
    # cheap prefix probe: queueing in the first block rejects congested
    # cells without paying the full-array sort
    probe = 2048
    if n > probe:
        tp = t[:probe]
        kp = cap_k[np.searchsorted(cap_t, tp, side="right") - 1]
        infl_p = (np.arange(probe)
                  - np.searchsorted(np.sort(fin[:probe]), tp, side="right"))
        if not np.all(infl_p < kp):
            return None
    k_at = cap_k[np.searchsorted(cap_t, t, side="right") - 1]
    inflight = np.arange(n) - np.searchsorted(np.sort(fin), t, side="right")
    if not np.all(inflight < k_at):
        return None
    return fin - t, np.zeros(n)


def _simulate_constant(t: np.ndarray, svc: np.ndarray, k: int,
                       horizon: float
                       ) -> Tuple[np.ndarray, np.ndarray, int]:
    """Constant-capacity FIFO M/G/k: Kiefer–Wolfowitz rolling-finish
    recurrence over a k-slot heap of slot-free times, O(N log k).

    A request starts at max(arrival, earliest slot-free time) and replaces
    that slot's finish — no capacity lookups, no retry loop. Bit-identical
    to the reference loop (same max/add float64 arithmetic).
    """
    n = len(t)
    lat = [_INF] * n
    wait = [_INF] * n
    if k <= 0:
        return np.asarray(lat), np.asarray(wait), n
    sl = svc.tolist()
    heapreplace = heapq.heapreplace
    heappush = heapq.heappush
    busy: List[float] = []          # slot free times, at most k entries
    unserved = 0
    for i, t0 in enumerate(t.tolist()):
        if len(busy) < k:
            if t0 >= horizon:
                unserved += 1
                continue
            fin = t0 + sl[i]
            heappush(busy, fin)
            lat[i] = fin - t0
            wait[i] = 0.0
            continue
        m = busy[0]
        start = t0 if t0 > m else m
        if start >= horizon:
            unserved += 1
            continue
        fin = start + sl[i]
        heapreplace(busy, fin)
        wait[i] = start - t0
        lat[i] = fin - t0
    return np.asarray(lat), np.asarray(wait), unserved


def _next_rise(cap_k: Sequence[int]) -> List[int]:
    """next_rise[j] = smallest j' > j with cap_k[j'] > cap_k[j], else nc.

    Monotonic-stack precompute so the event-merged sweep finds "when does
    capacity next exceed the current level" in O(1) instead of scanning."""
    nc = len(cap_k)
    out = [nc] * nc
    stack: List[int] = []
    for j in range(nc):
        kj = cap_k[j]
        while stack and cap_k[stack[-1]] < kj:
            out[stack.pop()] = j
        stack.append(j)
    return out


def _simulate_event(t: np.ndarray, svc: np.ndarray, cap_t: np.ndarray,
                    cap_k: np.ndarray, horizon: float
                    ) -> Tuple[np.ndarray, np.ndarray, int]:
    """Piecewise-capacity FIFO sweep: two pointers (requests, capacity
    events) merged in time, O((N + E) log k).

    The capacity interval of every *arrival* is precomputed in one
    vectorized searchsorted; the scalar pointer only walks events for the
    requests whose start was pushed past their arrival by the FIFO queue.
    It advances monotonically with the committed start time (which is
    nondecreasing across *served* requests); a request that turns out
    unserved searches with a local copy so future capacity never leaks
    back to earlier arrivals. Blocked requests jump straight to
    min(earliest finish, next capacity rise) via the ``_next_rise`` table
    instead of rescanning events per retry. Bit-identical to the
    reference loop.
    """
    n = len(t)
    sl = svc.tolist()
    ct = cap_t.tolist()
    ck = cap_k.tolist()
    nc = len(ct)
    ngr = _next_rise(ck)
    heappush = heapq.heappush
    heappop = heapq.heappop
    lat = [_INF] * n
    wait = [_INF] * n
    ci_of_t = (np.searchsorted(cap_t, t, side="right") - 1).tolist()
    busy: List[float] = []          # completion-time heap of in-flight slots
    blen = 0                        # len(busy), tracked to skip len() calls
    unserved = 0
    prev_start = 0.0                # FIFO discipline: a request never starts
    ci_done = 0                     # capacity interval at prev_start
    for i, t0 in enumerate(t.tolist()):
        if t0 >= prev_start:        # common case: arrival interval known
            start = t0
            ci = ci_of_t[i]
        else:
            start = prev_start
            ci = ci_done
            while ci + 1 < nc and ct[ci + 1] <= start:
                ci += 1
        while True:
            k = ck[ci]
            while blen and busy[0] <= start:
                heappop(busy)
                blen -= 1
            if blen < k:
                break
            # blocked: wait for a slot to free or capacity to rise
            cand = busy[0] if blen else _INF
            jn = ngr[ci]
            if jn < nc and ct[jn] < cand:
                cand = ct[jn]
            if cand == _INF:
                start = _INF
                break
            if cand > start:
                start = cand
            if start >= horizon:
                start = _INF
                break
            while ci + 1 < nc and ct[ci + 1] <= start:
                ci += 1
        if start >= horizon:            # also catches start == inf
            unserved += 1
            continue
        prev_start = start
        ci_done = ci
        fin = start + sl[i]
        heappush(busy, fin)
        blen += 1
        wait[i] = start - t0
        lat[i] = fin - t0
    return np.asarray(lat), np.asarray(wait), unserved


def _simulate_reference(t: np.ndarray, svc: np.ndarray, cap_t: np.ndarray,
                        cap_k: np.ndarray, horizon: float
                        ) -> Tuple[np.ndarray, np.ndarray, int]:
    """The original per-request loop (searchsorted capacity lookup inside a
    retry loop). Kept verbatim as the golden oracle and bench baseline."""
    n = len(t)
    busy: List[float] = []          # completion-time heap of in-flight slots
    lat = np.empty(n)
    wait = np.empty(n)
    unserved = 0
    nc = len(cap_t)
    prev_start = 0.0                # FIFO discipline: a request never starts
    #                                 before the one queued ahead of it

    for i in range(n):
        t0 = float(t[i])
        start = max(t0, prev_start)
        while True:
            # capacity level AT `start` (looked up per request — a global
            # monotone pointer would apply a later capacity step to this
            # request whenever an earlier one blocked past it)
            ci = int(np.searchsorted(cap_t, start, side="right")) - 1
            k = int(cap_k[ci])
            while busy and busy[0] <= start:
                heapq.heappop(busy)
            if len(busy) < k:
                break
            # blocked: wait for a slot to free or capacity to rise
            nxt = []
            if busy:
                nxt.append(busy[0])
            j = ci + 1
            while j < nc:
                if cap_k[j] > k:
                    nxt.append(float(cap_t[j]))
                    break
                j += 1
            if not nxt:
                start = np.inf
                break
            start = max(start, min(nxt))
            if start >= horizon:
                start = np.inf
                break
        if not np.isfinite(start) or start >= horizon:
            unserved += 1
            lat[i] = np.inf
            wait[i] = np.inf
            continue
        prev_start = start
        fin = start + float(svc[i])
        heapq.heappush(busy, fin)
        wait[i] = start - t0
        lat[i] = fin - t0
    return lat, wait, unserved


IMPLS = ("auto", "fast", "event", "reference")


def simulate_queue(trace: RequestTrace,
                   capacity_events: Sequence[Tuple[float, int]],
                   model: ServiceTimeModel,
                   slo: SLOConfig,
                   horizon: Optional[float] = None,
                   impl: str = "auto") -> QueueMetrics:
    """FIFO M/G/k(t) simulation; returns latency + SLO metrics.

    capacity_events: (time, n_nodes) change events (each node contributes
    ``model.slots_per_replica`` slots). Requests that cannot start before
    `horizon` (capacity starvation) count as unserved AND as violations —
    an unserved request is the worst possible latency.

    impl: ``auto`` picks the fastest exact path (vectorized no-wait ->
    constant-capacity recurrence -> event-merged sweep); ``fast`` forces
    the vectorized family (raises on piecewise capacity with queueing);
    ``event`` and ``reference`` force those loops. All paths produce
    bit-identical float64 metrics.
    """
    if impl not in IMPLS:
        raise ValueError(f"unknown impl {impl!r}; have {IMPLS}")
    n = len(trace)
    if horizon is None:
        horizon = float(trace.t[-1]) + 1e9 if n else 0.0
    if n == 0:
        return QueueMetrics(0, 0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0,
                            True, 0)

    t0_wall = time.perf_counter()
    svc = model.service_times(trace.prompt_tokens, trace.decode_tokens)
    cap_t, cap_k = capacity_steps(capacity_events, model.slots_per_replica)
    t = np.asarray(trace.t, dtype=np.float64)
    horizon = float(horizon)
    constant = bool(np.all(cap_k == cap_k[0]))

    used = impl
    if impl == "reference":
        lat, wait, unserved = _simulate_reference(t, svc, cap_t, cap_k,
                                                  horizon)
    elif impl == "event":
        lat, wait, unserved = _simulate_event(t, svc, cap_t, cap_k, horizon)
    else:
        nw = _try_no_wait(t, svc, cap_t, cap_k, horizon)
        if nw is not None:
            lat, wait = nw
            unserved = 0
            used = "no_wait"
        elif constant:
            lat, wait, unserved = _simulate_constant(t, svc, int(cap_k[0]),
                                                     horizon)
            used = "constant"
        elif impl == "fast":
            raise ValueError("impl='fast' needs constant capacity or a "
                             "contention-free trace; use 'auto' or 'event'")
        else:
            lat, wait, unserved = _simulate_event(t, svc, cap_t, cap_k,
                                                  horizon)
            used = "event"

    SIM_COUNTERS["calls"] += 1
    SIM_COUNTERS["requests"] += n
    SIM_COUNTERS["seconds"] += time.perf_counter() - t0_wall
    SIM_COUNTERS[used] += 1
    return _metrics(n, lat, wait, unserved, slo)


def simulate_queue_reference(trace: RequestTrace,
                             capacity_events: Sequence[Tuple[float, int]],
                             model: ServiceTimeModel,
                             slo: SLOConfig,
                             horizon: Optional[float] = None
                             ) -> QueueMetrics:
    """The pre-vectorization implementation (golden oracle / baseline)."""
    return simulate_queue(trace, capacity_events, model, slo,
                          horizon=horizon, impl="reference")


# ------------------------------------------------------- batched (JAX)


@dataclasses.dataclass(frozen=True)
class QueueJob:
    """One cell of a batched queue simulation (``simulate_queue_batch``)."""
    trace: RequestTrace
    capacity_events: Sequence[Tuple[float, int]]
    model: ServiceTimeModel
    slo: SLOConfig
    horizon: Optional[float] = None


_JAX_CORES: "OrderedDict[tuple, object]" = OrderedDict()
_JAX_CORES_MAX = 32          # LRU bound on compiled cores per process


def _jax_modules():
    try:
        import jax
        import jax.numpy as jnp
        return jax, jnp
    except Exception:                                    # pragma: no cover
        return None


def _cached_core(key: tuple, build):
    core = _JAX_CORES.get(key)
    if core is None:
        core = build()
        _JAX_CORES[key] = core
        while len(_JAX_CORES) > _JAX_CORES_MAX:
            _JAX_CORES.popitem(last=False)
    else:
        _JAX_CORES.move_to_end(key)
    return core


# columns of the on-device metric fold, in order
FOLD_COLS = ("n_served", "p50_s", "p95_s", "p99_s", "mean_s", "max_s",
             "mean_wait_s", "violations")


def _device_fold(jax, jnp, lat, wait, n_valid, slo_t):
    """[n_pad] per-request arrays -> the FOLD_COLS row, on device.

    Both padded rows and unserved requests carry inf latency; padding is
    excluded from the violation count by the ``n_valid`` mask (it never
    produces *finite* latency, so the served-side stats need no mask).
    Percentiles reproduce numpy's 'linear' interpolation over the served
    (finite) prefix of the sorted latencies — but without sorting: XLA's
    CPU sort is ~40x slower than numpy's partition, so the order statistics
    are selected exactly by binary search over the float32 bit space
    (non-negative IEEE-754 floats are order-isomorphic to their integer
    bits; 31 masked-count rounds pin the k-th smallest bit-exactly,
    identically to sort-then-gather).  Only the three floor ranks are
    searched; each ceil-rank statistic is either the same value (duplicate
    run) or the smallest value strictly above it, recovered in one masked
    min pass.
    """
    served = jnp.isfinite(lat)
    m = jnp.sum(served)
    mf = m.astype(lat.dtype)
    bits = lat.view(jnp.int32)               # lat >= 0, so order-preserving
    m1 = jnp.maximum(m - 1, 0)

    # ranks lo/hi per percentile (0-indexed among ALL entries: the served
    # latencies are exactly the m smallest, inf padding sorts last)
    qs = jnp.asarray([50.0, 95.0, 99.0], dtype=lat.dtype)
    pos = jnp.maximum(mf - 1.0, 0.0) * (qs / 100.0)
    lo_r = jnp.floor(pos).astype(jnp.int32)
    hi_r = jnp.minimum(lo_r + 1, m1)

    def select(st, _):
        # invariant: kth-smallest bits in (lb, ub]; probe the midpoint
        lb, ub = st
        mid = lb + ((ub - lb) >> 1)    # lb+ub would overflow int32
        cnt = jnp.sum(bits[None, :] <= mid[:, None], axis=1)
        take = cnt >= lo_r + 1               # kth smallest <= mid
        ub = jnp.where(take, mid, ub)
        lb = jnp.where(take, lb, mid)
        return (lb, ub), None

    lb0 = jnp.full((3,), -1, dtype=jnp.int32)
    ub0 = jnp.full((3,), np.float32(np.inf).view(np.int32).item(),
                   dtype=jnp.int32)
    (_, ub), _ = jax.lax.scan(select, (lb0, ub0), None, length=31)
    lo_stat = ub.view(lat.dtype)             # [3] exact floor-rank stats
    # ceil-rank stat: ranks lo_r..(count<=lo_stat)-1 all equal lo_stat, so
    # hi_r lands on lo_stat unless it is the first strictly-larger value
    above = lat[None, :] > lo_stat[:, None]
    c_le = jnp.sum(~above, axis=1)
    next_up = jnp.min(jnp.where(above, lat[None, :], jnp.inf), axis=1)
    hi_stat = jnp.where(hi_r <= c_le - 1, lo_stat, next_up)
    frac = pos - lo_r.astype(lat.dtype)
    pcts = lo_stat * (1.0 - frac) + hi_stat * frac

    denom = jnp.maximum(mf, 1.0)
    mean = jnp.sum(jnp.where(served, lat, 0.0)) / denom
    mx = jnp.max(jnp.where(served, lat, -jnp.inf))
    mean_w = jnp.sum(jnp.where(served, wait, 0.0)) / denom
    valid = jnp.arange(lat.shape[0]) < n_valid
    viol = jnp.sum(valid & (~served | (lat > slo_t)))
    return jnp.concatenate([
        jnp.stack([mf]), pcts,
        jnp.stack([mean, mx, mean_w, viol.astype(lat.dtype)])])


def _kw_batched_core(n_pad: int, k_pad: int):
    """jit(vmap(scan)) Kiefer–Wolfowitz core for constant-capacity cells:
    [B, n_pad] traces, [B, k_pad] slot-free-time vectors (slots beyond a
    cell's k are pinned to inf), metric fold fused on device so the host
    transfer is one [B, len(FOLD_COLS)] block."""
    mods = _jax_modules()
    if mods is None:                                     # pragma: no cover
        return None
    jax, jnp = mods

    def build():
        def one(t, s, free0, horizon, n_valid, slo_t):
            def body(free, t_i, s_i):
                start = jnp.maximum(t_i, jnp.min(free))
                ok = start < horizon
                fin = start + s_i
                free2 = free.at[jnp.argmin(free)].set(fin)
                free = jnp.where(ok, free2, free)
                lat = jnp.where(ok, fin - t_i, jnp.inf)
                wait = jnp.where(ok, start - t_i, jnp.inf)
                return free, lat, wait

            def step(free, ts):
                t_c, s_c = ts               # [_UNROLL] requests per step
                lats, waits = [], []
                for c in range(_UNROLL):
                    free, lat, wait = body(free, t_c[c], s_c[c])
                    lats.append(lat)
                    waits.append(wait)
                return free, (jnp.stack(lats), jnp.stack(waits))

            _, (lat, wait) = jax.lax.scan(
                step, free0, (t.reshape(-1, _UNROLL),
                              s.reshape(-1, _UNROLL)))
            return _device_fold(jax, jnp, lat.reshape(-1),
                                wait.reshape(-1), n_valid, slo_t)

        return jax.jit(jax.vmap(one))

    return _cached_core(("const", n_pad, k_pad), build)


def _pw_batched_core(n_pad: int, e_pad: int, k_pad: int):
    """jit(vmap(scan)) core for piecewise capacity k(t).

    Per cell the capacity is padded step arrays [e_pad] (change times,
    slot levels, next-change times); the carry is the sorted ascending
    vector of the k_pad slot finish times plus the FIFO commit point
    ``prev_start``. Per request the earliest feasible start within
    interval e is when fewer than k_e slots are still busy — with sorted
    ``free`` that threshold is the (K - k_e)-th entry — clipped to the
    interval; the served request drops the earliest finish time (<= start
    by feasibility) and inserts its own, keeping the carry sorted.

    Unserved semantics follow the golden oracle exactly: the reference
    loop's blocked search pops the *shared* busy heap while walking
    forward, and the pops persist. Its terminal states leave the heap
    holding precisely the finish times >= horizon, so an unserved request
    whose queue-adjusted arrival is still inside the horizon zeroes every
    slot finishing before the horizon (zeros keep the carry sorted).
    """
    mods = _jax_modules()
    if mods is None:                                     # pragma: no cover
        return None
    jax, jnp = mods
    K = k_pad

    def build():
        def one(t, s, cap_t, cap_k, hi_t, horizon, n_valid, slo_t):
            j = jnp.arange(K)
            # loop-invariant interval tables, hoisted out of the scan
            gi = jnp.clip(K - cap_k, 0, K - 1)
            closed = cap_k <= 0

            def body(carry, t_i, s_i):
                free, prev_start = carry
                s0 = jnp.maximum(t_i, prev_start)
                thresh = jnp.where(closed, jnp.inf, free[gi])
                lo = jnp.maximum(jnp.maximum(cap_t, thresh), s0)
                cand = jnp.where(lo < hi_t, lo, jnp.inf)
                start = jnp.min(cand)
                served = start < horizon
                fin = start + s_i
                g = free[1:]
                pos = jnp.sum(g < fin)
                g_up = jnp.concatenate([g, jnp.full((1,), jnp.inf,
                                                    g.dtype)])
                g_dn = jnp.concatenate([jnp.zeros((1,), g.dtype), g])
                merged = jnp.where(j < pos, g_up,
                                   jnp.where(j == pos, fin, g_dn))
                drained = (~served) & (s0 < horizon)
                free_u = jnp.where(drained & (free < horizon), 0.0, free)
                free2 = jnp.where(served, merged, free_u)
                prev2 = jnp.where(served, start, prev_start)
                lat = jnp.where(served, fin - t_i, jnp.inf)
                wait = jnp.where(served, start - t_i, jnp.inf)
                return (free2, prev2), lat, wait

            def step(carry, ts):
                t_c, s_c = ts               # [_UNROLL] requests per step
                lats, waits = [], []
                for c in range(_UNROLL):
                    carry, lat, wait = body(carry, t_c[c], s_c[c])
                    lats.append(lat)
                    waits.append(wait)
                return carry, (jnp.stack(lats), jnp.stack(waits))

            (_, _), (lat, wait) = jax.lax.scan(
                step, (jnp.zeros((K,), t.dtype), jnp.zeros((), t.dtype)),
                (t.reshape(-1, _UNROLL), s.reshape(-1, _UNROLL)))
            return _device_fold(jax, jnp, lat.reshape(-1),
                                wait.reshape(-1), n_valid, slo_t)

        return jax.jit(jax.vmap(one))

    return _cached_core(("pw", n_pad, e_pad, k_pad), build)


# requests consumed per scan step: amortizes the fixed per-step cost of
# the XLA loop (~2-3us on CPU, which otherwise dominates small batches)
# over several Kiefer–Wolfowitz updates. n_pad is always a multiple of it.
_UNROLL = 8


def _pad_bucket(n: int, floor: int) -> int:
    """Smallest grid point >= n on the half-pow2 grid {p, 1.5p, 2p}:
    per-cell padding waste stays under 50% (above ``floor``) while cells
    of similar size share a bucket — one compiled core, one batch — and
    the number of distinct compiled shapes stays logarithmic."""
    if n <= floor:
        return floor
    p = floor
    while p * 2 < n:
        p *= 2
    if p * 3 // 2 >= n:
        return p * 3 // 2
    return p * 2


def _pad_pow2(n: int, floor: int) -> int:
    """Smallest power-of-two grid point >= n. Used for the e/k axes of
    the bucket key: padding there only adds elementwise work (values are
    invariant — padded intervals are empty, padded slots hold inf), so a
    coarser band merges more cells per bucket, and per-step loop overhead
    amortizes over a bigger batch."""
    p = floor
    while p < n:
        p *= 2
    return p


def _job_horizon(job: QueueJob) -> float:
    if job.horizon is not None:
        return float(job.horizon)
    return float(job.trace.t[-1]) + 1e9 if len(job.trace) else 0.0


def _plan(jobs: Sequence[QueueJob]):
    """Bucket jobs by kind and padded trace length; returns (buckets,
    caps) where caps[i] is job i's ``capacity_steps`` arrays.

    Only ``n_pad`` is part of the key: the on-device fold reduces over the
    n axis, so a cell's float32 metrics depend on its n_pad (reduction
    tree shape) and that must stay a pure function of the cell alone —
    shard merges must stay bit-identical to single-shot campaign runs.
    The e/k axes are padded at dispatch time to the batch maximum instead:
    padding there is exactly value-invariant per lane (padded intervals
    start at +inf and never produce a candidate, padded slots only add
    zeros below the sorted free list, and gather/min/count ops on them are
    elementwise), so co-batching cells with different e/k changes the
    compiled shape but not one bit of any lane's result."""
    buckets: Dict[tuple, List[int]] = {}
    caps: List[Optional[tuple]] = [None] * len(jobs)
    for i, job in enumerate(jobs):
        n = len(job.trace)
        if n == 0:
            continue
        cap_t, cap_k = capacity_steps(job.capacity_events,
                                      job.model.slots_per_replica)
        caps[i] = (cap_t, cap_k)
        kind = "const" if len(cap_t) == 1 else "pw"
        buckets.setdefault((kind, _pad_bucket(n, 256)), []).append(i)
    return buckets, caps


def plan_queue_buckets(jobs: Sequence[QueueJob]) -> Dict[tuple, List[int]]:
    """Public view of the shape-bucket plan: {key: [job indices]}.

    Keys are ("const", n_pad) or ("pw", n_pad); a bucket's padded element
    count is ``len(rows) * n_pad``. Jobs with empty traces are handled on
    host and appear in no bucket."""
    return _plan(jobs)[0]


def _metrics_from_fold(n: int, cols: np.ndarray,
                       slo: SLOConfig) -> QueueMetrics:
    m = int(cols[0])
    if m == 0:
        return QueueMetrics(n, 0, np.inf, np.inf, np.inf, np.inf, np.inf,
                            np.inf, 1.0, False, n)
    viol = float(cols[7]) / n
    return QueueMetrics(n, m, float(cols[1]), float(cols[2]),
                        float(cols[3]), float(cols[4]), float(cols[5]),
                        float(cols[6]), viol,
                        viol <= slo.max_violation_rate, n - m)


def simulate_queue_batch(jobs: Sequence[QueueJob], backend: str = "auto",
                         stats_out: Optional[List[str]] = None
                         ) -> List[QueueMetrics]:
    """Batched FIFO M/G/k(t) simulation over heterogeneous cells.

    Jobs are grouped into padded shape buckets and dispatched as
    ``jit(vmap(lax.scan))`` device programs — constant-capacity cells on
    the Kiefer–Wolfowitz core, piecewise-capacity cells on the k(t)-aware
    sorted-slot core — with the metric fold fused on device (float32:
    metrics agree with the exact paths to golden tolerance, not bitwise).
    Falls back to the exact per-cell ``simulate_queue`` dispatch when JAX
    is unavailable or ``backend='numpy'``. Results come back in input
    order; ``stats_out``, when given, receives one impl tag per job
    ("jax_batched" or "numpy")."""
    if backend not in ("auto", "jax", "numpy"):
        raise ValueError(f"unknown backend {backend!r}")
    out: List[Optional[QueueMetrics]] = [None] * len(jobs)
    tags = ["numpy"] * len(jobs)
    use_jax = backend != "numpy" and _jax_modules() is not None
    buckets, caps = _plan(jobs) if use_jax else ({}, [None] * len(jobs))
    on_device = {i for rows in buckets.values() for i in rows}
    for i, job in enumerate(jobs):
        if i not in on_device:
            out[i] = simulate_queue(job.trace, job.capacity_events,
                                    job.model, job.slo,
                                    horizon=job.horizon)
    if not buckets:
        if stats_out is not None:
            stats_out.extend(tags)
        return out  # type: ignore[return-value]

    t0_wall = time.perf_counter()
    _, jnp = _jax_modules()
    n_req = 0
    for key, rows in sorted(buckets.items()):
        kind, n_pad = key[0], key[1]
        B = len(rows)
        t_b = np.full((B, n_pad), np.inf, dtype=np.float32)
        s_b = np.zeros((B, n_pad), dtype=np.float32)
        hz = np.empty(B, dtype=np.float32)
        nv = np.empty(B, dtype=np.int32)
        st = np.empty(B, dtype=np.float32)
        for r, i in enumerate(rows):
            job = jobs[i]
            tr = job.trace
            n = len(tr)
            t_b[r, :n] = tr.t
            s_b[r, :n] = job.model.service_times(tr.prompt_tokens,
                                                 tr.decode_tokens)
            hz[r] = _job_horizon(job)
            nv[r] = n
            st[r] = job.slo.latency_target_s
        if kind == "const":
            k_pad = _pad_pow2(max(max(int(caps[i][1][0]), 1)
                                  for i in rows), 8)
            free0 = np.zeros((B, k_pad), dtype=np.float32)
            for r, i in enumerate(rows):
                free0[r, int(caps[i][1][0]):] = np.inf
            core = _kw_batched_core(n_pad, k_pad)
            res = core(jnp.asarray(t_b), jnp.asarray(s_b),
                       jnp.asarray(free0), jnp.asarray(hz),
                       jnp.asarray(nv), jnp.asarray(st))
        else:
            e_pad = -8 * (-max(len(caps[i][0]) for i in rows) // 8)
            k_pad = -8 * (-max(max(int(caps[i][1].max()), 1)
                               for i in rows) // 8)
            ct_b = np.full((B, e_pad), np.inf, dtype=np.float32)
            hi_b = np.full((B, e_pad), np.inf, dtype=np.float32)
            ck_b = np.zeros((B, e_pad), dtype=np.int32)
            for r, i in enumerate(rows):
                cap_t, cap_k = caps[i]
                e = len(cap_t)
                ct_b[r, :e] = cap_t
                ck_b[r, :e] = cap_k
                hi_b[r, :e - 1] = cap_t[1:]
            core = _pw_batched_core(n_pad, e_pad, k_pad)
            res = core(jnp.asarray(t_b), jnp.asarray(s_b),
                       jnp.asarray(ct_b), jnp.asarray(ck_b),
                       jnp.asarray(hi_b), jnp.asarray(hz),
                       jnp.asarray(nv), jnp.asarray(st))
        res = np.asarray(res, dtype=np.float64)          # [B, FOLD_COLS]
        for r, i in enumerate(rows):
            out[i] = _metrics_from_fold(len(jobs[i].trace), res[r],
                                        jobs[i].slo)
            tags[i] = "jax_batched"
            n_req += len(jobs[i].trace)
    SIM_COUNTERS["calls"] += len(on_device)
    SIM_COUNTERS["requests"] += n_req
    SIM_COUNTERS["seconds"] += time.perf_counter() - t0_wall
    SIM_COUNTERS["jax_batched"] += len(on_device)
    if stats_out is not None:
        stats_out.extend(tags)
    return out  # type: ignore[return-value]


def simulate_queue_many(traces: Sequence[RequestTrace],
                        capacities: Sequence[Sequence[Tuple[float, int]]],
                        model: ServiceTimeModel,
                        slo: SLOConfig,
                        horizon: Optional[float] = None,
                        backend: str = "auto") -> List[QueueMetrics]:
    """Batched FIFO queue simulation over many grid cells sharing one
    model/slo/horizon — a thin wrapper over ``simulate_queue_batch``."""
    if len(traces) != len(capacities):
        raise ValueError("traces and capacities must align")
    jobs = [QueueJob(tr, ev, model, slo, horizon)
            for tr, ev in zip(traces, capacities)]
    return simulate_queue_batch(jobs, backend=backend)


# ------------------------------------------------- analytic approximation


def sakasegawa_wait(rate: float, mean_s: float, scv_s: float,
                    k_slots: int, scv_a: float = 1.0) -> float:
    """Allen–Cunneen / Sakasegawa mean-wait approximation for G/G/k.

    Wq ~= (Ca^2 + Cs^2)/2 * rho^(sqrt(2(k+1)) - 1) / (k (1 - rho)) * E[s].
    Returns inf when rho >= 1. The autoscaler inverts this numerically to
    pick the smallest k meeting the latency target.
    """
    if k_slots <= 0:
        return np.inf
    rho = rate * mean_s / k_slots
    if rho >= 1.0:
        return np.inf
    if rho <= 0.0:
        return 0.0
    return ((scv_a + scv_s) / 2.0
            * rho ** (np.sqrt(2.0 * (k_slots + 1)) - 1.0)
            / (k_slots * (1.0 - rho)) * mean_s)


def predicted_percentile_latency(rate: float, mean_s: float, scv_s: float,
                                 p99_service_s: float, k_slots: int,
                                 percentile: float = 99.0,
                                 scv_a: float = 1.0) -> float:
    """Predicted latency percentile: service tail + exponential wait tail.

    With mean wait Wq, the waiting-time tail is approximated exponential, so
    the p-th percentile of wait is -ln(1 - p/100) * Wq (4.6x Wq at p99).
    """
    wq = sakasegawa_wait(rate, mean_s, scv_s, k_slots, scv_a)
    if not np.isfinite(wq):
        return np.inf
    tail = -np.log(max(1e-12, 1.0 - percentile / 100.0))
    return p99_service_s + tail * wq
