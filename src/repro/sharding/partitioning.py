"""Parameter/activation partitioning rules (logical rules -> PartitionSpec).

Axes:
  model : tensor parallelism (Megatron-style column/row parallel + expert-TP)
  data  : data parallelism; with ``fsdp=True`` parameters are additionally
          sharded over `data` on a free dimension (ZeRO-3 / weight-gather);
          optimizer state is always sharded over `data` (ZeRO-1) when possible
  pod   : outer data-parallel axis of the multi-pod mesh (batch only)

Rules are path-based over the parameter pytree produced by
``repro.models.model.init_params``. Parameter names are unique per role:
column-parallel projections, row-parallel projections, rglru channel params,
and xLSTM mixers (replicated baseline — 4 heads give no useful TP; revisited
in the perf hillclimb). XLA GSPMD propagates everything else.
"""
from __future__ import annotations

import re
from typing import Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

# output dim -> model (column parallel); FSDP shards a free dim over data
_COL_NAMES = {"wq", "wk", "wv", "wi_gate", "wi_up", "w_gate_in", "w_rnn_in",
              "w_ff_up", "head"}
# input dim -> model (row parallel)
_ROW_NAMES = {"wo", "w_out", "w_down", "w_ff_down"}
# rglru per-channel params: last dim follows the model-sharded rnn width
_RG_CHANNEL = {"rg_conv_w", "rg_conv_b", "lam"}
# rglru gate matrices [W, W]: row-parallel (contract the sharded channel dim)
_RG_GATES = {"w_rg", "w_ig"}
# xLSTM mixer params: replicated baseline
_XLSTM = {"w_up", "w_gate", "w_q", "w_k", "w_v", "w_i", "w_f", "rec",
          "out_scale", "conv_w", "conv_b", "w_z", "w_o"}


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def _owner(path: str) -> str:
    """Name of the parameter (dict key above the kernel/bias/scale leaf)."""
    parts = path.split("/")
    return parts[-2] if parts[-1] in ("kernel", "bias", "scale") else parts[-1]


def _shard_free_dim(shape, spec, axis: str, size: int):
    best, best_dim = -1, -1
    for i, s in enumerate(shape):
        if spec[i] is None and s % size == 0 and s > best:
            best, best_dim = s, i
    if best_dim >= 0:
        spec[best_dim] = axis
    return spec


def param_specs(shape_tree, cfg: ModelConfig, mesh: Mesh, *, fsdp: bool = False,
                tp: int = 0):
    """Tree of PartitionSpec matching a params (or ShapeDtypeStruct) tree.

    tp=1 selects the pure-FSDP layout: no tensor parallelism; parameters are
    sharded over the combined (data, model) axes and the batch uses both
    axes as data parallelism (see dp_axes). Default tp=0 means full-width TP.
    """
    msz = mesh.shape["model"] if tp == 0 else tp
    dsz = mesh.shape["data"]
    if tp == 1:
        fs_axis = ("data", "model")
        fs_size = mesh.shape["data"] * mesh.shape["model"]

        def one_fsdp(path, leaf):
            spec = [None] * len(leaf.shape)
            if fsdp and leaf.size >= 1 << 16:
                _shard_free_dim(leaf.shape, spec, fs_axis, fs_size)
            return P(*spec)

        return jax.tree_util.tree_map_with_path(one_fsdp, shape_tree)

    def one(path, leaf):
        p = _path_str(path)
        shape = leaf.shape
        ndim = len(shape)
        spec = [None] * ndim
        name = _owner(p)
        leafname = p.split("/")[-1]
        is_moe = "/moe/" in p

        if name == "router":
            return P(*spec)                                   # replicated
        if name in _XLSTM and not is_moe:
            if fsdp and leaf.size >= 1 << 20:
                _shard_free_dim(shape, spec, "data", dsz)     # generic ZeRO-3
            return P(*spec)
        if "embed/table" in p:
            if shape[0] % msz == 0:
                spec[0] = "model"
            if fsdp and shape[1] % dsz == 0:
                spec[1] = "data"
        elif is_moe and leafname != "kernel":
            # stacked expert weights [R?, E, in, out]-style
            if name in ("wi_gate", "wi_up") and shape[-1] % msz == 0:
                spec[-1] = "model"
            elif name == "wo" and shape[-2] % msz == 0:
                spec[-2] = "model"
            if cfg.moe is not None and cfg.moe.expert_parallel:
                off = 1 if "repeats/" in p else 0
                if shape[off] % dsz == 0:
                    spec[off] = "data"       # expert parallelism
                elif fsdp:
                    _shard_free_dim(shape, spec, "data", dsz)
            elif fsdp:
                _shard_free_dim(shape, spec, "data", dsz)
        elif name in _COL_NAMES:
            if leafname == "kernel":
                if shape[-1] % msz == 0:
                    spec[-1] = "model"
                if fsdp:
                    _shard_free_dim(shape, spec, "data", dsz)
            elif leafname == "bias" and shape[-1] % msz == 0:
                spec[-1] = "model"
        elif name in _ROW_NAMES:
            if leafname == "kernel":
                if shape[-2] % msz == 0:
                    spec[-2] = "model"
                if fsdp:
                    _shard_free_dim(shape, spec, "data", dsz)
        elif name in _RG_CHANNEL or leafname in _RG_CHANNEL:
            if shape[-1] % msz == 0:
                spec[-1] = "model"
        elif name in _RG_GATES:
            if leafname == "kernel" and shape[-2] % msz == 0:
                spec[-2] = "model"
        return P(*spec)

    return jax.tree_util.tree_map_with_path(one, shape_tree)


# ----------------------------------------------------------------- batches


def batch_axes(mesh: Mesh, tp: int = 0):
    axes = ("pod", "data") if "pod" in mesh.shape else ("data",)
    if tp == 1:
        axes = axes + ("model",)
    return axes


def dp_size(mesh: Mesh, tp: int = 0) -> int:
    total = 1
    for a in batch_axes(mesh, tp):
        total *= mesh.shape[a]
    return total


def data_spec(mesh: Mesh, shape: Tuple[int, ...], *, batch_dim: int = 0,
              tp: int = 0) -> P:
    """Shard the batch dim over the widest divisible prefix of the DP axes
    (e.g. global_batch=256 on the 2x16x16 mesh with tp=1 shards over
    (data, model) = 256 and replicates over pod)."""
    axes = batch_axes(mesh, tp)
    spec = [None] * len(shape)
    candidates = [axes]
    if len(axes) > 1:
        candidates += [axes[1:], axes[:-1], axes[1:-1] or axes[-1:],
                       axes[-1:], axes[:1]]
    for cand in candidates:
        size = 1
        for a in cand:
            size *= mesh.shape[a]
        if size and shape[batch_dim] % size == 0:
            spec[batch_dim] = cand if len(cand) > 1 else cand[0]
            return P(*spec)
    return P(*spec)


def cache_specs(cache_tree, cfg: ModelConfig, mesh: Mesh, *, tp: int = 0):
    """Specs for a KV/recurrent cache tree.

    k/v [R?, B, L, K, hd]: batch over data axes when divisible; otherwise the
    kv-head dim (K % model == 0) or a large length dim goes over `model`.
    With tp=1 the model axis joins the batch axes instead.
    """
    msz = mesh.shape["model"] if tp == 0 else tp

    def one(path, leaf):
        p = _path_str(path)
        shape = leaf.shape
        ndim = len(shape)
        off = 1 if "repeats/" in p else 0
        name = p.split("/")[-1]
        spec = [None] * ndim
        if name in ("k", "v"):
            bs = data_spec(mesh, shape, batch_dim=off, tp=tp)
            spec[off] = bs[off]
            L, K = shape[off + 1], shape[off + 2]
            if tp != 1:
                if K % msz == 0:
                    spec[off + 2] = "model"
                elif L % msz == 0 and L >= 8192:
                    spec[off + 1] = "model"
        elif name == "pos":
            pass
        elif name in ("h", "conv") and shape[-1] in (cfg.lru_width,):
            bs = data_spec(mesh, shape, batch_dim=off, tp=tp)
            spec[off] = bs[off]
            if tp != 1 and shape[-1] % msz == 0:
                spec[-1] = "model"
        else:  # xlstm states: batch-shard only
            bs = data_spec(mesh, shape, batch_dim=off, tp=tp)
            spec[off] = bs[off]
        return P(*spec)

    return jax.tree_util.tree_map_with_path(one, cache_tree)


# ------------------------------------------------------------- activations


def make_constrain(mesh: Mesh, *, sequence_parallel: bool = False,
                   tp: int = 0):
    """Residual-stream constraint hook passed into the model."""
    axes = batch_axes(mesh, tp)
    baxis = axes if len(axes) > 1 else axes[0]

    def _bspec(x):
        # widest divisible DP-axis prefix (same fallback chain as data_spec)
        return data_spec(mesh, x.shape, batch_dim=0, tp=tp)[0]

    def constrain(x, kind: str):
        if x.ndim == 3 and kind in ("residual", "moe_group"):
            seq = None
            if (tp != 1 and kind == "residual" and sequence_parallel
                    and x.shape[1] % mesh.shape["model"] == 0):
                seq = "model"
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(_bspec(x), seq, None)))
        if kind in ("moe_local", "moe_ff"):
            # MoE dispatch intermediates: group dim 0 stays on the data
            # axes, everything else local — GSPMD otherwise loses the
            # sharding through sort/scatter and replicates TB-scale dispatch
            # buffers (the "involuntary full rematerialization" warnings).
            spec = [_bspec(x)] + [None] * (x.ndim - 1)
            if (kind == "moe_ff" and tp != 1
                    and x.shape[-1] % mesh.shape["model"] == 0):
                spec[-1] = "model"   # expert-TP: ffn dim on the model axis
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(*spec)))
        if kind in ("moe_ep_buf", "moe_ep_ff"):
            # expert parallelism: resharding [G, E, ...] from group-sharded
            # to expert-sharded makes GSPMD emit the all-to-all; the expert
            # matmuls then run on data-axis-local experts.
            spec = [None] * x.ndim
            if x.shape[1] % mesh.shape["data"] == 0:
                spec[1] = "data"
            if (kind == "moe_ep_ff" and tp != 1
                    and x.shape[-1] % mesh.shape["model"] == 0):
                spec[-1] = "model"
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(*spec)))
        return x

    return constrain


def to_shardings(spec_tree, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def zero1_specs(param_spec_tree, shape_tree, mesh: Mesh):
    """Optimizer-state specs: param spec + extra `data` sharding (ZeRO-1)."""
    dsz = mesh.shape["data"]

    def one(spec: P, leaf):
        shape = leaf.shape
        s = list(spec) + [None] * (len(shape) - len(spec))
        used = set()
        for a in s:
            used.update(a if isinstance(a, tuple) else (a,))
        if "data" not in used:
            _shard_free_dim(shape, s, "data", dsz)
        return P(*s)

    return jax.tree.map(one, param_spec_tree, shape_tree,
                        is_leaf=lambda x: isinstance(x, P))
