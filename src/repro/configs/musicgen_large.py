"""musicgen-large [audio] — decoder-only over EnCodec tokens.

48L d_model=2048 32H (kv=32) d_ff=8192 vocab=2048, 4 codebooks (delay pattern).
[arXiv:2306.05284; hf]
The EnCodec frontend is a modality stub: input_specs() provides precomputed
per-frame embeddings [B, S, d_model] (sum of the 4 codebook embeddings); the
output is 4 codebook heads of vocab 2048 each.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    block_pattern=("attn",),
    norm="layernorm",
    act="gelu",
    num_codebooks=4,
    input_mode="embeddings",
    rope_theta=10_000.0,
)
