"""deepseek-7b [dense] — llama-arch. 30L d=4096 32H (kv=32) ff=11008 vocab=102400.

[arXiv:2401.02954; hf]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b",
    family="dense",
    num_layers=30,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    head_dim=128,
    d_ff=11_008,
    vocab_size=102_400,
    block_pattern=("attn",),
    act="silu",
    rope_theta=10_000.0,
)
