"""mistral-large-123b [dense] — 88L d=12288 96H (kv=8) ff=28672 vocab=32768.

[hf:mistralai/Mistral-Large-Instruct-2407; unverified]
The memory-pressure stress case of the pool: ~123B params.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-large-123b",
    family="dense",
    num_layers=88,
    d_model=12_288,
    num_heads=96,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28_672,
    vocab_size=32_768,
    block_pattern=("attn",),
    act="silu",
    rope_theta=1_000_000.0,
)
