"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, 1:2 attn:recurrent.

26L d_model=2560 10H (MQA kv=1) d_ff=7680 vocab=256000  [arXiv:2402.19427; hf]
Pattern: (rglru, rglru, local) repeated; 26 % 3 = 2 trailing rglru layers.
Local attention window 2048 (Griffin); head_dim = 256.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256_000,
    block_pattern=("rglru", "rglru", "local"),
    window_size=2048,
    rnn_width=2560,
    conv_width=4,
    act="gelu",
    rope_theta=10_000.0,
    tie_embeddings=True,
    logit_softcap=30.0,
    supports_long_context=True,
)
