"""Architecture registry: one module per assigned architecture."""
from __future__ import annotations

from .base import (ALL_SHAPES, DECODE_32K, LONG_500K, PREFILL_32K, SHAPES_BY_NAME,
                   TRAIN_4K, ModelConfig, MoEConfig, ShapeConfig, TrainConfig,
                   shapes_for)

from .recurrentgemma_2b import CONFIG as recurrentgemma_2b
from .deepseek_7b import CONFIG as deepseek_7b
from .qwen2_7b import CONFIG as qwen2_7b
from .mistral_large_123b import CONFIG as mistral_large_123b
from .gemma3_12b import CONFIG as gemma3_12b
from .chameleon_34b import CONFIG as chameleon_34b
from .qwen3_moe_30b_a3b import CONFIG as qwen3_moe_30b_a3b
from .dbrx_132b import CONFIG as dbrx_132b
from .musicgen_large import CONFIG as musicgen_large
from .xlstm_1_3b import CONFIG as xlstm_1_3b

ARCHS = {
    c.name: c
    for c in (
        recurrentgemma_2b,
        deepseek_7b,
        qwen2_7b,
        mistral_large_123b,
        gemma3_12b,
        chameleon_34b,
        qwen3_moe_30b_a3b,
        dbrx_132b,
        musicgen_large,
        xlstm_1_3b,
    )
}


def get_config(name: str) -> ModelConfig:
    key = name.replace("_", "-")
    if key not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[key]


def reduced_config(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests (one fwd/train step)."""
    kw = dict(
        num_layers=max(len(cfg.block_pattern), 2),
        d_model=64,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads < cfg.num_heads else 4,
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        window_size=min(cfg.window_size, 16) if cfg.window_size else 0,
        rnn_width=64 if cfg.rnn_width else 0,
        param_dtype="float32",
        compute_dtype="float32",
    )
    if cfg.moe is not None:
        # capacity_factor 8 => effectively dropless at smoke-test scale, so
        # train-vs-decode consistency checks are exact (dropping is a
        # legitimate train/serve divergence in capacity-bounded MoE).
        kw["moe"] = MoEConfig(num_experts=8, top_k=2, d_ff_expert=32,
                              capacity_factor=8.0)
    return cfg.with_(**kw)
