"""dbrx-132b [moe] — 16 experts top-4, fine-grained.

40L d_model=6144 48H (kv=8) ff_expert=10752 vocab=100352.
[hf:databricks/dbrx-base; unverified]
"""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=10_752,
    vocab_size=100_352,
    block_pattern=("attn",),
    act="silu",
    rope_theta=500_000.0,
    moe=MoEConfig(num_experts=16, top_k=4, d_ff_expert=10_752, capacity_factor=1.25),
)
