"""gemma3-12b [dense] — 5:1 local:global, 128k context.

48L d_model=3840 16H (kv=8) d_ff=15360 vocab=262144, head_dim=256, window=1024.
[hf:google/gemma-3-1b-pt; unverified]
Runs long_500k: only the 8 global layers keep a full-length KV cache.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    num_layers=48,
    d_model=3840,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=15_360,
    vocab_size=262_144,
    block_pattern=("local", "local", "local", "local", "local", "attn"),
    window_size=1024,
    act="gelu",
    qk_norm=True,
    rope_theta=1_000_000.0,
    rope_theta_local=10_000.0,
    tie_embeddings=True,
    supports_long_context=True,
)
