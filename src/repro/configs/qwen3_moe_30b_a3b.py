"""qwen3-moe-30b-a3b [moe] — 128 experts top-8, fine-grained (ff_expert=768).

48L d_model=2048 32H (kv=4) vocab=151936.  [hf:Qwen/Qwen3-30B-A3B; hf]
"""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=768,  # per-expert ff (dense path unused)
    vocab_size=151_936,
    block_pattern=("attn",),
    qk_norm=True,
    act="silu",
    rope_theta=1_000_000.0,
    moe=MoEConfig(num_experts=128, top_k=8, d_ff_expert=768, capacity_factor=1.25),
)
