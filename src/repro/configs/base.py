"""Model / run configuration dataclasses.

Every assigned architecture is expressed as a ``ModelConfig``: a decoder-only
stack whose per-layer *kind* is given by ``block_pattern`` repeated over
``num_layers``.  Block kinds:

  ``attn``   global causal self-attention + gated MLP
  ``local``  sliding-window causal self-attention + gated MLP
  ``rglru``  RG-LRU recurrent block (Griffin-style) + gated MLP
  ``mlstm``  mLSTM block (matrix memory, chunkwise-parallel), self-contained
  ``slstm``  sLSTM block (scalar memory, sequential recurrence), self-contained

MoE replaces the dense MLP in ``attn``/``local`` blocks when ``moe`` is set.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    # number of token groups used for sort-based dispatch; 0 -> one group per
    # data shard (set at lowering time from the mesh).
    num_groups: int = 0
    # expert parallelism: shard experts over the data axis and route dispatch
    # buffers with all-to-alls (vs the default expert-TP which keeps dispatch
    # local and reduces over the model axis). EXPERIMENTS.md §Perf i5.
    expert_parallel: bool = False


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    block_pattern: Tuple[str, ...] = ("attn",)
    window_size: int = 0              # sliding window for "local" blocks
    qkv_bias: bool = False
    qk_norm: bool = False
    logit_softcap: float = 0.0
    rope_theta: float = 10_000.0
    rope_theta_local: float = 0.0     # gemma3 uses a different theta for local layers
    norm: str = "rmsnorm"             # rmsnorm | layernorm
    act: str = "silu"                 # silu | gelu
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    # --- audio (musicgen) ---
    num_codebooks: int = 0            # >0: multi-codebook output heads
    input_mode: str = "tokens"        # tokens | embeddings (modality stub)
    # --- recurrent blocks ---
    rnn_width: int = 0                # RG-LRU state width (0 -> d_model)
    conv_width: int = 4               # temporal conv width in recurrent blocks
    # --- xlstm ---
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 4.0 / 3.0
    # --- numerics ---
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    # sub-quadratic archs support the 500k decode shape
    supports_long_context: bool = False

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def lru_width(self) -> int:
        return self.rnn_width or self.d_model

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer block kinds, pattern repeated/truncated to num_layers."""
        p = self.block_pattern
        reps = (self.num_layers + len(p) - 1) // len(p)
        return tuple((p * reps)[: self.num_layers])

    def num_param_layers(self) -> int:
        return self.num_layers

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- parameter count (analytic; used for MODEL_FLOPS = 6 N D) ----
    def param_count(self, active_only: bool = False) -> int:
        d, ff = self.d_model, self.d_ff
        n = 0
        emb = self.vocab_size * d
        n += emb  # input embedding
        if not self.tie_embeddings:
            if self.num_codebooks > 0:
                n += self.num_codebooks * self.vocab_size * d
            else:
                n += emb
        for kind in self.layer_kinds():
            if kind in ("attn", "local"):
                n += d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
                if self.qkv_bias:
                    n += self.q_dim + 2 * self.kv_dim
                if self.moe is not None:
                    e = self.moe.top_k if active_only else self.moe.num_experts
                    n += d * self.moe.num_experts  # router
                    n += e * 3 * d * self.moe.d_ff_expert
                else:
                    n += 3 * d * ff
                n += 2 * d  # norms
            elif kind == "rglru":
                w = self.lru_width
                n += 2 * d * w + w * d          # branch in/out projections
                n += self.conv_width * w         # temporal conv
                n += 2 * w * w                   # gate projections (block-diag approx)
                n += 2 * w                       # Lambda + input-gate params
                n += 3 * d * ff + 2 * d          # MLP + norms
            elif kind == "mlstm":
                inner = int(self.d_model * self.mlstm_proj_factor)
                n += 2 * d * inner               # up (x and gate)
                n += 3 * inner * inner // 1      # q,k,v projections (inner->inner)
                n += 2 * inner                   # i,f gate projections (per-dim)
                n += inner * d                   # down
                n += 2 * d
            elif kind == "slstm":
                inner = int(self.d_model * self.slstm_proj_factor)
                n += 4 * d * d                   # z,i,f,o input projections
                n += 4 * d * self.head_dim       # block-diag recurrent weights
                n += 4 * d                       # biases
                n += d * inner + inner * d       # post-FFN
                n += 2 * d
        return n


@dataclass(frozen=True)
class ShapeConfig:
    """One (workload shape) cell: what gets lowered for the dry-run."""
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


def shapes_for(cfg: ModelConfig) -> Tuple[ShapeConfig, ...]:
    """Shapes applicable to this architecture (long_500k needs sub-quadratic)."""
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.supports_long_context:
        out.append(LONG_500K)
    return tuple(out)


@dataclass(frozen=True)
class TrainConfig:
    """Per-run training hyperparameters / distribution knobs."""
    microbatch: int = 0            # 0 -> no gradient accumulation
    remat: str = "block"           # none | block | full
    zero1: bool = True             # shard optimizer state over data axis
    sequence_parallel: bool = False
    grad_compression: str = "none" # none | int8
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    z_loss: float = 1e-4
