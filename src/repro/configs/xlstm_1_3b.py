"""xlstm-1.3b [ssm] — sLSTM + mLSTM blocks, xLSTM[7:1] ratio.

48L d_model=2048 4H vocab=50304, d_ff=0 (blocks carry their own projections).
[arXiv:2405.04517; unverified]
Pattern: 7 mLSTM : 1 sLSTM, repeated 6x over 48 layers.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    head_dim=512,
    d_ff=0,
    vocab_size=50_304,
    block_pattern=("mlstm",) * 7 + ("slstm",),
    norm="layernorm",
    act="gelu",
    mlstm_proj_factor=2.0,
    slstm_proj_factor=4.0 / 3.0,
    supports_long_context=True,
)
