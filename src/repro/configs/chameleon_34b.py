"""chameleon-34b [vlm] — early-fusion, VQ image tokens share the vocab.

48L d_model=8192 64H (kv=8) d_ff=22016 vocab=65536.  [arXiv:2405.09818; unverified]
The VQ-GAN image tokenizer is a modality frontend stub: input_specs() feeds
precomputed token ids (text + image tokens interleaved in one sequence).
QK-norm per the Chameleon stability recipe.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    num_layers=48,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22_016,
    vocab_size=65_536,
    block_pattern=("attn",),
    qk_norm=True,
    act="silu",
    rope_theta=10_000.0,
)
