"""Flash-decode Pallas TPU kernel: one query token vs a long KV cache.

Grid: (batch*kv_heads, num_kv_blocks) — kv blocks iterate sequentially, the
online-softmax state for the G = H/K grouped query heads persists in VMEM
scratch. Slot validity comes from the cache's position array (ring buffers
store -1 in empty slots); the sliding-window test uses the stored absolute
positions, so ring wraparound needs no special casing.

This is the decode_32k / long_500k hot spot: arithmetic intensity is O(1)
FLOP/byte (every cache byte is read once per token), i.e. HBM-bandwidth
-bound — the kernel's job is to stream the cache at full bandwidth with the
softmax state pinned in VMEM.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(cur_pos_ref, q_ref, k_ref, v_ref, pos_ref, o_ref,
                   acc_ref, m_ref, l_ref, *,
                   scale: float, block_k: int, window: int):
    ik = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)                     # [G, hd]
    k = k_ref[0].astype(jnp.float32)                     # [bk, hd]
    v = v_ref[0]                                         # [bk, hd]
    slot_pos = pos_ref[...]                              # [1, bk] i32
    cur_pos = cur_pos_ref[0]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    valid = (slot_pos >= 0) & (slot_pos <= cur_pos)
    if window > 0:
        valid &= slot_pos > cur_pos - window
    s = jnp.where(valid, s, NEG_INF)                     # [G, bk] via bcast

    m_prev = m_ref[...]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    m_ref[...] = m_new
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ik == nk - 1)
    def _done():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def decode_attention_fwd(q, k, v, slot_pos, cur_pos, *, window: int = 0,
                         block_k: int = 512, interpret: bool = False):
    """q: [BK, G, hd]; k/v: [BK, S, hd]; slot_pos: [1, S] i32; cur_pos: [1] i32.

    BK = batch * kv_heads; G = query heads per kv head. Returns [BK, G, hd].
    """
    BK, G, hd = q.shape
    S = k.shape[1]
    block_k = min(block_k, S)
    assert S % block_k == 0, (S, block_k)
    grid = (BK, S // block_k)
    scale = 1.0 / math.sqrt(hd)

    kernel = functools.partial(_decode_kernel, scale=scale, block_k=block_k,
                               window=window)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),       # cur_pos
            pl.BlockSpec((1, G, hd), lambda b, ik: (b, 0, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, ik: (b, ik, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, ik: (b, ik, 0)),
            pl.BlockSpec((1, block_k), lambda b, ik: (0, ik)),
        ],
        out_specs=pl.BlockSpec((1, G, hd), lambda b, ik: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BK, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, hd), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
        interpret=interpret,
    )(cur_pos, q, k, v, slot_pos)
