"""Pure-jnp oracle for the decode attention kernel."""
from __future__ import annotations

import math

import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_reference(q, k, v, slot_pos, cur_pos, *,
                               window: int = 0):
    """q: [BK, G, hd]; k/v: [BK, S, hd]; slot_pos: [1, S]; cur_pos: [1]."""
    hd = q.shape[-1]
    s = jnp.einsum("bgd,bsd->bgs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    sp = slot_pos[0]
    valid = (sp >= 0) & (sp <= cur_pos[0])
    if window > 0:
        valid &= sp > cur_pos[0] - window
    s = jnp.where(valid[None, None, :], s, NEG_INF)
    w = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-30)
    return jnp.einsum("bgs,bsd->bgd", w,
                      v.astype(jnp.float32)).astype(q.dtype)
