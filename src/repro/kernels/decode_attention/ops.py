"""Jit'd wrapper for decode attention (model cache layout in)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import decode_attention_fwd
from repro.kernels.decode_attention.ref import decode_attention_reference


@functools.partial(jax.jit, static_argnames=("window", "impl", "block_k"))
def decode_attention(q, cache_k, cache_v, slot_pos, cur_pos, *,
                     window: int = 0, impl: str = "auto",
                     block_k: int = 512):
    """q: [B, H, hd]; cache_k/v: [B, L, K, hd]; slot_pos: [L]; cur_pos scalar.

    Returns [B, H, hd].
    """
    B, H, hd = q.shape
    L, K = cache_k.shape[1], cache_k.shape[2]
    G = H // K
    qk = q.reshape(B, K, G, hd).reshape(B * K, G, hd)
    kk = cache_k.transpose(0, 2, 1, 3).reshape(B * K, L, hd)
    vk = cache_v.transpose(0, 2, 1, 3).reshape(B * K, L, hd)
    sp = slot_pos.reshape(1, L)
    cp = jnp.asarray(cur_pos, jnp.int32).reshape(1)
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        out = decode_attention_reference(qk, kk, vk, sp, cp, window=window)
    else:
        out = decode_attention_fwd(qk, kk, vk, sp, cp, window=window,
                                   block_k=block_k,
                                   interpret=(impl == "interpret"))
    return out.reshape(B, K, G, hd).reshape(B, H, hd)
