"""Jit'd public wrapper: model-layout in, kernel-layout dispatch.

``flash_attention`` takes [B, S, H, hd] / [B, S, K, hd] (the model layout of
repro.models.attention) and dispatches to the Pallas TPU kernel on TPU
backends, interpret-mode Pallas when requested, or the jnp reference
otherwise (CPU dry-run path).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_fwd
from repro.kernels.flash_attention.ref import flash_attention_reference


def _to_kernel_layout(x):
    # [B, S, H, hd] -> [B*H, S, hd]
    B, S, H, hd = x.shape
    return x.transpose(0, 2, 1, 3).reshape(B * H, S, hd)


def _from_kernel_layout(x, B, H):
    BH, S, hd = x.shape
    return x.reshape(B, H, S, hd).transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("causal", "window", "impl",
                                             "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    impl: str = "auto", block_q: int = 256,
                    block_k: int = 256):
    """q: [B, S, H, hd]; k/v: [B, S, K, hd]. Returns [B, S, H, hd]."""
    B, S, H, hd = q.shape
    K = k.shape[2]
    qk = _to_kernel_layout(q)
    kk = _to_kernel_layout(k)
    vk = _to_kernel_layout(v)
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        out = flash_attention_reference(qk, kk, vk, causal=causal,
                                        window=window)
    else:
        out = flash_attention_fwd(qk, kk, vk, causal=causal, window=window,
                                  block_q=block_q, block_k=block_k,
                                  interpret=(impl == "interpret"))
    return _from_kernel_layout(out, B, H)
