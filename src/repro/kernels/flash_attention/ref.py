"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import math

import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_reference(q, k, v, *, causal: bool = True,
                              window: int = 0):
    """q: [BH, S, hd]; k/v: [BK, S, hd] (GQA group = BH // BK)."""
    BH, S, hd = q.shape
    BK = k.shape[0]
    g = BH // BK
    k = jnp.repeat(k, g, axis=0)
    v = jnp.repeat(v, g, axis=0)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    qp = jnp.arange(S)[:, None]
    kp = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= kp <= qp
    if window > 0:
        mask &= kp > qp - window
    s = jnp.where(mask[None], s, NEG_INF)
    w = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-30)
    return jnp.einsum("bqk,bkd->bqd", w, v.astype(jnp.float32)).astype(q.dtype)
