"""Flash attention Pallas TPU kernel (forward).

Grid: (batch*q_heads, num_q_blocks, num_kv_blocks) — the kv dimension is the
innermost, sequentially-iterated grid axis on TPU, so the online-softmax
running state (m, l, acc) lives in VMEM scratch and persists across kv steps.

BlockSpecs stage [block_q, head_dim] query tiles and [block_k, head_dim]
key/value tiles into VMEM; `head_dim` and the block sizes should be multiples
of 128 to keep the MXU fully fed (lanes=128; sublanes=8 for f32/bf16 tiles).

GQA is handled in the index maps: query head h reads kv head h // group_size.
Causal and sliding-window masking are applied with 2D iotas; fully-masked
tiles still occupy grid slots (documented roofline overhead ~2x on the
attention term; the XLA path in models/attention.py skips above-diagonal
tiles instead — see EXPERIMENTS.md §Perf for the comparison).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                      scale: float, block_q: int, block_k: int,
                      seq_len: int, causal: bool, window: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)                       # [bq, hd]
    k = k_ref[0].astype(jnp.float32)                       # [bk, hd]
    v = v_ref[0]                                           # [bk, hd]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
    mask = k_pos < seq_len
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                    # [bq, 1]
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=-1, keepdims=True)             # [bq, 1]
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                                 # [bq, bk]
    alpha = jnp.exp(m_prev - m_new)                        # [bq, 1]
    l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc = acc_ref[...] * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new
    acc_ref[...] = acc

    @pl.when(ik == nk - 1)
    def _done():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = out.astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal: bool = True, window: int = 0,
                        block_q: int = 256, block_k: int = 256,
                        interpret: bool = False):
    """q: [BH, S, hd]; k/v: [BK, S, hd] with BH = BK * group. Returns [BH,S,hd].

    The caller flattens batch x heads; group = BH // BK query heads share one
    kv head (GQA).
    """
    BH, S, hd = q.shape
    BK = k.shape[0]
    group = BH // BK
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    grid = (BH, S // block_q, S // block_k)
    scale = 1.0 / math.sqrt(hd)

    kernel = functools.partial(
        _flash_fwd_kernel, scale=scale, block_q=block_q, block_k=block_k,
        seq_len=S, causal=causal, window=window)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, block_k, hd),
                         lambda bh, iq, ik, g=group: (bh // g, ik, 0)),
            pl.BlockSpec((1, block_k, hd),
                         lambda bh, iq, ik, g=group: (bh // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd),
                               lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),   # acc
            pltpu.VMEM((block_q, 1), jnp.float32),    # m (running max)
            pltpu.VMEM((block_q, 1), jnp.float32),    # l (running denom)
        ],
        interpret=interpret,
    )(q, k, v)
