from repro.kernels.mlstm_chunk.ops import mlstm_chunk
from repro.kernels.mlstm_chunk.ref import mlstm_chunk_reference
