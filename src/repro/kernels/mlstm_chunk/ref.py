"""Oracle: the model's chunkwise mLSTM (models/xlstm.mlstm_chunkwise)."""
from __future__ import annotations

from repro.models.xlstm import mlstm_chunkwise


def mlstm_chunk_reference(q, k, v, i_log, f_log, *, chunk: int = 128):
    """q,k: [B, S, H, dqk]; v: [B, S, H, dv]; gates [B, S, H]."""
    return mlstm_chunkwise(q, k, v, i_log, f_log, chunk=chunk)
