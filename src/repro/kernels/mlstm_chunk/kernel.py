"""Chunkwise-parallel mLSTM Pallas TPU kernel (xLSTM matrix memory).

Grid: (batch*heads, num_chunks) — chunks iterate sequentially; the matrix
memory C [dqk, dv], normalizer n [dqk] and max-stabilizer m live in VMEM
scratch and carry across chunks. Per chunk the kernel computes the
intra-chunk attention-like term (q k^T decayed by the gate matrix D) on the
MXU plus the inter-chunk contribution through C, then updates the state —
the same stabilized math as models/xlstm.mlstm_chunkwise (the oracle).

VMEM budget per step: q,k [c,dqk] + v,h [c,dv] + D,scores [c,c] + C [dqk,dv]
(f32). With c=128, dqk=256, dv=512: ~1.3 MB — well within v5e VMEM; chunk
sizes are multiples of 8 (sublanes), dqk/dv multiples of 128 (lanes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _mlstm_kernel(q_ref, k_ref, v_ref, i_ref, f_ref, o_ref,
                  c_ref, n_ref, m_ref, *, chunk: int):
    it = pl.program_id(1)

    @pl.when(it == 0)
    def _init():
        c_ref[...] = jnp.zeros_like(c_ref)
        n_ref[...] = jnp.zeros_like(n_ref)
        m_ref[...] = jnp.zeros_like(m_ref)

    q = q_ref[0].astype(jnp.float32)                    # [c, dqk]
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)                    # [c, dv]
    ig = i_ref[...].astype(jnp.float32)                 # [1, c] row vector
    fg = f_ref[...].astype(jnp.float32)

    b = jnp.cumsum(fg, axis=-1)                         # [1, c]
    btot = b[0, chunk - 1]
    m_prev = m_ref[0, 0]
    C = c_ref[...]
    n = n_ref[...]                                      # [1? dqk]

    # intra-chunk decay matrix: D[j,l] = b_j - b_l + i_l  (l <= j)
    bj = b.reshape(chunk, 1)
    bl = b.reshape(1, chunk)
    il = ig.reshape(1, chunk)
    logD = bj - bl + il
    causal = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    logD = jnp.where(causal, logD, NEG_INF)
    m_intra = jnp.max(logD, axis=-1)                    # [c]
    m_inter = b[0] + m_prev                             # [c]
    m_j = jnp.maximum(m_intra, m_inter)
    D = jnp.exp(logD - m_j[:, None])

    scores = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    w = scores * D
    h_intra = jax.lax.dot_general(w, v, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    n_intra = jax.lax.dot_general(w, k, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    dec_q = jnp.exp(m_inter - m_j)                      # [c]
    h_inter = jax.lax.dot_general(q, C, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32) \
        * dec_q[:, None]
    n_inter = (q @ n.reshape(-1, 1))[:, 0] * dec_q      # [c]
    num = h_intra + h_inter
    den = jnp.abs(jnp.sum(q * n_intra, axis=-1) + n_inter)
    h = num / jnp.maximum(den, jnp.exp(-m_j))[:, None]
    o_ref[0] = h.astype(o_ref.dtype)

    # ---- state update ----
    m_state = jnp.maximum(btot + m_prev, jnp.max(btot - b[0] + ig[0]))
    dec_k = jnp.exp(btot - b[0] + ig[0] - m_state)      # [c]
    kd = k * dec_k[:, None]
    c_ref[...] = C * jnp.exp(btot + m_prev - m_state) + jax.lax.dot_general(
        kd, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    n_ref[...] = n * jnp.exp(btot + m_prev - m_state) \
        + jnp.sum(kd, axis=0).reshape(n.shape)
    m_ref[0, 0] = m_state


def mlstm_chunk_fwd(q, k, v, i_log, f_log, *, chunk: int = 128,
                    interpret: bool = False):
    """q,k: [BH, S, dqk]; v: [BH, S, dv]; i_log/f_log: [BH, S].

    Returns h: [BH, S, dv].
    """
    BH, S, dqk = q.shape
    dv = v.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    grid = (BH, S // chunk)
    kernel = functools.partial(_mlstm_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, dqk), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, chunk, dqk), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, chunk, dv), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, chunk), lambda b, t: (b, t)),
            pl.BlockSpec((1, chunk), lambda b, t: (b, t)),
        ],
        out_specs=pl.BlockSpec((1, chunk, dv), lambda b, t: (b, t, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, dv), v.dtype),
        scratch_shapes=[
            pltpu.VMEM((dqk, dv), jnp.float32),   # C
            pltpu.VMEM((1, dqk), jnp.float32),    # n
            pltpu.VMEM((1, 1), jnp.float32),      # m
        ],
        interpret=interpret,
    )(q, k, v, i_log, f_log)
