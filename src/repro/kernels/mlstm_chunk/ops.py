"""Jit'd wrapper for the chunkwise mLSTM kernel (model layout in)."""
from __future__ import annotations

import functools

import jax

from repro.kernels.mlstm_chunk.kernel import mlstm_chunk_fwd
from repro.kernels.mlstm_chunk.ref import mlstm_chunk_reference


@functools.partial(jax.jit, static_argnames=("impl", "chunk"))
def mlstm_chunk(q, k, v, i_log, f_log, *, impl: str = "auto",
                chunk: int = 128):
    """q,k: [B,S,H,dqk]; v: [B,S,H,dv]; i_log/f_log: [B,S,H] -> [B,S,H,dv]."""
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return mlstm_chunk_reference(q, k, v, i_log, f_log, chunk=chunk)
    B, S, H, dqk = q.shape
    dv = v.shape[-1]

    def flat(x, d):
        return x.transpose(0, 2, 1, 3).reshape(B * H, S, d)

    out = mlstm_chunk_fwd(flat(q, dqk), flat(k, dqk), flat(v, dv),
                          i_log.transpose(0, 2, 1).reshape(B * H, S),
                          f_log.transpose(0, 2, 1).reshape(B * H, S),
                          chunk=chunk, interpret=(impl == "interpret"))
    return out.reshape(B, H, S, dv).transpose(0, 2, 1, 3)
