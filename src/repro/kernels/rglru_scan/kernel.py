"""Blocked linear-recurrence Pallas TPU kernel for the RG-LRU.

h_t = a_t * h_{t-1} + b_t, elementwise over the rnn width. Grid:
(batch, width_blocks, seq_blocks) with the sequence axis innermost and
sequential; the carry h lives in VMEM scratch and flows across seq blocks.
Within a block the recurrence is stepped with a fori_loop over the time
rows of the VMEM tile — the channel dimension (lanes) stays fully vectorized.

The XLA path (models/rglru.py) uses an associative scan, which is O(S log S)
data movement; this kernel is the O(S) streaming version — the win is on the
memory roofline term, which dominates recurrent layers at train/prefill.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(a_ref, b_ref, h0_ref, o_ref, carry_ref, *, block_s: int):
    is_ = pl.program_id(2)

    @pl.when(is_ == 0)
    def _init():
        carry_ref[...] = h0_ref[...].astype(jnp.float32)

    # all ref indices are Slices (pl.dslice), never bare ints: older JAX
    # interpret-mode discharge rules reject scalar int indices
    row = (pl.dslice(0, 1),)

    def step(t, h):
        a_t = pl.load(a_ref, row + (pl.dslice(t, 1), slice(None)))[0, 0]
        b_t = pl.load(b_ref, row + (pl.dslice(t, 1), slice(None)))[0, 0]
        h = a_t.astype(jnp.float32) * h + b_t.astype(jnp.float32)   # [bw]
        pl.store(o_ref, row + (pl.dslice(t, 1), slice(None)),
                 h[None, None].astype(o_ref.dtype))
        return h

    h = jax.lax.fori_loop(0, block_s, step, carry_ref[...][0])
    carry_ref[...] = h[None]


def rglru_scan_fwd(a, b, h0, *, block_s: int = 256, block_w: int = 512,
                   interpret: bool = False):
    """a, b: [B, S, W]; h0: [B, W]. Returns h: [B, S, W] (same dtype as b)."""
    B, S, W = a.shape
    block_s = min(block_s, S)
    block_w = min(block_w, W)
    assert S % block_s == 0 and W % block_w == 0, (S, W, block_s, block_w)
    grid = (B, W // block_w, S // block_s)

    kernel = functools.partial(_rglru_kernel, block_s=block_s)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_s, block_w), lambda ib, iw, is_: (ib, is_, iw)),
            pl.BlockSpec((1, block_s, block_w), lambda ib, iw, is_: (ib, is_, iw)),
            pl.BlockSpec((1, block_w), lambda ib, iw, is_: (ib, iw)),
        ],
        out_specs=pl.BlockSpec((1, block_s, block_w),
                               lambda ib, iw, is_: (ib, is_, iw)),
        out_shape=jax.ShapeDtypeStruct((B, S, W), b.dtype),
        scratch_shapes=[pltpu.VMEM((1, block_w), jnp.float32)],
        interpret=interpret,
    )(a, b, h0)
