"""Jit'd wrapper for the RG-LRU scan kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.rglru_scan.kernel import rglru_scan_fwd
from repro.kernels.rglru_scan.ref import rglru_scan_reference


@functools.partial(jax.jit, static_argnames=("impl", "block_s", "block_w"))
def rglru_scan(a, b, h0, *, impl: str = "auto", block_s: int = 256,
               block_w: int = 512):
    """Linear recurrence h_t = a_t h_{t-1} + b_t. a,b: [B,S,W]; h0: [B,W]."""
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return rglru_scan_reference(a, b, h0)
    return rglru_scan_fwd(a, b, h0, block_s=block_s, block_w=block_w,
                          interpret=(impl == "interpret"))
