"""Production serving launcher: WS-CMS pool + continuous batcher driven by a
synthetic (or World-Cup-like) request trace, with the paper's autoscaler.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --reduced \
        --requests 64 --devices 4
"""
import os
import sys


def _early_args(argv):
    for i, a in enumerate(argv):
        if a == "--devices" and i + 1 < len(argv):
            os.environ["XLA_FLAGS"] = (
                f"--xla_force_host_platform_device_count={argv[i + 1]}")


_early_args(sys.argv)

import argparse  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-7b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--capacity", type=float, default=400.0,
                    help="tokens/interval one replica absorbs at 100%% util")
    ap.add_argument("--devices", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.configs import ARCHS, reduced_config
    from repro.models import model as M
    from repro.runtime.serving_pool import ServingPool
    from repro.serving.batching import ContinuousBatcher, Request

    cfg = reduced_config(ARCHS[args.arch]) if args.reduced else ARCHS[args.arch]
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    pool = ServingPool(cfg, params, capacity_tokens_per_replica=args.capacity)
    pool.scale_to(jax.devices()[:1])
    batcher = ContinuousBatcher(max_batch=args.max_batch)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        batcher.submit(Request(
            i, rng.integers(0, cfg.vocab_size, args.prompt_len,
                            dtype=np.int32), args.max_new))
    t0 = time.time()
    rounds = 0
    while batcher.queue:
        reqs = batcher.next_round()
        offered = float(sum(len(r.prompt) + r.max_new
                            for r in list(batcher.queue) + reqs))
        pool.scale_to(jax.devices()[:max(
            1, min(pool.desired_replicas(offered), len(jax.devices())))])
        batcher.run_round(reqs, pool.submit, now=time.time() - t0)
        rounds += 1
        print(f"round {rounds}: batch={len(reqs)} "
              f"replicas={len(pool.replicas)} queued={len(batcher.queue)}",
              flush=True)
    dt = time.time() - t0
    total_new = sum(r.max_new for r in batcher.completed)
    print(f"served {len(batcher.completed)} requests / {total_new} tokens "
          f"in {dt:.2f}s ({total_new / dt:.1f} tok/s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
