"""Production mesh builders.

Defined as functions (not module constants) so importing this module never
touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod adds a 2-pod outer axis (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh(shape, axes):
    """Arbitrary mesh (tests / elastic runtime resizing)."""
    return jax.make_mesh(
        tuple(shape), tuple(axes),
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


# TPU v5e-like hardware model (per chip) — values from the assignment.
HW = {
    "peak_flops_bf16": 197e12,   # FLOP/s
    "hbm_bw": 819e9,             # B/s
    "ici_bw": 50e9,              # B/s per link
    "hbm_bytes": 16e9,           # capacity
}
