"""Production mesh builders.

Defined as functions (not module constants) so importing this module never
touches jax device state.

Newer JAX exposes explicit axis types (``jax.sharding.AxisType``) and an
ambient-mesh setter (``jax.set_mesh``); older releases have neither. The
helpers below feature-detect once so every call site works on both.
"""
from __future__ import annotations

import contextlib

import jax

_HAS_AXIS_TYPES = hasattr(jax.sharding, "AxisType")


def _axis_type_kwargs(n_axes: int) -> dict:
    """axis_types kwarg for jax.make_mesh, or nothing on older JAX."""
    if _HAS_AXIS_TYPES:
        return {"axis_types": (jax.sharding.AxisType.Auto,) * n_axes}
    return {}


def set_mesh(mesh):
    """Context manager making `mesh` ambient: jax.set_mesh when available,
    otherwise the legacy Mesh context manager (same scoping semantics)."""
    if hasattr(jax, "set_mesh"):
        cm = jax.set_mesh(mesh)
        # jax.set_mesh is itself a context manager in current releases; be
        # defensive in case a future version makes it a plain setter.
        if hasattr(cm, "__enter__"):
            return cm
        return contextlib.nullcontext(mesh)
    return mesh  # jax.sharding.Mesh is a context manager on older JAX


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod adds a 2-pod outer axis (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_mesh(shape, axes):
    """Arbitrary mesh (tests / elastic runtime resizing)."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         **_axis_type_kwargs(len(axes)))


# TPU v5e-like hardware model (per chip) — values from the assignment.
HW = {
    "peak_flops_bf16": 197e12,   # FLOP/s
    "hbm_bw": 819e9,             # B/s
    "ici_bw": 50e9,              # B/s per link
    "hbm_bytes": 16e9,           # capacity
}
