"""Per-cell (arch x shape x mesh) lowering plans and abstract input specs.

``input_specs`` returns weak-type-correct ``ShapeDtypeStruct`` stand-ins for
every model input — nothing is allocated. ``cell_plan`` picks the
distribution knobs (FSDP, microbatching, sequence parallelism, MoE groups)
from the arch/shape/mesh geometry; the dry-run's memory_analysis() validates
the choices.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import (ModelConfig, ShapeConfig, TrainConfig)
from repro.sharding import partitioning as pt

# Per-chip activation budget targeted by the microbatch heuristic (bytes).
_ACT_BUDGET = 3.0e9
# Params-per-chip threshold beyond which we turn on FSDP (ZeRO-3).
_FSDP_THRESHOLD = 4.0e9


@dataclasses.dataclass(frozen=True)
class CellPlan:
    tcfg: TrainConfig
    fsdp: bool
    moe_groups: int
    max_len: int  # serving cache length
    tp: int = 0   # 0 = full model-axis TP; 1 = pure FSDP/DP layout

    def as_dict(self):
        return {"fsdp": self.fsdp, "microbatch": self.tcfg.microbatch,
                "sequence_parallel": self.tcfg.sequence_parallel,
                "remat": self.tcfg.remat, "moe_groups": self.moe_groups,
                "tp": self.tp}


def _divisor_at_most(n: int, k: int) -> int:
    """Largest divisor of n that is <= k."""
    k = max(1, min(n, k))
    for d in range(k, 0, -1):
        if n % d == 0:
            return d
    return 1


def cell_plan(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
              tp: int = -1) -> CellPlan:
    """tp=-1 (auto): training uses the pure-FSDP layout (tp=1) — measured
    2-12x better roofline fraction than Megatron-TP on every train cell
    except xlstm, where it is the only layout that fits HBM (EXPERIMENTS.md
    §Perf i4); serving keeps full model-axis TP (tp=0), which won on
    prefill/decode. Explicit 0/1 forces a layout (hillclimb flags)."""
    if tp < 0:
        tp = 1 if shape.kind == "train" else 0
    msz = mesh.shape["model"] if tp == 0 else tp
    dp = pt.dp_size(mesh, tp)
    param_bytes = cfg.param_count() * 2  # bf16
    # ssm-family mixers are replicated (no useful 16-way TP at 4 heads), so
    # their effective TP for storage is ~1.
    tp_eff = 1 if cfg.family == "ssm" else msz
    per_chip = param_bytes / tp_eff
    # FSDP when bf16 params per chip exceed 2 GB: full f32 grads (+ the
    # accumulation buffer when microbatching) would otherwise eat HBM.
    fsdp = (per_chip > 2.0e9) if shape.kind == "train" else \
        (per_chip > _FSDP_THRESHOLD)
    seq_par = fsdp or cfg.d_model >= 6000

    microbatch = 0
    if shape.kind == "train":
        local_b = max(1, shape.global_batch // dp)
        # saved scan carries: one residual per pattern-repeat scan step
        reps = max(1, cfg.num_layers // len(cfg.block_pattern))
        carry = shape.seq_len * cfg.d_model * 2 * reps
        if seq_par:
            carry /= msz
        # working set of one rematted block ~ S*d*2B*8
        work = shape.seq_len * cfg.d_model * 2 * 8
        mb_local = max(1, int(_ACT_BUDGET / max(carry + work, 1)))
        mb_local = _divisor_at_most(local_b, mb_local)
        if mb_local < local_b:
            microbatch = local_b // mb_local

    if cfg.moe is not None:
        tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                       else 1)
        moe_groups = _divisor_at_most(tokens, dp)
    else:
        moe_groups = 1

    tcfg = TrainConfig(microbatch=microbatch, remat="full",
                       sequence_parallel=seq_par, zero1=True)
    return CellPlan(tcfg=tcfg, fsdp=fsdp or tp == 1, moe_groups=moe_groups,
                    max_len=shape.seq_len, tp=tp)


# --------------------------------------------------------------- input specs


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Abstract model inputs for one cell (ShapeDtypeStructs)."""
    B, S = shape.global_batch, shape.seq_len
    cdt = jnp.dtype(cfg.compute_dtype)
    if shape.kind == "train":
        if cfg.input_mode == "embeddings":
            batch = {"embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), cdt)}
        else:
            batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        lbl_shape = (B, S, cfg.num_codebooks) if cfg.num_codebooks else (B, S)
        batch["labels"] = jax.ShapeDtypeStruct(lbl_shape, jnp.int32)
        return batch
    if shape.kind == "prefill":
        if cfg.input_mode == "embeddings":
            return {"batch_in": jax.ShapeDtypeStruct((B, S, cfg.d_model), cdt)}
        return {"batch_in": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    # decode: one new token against a cache of length S
    if cfg.input_mode == "embeddings":
        tok = jax.ShapeDtypeStruct((B, 1, cfg.d_model), cdt)
    else:
        tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    return {"tokens": tok, "cur_pos": jax.ShapeDtypeStruct((), jnp.int32)}


def batch_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                    tp: int = 0):
    """PartitionSpecs matching input_specs."""
    specs = {}
    for k, v in input_specs(cfg, shape).items():
        if k == "cur_pos":
            specs[k] = P()
        else:
            specs[k] = pt.data_spec(mesh, v.shape, tp=tp)
    return specs
