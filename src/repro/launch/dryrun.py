import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the two lines above MUST run before any other import (jax locks the
# device count on first init). --devices can override them for small smoke
# runs, which is why argument parsing also happens before `import jax`.
import argparse
import sys


def _parse_args(argv=None):
    ap = argparse.ArgumentParser(
        description="Multi-pod dry-run: lower + compile every "
                    "(arch x shape x mesh) cell; record memory/cost/roofline.")
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--devices", type=int, default=512)
    ap.add_argument("--mesh-shape", default="",
                    help="override mesh, e.g. '2,4' or '2,2,4' (test-scale)")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--no-hlo", action="store_true",
                    help="do not save gzipped HLO text")
    ap.add_argument("--sequence-parallel", default="",
                    help="force on/off (hillclimb experiments)")
    ap.add_argument("--fsdp", default="", help="force on/off")
    ap.add_argument("--remat", default="", help="override remat policy")
    ap.add_argument("--moe-ep", action="store_true",
                    help="expert-parallel MoE (all-to-all dispatch)")
    ap.add_argument("--microbatch", type=int, default=-1,
                    help="override gradient-accumulation count (-1 = plan)")
    ap.add_argument("--tp", type=int, default=-1,
                    help="-1=auto (train: pure-FSDP, serve: TP); "
                         "0=force model-axis TP; 1=force pure FSDP")
    ap.add_argument("--tag", default="", help="suffix for result files")
    return ap.parse_args(argv)


ARGS = _parse_args()
if ARGS.devices != 512:
    os.environ["XLA_FLAGS"] = \
        f"--xla_force_host_platform_device_count={ARGS.devices}"

import dataclasses
import gzip
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, SHAPES_BY_NAME, shapes_for
from repro.hlo.analysis import analyze_text
from repro.hlo.roofline import score as roofline_score
from repro.launch.mesh import HW, make_mesh, make_production_mesh
from repro.launch.specs import batch_shardings, cell_plan, input_specs
from repro.models import model as M
from repro.serving.engine import make_decode_fn, make_prefill_fn
from repro.sharding import partitioning as pt
from repro.training.optimizer import OptState
from repro.training.train_step import TrainState, init_state, make_train_step


def _mesh_for(tag: str):
    if ARGS.mesh_shape:
        dims = tuple(int(x) for x in ARGS.mesh_shape.split(","))
        if tag == "multi":
            assert len(dims) == 3, "multi mesh override needs 3 dims"
            return make_mesh(dims, ("pod", "data", "model"))
        return make_mesh(dims[-2:], ("data", "model"))
    return make_production_mesh(multi_pod=(tag == "multi"))


def _ns(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _state_specs(state_shapes, cfg, mesh, plan):
    pspecs = pt.param_specs(state_shapes.params, cfg, mesh, fsdp=plan.fsdp,
                            tp=plan.tp)
    if plan.tcfg.zero1 and not plan.fsdp:
        opt_p = pt.zero1_specs(pspecs, state_shapes.params, mesh)
    else:
        opt_p = pspecs
    return TrainState(
        params=pspecs,
        opt=OptState(step=P(), m=opt_p, v=opt_p, master=opt_p))


def lower_cell(cfg, shape, mesh, plan):
    """Returns the lowered computation for one cell."""
    constrain = pt.make_constrain(
        mesh, sequence_parallel=plan.tcfg.sequence_parallel, tp=plan.tp)
    ins = input_specs(cfg, shape)
    bspecs = batch_shardings(cfg, shape, mesh, tp=plan.tp)

    if shape.kind == "train":
        state_shapes = jax.eval_shape(
            lambda k: init_state(k, cfg), jax.random.PRNGKey(0))
        sspecs = _state_specs(state_shapes, cfg, mesh, plan)
        step = make_train_step(cfg, plan.tcfg, constrain=constrain,
                               moe_groups=plan.moe_groups)
        metr_specs = {"loss": P(), "nll": P(), "grad_norm": P()}
        fn = jax.jit(step,
                     in_shardings=(_ns(mesh, sspecs), _ns(mesh, bspecs)),
                     out_shardings=(_ns(mesh, sspecs), _ns(mesh, metr_specs)),
                     donate_argnums=(0,))
        return fn.lower(state_shapes, ins)

    params_shapes = jax.eval_shape(lambda k: M.init_params(k, cfg),
                                   jax.random.PRNGKey(0))
    pspecs = pt.param_specs(params_shapes, cfg, mesh, fsdp=plan.fsdp,
                            tp=plan.tp)

    if shape.kind == "prefill":
        fn = make_prefill_fn(cfg, constrain=constrain,
                             moe_groups=plan.moe_groups, max_len=plan.max_len)
        out_shapes = jax.eval_shape(fn, params_shapes, ins["batch_in"])
        tok_spec = pt.data_spec(mesh, out_shapes[0].shape, tp=plan.tp)
        cspecs = pt.cache_specs(out_shapes[1], cfg, mesh, tp=plan.tp)
        jfn = jax.jit(fn,
                      in_shardings=(_ns(mesh, pspecs),
                                    _ns(mesh, bspecs["batch_in"])),
                      out_shardings=(_ns(mesh, tok_spec), _ns(mesh, cspecs)))
        return jfn.lower(params_shapes, ins["batch_in"])

    # decode
    cache_shapes = M.init_cache(cfg, shape.global_batch, plan.max_len,
                                dtype=jnp.dtype(cfg.compute_dtype),
                                abstract=True)
    cspecs = pt.cache_specs(cache_shapes, cfg, mesh, tp=plan.tp)
    fn = make_decode_fn(cfg, constrain=constrain, moe_groups=plan.moe_groups)
    out_shapes = jax.eval_shape(fn, params_shapes, cache_shapes,
                                ins["tokens"], ins["cur_pos"])
    tok_spec = pt.data_spec(mesh, out_shapes[0].shape, tp=plan.tp)
    jfn = jax.jit(fn,
                  in_shardings=(_ns(mesh, pspecs), _ns(mesh, cspecs),
                                _ns(mesh, bspecs["tokens"]),
                                _ns(mesh, P())),
                  out_shardings=(_ns(mesh, tok_spec), _ns(mesh, cspecs)),
                  donate_argnums=(1,))
    return jfn.lower(params_shapes, cache_shapes, ins["tokens"],
                     ins["cur_pos"])


def run_cell(arch: str, shape_name: str, mesh_tag: str, outdir: str) -> dict:
    cfg = ARCHS[arch]
    if ARGS.moe_ep and cfg.moe is not None:
        cfg = cfg.with_(moe=dataclasses.replace(cfg.moe,
                                                expert_parallel=True))
    shape = SHAPES_BY_NAME[shape_name]
    mesh = _mesh_for(mesh_tag)
    plan = cell_plan(cfg, shape, mesh, tp=ARGS.tp)
    if ARGS.sequence_parallel:
        plan = dataclasses.replace(plan, tcfg=dataclasses.replace(
            plan.tcfg, sequence_parallel=ARGS.sequence_parallel == "on"))
    if ARGS.fsdp:
        plan = dataclasses.replace(plan, fsdp=ARGS.fsdp == "on")
    if ARGS.remat:
        plan = dataclasses.replace(plan, tcfg=dataclasses.replace(
            plan.tcfg, remat=ARGS.remat))
    if ARGS.microbatch >= 0:
        plan = dataclasses.replace(plan, tcfg=dataclasses.replace(
            plan.tcfg, microbatch=ARGS.microbatch))

    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_tag,
        "mesh_shape": dict(mesh.shape), "devices": mesh.size,
        "plan": plan.as_dict(),
        "status": "ok",
    }
    t0 = time.time()
    try:
        lowered = lower_cell(cfg, shape, mesh, plan)
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)
        mem = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_bytes_est": mem.argument_size_in_bytes
            + mem.output_size_in_bytes + mem.temp_size_in_bytes
            - mem.alias_size_in_bytes,
            "generated_code_bytes": mem.generated_code_size_in_bytes,
        }
        rec["fits_hbm"] = rec["memory"]["peak_bytes_est"] <= HW["hbm_bytes"]
        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):   # older JAX: list of per-computation dicts
            ca = ca[0] if ca else {}
        rec["xla_cost"] = {k: float(v) for k, v in ca.items()
                           if k in ("flops", "bytes accessed",
                                    "transcendentals")}
        text = compiled.as_text()
        totals = analyze_text(text)
        rec["hlo"] = {k: v for k, v in totals.items()
                      if k != "collective_detail"}
        rec["collective_detail"] = totals["collective_detail"]
        rec["roofline"] = roofline_score(cfg, shape, mesh.size,
                                         rec["plan"], totals)
        if not ARGS.no_hlo:
            hdir = os.path.join(outdir, "hlo")
            os.makedirs(hdir, exist_ok=True)
            with gzip.open(os.path.join(
                    hdir, f"{mesh_tag}__{arch}__{shape_name}{ARGS.tag}"
                          ".hlo.gz"), "wt") as f:
                f.write(text)
    except Exception as e:  # noqa: BLE001 — sweep must survive cell failures
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    return rec


def main():
    archs = sorted(ARCHS) if ARGS.arch == "all" else ARGS.arch.split(",")
    mesh_tags = {"single": ["single"], "multi": ["multi"],
                 "both": ["single", "multi"]}[ARGS.mesh]
    failures = 0
    for mesh_tag in mesh_tags:
        os.makedirs(os.path.join(ARGS.out, mesh_tag), exist_ok=True)
        for arch in archs:
            cfg = ARCHS[arch]
            names = [s.name for s in shapes_for(cfg)] if ARGS.shape == "all" \
                else [s for s in ARGS.shape.split(",")
                      if s in {x.name for x in shapes_for(cfg)}]
            for shape_name in names:
                path = os.path.join(ARGS.out, mesh_tag,
                                    f"{arch}__{shape_name}{ARGS.tag}.json")
                if ARGS.skip_existing and os.path.exists(path):
                    print(f"[skip] {mesh_tag} {arch} {shape_name}", flush=True)
                    continue
                print(f"[cell] {mesh_tag} {arch} {shape_name} ...", flush=True)
                rec = run_cell(arch, shape_name, mesh_tag, ARGS.out)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                if rec["status"] == "ok":
                    r = rec["roofline"]
                    print(f"  ok lower={rec['lower_s']}s "
                          f"compile={rec['compile_s']}s "
                          f"peak={rec['memory']['peak_bytes_est']/1e9:.2f}GB "
                          f"dom={r['dominant']} "
                          f"frac={r['roofline_fraction']:.3f}", flush=True)
                else:
                    failures += 1
                    print(f"  ERROR {rec['error']}", flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
