"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch deepseek-7b \
        --steps 100 --batch 8 --seq 128 --reduced --ckpt-dir /tmp/ckpt

Wraps the elastic trainer: checkpoint/restart comes for free (re-running the
same command resumes from the latest step); --devices simulates a host
device count for local runs (on real TPU hosts leave it unset). The
paper-facing orchestration (provision policies + serving co-tenant) lives in
examples/elastic_train.py; this is the bare ST-CMS payload.
"""
import os
import sys


def _early_args(argv):
    # --devices must be applied before jax import
    for i, a in enumerate(argv):
        if a == "--devices" and i + 1 < len(argv):
            os.environ["XLA_FLAGS"] = (
                f"--xla_force_host_platform_device_count={argv[i + 1]}")


_early_args(sys.argv)

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced same-family config (CPU-scale)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--model-size", type=int, default=1,
                    help="TP width (devices per model replica)")
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--devices", type=int, default=0)  # handled pre-import
    ap.add_argument("--log", default="")
    args = ap.parse_args(argv)

    from repro.configs import ARCHS, reduced_config
    from repro.configs.base import TrainConfig
    from repro.data.pipeline import SyntheticLM
    from repro.runtime.elastic import ElasticTrainer

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = reduced_config(cfg)
    tcfg = TrainConfig(learning_rate=args.lr, microbatch=args.microbatch)
    data = SyntheticLM(cfg, seed=0)
    trainer = ElasticTrainer(cfg, tcfg, global_batch=args.batch,
                             seq_len=args.seq, ckpt_dir=args.ckpt_dir,
                             model_size=args.model_size,
                             data_fn=data.data_fn)
    os.makedirs(args.ckpt_dir, exist_ok=True)
    trainer.start(jax.devices())
    print(f"arch={cfg.name} devices={trainer.mesh.size} "
          f"start_step={trainer.step}")
    t0 = time.time()
    while trainer.step < args.steps:
        n = min(args.ckpt_every, args.steps - trainer.step)
        m = trainer.train_steps(n)
        trainer.checkpoint()
        print(f"step {m['step']}: loss={m['loss']:.4f} "
              f"({(time.time() - t0):.1f}s)", flush=True)
    if args.log:
        json.dump(trainer.metrics_log, open(args.log, "w"), indent=1)
    print("done; checkpoint at", args.ckpt_dir)
    return 0


if __name__ == "__main__":
    sys.exit(main())
