"""Roofline scoring shared by launch/dryrun.py and benchmarks/roofline.py.

Three measured terms (per device, from the HLO cost model with trip counts):
    compute_s    = HLO_dot_FLOPs / peak_FLOPs
    memory_s     = HBM traffic (fusion-boundary model) / HBM_bw
    collective_s = collective wire bytes (ring factors) / ICI_bw

plus two physics floors used for scoring:
    ideal_compute_s = MODEL_FLOPS / (chips x peak)
    ideal_memory_s  = mandatory bytes (stored weights + activations floor +
                      caches, each touched the minimum number of times) / bw

roofline_fraction = max(ideal_compute_s, ideal_memory_s) / max(terms)
  == 1.0 when the cell runs exactly at the binding physical roofline;
  small when the implementation moves more bytes / does more flops / talks
  more than physics requires. This makes decode cells (intrinsically
  bandwidth-bound) score on achieved-vs-possible bandwidth rather than on a
  meaningless MFU.

NOTE the memory term is derived from CPU-backend HLO, whose fusion
granularity is finer than TPU's — it over-counts HBM traffic and should be
read as an upper bound (the floor is the lower bound; truth on real TPUs is
in between, and the *ratios between iterations* are what the hillclimb uses).
"""
from __future__ import annotations

from typing import Dict

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.mesh import HW


def _cache_bytes_global(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """KV/state cache bytes for a decode cell (global)."""
    B, S = shape.global_batch, shape.seq_len
    total = 0.0
    for kind in cfg.layer_kinds():
        if kind == "attn":
            total += 2 * B * S * cfg.kv_dim * 2
        elif kind == "local":
            total += 2 * B * min(cfg.window_size or S, S) * cfg.kv_dim * 2
        elif kind == "rglru":
            total += B * cfg.lru_width * 4
        elif kind == "mlstm":
            inner = int(cfg.d_model * cfg.mlstm_proj_factor)
            dv = inner // cfg.num_heads
            total += B * cfg.num_heads * (dv // 2) * dv * 4
        elif kind == "slstm":
            total += 4 * B * cfg.d_model * 4
    return total


def mandatory_bytes_per_chip(cfg: ModelConfig, shape: ShapeConfig,
                             devices: int, plan: Dict) -> float:
    """Optimistic per-chip HBM floor: stored weight shard read once per pass,
    residual activations written+read once, caches read once per token."""
    msz = plan.get("tp", 16) or 16
    dp = max(1, devices // msz)
    p_total = cfg.param_count() * 2.0                       # bf16
    p_active = cfg.param_count(active_only=True) * 2.0
    stored = p_total / (devices if plan.get("fsdp") else msz)
    d, L = cfg.d_model, cfg.num_layers
    if shape.kind == "train":
        tokens_l = shape.tokens / dp
        passes = 2.0                                        # fwd + bwd reads
        opt = 3 * 4 * cfg.param_count() * 2.0 / devices     # m,v,master r+w
        act = 2.0 * L * tokens_l * d * 2.0 / (msz if
                                              plan.get("sequence_parallel")
                                              else 1)
        return stored * passes + opt + act
    if shape.kind == "prefill":
        tokens_l = shape.tokens / dp
        act = 2.0 * L * tokens_l * d * 2.0
        cache = _cache_bytes_global(cfg, shape) / devices
        return stored + act + cache
    # decode: active weights + the whole cache shard, once per token
    cache = _cache_bytes_global(cfg, shape) / devices
    return p_active / (devices if plan.get("fsdp") else msz) + cache


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    n_matmul = cfg.param_count(active_only=True) \
        - cfg.vocab_size * cfg.d_model
    if shape.kind == "train":
        return 6.0 * n_matmul * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * n_matmul * shape.tokens
    return 2.0 * n_matmul * shape.global_batch


def score(cfg: ModelConfig, shape: ShapeConfig, devices: int, plan: Dict,
          hlo_totals: Dict) -> Dict:
    peak, hbm_bw, ici = (HW["peak_flops_bf16"], HW["hbm_bw"], HW["ici_bw"])
    f = hlo_totals["flops"]
    # TPU-target traffic: excludes bf16<->f32 convert copies that only exist
    # in the CPU lowering (bf16 dots are native on TPU)
    h = hlo_totals.get("hbm_bytes_tpu", hlo_totals["hbm_bytes"])
    c = hlo_totals["collective_bytes"]
    terms = {
        "compute_s": f / peak,
        "memory_s": h / hbm_bw,
        "collective_s": c / ici,
    }
    mf = model_flops(cfg, shape)
    floor_bytes = mandatory_bytes_per_chip(cfg, shape, devices, plan)
    ideal_compute = mf / (devices * peak)
    ideal_memory = floor_bytes / hbm_bw
    ideal_s = max(ideal_compute, ideal_memory)
    bound_s = max(terms.values())
    hlo_global = f * devices
    return {
        **terms,
        "dominant": max(terms, key=terms.get),
        "model_flops": mf,
        "hlo_flops_global": hlo_global,
        "useful_flops_ratio": mf / hlo_global if hlo_global else 0.0,
        "ideal_compute_s": ideal_compute,
        "ideal_memory_s": ideal_memory,
        "mandatory_bytes_per_chip": floor_bytes,
        "bound_s": bound_s,
        "roofline_fraction": ideal_s / bound_s if bound_s else 0.0,
    }
