"""HLO-text analyzer: FLOPs, HBM traffic, and collective bytes with
while-loop trip-count multipliers.

Why not ``compiled.cost_analysis()``: XLA's cost analysis counts a while-loop
body ONCE, so any scan-over-layers program (all of ours) under-reports by the
layer count. This module parses ``compiled.as_text()`` and:

  * counts dot/convolution FLOPs exactly from shapes + contracting dims,
  * multiplies every computation reached through a ``while`` by its
    ``known_trip_count`` (emitted by XLA for counted loops),
  * recurses into fusions for FLOPs but treats a fusion as a single HBM
    round-trip (operands + results) for the memory term — i.e. fusion
    internals live in VMEM/registers, which is the TPU cost model,
  * sums per-device wire bytes for each collective with ring-algorithm
    factors (all-reduce 2x, all-gather/reduce-scatter ~1x of full payload).

Used by launch/dryrun.py (inline) and benchmarks/roofline.py (offline on the
saved .hlo.gz artifacts).
"""
from __future__ import annotations

import dataclasses
import gzip
import json
import math
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
    "f8e5m2fnuz": 1, "f8e4m3fnuz": 1, "f4e2m1fn": 1, "s4": 1, "u4": 1,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(ROOT\s+)?%?([\w.\-]+)\s*=\s*"
    r"(\((?:[^()]|\([^()]*\))*\)|[a-z0-9]+\[[0-9,]*\]\S*)\s*"
    r"([\w\-]+)\((.*?)\)(.*)$")
_COMMENT_RE = re.compile(r"/\*.*?\*/")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^{]*\))?\s*(?:->[^{]*)?\{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count"\s*:\s*\{\s*"n"\s*:\s*"?(\d+)"?')
_CALLED_RE = re.compile(
    r"(?:body|condition|to_apply|calls|branch_computations)=\{?%?([\w.\-]+)"
    r"(?:,\s*%?([\w.\-]+))*\}?")
_REPL_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_REPL_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def shape_elems(type_str: str) -> int:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0
    n = 1
    if m.group(2):
        for d in m.group(2).split(","):
            if d:
                n *= int(d)
    return n


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    operands: List[str]
    attrs: str
    is_root: bool


@dataclasses.dataclass
class Computation:
    name: str
    ops: Dict[str, Op]
    order: List[str]


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], str]:
    comps: Dict[str, Computation] = {}
    entry = ""
    cur: Optional[Computation] = None
    for line in text.splitlines():
        line = _COMMENT_RE.sub("", line)
        if cur is None:
            m = _COMP_HDR_RE.match(line)
            if m and ("{" in line):
                cur = Computation(m.group(2), {}, [])
                if m.group(1):
                    entry = m.group(2)
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        is_root, name, type_str, opcode, arg_str, attrs = m.groups()
        operands = []
        depth = 0
        tok = ""
        for ch in arg_str:
            if ch == "(" or ch == "{" or ch == "[":
                depth += 1
            elif ch == ")" or ch == "}" or ch == "]":
                depth -= 1
            if ch == "," and depth == 0:
                operands.append(tok.strip())
                tok = ""
            else:
                tok += ch
        if tok.strip():
            operands.append(tok.strip())
        operand_names = []
        for o in operands:
            o = o.strip()
            # operands may be typed: "f32[2,3]{1,0} %name" — take the %-token
            pm = re.findall(r"%([\w.\-]+)", o)
            if pm:
                operand_names.append(pm[-1])
            else:
                om = re.match(r"([\w.\-]+)", o)
                if om:
                    operand_names.append(om.group(1))
        cur.ops[name] = Op(name, type_str, opcode, operand_names, attrs,
                           bool(is_root))
        cur.order.append(name)
    return comps, entry


def _called_comps(op: Op) -> List[str]:
    out = []
    for key in ("body", "condition", "to_apply", "calls"):
        # braced list: key={%a, %b}; bare: key=%a (single name only)
        for m in re.finditer(key + r"=\{([^}]*)\}", op.attrs):
            for nm in m.group(1).split(","):
                nm = nm.strip().lstrip("%")
                if nm:
                    out.append(nm)
        for m in re.finditer(key + r"=%?([\w.\-]+)", op.attrs):
            out.append(m.group(1))
    m = re.search(r"branch_computations=\{([^}]*)\}", op.attrs)
    if m:
        for nm in m.group(1).split(","):
            out.append(nm.strip().lstrip("%"))
    # dedupe, preserve order
    seen, uniq = set(), []
    for nm in out:
        if nm not in seen:
            seen.add(nm)
            uniq.append(nm)
    return uniq


def _dot_flops(op: Op, comp: Computation, params: Dict[str, str]) -> float:
    lhs_t = _operand_type(op.operands[0], comp, params)
    if lhs_t is None:
        return 0.0
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.attrs)
    cdims = [int(x) for x in m.group(1).split(",") if x] if m else []
    sm = _SHAPE_RE.search(lhs_t)
    dims = [int(x) for x in sm.group(2).split(",") if x] if sm and sm.group(2) \
        else []
    csize = 1
    for c in cdims:
        if c < len(dims):
            csize *= dims[c]
    return 2.0 * shape_elems(op.type_str) * csize


def _operand_type(name: str, comp: Computation, params: Dict[str, str]):
    if name in comp.ops:
        return comp.ops[name].type_str
    return params.get(name)


_SKIP_BYTES_OPS = {"tuple", "get-tuple-element", "parameter", "constant",
                   "bitcast", "after-all", "partition-id", "replica-id"}


class HloCostModel:
    """Computes flops / hbm bytes / collective wire-bytes with trip counts."""

    def __init__(self, text: str, default_trip: int = 1):
        self.comps, self.entry = parse_hlo(text)
        self.default_trip = default_trip
        self.unknown_trips: List[str] = []
        self._memo: Dict[Tuple[str, bool], Tuple[float, float, float, dict]] = {}
        self._param_reads_memo: Dict[str, Dict[int, float]] = {}

    def _fusion_root_opcode(self, op: Op) -> str:
        for c in _called_comps(op):
            comp = self.comps.get(c)
            if comp:
                for nm in comp.order:
                    if comp.ops[nm].is_root:
                        return comp.ops[nm].opcode
        return ""

    def _fusion_param_reads(self, comp_name: str) -> Dict[int, float]:
        """Per-parameter bytes actually READ by one fusion execution.

        A fusion operand consumed only through dynamic-slice/gather/slice
        reads window-sized bytes, not the whole buffer — the dominant case
        for scan bodies slicing big loop-invariant arrays. Returns
        {operand_index: bytes} for window-read params only.
        """
        if comp_name in self._param_reads_memo:
            return self._param_reads_memo[comp_name]
        out: Dict[int, float] = {}
        comp = self.comps.get(comp_name)
        if comp is not None:
            params: Dict[str, int] = {}
            for nm in comp.order:
                op = comp.ops[nm]
                if op.opcode == "parameter" and op.operands:
                    try:
                        params[nm] = int(op.operands[0])
                    except ValueError:
                        pass
            for pname, idx in params.items():
                consumers = [comp.ops[nm] for nm in comp.order
                             if pname in comp.ops[nm].operands
                             and comp.ops[nm].opcode != "parameter"]
                if consumers and all(
                        c.opcode in ("dynamic-slice", "gather", "slice")
                        for c in consumers):
                    out[idx] = float(sum(shape_bytes(c.type_str)
                                         for c in consumers))
        self._param_reads_memo[comp_name] = out
        return out

    def _op_hbm_bytes(self, op: Op, comp: Computation) -> float:
        """HBM traffic of one top-level op.

        Window ops only touch window-sized bytes of their big operand:
          * dynamic-update-slice / scatter update IN PLACE (XLA aliases
            loop-carried buffers) -> charge 2x the non-target operands;
          * dynamic-slice / gather / slice READ only result-sized bytes of
            the big operand -> charge result + small operands.
        Charging full operands would over-count a KV-cache update (or a
        scan reading one timestep) by the buffer size x trip count.
        """
        window_reads: Dict[int, float] = {}
        if op.opcode == "fusion":
            for c in _called_comps(op):
                window_reads.update(self._fusion_param_reads(c))
        opsz = []
        for i, on in enumerate(op.operands):
            t = _operand_type(on, comp, {})
            if t:
                full = shape_bytes(t)
                opsz.append(min(window_reads.get(i, full), full))
        res = shape_bytes(op.type_str)
        root = op.opcode if op.opcode != "fusion" \
            else self._fusion_root_opcode(op)
        if root in ("dynamic-update-slice", "scatter") and opsz:
            small = sum(opsz) - max(opsz)
            return 2.0 * small
        if root in ("dynamic-slice", "gather", "slice") and opsz:
            small = sum(opsz) - max(opsz)
            return 2.0 * res + small
        if root == "convert" and opsz:
            # dtype converts are an XLA:CPU artifact (bf16 dots get upcast
            # to f32); TPU reads bf16 natively — charge the narrower side.
            return 2.0 * min(res, max(opsz))
        return res + sum(opsz)

    def _ring_factor(self, opcode: str, attrs: str, type_str: str) -> float:
        m = _REPL_GROUPS_RE.search(attrs)
        if m:
            n = int(m.group(2))  # [groups, group_size]<=[...]
        else:
            m2 = _REPL_GROUPS_LIST_RE.search(attrs)
            n = len(m2.group(1).split(",")) if m2 else 2
        n = max(n, 2)
        frac = (n - 1) / n
        b = shape_bytes(type_str)
        if opcode == "all-reduce":
            return 2.0 * frac * b
        if opcode == "all-gather":
            return frac * b                       # result is the full payload
        if opcode == "reduce-scatter":
            return frac * b * n                   # input is the full payload
        if opcode == "all-to-all":
            return frac * b
        if opcode == "collective-permute":
            return float(b)
        return 0.0

    def comp_cost(self, comp_name: str, inside_fusion: bool = False):
        """Returns (flops, hbm_bytes, coll_bytes, detail).

        detail carries collective byte breakdowns plus "_convert_bytes":
        HBM traffic of dtype-convert-rooted ops, reported separately because
        bf16<->f32 converts around dots are an XLA:CPU lowering artifact
        that does not exist on the TPU target.
        """
        key = (comp_name, inside_fusion)
        if key in self._memo:
            return self._memo[key]
        comp = self.comps.get(comp_name)
        if comp is None:
            return (0.0, 0.0, 0.0, {})
        flops = 0.0
        hbm = 0.0
        coll = 0.0
        detail: Dict[str, float] = defaultdict(float)
        for nm in comp.order:
            op = comp.ops[nm]
            oc = op.opcode
            if oc == "dot":
                flops += _dot_flops(op, comp, {})
            elif oc == "convolution":
                # rough upper bound: 2 * out_elems * kernel_elems (convs do
                # not appear in our lowered programs; shifts are used instead)
                rhs_t = _operand_type(op.operands[1], comp, {}) if \
                    len(op.operands) > 1 else None
                flops += 2.0 * shape_elems(op.type_str) * \
                    max(shape_elems(rhs_t) if rhs_t else 1, 1)
            if oc in COLLECTIVES or (oc + "-start") in COLLECTIVES or \
                    oc.replace("-start", "") in COLLECTIVES:
                base = oc.replace("-start", "")
                if base in COLLECTIVES and not oc.endswith("-done"):
                    w = self._ring_factor(base, op.attrs, op.type_str)
                    coll += w
                    detail[base] += w
            if oc == "while":
                m = _TRIP_RE.search(op.attrs)
                trip = int(m.group(1)) if m else self.default_trip
                if not m:
                    self.unknown_trips.append(f"{comp_name}/{nm}")
                called = _called_comps(op)
                for c in called:
                    f, h, cl, dt = self.comp_cost(c)
                    flops += trip * f
                    hbm += trip * h
                    coll += trip * cl
                    for k2, v in dt.items():
                        detail[k2] += trip * v
                continue
            called = _called_comps(op)
            if oc == "fusion":
                for c in called:
                    f, _h, cl, dt = self.comp_cost(c, inside_fusion=True)
                    flops += f
                    coll += cl
                    for k2, v in dt.items():
                        detail[k2] += v
                # fusion = one HBM round trip: operands + result
                if not inside_fusion:
                    b = self._op_hbm_bytes(op, comp)
                    hbm += b
                    if self._fusion_root_opcode(op) == "convert":
                        detail["_convert_bytes"] += b
                continue
            if oc in ("call", "conditional", "map", "reduce", "reduce-window",
                      "scatter", "select-and-scatter", "sort",
                      "custom-call") and called:
                for c in called:
                    f, h, cl, dt = self.comp_cost(c, inside_fusion)
                    flops += f
                    hbm += h
                    coll += cl
                    for k2, v in dt.items():
                        detail[k2] += v
            # HBM traffic for non-fused top-level ops
            if not inside_fusion and oc not in _SKIP_BYTES_OPS \
                    and oc != "fusion":
                b = self._op_hbm_bytes(op, comp)
                hbm += b
                if oc == "convert":
                    detail["_convert_bytes"] += b
        out = (flops, hbm, coll, dict(detail))
        self._memo[key] = out
        return out

    def totals(self) -> dict:
        f, h, c, d = self.comp_cost(self.entry)
        d = dict(d)
        conv = d.pop("_convert_bytes", 0.0)
        return {"flops": f, "hbm_bytes": h, "convert_bytes": conv,
                "hbm_bytes_tpu": h - conv, "collective_bytes": c,
                "collective_detail": d,
                "unknown_trip_whiles": list(self.unknown_trips)}


def analyze_text(text: str, default_trip: int = 1) -> dict:
    return HloCostModel(text, default_trip).totals()


def analyze_file(path: str, default_trip: int = 1) -> dict:
    op = gzip.open if path.endswith(".gz") else open
    with op(path, "rt") as f:
        return analyze_text(f.read(), default_trip)
