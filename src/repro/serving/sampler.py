"""Token samplers for the serving engine: greedy, temperature, top-k,
nucleus (top-p) — pure functions over logits, jit-safe.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    temperature: float = 1.0
    top_k: int = 0          # 0 = disabled
    top_p: float = 1.0      # 1.0 = disabled
    greedy: bool = False


def sample(logits: jnp.ndarray, key, cfg: SamplerConfig) -> jnp.ndarray:
    """logits: [..., V] -> token ids [...]."""
    if cfg.greedy:
        return jnp.argmax(logits, axis=-1)
    logits = logits.astype(jnp.float32)
    if cfg.temperature != 1.0:
        logits = logits / jnp.maximum(cfg.temperature, 1e-6)
    if cfg.top_k and cfg.top_k > 0:
        kth = jnp.sort(logits, axis=-1)[..., -cfg.top_k][..., None]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    if cfg.top_p < 1.0:
        sorted_logits = jnp.sort(logits, axis=-1)[..., ::-1]
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        # keep the smallest prefix with cumulative mass >= top_p
        keep = cum - probs < cfg.top_p
        cutoff = jnp.max(jnp.where(keep, sorted_logits, -jnp.inf), axis=-1,
                         keepdims=True)
        logits = jnp.where(logits < cutoff, -jnp.inf, logits)
    return jax.random.categorical(key, logits, axis=-1)
