"""Serving steps: prefill and single-token decode against a KV/state cache.

These are the functions lowered by the dry-run's ``prefill_*`` / ``decode_*``
/ ``long_*`` cells, and driven by the continuous-batching layer in
``repro.serving.batching`` / ``repro.runtime.serving_pool``.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M


def make_prefill_fn(cfg: ModelConfig, *, constrain=M._ident,
                    moe_groups: int = 1, max_len: int = 0) -> Callable:
    def prefill_fn(params, batch_in):
        logits, cache = M.prefill(params, batch_in, cfg, constrain=constrain,
                                  moe_groups=moe_groups, max_len=max_len)
        # greedy next token (sampling lives in the batching layer)
        if cfg.num_codebooks:
            next_tok = jnp.argmax(logits, axis=-1)         # [B, C]
        else:
            next_tok = jnp.argmax(logits, axis=-1)         # [B]
        return next_tok, cache
    return prefill_fn


def make_decode_fn(cfg: ModelConfig, *, constrain=M._ident,
                   moe_groups: int = 1) -> Callable:
    def decode_fn(params, cache, tokens, cur_pos):
        logits, cache = M.decode_step(params, cache, tokens, cur_pos, cfg,
                                      constrain=constrain,
                                      moe_groups=moe_groups)
        next_tok = jnp.argmax(logits, axis=-1)
        return next_tok, cache
    return decode_fn


def decode_inputs(cfg: ModelConfig, batch: int, *, abstract: bool = False):
    """Token (or stub-embedding) inputs for one decode step."""
    if cfg.input_mode == "embeddings":
        sh, dt = (batch, 1, cfg.d_model), jnp.dtype(cfg.compute_dtype)
    else:
        sh, dt = (batch, 1), jnp.int32
    if abstract:
        return jax.ShapeDtypeStruct(sh, dt)
    return jnp.zeros(sh, dt)
