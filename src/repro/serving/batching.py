"""Continuous-batching request queue for the serving engine.

Requests arrive asynchronously; the scheduler packs compatible requests
(same max_new budget bucket) into batch slots, prefills them together and
interleaves decode steps, retiring sequences as they hit their budget. This
is the WS CMS's unit of work — the pool's replicas each run one of these.
"""
from __future__ import annotations

import dataclasses
import heapq
from collections import deque
from typing import Deque, Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class Request:
    req_id: int
    prompt: np.ndarray          # [S] int32
    max_new: int
    arrival: float = 0.0
    done: Optional[np.ndarray] = None
    finish_time: float = 0.0


class ContinuousBatcher:
    """Greedy slot-packing batcher (static shapes per generation round)."""

    def __init__(self, *, max_batch: int = 8, bucket: int = 64):
        self.max_batch = max_batch
        self.bucket = bucket
        self.queue: Deque[Request] = deque()
        self.completed: List[Request] = []

    def submit(self, req: Request):
        self.queue.append(req)

    def next_round(self) -> Optional[List[Request]]:
        """Pick up to max_batch requests with compatible shapes."""
        if not self.queue:
            return None
        head = self.queue[0]
        key = (len(head.prompt) // self.bucket, head.max_new // self.bucket)
        round_reqs = []
        rest: Deque[Request] = deque()
        while self.queue and len(round_reqs) < self.max_batch:
            r = self.queue.popleft()
            if (len(r.prompt) // self.bucket,
                    r.max_new // self.bucket) == key:
                round_reqs.append(r)
            else:
                rest.append(r)
        self.queue.extendleft(reversed(rest))
        return round_reqs

    def run_round(self, reqs: List[Request], generate_fn, now: float = 0.0):
        """generate_fn(prompts [B, S], max_new) -> [B, max_new]."""
        S = max(len(r.prompt) for r in reqs)
        prompts = np.stack([np.pad(r.prompt, (S - len(r.prompt), 0))
                            for r in reqs])
        max_new = max(r.max_new for r in reqs)
        out = generate_fn(prompts.astype(np.int32), max_new)
        for i, r in enumerate(reqs):
            r.done = out[i, :r.max_new]
            r.finish_time = now
            self.completed.append(r)
