"""Continuous-batching request queue for the serving engine.

Requests arrive asynchronously; the scheduler packs compatible requests
(same max_new budget bucket) into batch slots, prefills them together and
interleaves decode steps, retiring sequences as they hit their budget. This
is the WS CMS's unit of work — the pool's replicas each run one of these.
"""
from __future__ import annotations

import dataclasses
import heapq
from collections import deque
from typing import Deque, Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class Request:
    req_id: int
    prompt: np.ndarray          # [S] int32
    max_new: int
    arrival: float = 0.0
    done: Optional[np.ndarray] = None
    finish_time: float = 0.0


@dataclasses.dataclass(frozen=True)
class ServiceTimeModel:
    """Continuous-batching service-time model for one serving replica.

    A replica prefills at ``prefill_tokens_per_s`` (batch-amortized) and
    decodes each in-flight sequence at ``decode_tokens_per_s``; running b
    sequences concurrently slows every sequence down by a factor
    ``1 + batch_interference * (b - 1)`` (shared KV bandwidth / step sync).
    ``max_batch`` concurrent slots per replica — the same knob as
    ``ContinuousBatcher.max_batch``.

    This is the bridge between the real batcher below and the request-level
    queue simulator in ``repro.workloads.queueing``: both derive service
    times from the same model, so simulated latencies stay comparable to
    what a replica would actually deliver.
    """
    prefill_tokens_per_s: float = 8000.0
    decode_tokens_per_s: float = 160.0
    batch_interference: float = 0.08
    max_batch: int = 4

    def service_times(self, prompt_tokens, decode_tokens,
                      concurrency: Optional[int] = None) -> np.ndarray:
        """Vectorized per-request service seconds at a given concurrency.

        concurrency defaults to max_batch (the steady-state of a loaded
        replica — the conservative planning assumption).
        """
        b = self.max_batch if concurrency is None else max(1, concurrency)
        slow = 1.0 + self.batch_interference * (b - 1)
        prompt_tokens = np.asarray(prompt_tokens, dtype=np.float64)
        decode_tokens = np.asarray(decode_tokens, dtype=np.float64)
        return (prompt_tokens / self.prefill_tokens_per_s
                + decode_tokens * slow / self.decode_tokens_per_s)

    @property
    def slots_per_replica(self) -> int:
        return self.max_batch

    def replica_throughput_rps(self, mean_prompt: float,
                               mean_decode: float) -> float:
        """Requests/s one fully-loaded replica sustains (capacity for the
        80%-utilization rule and the SLO autoscaler's feasibility floor)."""
        s = float(self.service_times([mean_prompt], [mean_decode])[0])
        return self.max_batch / max(s, 1e-9)


class ContinuousBatcher:
    """Greedy slot-packing batcher (static shapes per generation round)."""

    def __init__(self, *, max_batch: int = 8, bucket: int = 64):
        self.max_batch = max_batch
        self.bucket = bucket
        self.queue: Deque[Request] = deque()
        self.completed: List[Request] = []

    def submit(self, req: Request):
        self.queue.append(req)

    def next_round(self) -> Optional[List[Request]]:
        """Pick up to max_batch requests with compatible shapes."""
        if not self.queue:
            return None
        head = self.queue[0]
        key = (len(head.prompt) // self.bucket, head.max_new // self.bucket)
        round_reqs = []
        rest: Deque[Request] = deque()
        while self.queue and len(round_reqs) < self.max_batch:
            r = self.queue.popleft()
            if (len(r.prompt) // self.bucket,
                    r.max_new // self.bucket) == key:
                round_reqs.append(r)
            else:
                rest.append(r)
        self.queue.extendleft(reversed(rest))
        return round_reqs

    def estimate_round_time(self, reqs: List[Request],
                            model: ServiceTimeModel) -> float:
        """Predicted wall seconds for one generation round of `reqs`.

        Prefill is batch-amortized over the padded prompt block; decode runs
        to the round's max_new with all sequences in flight.
        """
        if not reqs:
            return 0.0
        S = max(len(r.prompt) for r in reqs)
        max_new = max(r.max_new for r in reqs)
        b = len(reqs)
        slow = 1.0 + model.batch_interference * (b - 1)
        return (b * S / model.prefill_tokens_per_s
                + max_new * slow / model.decode_tokens_per_s)

    def run_round(self, reqs: List[Request], generate_fn, now: float = 0.0):
        """generate_fn(prompts [B, S], max_new) -> [B, max_new]."""
        S = max(len(r.prompt) for r in reqs)
        prompts = np.stack([np.pad(r.prompt, (S - len(r.prompt), 0))
                            for r in reqs])
        max_new = max(r.max_new for r in reqs)
        out = generate_fn(prompts.astype(np.int32), max_new)
        for i, r in enumerate(reqs):
            r.done = out[i, :r.max_new]
            r.finish_time = now
            self.completed.append(r)
