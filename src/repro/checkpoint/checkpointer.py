"""Sharding-aware checkpointing: save/restore arbitrary pytrees.

Design (orbax-free, numpy-backed):
  * leaves are gathered to host and written as .npy files keyed by their
    tree path; a manifest.json records paths, shapes, dtypes and the step;
  * writes go to a temp dir renamed atomically on completion — a crash
    mid-save never corrupts the latest checkpoint (step-atomic manifests);
  * ``AsyncCheckpointer`` stages device arrays to host synchronously (cheap)
    and does file I/O on a worker thread — the train loop continues;
  * restore takes a target pytree (shapes/dtypes/shardings) and lays leaves
    out on the *current* mesh — this is what makes elastic resizing work:
    save on a 16-device mesh, restore on 8, and every leaf is resharded to
    the new topology by ``jax.device_put``.
"""
from __future__ import annotations

import json
import os
import queue
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _path_key(path) -> str:
    parts = []
    for k in path:
        parts.append(str(getattr(k, "key", getattr(k, "idx", k))))
    return "/".join(parts)


def _sanitize(key: str) -> str:
    return re.sub(r"[^A-Za-z0-9_.-]", "_", key)


def save(ckpt_dir: str, tree: Any, *, step: int = 0) -> str:
    """Synchronous atomic save. Returns the final checkpoint path."""
    final = os.path.join(ckpt_dir, f"step_{step:010d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "leaves": []}
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in flat:
        key = _path_key(path)
        arr = np.asarray(jax.device_get(leaf))
        fname = _sanitize(key) + ".npy"
        logical_dtype = str(arr.dtype)
        if arr.dtype.kind == "V" or logical_dtype not in np.sctypeDict:
            # ml_dtypes (bfloat16, fp8) are not np.save-able: store raw bytes
            np.save(os.path.join(tmp, fname),
                    arr.view(np.uint8).reshape(arr.shape + (arr.itemsize,)))
        else:
            np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append({
            "key": key, "file": fname, "shape": list(arr.shape),
            "dtype": logical_dtype,
        })
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _update_latest(ckpt_dir, step)
    return final


def _update_latest(ckpt_dir: str, step: int):
    with open(os.path.join(ckpt_dir, "LATEST.tmp"), "w") as f:
        f.write(str(step))
    os.replace(os.path.join(ckpt_dir, "LATEST.tmp"),
               os.path.join(ckpt_dir, "LATEST"))


def latest_step(ckpt_dir: str) -> Optional[int]:
    p = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(p):
        return None
    step = int(open(p).read().strip())
    if os.path.isdir(os.path.join(ckpt_dir, f"step_{step:010d}")):
        return step
    return None


def restore(ckpt_dir: str, target: Any, *, step: Optional[int] = None,
            shardings: Any = None) -> Any:
    """Restore into the structure of `target` (pytree of arrays or
    ShapeDtypeStructs). `shardings` (same structure) lays leaves onto the
    current mesh — pass None to keep default placement."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:010d}")
    manifest = json.load(open(os.path.join(d, "manifest.json")))
    by_key = {m["key"]: m for m in manifest["leaves"]}

    flat, treedef = jax.tree_util.tree_flatten_with_path(target)
    shard_flat = None
    if shardings is not None:
        shard_flat = jax.tree_util.tree_flatten(
            shardings, is_leaf=lambda x: hasattr(x, "spec") or x is None)[0]
    leaves = []
    for i, (path, leaf) in enumerate(flat):
        key = _path_key(path)
        if key not in by_key:
            raise KeyError(f"checkpoint missing leaf {key}")
        meta = by_key[key]
        arr = np.load(os.path.join(d, meta["file"]))
        if arr.ndim == len(meta["shape"]) + 1:   # raw-bytes custom dtype
            import ml_dtypes
            arr = arr.view(np.dtype(getattr(ml_dtypes, meta["dtype"])
                                    if hasattr(ml_dtypes, meta["dtype"])
                                    else meta["dtype"]))[..., 0]
        want_dtype = leaf.dtype
        if str(arr.dtype) != str(want_dtype):
            arr = arr.astype(want_dtype)
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs {leaf.shape}")
        if shard_flat is not None and shard_flat[i] is not None:
            leaves.append(jax.device_put(arr, shard_flat[i]))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class AsyncCheckpointer:
    """Thread-backed async save with a bounded queue (backpressure = 1)."""

    def __init__(self, ckpt_dir: str):
        self.ckpt_dir = ckpt_dir
        self._q: "queue.Queue" = queue.Queue(maxsize=1)
        self._err: Optional[BaseException] = None
        self._worker = threading.Thread(target=self._run, daemon=True)
        self._worker.start()

    def _run(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            tree_host, step = item
            try:
                save(self.ckpt_dir, tree_host, step=step)
            except BaseException as e:  # noqa: BLE001
                self._err = e
            finally:
                self._q.task_done()

    def save(self, tree: Any, *, step: int):
        if self._err:
            raise self._err
        # stage to host synchronously (device buffers may be donated next step)
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self._q.put((host_tree, step))

    def wait(self):
        self._q.join()
        if self._err:
            raise self._err

    def close(self):
        self.wait()
        self._q.put(None)
        self._worker.join(timeout=10)
