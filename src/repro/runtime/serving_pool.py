"""Serving replica pool: the runtime analogue of the paper's WS CMS.

Each replica holds model params on one device and serves batched greedy
decoding. The balancer routes requests to the replica with the fewest
outstanding tokens (the paper's LVS least-connection policy); the §III-C
80% utilization rule decides replica count against the pool's capacity.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M


class Replica:
    def __init__(self, cfg: ModelConfig, params_host, device):
        self.cfg = cfg
        self.device = device
        self.params = jax.device_put(params_host, device)
        self.outstanding = 0
        self._decode = jax.jit(
            lambda p, c, t, pos: M.decode_step(p, c, t, pos, cfg),
            device=device)
        self._prefill = jax.jit(
            lambda p, t, ml: M.prefill(p, t, cfg, max_len=ml),
            static_argnums=(2,), device=device)

    def generate(self, prompt: np.ndarray, max_new: int) -> np.ndarray:
        """prompt: [B, S] int32. Greedy decode max_new tokens."""
        self.outstanding += prompt.size + max_new
        try:
            B, S = prompt.shape
            logits, cache = self._prefill(self.params, jnp.asarray(prompt),
                                          S + max_new)
            toks = [jnp.argmax(logits, axis=-1)]
            for i in range(max_new - 1):
                nxt, cache = self._decode(self.params, cache,
                                          toks[-1][:, None],
                                          jnp.int32(S + i))
                toks.append(jnp.argmax(nxt, axis=-1))
            return np.stack([np.asarray(t) for t in toks], axis=1)
        finally:
            self.outstanding -= prompt.size + max_new


class ServingPool:
    """Least-outstanding routing + utilization-rule autoscaling."""

    def __init__(self, cfg: ModelConfig, params_host, *,
                 capacity_tokens_per_replica: float = 4096.0):
        self.cfg = cfg
        self.params_host = params_host
        self.capacity = capacity_tokens_per_replica
        self.replicas: List[Replica] = []
        self.inflight_tokens = 0.0

    # -------------------------------------------------------------- scaling
    def scale_to(self, devices: Sequence):
        """Reconcile replicas with the granted device set."""
        want = {id(d): d for d in devices}
        self.replicas = [r for r in self.replicas if id(r.device) in want]
        have = {id(r.device) for r in self.replicas}
        for d in devices:
            if id(d) not in have:
                self.replicas.append(Replica(self.cfg, self.params_host, d))

    def desired_replicas(self, offered_load_tokens: float) -> int:
        """Paper §III-C rule against token throughput capacity."""
        n = max(1, len(self.replicas))
        util = offered_load_tokens / (n * self.capacity)
        if util > 0.80:
            return n + 1
        if n > 1 and util < 0.80 * (n - 1) / n:
            return n - 1
        return n

    # -------------------------------------------------------------- serving
    def submit(self, prompt: np.ndarray, max_new: int) -> np.ndarray:
        assert self.replicas, "no replicas provisioned"
        replica = min(self.replicas, key=lambda r: r.outstanding)
        return replica.generate(prompt, max_new)
