"""Elastic training runtime: the JAX bridge of the paper's ST CMS.

An ``ElasticTrainer`` is the payload of one ST "job": it trains a model on a
rectangular sub-mesh of the shared device pool. When the Phoenix provision
policy reclaims devices (WS spike) or grants more (WS trough), the trainer

  1. checkpoints at the current step (synchronous, atomic),
  2. rebuilds the mesh over the new device set (the data axis grows or
     shrinks; the model axis is preserved so TP groups stay intact),
  3. restores state with every leaf resharded onto the new topology,
  4. re-jits the train step and continues from the same step counter.

This is the TPU-native analogue of the paper's "kill job with minimum size /
reallocate nodes in seconds": instead of losing the job's work, the job
shrinks. The checkpoint/restore path doubles as the fault-tolerance story
(restart-after-failure = restore on whatever devices remain).
"""
from __future__ import annotations

import math
import time
from typing import Callable, Dict, List, Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint import checkpointer as ckpt
from repro.configs.base import ModelConfig, TrainConfig
from repro.launch.mesh import set_mesh
from repro.sharding import partitioning as pt
from repro.training.optimizer import OptState
from repro.training.train_step import TrainState, init_state, make_train_step


def _mesh_from_devices(devices: Sequence, model_size: int,
                       global_batch: Optional[int] = None) -> Mesh:
    """Largest usable rectangular mesh over `devices`.

    The DP extent is rounded DOWN to a divisor of the global batch (an
    elastic grant is rarely a perfect divisor; surplus devices idle until
    the next resize — they are not lost, just unused this interval).
    """
    n = len(devices)
    dp = n // model_size
    assert dp >= 1, (n, model_size)
    if global_batch is not None:
        while dp > 1 and global_batch % dp:
            dp -= 1
    arr = np.asarray(devices[:dp * model_size]).reshape(dp, model_size)
    return Mesh(arr, ("data", "model"))


class ElasticTrainer:
    def __init__(self, cfg: ModelConfig, tcfg: TrainConfig, *,
                 global_batch: int, seq_len: int, ckpt_dir: str,
                 model_size: int = 1, data_fn: Optional[Callable] = None,
                 seed: int = 0):
        self.cfg = cfg
        self.tcfg = tcfg
        self.global_batch = global_batch
        self.seq_len = seq_len
        self.ckpt_dir = ckpt_dir
        self.model_size = model_size
        self.data_fn = data_fn
        self.seed = seed
        self.step = 0
        self.mesh: Optional[Mesh] = None
        self.state: Optional[TrainState] = None
        self._jit_step = None
        self.resizes = 0
        self.metrics_log: List[Dict] = []

    # ------------------------------------------------------------- topology
    def start(self, devices: Sequence):
        """Initial launch (fresh init or restore-if-checkpoint-exists)."""
        self.mesh = _mesh_from_devices(devices, self.model_size,
                                       self.global_batch)
        restored = self._try_restore()
        if not restored:
            with set_mesh(self.mesh):
                state = init_state(jax.random.PRNGKey(self.seed), self.cfg)
            self.state = jax.device_put(state, self._state_shardings())
        self._compile()

    def resize(self, devices: Sequence):
        """Elastic resize: checkpoint -> new mesh -> restore -> re-jit."""
        assert self.state is not None
        self.checkpoint()
        self.mesh = _mesh_from_devices(devices, self.model_size,
                                       self.global_batch)
        self.state = None   # free old-buffers before restore
        self._try_restore(require=True)
        self._compile()
        self.resizes += 1

    # ---------------------------------------------------------- checkpoints
    def checkpoint(self):
        ckpt.save(self.ckpt_dir, self.state, step=self.step)

    def _state_shardings(self):
        shapes = jax.eval_shape(lambda: self.state) if self.state is not None \
            else jax.eval_shape(lambda k: init_state(k, self.cfg),
                                jax.random.PRNGKey(self.seed))
        pspecs = pt.param_specs(shapes.params, self.cfg, self.mesh)
        opt_specs = pt.zero1_specs(pspecs, shapes.params, self.mesh) \
            if self.tcfg.zero1 else pspecs
        specs = TrainState(params=pspecs,
                           opt=OptState(step=P(), m=opt_specs, v=opt_specs,
                                        master=opt_specs))
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s), specs,
                            is_leaf=lambda x: isinstance(x, P))

    def _try_restore(self, require: bool = False) -> bool:
        step = ckpt.latest_step(self.ckpt_dir)
        if step is None:
            if require:
                raise FileNotFoundError(self.ckpt_dir)
            return False
        shapes = jax.eval_shape(lambda k: init_state(k, self.cfg),
                                jax.random.PRNGKey(self.seed))
        self.state = ckpt.restore(self.ckpt_dir, shapes, step=step,
                                  shardings=self._state_shardings())
        self.step = step
        return True

    # -------------------------------------------------------------- compute
    def _compile(self):
        constrain = pt.make_constrain(
            self.mesh, sequence_parallel=self.tcfg.sequence_parallel)
        step_fn = make_train_step(self.cfg, self.tcfg, constrain=constrain,
                                  moe_groups=max(1, self.mesh.shape["data"]))
        sspec = self._state_shardings()
        bspec = NamedSharding(self.mesh, P("data", None))
        self._jit_step = jax.jit(
            step_fn,
            in_shardings=(sspec, {"tokens": bspec, "labels": bspec}),
            out_shardings=(sspec, None),
            donate_argnums=(0,))

    def _batch(self):
        if self.data_fn is not None:
            return self.data_fn(self.step, self.global_batch, self.seq_len)
        rng = np.random.default_rng(self.seed * 1_000_003 + self.step)
        toks = rng.integers(0, self.cfg.vocab_size,
                            (self.global_batch, self.seq_len), dtype=np.int32)
        return {"tokens": jax.numpy.asarray(toks),
                "labels": jax.numpy.asarray(np.roll(toks, -1, axis=1))}

    def train_steps(self, n: int) -> Dict:
        """Run n steps on the current mesh; returns the last metrics."""
        assert self._jit_step is not None, "call start() first"
        metrics = {}
        for _ in range(n):
            batch = self._batch()
            self.state, metrics = self._jit_step(self.state, batch)
            self.step += 1
        metrics = {k: float(v) for k, v in metrics.items()}
        metrics["step"] = self.step
        metrics["devices"] = self.mesh.size
        self.metrics_log.append(metrics)
        return metrics
