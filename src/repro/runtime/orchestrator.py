"""Runtime orchestrator: the paper's policies driving REAL JAX workloads.

Glues the provision service (counts) + DevicePool (devices) + elastic
trainers (batch departments) + serving pools (latency departments). The
provisioning rules are the same objects the simulator uses — this is
Phoenix Cloud's layered architecture with the cluster replaced by a JAX
device pool:

  WS load rises  -> autoscaler wants more replicas -> provision service
  grants free devices or FORCES a trainer to shrink (checkpoint-resize);
  WS load falls  -> replicas released -> idle devices flow back to the
  trainers per the cooperative policy, growing them at the next step
  boundary.

``PhoenixOrchestrator`` is the paper's two-department wiring (one trainer +
one serving pool over ``ResourceProvisionService``); ``MultiTenant
Orchestrator`` runs any department mix over ``TenantProvisionService`` with
a pluggable cooperative policy — the runtime twin of the N-department
``ConsolidationSim``.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional

from repro.core.cms import proxy_headroom_s
from repro.core.nodes import NodeInventory
from repro.core.provision import (ResourceProvisionService,
                                  TenantProvisionService)
from repro.core.telemetry import NULL_TRACER, Tracer
from repro.core.types import TenantSignals, TenantSpec
from repro.runtime.device_pool import DevicePool
from repro.runtime.elastic import ElasticTrainer
from repro.runtime.serving_pool import ServingPool


class _BatchDept:
    """A batch department: an elastic trainer behind the CMS protocol."""

    def __init__(self, name: str, trainer: ElasticTrainer,
                 min_devices: int = 0):
        self.name = name
        self.trainer = trainer
        self.min_devices = max(min_devices, trainer.model_size)
        self.started = False


class _LatencyDept:
    """A latency department: a serving replica pool + optional SLO scaler."""

    def __init__(self, name: str, pool: ServingPool, slo_autoscaler=None):
        self.name = name
        self.pool = pool
        self.slo_autoscaler = slo_autoscaler
        # most recent latency percentile: measured (observe_latency) or
        # predicted by the SLO autoscaler at the realized replica count —
        # feeds the TenantSignals headroom channel for reclaim planning
        self.observed_latency_s: Optional[float] = None
        self.demand = 0                # last requested replica count


class MultiTenantOrchestrator:
    """N departments sharing one JAX device pool under a cooperative policy.

    Register departments before ``start()``: each batch department wraps an
    ``ElasticTrainer`` (shrinks/grows by whole DP groups so TP collectives
    stay intact); each latency department wraps a ``ServingPool`` (one
    device per replica). Then drive latency departments with
    ``latency_tick``/``latency_tick_slo`` and batch ones with
    ``train_steps`` — grants, forced reclaims and idle reflows all run
    through the same ``TenantProvisionService`` the simulator uses.
    """

    def __init__(self, *, devices=None, policy="paper",
                 tracer: Optional[Tracer] = None, rack_size: int = 16):
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.devs = DevicePool(devices, groups=())
        self.svc = TenantProvisionService(self.devs.total, policy=policy,
                                          tracer=self.tracer)
        # identified-node layer: the orchestrator always carries the
        # inventory so operators see node-level grants/losses (which node
        # each department holds, its failure domain and lifecycle state),
        # not bare counts
        self.inventory = NodeInventory(self.devs.total,
                                       rack_size=rack_size,
                                       tracer=self.tracer)
        self.svc.attach_inventory(self.inventory)
        self.batch: Dict[str, _BatchDept] = {}
        self.latency: Dict[str, _LatencyDept] = {}
        self.events: List[Dict] = []
        self._started = False
        # the runtime has no virtual clock: control intervals are the time
        # axis, one tick per latency_tick/train_steps call
        self._ticks = 0

    def _tick_clock(self):
        self._ticks += 1
        if self.tracer.enabled:
            self.tracer.now = float(self._ticks)

    # ------------------------------------------------------------ registry
    def add_batch(self, name: str, trainer: ElasticTrainer, *,
                  priority: int = 1, weight: float = 1.0,
                  min_devices: int = 0, bid_weight: Optional[float] = None,
                  budget: Optional[float] = None, bid_policy: str = "linear"
                  ) -> None:
        assert not self._started, "register departments before start()"
        dept = _BatchDept(name, trainer, min_devices)
        self.batch[name] = dept
        self.devs.add_group(name)
        self.svc.register_spec(
            TenantSpec(name, "batch", priority=priority, weight=weight,
                       floor=dept.min_devices, bid_weight=bid_weight,
                       budget=budget, bid_policy=bid_policy),
            on_grant=lambda n, d=dept: self._grant_batch(d, n),
            on_force_release=lambda n, d=dept: self._force_release_batch(
                d, n),
            signals=lambda nm=name: self._batch_signals(nm))

    def add_latency(self, name: str, pool: ServingPool, *,
                    priority: int = 0, weight: float = 1.0,
                    slo_autoscaler=None, floor: int = 0,
                    bid_weight: Optional[float] = None,
                    budget: Optional[float] = None,
                    bid_policy: str = "linear") -> None:
        assert not self._started, "register departments before start()"
        self.latency[name] = _LatencyDept(name, pool, slo_autoscaler)
        self.devs.add_group(name)
        self.svc.register_spec(
            TenantSpec(name, "latency", priority=priority, weight=weight,
                       floor=floor, bid_weight=bid_weight,
                       budget=budget, bid_policy=bid_policy),
            on_force_release=lambda n, nm=name: self._force_release_latency(
                nm, n),
            signals=lambda nm=name: self._latency_signals(nm))

    def market_state(self) -> Optional[Dict]:
        """JSON-safe market snapshot (budgets, remaining, spend ledger,
        clearing prices) when a budget engine is active, else None."""
        market = getattr(self.svc.policy, "market", None)
        return None if market is None else market.snapshot()

    # ------------------------------------------------------------- signals
    def observe_latency(self, name: str, latency_s: float) -> None:
        """Feed a measured serving-pool latency percentile; reclaim
        planners see ``slo_target - latency`` as this department's
        headroom from the next decision on."""
        self.latency[name].observed_latency_s = latency_s

    def _latency_signals(self, name: str) -> TenantSignals:
        dept = self.latency[name]
        rec = self.svc.tenants[name]
        slo = getattr(dept.slo_autoscaler, "slo", None)
        target = slo.latency_target_s if slo is not None else 0.0
        if dept.observed_latency_s is not None and target > 0.0:
            headroom = target - dept.observed_latency_s
        else:
            # the simulator WS CMS's zero-clamped surplus proxy, shared so
            # runtime and simulated slo_elastic bids can never diverge
            headroom = proxy_headroom_s(rec.alloc, dept.demand, target)
        return TenantSignals(
            name=name, kind="latency", alloc=rec.alloc, demand=dept.demand,
            weight=rec.weight, latency_headroom_s=headroom,
            slo_target_s=target,
            queue_depth=max(0, dept.demand - rec.alloc))

    def _batch_signals(self, name: str) -> TenantSignals:
        dept = self.batch[name]
        rec = self.svc.tenants[name]
        # preemption cost in node-seconds: shrinking costs one checkpoint-
        # resize round of the current step time per affected DP group
        step_s = float(getattr(dept.trainer, "last_step_time_s", 0.0) or 0.0)
        return TenantSignals(
            name=name, kind="batch", alloc=rec.alloc, demand=rec.demand,
            weight=rec.weight, preemption_cost_s=step_s,
            queue_depth=max(0, rec.demand - rec.alloc))

    # ------------------------------------------------------------- wiring
    def _grant_batch(self, dept: _BatchDept, n: int):
        self.devs.grant(dept.name, n)
        devs = self.devs.groups[dept.name]
        if dept.started:
            dept.trainer.resize(devs)
        elif len(devs) >= dept.min_devices and devs:
            dept.trainer.start(devs)
            dept.started = True
        self.events.append({"kind": "grant", "dept": dept.name,
                            "devices": n})

    def _force_release_batch(self, dept: _BatchDept, n: int) -> int:
        """Shrink the trainer by n devices, rounded UP to a whole DP group
        (TP width is preserved) — surplus stays idle and is re-granted."""
        tp = dept.trainer.model_size
        have = len(self.devs.groups[dept.name])
        groups = math.ceil(n / tp)
        max_groups = (have - dept.min_devices) // tp
        groups = min(groups, max_groups)
        take = groups * tp
        if take <= 0:
            return 0
        self.devs.reclaim(dept.name, take)
        if dept.started and self.devs.groups[dept.name]:
            dept.trainer.resize(self.devs.groups[dept.name])
        self.events.append({"kind": "shrink", "dept": dept.name,
                            "devices": take, "step": dept.trainer.step})
        return take

    def _force_release_latency(self, name: str, n: int) -> int:
        """A higher-priority claim takes n replicas from this department."""
        dept = self.latency[name]
        got = len(self.devs.reclaim(name, n))
        dept.pool.scale_to(self.devs.groups[name])
        self.events.append({"kind": "preempt", "dept": name, "devices": got})
        return got

    # ------------------------------------------------------------- control
    def start(self):
        """Initial provision: batch demand declared, idle flows per policy."""
        self._started = True
        for name, dept in self.batch.items():
            # declared demand = the trainer's max useful scale (model width
            # x global batch caps the data-parallel extent); demand-aware
            # policies split idle between departments from these
            t = dept.trainer
            useful = t.model_size * max(1, getattr(t, "global_batch", 1))
            self.svc.set_demand(name, min(self.devs.total, useful),
                                provision=False)
        self.svc.provision_idle()

    def latency_tick(self, name: str, offered_load_tokens: float):
        """One control interval for a latency department: autoscale replicas
        to the offered load (paper §III-C utilization rule)."""
        self._tick_clock()
        dept = self.latency[name]
        self._scale_latency(name,
                            dept.pool.desired_replicas(offered_load_tokens))

    def latency_tick_slo(self, name: str, rate_rps: float,
                         mean_service_s: float, scv_service: float = 1.0,
                         p99_service_s: Optional[float] = None):
        """One control interval driven by the department's latency SLO."""
        self._tick_clock()
        dept = self.latency[name]
        assert dept.slo_autoscaler is not None, \
            f"add_latency({name!r}, ..., slo_autoscaler=...) first"
        if p99_service_s is None:
            # gamma-tail estimate from the SCV; using the mean here would
            # make the predicted percentile systematically optimistic
            p99_service_s = mean_service_s * (
                1.0 + 2.33 * math.sqrt(max(scv_service, 0.0)))
        want = dept.slo_autoscaler.desired_nodes(
            rate_rps, mean_service_s, scv_service, p99_service_s,
            current=len(dept.pool.replicas))
        self._scale_latency(name, want)
        # refresh the headroom signal with the predicted percentile at the
        # replica count actually realized (a claim may have granted less);
        # an explicit observe_latency() call overrides it until next tick
        dept.observed_latency_s = dept.slo_autoscaler.predicted_latency_s(
            rate_rps, mean_service_s, scv_service, p99_service_s,
            len(dept.pool.replicas))

    def _scale_latency(self, name: str, want: int):
        dept = self.latency[name]
        if self.tracer.enabled and want != dept.demand:
            self.tracer.emit("autoscale", tenant=name, prev=dept.demand,
                             demand=want, source="slo_autoscaler"
                             if dept.slo_autoscaler is not None
                             else "utilization")
        dept.demand = want
        have = len(dept.pool.replicas)
        if want > have:
            got = self.svc.claim(name, want - have)
            self.devs.grant(name, got)
        elif want < have:
            give = have - want
            self.devs.reclaim(name, give)
            self.svc.release(name, give)
        dept.pool.scale_to(self.devs.groups[name])
        self.events.append({"kind": "scale", "dept": name,
                            "replicas": len(dept.pool.replicas)})

    def train_steps(self, name: str, n: int) -> Dict:
        self._tick_clock()
        return self.batch[name].trainer.train_steps(n)

    # ----------------------------------------------------- node lifecycle
    def nodes_of(self, name: str) -> List[int]:
        """Sorted node ids a department (or ``"free"``) currently holds."""
        return self.inventory.pool(name)

    def node_states(self) -> Dict[str, int]:
        """Cluster-wide lifecycle census, e.g. {"healthy": 14, ...}."""
        return self.inventory.state_counts()

    def fail_node(self, node_id: Optional[int] = None) -> int:
        """Take one node down (operator drill / chaos hook). Default is
        the lowest-id up node; the owning department's devices shrink
        through its own resize path, exactly as a forced reclaim would.
        Returns the failed node id."""
        self._tick_clock()
        inv = self.inventory
        if node_id is None:
            up = inv.up_ids()
            assert up, "no up node to fail"
            node_id = up[0]
        owner = inv.owner_of(node_id)
        # shrink the owner's devices BEFORE the count layer hears of the
        # failure: node_failed may immediately re-provision (demand-driven
        # policies), and grants must find the device already free
        if owner in self.latency:
            dept = self.latency[owner]
            self.devs.reclaim(owner, 1)
            dept.pool.scale_to(self.devs.groups[owner])
        elif owner in self.batch:
            dept = self.batch[owner]
            self.devs.reclaim(owner, 1)
            if dept.started and self.devs.groups[owner]:
                dept.trainer.resize(self.devs.groups[owner])
        self.svc.node_failed(owner, node=node_id)
        self.events.append({"kind": "node_fail", "node": node_id,
                            "dept": owner})
        return node_id

    def repair_node(self, node_id: Optional[int] = None) -> int:
        """Bring a failed node back (lowest-id down node by default); it
        re-enters the free pool and flows out per the idle policy."""
        self._tick_clock()
        node_id = self.svc.node_repaired(node=node_id)
        self.events.append({"kind": "node_repair", "node": node_id})
        return node_id


class PhoenixOrchestrator:
    """The paper's two-department wiring: one ST trainer + one WS pool."""

    def __init__(self, trainer: ElasticTrainer, pool: ServingPool, *,
                 devices=None, min_st_devices: int = 0,
                 slo_autoscaler=None):
        """slo_autoscaler: optional ``workloads.SLOAutoscaler``. When set,
        ``ws_tick_slo`` scales replicas from request-level load statistics
        against the latency SLO instead of the §III-C utilization rule."""
        self.devs = DevicePool(devices)
        self.rps = ResourceProvisionService(self.devs.total)
        self.trainer = trainer
        self.pool = pool
        self.min_st = max(min_st_devices, trainer.model_size)
        self.slo_autoscaler = slo_autoscaler
        self.rps.force_st_release = self._force_st_release
        self.rps.on_grant_st = self._grant_st
        self.events: List[Dict] = []
        self._started = False

    # ------------------------------------------------------------- wiring
    def _grant_st(self, n: int):
        self.devs.grant_st(n)
        if self._started:
            self._resize_trainer()
        else:
            self.trainer.start(self.devs.st)
            self._started = True

    def _force_st_release(self, n: int) -> int:
        """Shrink the trainer by n devices, rounded UP to a whole DP group
        (TP width is preserved) — surplus stays idle and is re-granted."""
        tp = self.trainer.model_size
        groups = math.ceil(n / tp)
        max_groups = (len(self.devs.st) - self.min_st) // tp
        groups = min(groups, max_groups)
        take = groups * tp
        if take <= 0:
            return 0
        self.devs.reclaim_st(take)
        self._resize_trainer()
        self.events.append({"kind": "st_shrink", "devices": take,
                            "step": self.trainer.step})
        return take

    def _resize_trainer(self):
        if self._started and self.devs.st:
            self.trainer.resize(self.devs.st)

    # ------------------------------------------------------------- control
    def start(self):
        self.rps.provision_idle_to_st()

    def ws_tick(self, offered_load_tokens: float):
        """One WS control interval: autoscale replicas to the offered load
        (paper §III-C utilization rule)."""
        self._scale_ws(self.pool.desired_replicas(offered_load_tokens))

    def ws_tick_slo(self, rate_rps: float, mean_service_s: float,
                    scv_service: float = 1.0,
                    p99_service_s: Optional[float] = None):
        """One WS control interval driven by the latency SLO.

        Takes the window's request-level load statistics (arrival rate and
        service-time shape, e.g. from ``ServiceTimeModel.service_times`` over
        the window's token counts) and asks the SLO autoscaler for the
        replica count whose predicted latency percentile meets the target.
        """
        assert self.slo_autoscaler is not None, \
            "construct PhoenixOrchestrator(..., slo_autoscaler=...) first"
        if p99_service_s is None:
            # gamma-tail estimate from the SCV; using the mean here would
            # make the predicted percentile systematically optimistic
            p99_service_s = mean_service_s * (
                1.0 + 2.33 * math.sqrt(max(scv_service, 0.0)))
        want = self.slo_autoscaler.desired_nodes(
            rate_rps, mean_service_s, scv_service, p99_service_s,
            current=len(self.pool.replicas))
        self._scale_ws(want)

    def _scale_ws(self, want: int):
        have = len(self.pool.replicas)
        if want > have:
            got = self.rps.ws_request(want - have)
            self.devs.grant_ws(got)
        elif want < have:
            give = have - want
            self.devs.release_ws(give)
            self.rps.ws_release(give)
        self.pool.scale_to(self.devs.ws)
        self.events.append({"kind": "ws_scale", "replicas":
                            len(self.pool.replicas)})

    def train_steps(self, n: int) -> Dict:
        return self.trainer.train_steps(n)
