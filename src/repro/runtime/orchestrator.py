"""Runtime orchestrator: the paper's policies driving REAL JAX workloads.

Glues ResourceProvisionService (counts) + DevicePool (devices) + an
ElasticTrainer (ST job) + a ServingPool (WS replicas). The provisioning
rules are the same objects the simulator uses — this is Phoenix Cloud's
layered architecture with the cluster replaced by a JAX device pool:

  WS load rises  -> autoscaler wants more replicas -> provision service
  grants free devices or FORCES the trainer to shrink (checkpoint-resize);
  WS load falls  -> replicas released -> all idle devices flow back to the
  trainer, which grows at the next step boundary.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional

from repro.core.provision import ResourceProvisionService
from repro.runtime.device_pool import DevicePool
from repro.runtime.elastic import ElasticTrainer
from repro.runtime.serving_pool import ServingPool


class PhoenixOrchestrator:
    def __init__(self, trainer: ElasticTrainer, pool: ServingPool, *,
                 devices=None, min_st_devices: int = 0,
                 slo_autoscaler=None):
        """slo_autoscaler: optional ``workloads.SLOAutoscaler``. When set,
        ``ws_tick_slo`` scales replicas from request-level load statistics
        against the latency SLO instead of the §III-C utilization rule."""
        self.devs = DevicePool(devices)
        self.rps = ResourceProvisionService(self.devs.total)
        self.trainer = trainer
        self.pool = pool
        self.min_st = max(min_st_devices, trainer.model_size)
        self.slo_autoscaler = slo_autoscaler
        self.rps.force_st_release = self._force_st_release
        self.rps.on_grant_st = self._grant_st
        self.events: List[Dict] = []
        self._started = False

    # ------------------------------------------------------------- wiring
    def _grant_st(self, n: int):
        self.devs.grant_st(n)
        if self._started:
            self._resize_trainer()
        else:
            self.trainer.start(self.devs.st)
            self._started = True

    def _force_st_release(self, n: int) -> int:
        """Shrink the trainer by n devices, rounded UP to a whole DP group
        (TP width is preserved) — surplus stays idle and is re-granted."""
        tp = self.trainer.model_size
        groups = math.ceil(n / tp)
        max_groups = (len(self.devs.st) - self.min_st) // tp
        groups = min(groups, max_groups)
        take = groups * tp
        if take <= 0:
            return 0
        self.devs.reclaim_st(take)
        self._resize_trainer()
        self.events.append({"kind": "st_shrink", "devices": take,
                            "step": self.trainer.step})
        return take

    def _resize_trainer(self):
        if self._started and self.devs.st:
            self.trainer.resize(self.devs.st)

    # ------------------------------------------------------------- control
    def start(self):
        self.rps.provision_idle_to_st()

    def ws_tick(self, offered_load_tokens: float):
        """One WS control interval: autoscale replicas to the offered load
        (paper §III-C utilization rule)."""
        self._scale_ws(self.pool.desired_replicas(offered_load_tokens))

    def ws_tick_slo(self, rate_rps: float, mean_service_s: float,
                    scv_service: float = 1.0,
                    p99_service_s: Optional[float] = None):
        """One WS control interval driven by the latency SLO.

        Takes the window's request-level load statistics (arrival rate and
        service-time shape, e.g. from ``ServiceTimeModel.service_times`` over
        the window's token counts) and asks the SLO autoscaler for the
        replica count whose predicted latency percentile meets the target.
        """
        assert self.slo_autoscaler is not None, \
            "construct PhoenixOrchestrator(..., slo_autoscaler=...) first"
        if p99_service_s is None:
            # gamma-tail estimate from the SCV; using the mean here would
            # make the predicted percentile systematically optimistic
            p99_service_s = mean_service_s * (
                1.0 + 2.33 * math.sqrt(max(scv_service, 0.0)))
        want = self.slo_autoscaler.desired_nodes(
            rate_rps, mean_service_s, scv_service, p99_service_s,
            current=len(self.pool.replicas))
        self._scale_ws(want)

    def _scale_ws(self, want: int):
        have = len(self.pool.replicas)
        if want > have:
            got = self.rps.ws_request(want - have)
            self.devs.grant_ws(got)
        elif want < have:
            give = have - want
            self.devs.release_ws(give)
            self.rps.ws_release(give)
        self.pool.scale_to(self.devs.ws)
        self.events.append({"kind": "ws_scale", "replicas":
                            len(self.pool.replicas)})

    def train_steps(self, n: int) -> Dict:
        return self.trainer.train_steps(n)
