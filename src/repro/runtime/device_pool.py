"""Maps Phoenix node counts onto concrete JAX devices, for N tenants.

The provision service reasons in fungible node counts; this pool assigns
actual devices to named tenant groups: batch tenants (elastic trainers)
receive rectangular groups (multiples of the training job's model-parallel
width) so TP collectives stay intact; latency tenants (serving pools)
receive single devices per replica. The legacy two-group (``st``/``ws``)
interface is preserved as aliases over the named groups.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import jax


class DevicePool:
    def __init__(self, devices: Optional[Sequence] = None,
                 groups: Sequence[str] = ("st", "ws")):
        self.devices = list(devices if devices is not None else jax.devices())
        self.free = list(self.devices)
        self.groups: Dict[str, List] = {g: [] for g in groups}

    @property
    def total(self) -> int:
        return len(self.devices)

    def add_group(self, name: str) -> None:
        assert name not in self.groups, name
        self.groups[name] = []

    def check(self):
        assigned = sum(len(g) for g in self.groups.values())
        assert len(self.free) + assigned == self.total, \
            (len(self.free), {k: len(v) for k, v in self.groups.items()},
             self.total)

    # -------------------------------------------------------- named groups
    def grant(self, name: str, n: int) -> List:
        """Move up to n free devices into the named group."""
        n = min(n, len(self.free))
        got, self.free = self.free[:n], self.free[n:]
        self.groups[name].extend(got)
        self.check()
        return got

    def reclaim(self, name: str, n: int) -> List:
        """Take n devices back from the named group (most recent first;
        the caller must resize/stop the workload on them)."""
        grp = self.groups[name]
        n = min(n, len(grp))
        got = grp[-n:] if n else []
        self.groups[name] = grp[:-n] if n else grp
        self.free.extend(got)
        self.check()
        return got

    # ------------------------------------------------- legacy two-tenant API
    @property
    def st(self) -> List:
        return self.groups["st"]

    @property
    def ws(self) -> List:
        return self.groups["ws"]

    def grant_st(self, n: int) -> List:
        return self.grant("st", n)

    def grant_ws(self, n: int) -> List:
        return self.grant("ws", n)

    def reclaim_st(self, n: int) -> List:
        return self.reclaim("st", n)

    def release_ws(self, n: int) -> List:
        return self.reclaim("ws", n)
