"""Maps Phoenix node counts onto concrete JAX devices.

The provision service reasons in fungible node counts; this pool assigns
actual devices: the ST side receives rectangular groups (multiples of the
training job's model-parallel width) so TP collectives stay intact; the WS
side receives single devices per serving replica.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import jax


class DevicePool:
    def __init__(self, devices: Optional[Sequence] = None):
        self.devices = list(devices if devices is not None else jax.devices())
        self.free = list(self.devices)
        self.st: List = []
        self.ws: List = []

    @property
    def total(self) -> int:
        return len(self.devices)

    def check(self):
        assert len(self.free) + len(self.st) + len(self.ws) == self.total

    def grant_st(self, n: int) -> List:
        n = min(n, len(self.free))
        got, self.free = self.free[:n], self.free[n:]
        self.st.extend(got)
        self.check()
        return got

    def grant_ws(self, n: int) -> List:
        n = min(n, len(self.free))
        got, self.free = self.free[:n], self.free[n:]
        self.ws.extend(got)
        self.check()
        return got

    def reclaim_st(self, n: int) -> List:
        """Take n devices back from ST (caller must resize the trainer)."""
        n = min(n, len(self.st))
        got = self.st[-n:]
        self.st = self.st[:-n] if n else self.st
        self.free.extend(got)
        self.check()
        return got

    def release_ws(self, n: int) -> List:
        n = min(n, len(self.ws))
        got = self.ws[-n:]
        self.ws = self.ws[:-n] if n else self.ws
        self.free.extend(got)
        self.check()
        return got
