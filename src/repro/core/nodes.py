"""Node-lifecycle layer: identified nodes, per-node state machines, and
failure domains.

The provision service (core/provision.py) is a pure state machine over
node *counts* — perfect for the paper's fungible-node model, but blind to
which physical node moved where, and unable to express correlated
failures ("this rack lost power") or drain windows ("this node serves
neither tenant for 30 s while it is repurposed"). This module adds the
missing identity without changing the count layer's semantics:

  * :class:`NodeInventory` — an explicit inventory of ``total`` identified
    nodes, each a :class:`Node` with a per-node state machine::

        healthy ──► draining ──► healthy        (reclaim drain window)
        healthy / flapping / draining ──► failed ──► repairing
        repairing ──► healthy   (or ──► flapping for designated flappers)

    Illegal transitions raise — the table below is the contract.
  * **failure domains**: node ``i`` lives in rack ``i // rack_size``;
    correlated injectors (core/faults.py) blast whole domains.
  * **ownership pools** mirroring the service's counts: ``"free"``, one
    pool per tenant, plus the :data:`DRAIN_POOL` holding mid-drain nodes.
    The service syncs every count move into the inventory (when one is
    attached), always choosing the **lowest-id** nodes of a pool — node
    identity is fully deterministic and consumes no RNG, so attaching an
    inventory can never perturb a seeded run.
  * **telemetry**: every state transition emits a ``node_state`` event
    (``{node, from, to, parent}``), parented to the causal context that
    forced it (the failure's span, the reclaim step's span, ...), so the
    full lifecycle of any node is one linked chain in the trace.

The count layer stays authoritative for *how many*; the inventory answers
*which*, *where* (domain) and *in what state*.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set

from repro.core.telemetry import NULL_TRACER, Tracer

#: reserved pool name for nodes inside a reclaim drain window (serving
#: neither the victim nor the claimant); never a registrable tenant name
DRAIN_POOL = "__drain__"


class NodeState(enum.Enum):
    HEALTHY = "healthy"
    DRAINING = "draining"
    FAILED = "failed"
    REPAIRING = "repairing"
    FLAPPING = "flapping"      # up, but designated unreliable (fails often)


# the lifecycle contract: (from, to) pairs the inventory will perform.
# Anything else raises — a state-machine bug must never be silently
# absorbed into the count layer.
LEGAL_TRANSITIONS = frozenset({
    (NodeState.HEALTHY, NodeState.FLAPPING),     # flapper designation
    (NodeState.HEALTHY, NodeState.DRAINING),     # reclaim drain start
    (NodeState.FLAPPING, NodeState.DRAINING),
    (NodeState.DRAINING, NodeState.HEALTHY),     # drain complete
    (NodeState.DRAINING, NodeState.FLAPPING),
    (NodeState.HEALTHY, NodeState.FAILED),       # failure
    (NodeState.FLAPPING, NodeState.FAILED),
    (NodeState.DRAINING, NodeState.FAILED),      # fault mid-drain
    (NodeState.FAILED, NodeState.REPAIRING),     # repair crew dispatched
    (NodeState.REPAIRING, NodeState.HEALTHY),    # repair complete
    (NodeState.REPAIRING, NodeState.FLAPPING),   # flappers stay flappers
})

#: states in which a node occupies real hardware and can therefore fail
#: (draining nodes still sit in a rack; failed/repairing ones are already
#: down). Injectors select victims from this set only.
UP_STATES = (NodeState.HEALTHY, NodeState.FLAPPING, NodeState.DRAINING)


@dataclass
class Node:
    """One identified node: id, failure domain, lifecycle state, owner."""
    id: int
    domain: int
    state: NodeState = NodeState.HEALTHY
    owner: str = "free"
    flapper: bool = False
    # span of the node_fail event that took this node down; the matching
    # node_repair parents it (0 = untraced)
    fail_span: int = 0


class NodeInventory:
    """Identified-node mirror of a provision service's count pools.

    Deterministic by construction: pool picks are lowest-id, iteration is
    sorted, and no method draws randomness — the fault injectors own all
    RNG. Attach to a service with ``svc.attach_inventory(inv)`` *before*
    any provisioning so pools and counts start in lockstep.
    """

    def __init__(self, total: int, *, rack_size: int = 16,
                 tracer: Optional[Tracer] = None):
        assert total >= 0 and rack_size >= 1, (total, rack_size)
        self.total = total
        self.rack_size = rack_size
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.nodes: List[Node] = [Node(id=i, domain=i // rack_size)
                                  for i in range(total)]
        # owner -> node-id set; "free" plus one pool per tenant plus
        # DRAIN_POOL; failed/repairing nodes live in the down pool
        self.pools: Dict[str, Set[int]] = {"free": set(range(total))}
        self._down: Set[int] = set()

    # ------------------------------------------------------------- queries
    def owner_of(self, node_id: int) -> str:
        return self.nodes[node_id].owner

    def state_of(self, node_id: int) -> NodeState:
        return self.nodes[node_id].state

    def pool(self, owner: str) -> List[int]:
        """Sorted node ids currently owned by ``owner``."""
        return sorted(self.pools.get(owner, ()))

    def up_ids(self) -> List[int]:
        """Sorted ids of all nodes occupying hardware (healthy, flapping
        or draining) — the set fault injectors pick victims from. Depends
        only on past fault/repair events, never on which tenant owns a
        node, so seeded fault sequences stay policy-independent."""
        return sorted(n.id for n in self.nodes if n.state in UP_STATES)

    def domain_up_ids(self, domain: int) -> List[int]:
        return [i for i in self.up_ids()
                if self.nodes[i].domain == domain]

    def domains(self) -> List[int]:
        return sorted({n.domain for n in self.nodes})

    def counts(self) -> Dict[str, int]:
        return {owner: len(ids) for owner, ids in sorted(self.pools.items())
                if ids}

    def state_counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for n in self.nodes:
            out[n.state.value] = out.get(n.state.value, 0) + 1
        return out

    # --------------------------------------------------------- transitions
    def _set_state(self, node: Node, to: NodeState,
                   parent: Optional[int] = None) -> None:
        if node.state is to:
            return
        if (node.state, to) not in LEGAL_TRANSITIONS:
            raise ValueError(
                f"illegal node transition {node.state.value} -> {to.value} "
                f"(node {node.id})")
        tr = self.tracer
        if tr.enabled:
            tr.append({"type": "node_state", "node": node.id,
                       "from": node.state.value, "to": to.value,
                       "parent": parent})
        node.state = to

    def _move(self, node: Node, dst: str) -> None:
        self.pools[node.owner].discard(node.id)
        self.pools.setdefault(dst, set()).add(node.id)
        node.owner = dst

    def transfer(self, src: str, dst: str, k: int, *,
                 state: Optional[NodeState] = None,
                 parent: Optional[int] = None) -> List[int]:
        """Move the ``k`` lowest-id nodes from pool ``src`` to ``dst``,
        optionally transitioning their state (drain start/complete).
        Returns the moved ids."""
        if k <= 0:
            return []
        pool = self.pools.get(src, set())
        assert len(pool) >= k, \
            f"pool {src!r} has {len(pool)} nodes, need {k}"
        ids = sorted(pool)[:k]
        for nid in ids:
            node = self.nodes[nid]
            self._move(node, dst)
            if state is not None:
                self._set_state(node, state, parent=parent)
        return ids

    def move_nodes(self, ids: List[int], dst: str, *,
                   state: Optional[NodeState] = None,
                   parent: Optional[int] = None) -> None:
        """Move specific nodes (drain completions reference the exact ids
        that entered the drain window)."""
        for nid in ids:
            node = self.nodes[nid]
            self._move(node, dst)
            if state is not None:
                to = state
                if to is NodeState.HEALTHY and node.flapper:
                    to = NodeState.FLAPPING   # flappers never become healthy
                self._set_state(node, to, parent=parent)

    def pick(self, owner: str) -> int:
        """Lowest-id node of a pool (deterministic count->identity map for
        failures attributed by pool share)."""
        pool = self.pools.get(owner, set())
        assert pool, f"pool {owner!r} is empty"
        return min(pool)

    def designate_flappers(self, ids: List[int]) -> None:
        for nid in sorted(ids):
            node = self.nodes[nid]
            node.flapper = True
            self._set_state(node, NodeState.FLAPPING)

    def fail(self, node_id: int, *, span: int = 0,
             cause: Optional[str] = None) -> Node:
        """``<up state>`` -> FAILED -> REPAIRING: the node leaves its
        owner's pool; both transitions parent to the failure's span."""
        node = self.nodes[node_id]
        self._set_state(node, NodeState.FAILED, parent=span or None)
        self._set_state(node, NodeState.REPAIRING, parent=span or None)
        node.fail_span = span
        self.pools[node.owner].discard(node_id)
        self._down.add(node_id)
        node.owner = "__down__"
        return node

    def repair(self, node_id: Optional[int] = None) -> Node:
        """REPAIRING -> HEALTHY (FLAPPING for flappers); the node returns
        to the free pool. ``None`` repairs the lowest-id down node (the
        count-only legacy path does not thread node ids through repair
        events)."""
        if node_id is None:
            assert self._down, "repair with no node down"
            node_id = min(self._down)
        node = self.nodes[node_id]
        to = NodeState.FLAPPING if node.flapper else NodeState.HEALTHY
        self._set_state(node, to, parent=node.fail_span or None)
        self._down.discard(node_id)
        self.pools["free"].add(node_id)
        node.owner = "free"
        return node

    # --------------------------------------------------------------- audit
    def audit(self, svc) -> None:
        """Assert the inventory's pools mirror a provision service's counts
        exactly (free / per-tenant / draining / down). O(total); meant for
        tests and quiescent points, not the claim hot path."""
        assert len(self.pools.get("free", ())) == svc.free, \
            (sorted(self.pools.get("free", ())), svc.free)
        for t in svc.tenants.values():
            assert len(self.pools.get(t.name, ())) == t.alloc, \
                (t.name, sorted(self.pools.get(t.name, ())), t.alloc)
        assert len(self.pools.get(DRAIN_POOL, ())) == \
            getattr(svc, "draining", 0), \
            (sorted(self.pools.get(DRAIN_POOL, ())), svc.draining)
        assert len(self._down) == self.total - svc.total, \
            (sorted(self._down), self.total, svc.total)
