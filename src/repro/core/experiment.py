"""SC-vs-DC consolidation experiment (paper §III-D).

Static configuration (SC): each department runs a dedicated system —
144 nodes for HPC (the SDSC BLUE machine size) + 64 for Web services (the
peak demand of Fig. 5) = 208 nodes total.

Dynamic configuration (DC): one shared system of {200,190,180,170,160,150}
nodes under the cooperative policies.

Paper claims validated here (EXPERIMENTS.md §Paper-claims):
  * at DC=160 (76.9% of 208), ST completed jobs  >= SC completed jobs;
  * at DC=160, 1/avg-turnaround >= SC's;
  * killed jobs generally grow as the cluster shrinks (blips allowed — the
    paper itself reports a non-monotonicity at 170);
  * WS benefit unchanged (demand always met: unmet node-seconds == 0).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.core.simulator import ConsolidationSim, SimResult
from repro.core.traces import (SDSC_BLUE_NODES, TWO_WEEKS_S,
                               WORLDCUP_PEAK_INSTANCES, synthetic_sdsc_blue,
                               worldcup_demand_events)
from repro.core.types import Job, SimConfig

SC_TOTAL = SDSC_BLUE_NODES + WORLDCUP_PEAK_INSTANCES  # 208
DC_SIZES = (200, 190, 180, 170, 160, 150)


def run_static(jobs: List[Job], *, cfg: Optional[SimConfig] = None,
               horizon: float = TWO_WEEKS_S) -> SimResult:
    """SC: dedicated 144-node HPC system (WS runs on its own 64 nodes; its
    benefit is load-independent, so only the ST side needs simulating)."""
    cfg = dataclasses.replace(cfg or SimConfig(),
                              total_nodes=SDSC_BLUE_NODES)
    sim = ConsolidationSim(cfg, jobs, ws_demand=[], horizon=horizon)
    return sim.run()


def run_dynamic(jobs: List[Job], ws_demand: List[Tuple[float, int]],
                total_nodes: int, *, cfg: Optional[SimConfig] = None,
                horizon: float = TWO_WEEKS_S) -> SimResult:
    cfg = dataclasses.replace(cfg or SimConfig(), total_nodes=total_nodes)
    sim = ConsolidationSim(cfg, jobs, ws_demand=ws_demand, horizon=horizon)
    return sim.run()


def run_experiment(*, seed: int = 0, cfg: Optional[SimConfig] = None,
                   sizes: Tuple[int, ...] = DC_SIZES,
                   horizon: float = TWO_WEEKS_S,
                   jobs: Optional[List[Job]] = None,
                   ws_demand=None) -> Dict:
    """Full Fig. 7/8 sweep. Returns {'SC': SimResult, 'DC': {size: SimResult}}."""
    jobs = jobs if jobs is not None else synthetic_sdsc_blue(seed)
    ws_demand = ws_demand if ws_demand is not None \
        else worldcup_demand_events(seed, horizon)
    out = {"SC": run_static(jobs, cfg=cfg, horizon=horizon), "DC": {}}
    for size in sizes:
        out["DC"][size] = run_dynamic(jobs, ws_demand, size, cfg=cfg,
                                      horizon=horizon)
    return out


def validate_claims(results: Dict, *, dc_ref: int = 160) -> Dict[str, bool]:
    sc: SimResult = results["SC"]
    dc: SimResult = results["DC"][dc_ref]
    sizes = sorted(results["DC"])
    kills = [results["DC"][s].killed for s in sizes]          # ascending size
    # "killed increases in general as size decreases": compare largest vs
    # smallest and allow local blips (the paper has one at 170).
    kill_trend = kills[0] >= kills[-1]
    return {
        "dc160_completed_ge_sc": dc.completed >= sc.completed,
        "dc160_user_benefit_ge_sc":
            dc.benefit_user >= sc.benefit_user,
        "ws_demand_always_met": all(
            results["DC"][s].ws_unmet_node_seconds == 0.0 for s in sizes),
        "killed_grows_as_cluster_shrinks": kill_trend,
        "cost_ratio_at_160": dc_ref / SC_TOTAL,  # 0.769...
    }
