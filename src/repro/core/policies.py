"""Pluggable cooperative policies for the N-department tenancy framework.

The 2009 paper hard-codes one policy triple for exactly two departments:

  * WS demands have higher priority than ST demands;
  * ALL idle resources are provisioned to ST;
  * an urgent WS claim forcibly reclaims from ST.

``TenantProvisionService`` (core/provision.py) generalizes the state machine
to N registered tenants; THIS module supplies the policy objects that decide
(a) how idle nodes are distributed across batch-class tenants and (b) in
which order victims are drained when an urgent claim cannot be met from the
free pool. The paper's verbatim behaviour is the named ``"paper"``
configuration; ``"demand_capped"`` and ``"proportional_share"`` are the
beyond-paper alternatives (arXiv:1006.1401 provisions heterogeneous
workloads; arXiv:1004.1276 studies many consolidated communities — both
need exactly this pluggability).

A policy never mutates service state itself: it returns grant/victim plans
and the service applies them, so every policy inherits the same conservation
invariants.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass
class Tenant:
    """Runtime per-tenant record held by the provision service registry."""
    name: str
    kind: str                  # "latency" | "batch"
    priority: int              # lower number = higher priority
    alloc: int = 0
    # batch tenants: how many nodes they could still use (queue demand);
    # latency tenants: their current target demand
    demand: int = 0
    # proportional-share policies: relative share of idle capacity
    weight: float = 1.0
    # batch tenants: called to release n nodes (kill/preempt); returns freed.
    # A batch tenant WITHOUT a release hook is not forcibly reclaimable
    # (matches the paper service, which skips reclaim when unwired).
    on_force_release: Optional[Callable[[int], int]] = None
    # called when nodes are granted
    on_grant: Optional[Callable[[int], None]] = None


class CooperativePolicy:
    """Base cooperative policy: distribution of idle nodes + reclaim order.

    ``idle_grants`` returns ``[(tenant, n), ...]`` (one entry per tenant)
    for the service to apply; ``victim_order`` returns the tenants an urgent
    claim may drain, most-expendable first. ``demand_driven`` tells callers
    (the simulator) whether batch demand must be kept up to date and surplus
    idle allocation voluntarily returned — the paper's policy ignores demand
    entirely, so the simulator skips that bookkeeping for it.
    """

    name = "base"
    demand_driven = True

    # ------------------------------------------------------------- idle
    def idle_grants(self, free: int, batch: Sequence[Tenant]
                    ) -> List[Tuple[Tenant, int]]:
        raise NotImplementedError

    # ---------------------------------------------------------- reclaim
    def victim_order(self, tenants: Sequence[Tenant], claimant: Tenant
                     ) -> List[Tenant]:
        """Paper rule 3 generalized: batch tenants in REVERSE priority order
        (cheapest victim first), then lower-priority latency tenants."""
        batch = sorted((t for t in tenants if t.kind == "batch"),
                       key=lambda t: t.priority, reverse=True)
        latency = sorted(
            (t for t in tenants
             if t.kind == "latency" and t.name != claimant.name
             and t.priority > claimant.priority),
            key=lambda t: t.priority, reverse=True)
        return batch + latency

    @staticmethod
    def _fill_demand(free: int, batch: Sequence[Tenant]) -> Dict[str, int]:
        """Priority-ordered fill of unmet demand, capped at ``free``."""
        grants: Dict[str, int] = {}
        for t in batch:
            if free <= 0:
                break
            give = min(max(0, t.demand - t.alloc), free)
            if give > 0:
                grants[t.name] = grants.get(t.name, 0) + give
                free -= give
        return grants


class PaperPolicy(CooperativePolicy):
    """The paper's verbatim configuration: WS preempts, ALL idle to ST.

    Idle nodes first cover declared batch demand in priority order (a no-op
    in the paper's two-tenant wiring, where demand is never declared), then
    EVERYTHING left is dumped on the highest-priority batch tenant whether
    it asked or not."""

    name = "paper"
    demand_driven = False

    def idle_grants(self, free, batch):
        grants = self._fill_demand(free, batch)
        leftover = free - sum(grants.values())
        if leftover > 0 and batch:
            top = batch[0].name
            grants[top] = grants.get(top, 0) + leftover
        return [(t, grants[t.name]) for t in batch if grants.get(t.name)]


class DemandCappedIdlePolicy(CooperativePolicy):
    """Idle flows to batch tenants by priority but stops at declared demand;
    the remainder stays free (cheap to claim later — no kills)."""

    name = "demand_capped"

    def idle_grants(self, free, batch):
        grants = self._fill_demand(free, batch)
        return [(t, grants[t.name]) for t in batch if grants.get(t.name)]


class ProportionalSharePolicy(CooperativePolicy):
    """Idle is split across batch tenants with unmet demand in proportion to
    their ``weight`` (water-filling: a tenant whose demand saturates early
    frees its share for the others). Leftover beyond total demand stays
    free."""

    name = "proportional_share"

    def idle_grants(self, free, batch):
        want = {t.name: max(0, t.demand - t.alloc) for t in batch}
        grants = {t.name: 0 for t in batch}
        remaining = free
        while remaining > 0:
            active = [t for t in batch if want[t.name] > 0]
            if not active:
                break
            weights = {t.name: max(t.weight, 0.0) for t in active}
            wsum = sum(weights.values())
            if wsum <= 0:
                weights = {t.name: 1.0 for t in active}
                wsum = float(len(active))
            granted_round = 0
            for t in active:
                share = min(want[t.name],
                            int(remaining * weights[t.name] / wsum))
                if share > 0:
                    grants[t.name] += share
                    want[t.name] -= share
                    granted_round += share
            if granted_round == 0:
                # integer floors all rounded to zero: hand out single nodes
                # in priority order so the loop always makes progress
                for t in active:
                    if granted_round >= remaining:
                        break
                    grants[t.name] += 1
                    want[t.name] -= 1
                    granted_round += 1
            remaining -= granted_round
        return [(t, grants[t.name]) for t in batch if grants.get(t.name)]


POLICIES: Dict[str, Callable[[], CooperativePolicy]] = {
    PaperPolicy.name: PaperPolicy,
    DemandCappedIdlePolicy.name: DemandCappedIdlePolicy,
    ProportionalSharePolicy.name: ProportionalSharePolicy,
}


def get_policy(policy) -> CooperativePolicy:
    """Resolve a policy name or instance to a CooperativePolicy."""
    if isinstance(policy, CooperativePolicy):
        return policy
    if isinstance(policy, type) and issubclass(policy, CooperativePolicy):
        return policy()
    try:
        return POLICIES[policy]()
    except KeyError:
        raise ValueError(
            f"unknown cooperative policy {policy!r}; "
            f"have {sorted(POLICIES)}") from None


def __getattr__(name):
    # Historical home of the multi-tenant service (now built on the registry
    # state machine in core/provision.py); re-exported lazily so the two
    # modules can import in either order.
    if name == "MultiTenantProvisionService":
        from repro.core.provision import MultiTenantProvisionService
        return MultiTenantProvisionService
    raise AttributeError(name)
