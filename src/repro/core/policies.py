"""Multi-tenant generalization of the paper's cooperative policies.

The paper wires exactly two departments (one WS, one ST). Real organizations
have many: this module generalizes the Resource Provision Service to N
tenants with strict priorities, preserving the paper's three rules as the
two-tenant special case:

  * latency-class tenants (the WS CMSes) claim urgently in priority order;
  * ALL idle resources flow to batch-class tenants (the ST CMSes), highest
    priority first, each taking what it can use (open jobs) before the next;
  * a claim that cannot be met from the free pool forcibly reclaims from
    batch tenants in REVERSE priority order (cheapest victim first), then
    from lower-priority latency tenants.

`ConsolidationSim` keeps the paper's fixed 2-tenant wiring; the multi-tenant
service is exercised by `tests/test_multitenant.py` and available to the
runtime orchestrator for >2 departments.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional


@dataclasses.dataclass
class Tenant:
    name: str
    kind: str                  # "latency" | "batch"
    priority: int              # lower number = higher priority
    alloc: int = 0
    # batch tenants: how many nodes they could still use (queue demand);
    # latency tenants: their current target demand
    demand: int = 0
    # batch tenants: called to release n nodes (kill/preempt); returns freed
    on_force_release: Optional[Callable[[int], int]] = None
    # called when nodes are granted
    on_grant: Optional[Callable[[int], None]] = None


class MultiTenantProvisionService:
    def __init__(self, total_nodes: int, *, greedy_idle: bool = False):
        """greedy_idle=True reproduces the paper's two-tenant rule verbatim
        (ALL leftover idle nodes are dumped on the highest-priority batch
        tenant, demand or not). The default caps grants at declared demand
        and leaves the remainder free — a tenant that declared zero demand
        never receives nodes it cannot use."""
        self.total = total_nodes
        self.free = total_nodes
        self.greedy_idle = greedy_idle
        self.tenants: Dict[str, Tenant] = {}

    # ------------------------------------------------------------- wiring
    def register(self, tenant: Tenant):
        assert tenant.name not in self.tenants
        self.tenants[tenant.name] = tenant

    def check(self):
        used = sum(t.alloc for t in self.tenants.values())
        assert used + self.free == self.total, (used, self.free, self.total)
        assert self.free >= 0
        assert all(t.alloc >= 0 for t in self.tenants.values())
        if not self.greedy_idle:
            # demand-capped invariant: nodes sit free only when every batch
            # tenant's declared demand is already covered (claims only drain
            # `free`, and every demand/release change reruns provision_idle,
            # so this holds at every quiescent point)
            assert self.free == 0 or all(
                t.alloc >= t.demand for t in self.tenants.values()
                if t.kind == "batch"), \
                (self.free, {t.name: (t.alloc, t.demand)
                             for t in self.tenants.values()
                             if t.kind == "batch"})

    def _batch_by_priority(self, reverse: bool = False) -> List[Tenant]:
        ts = [t for t in self.tenants.values() if t.kind == "batch"]
        return sorted(ts, key=lambda t: t.priority, reverse=reverse)

    def _latency_by_priority(self, reverse: bool = False) -> List[Tenant]:
        ts = [t for t in self.tenants.values() if t.kind == "latency"]
        return sorted(ts, key=lambda t: t.priority, reverse=reverse)

    # ------------------------------------------------------------ requests
    def claim(self, name: str, n: int) -> int:
        """A latency tenant urgently claims n more nodes (paper rule 1/3)."""
        t = self.tenants[name]
        assert t.kind == "latency"
        granted = min(self.free, n)
        self.free -= granted
        t.alloc += granted
        short = n - granted
        # forced reclaim: batch tenants in reverse priority order first
        victims = self._batch_by_priority(reverse=True) + [
            lt for lt in self._latency_by_priority(reverse=True)
            if lt.priority > t.priority and lt.name != name]
        for v in victims:
            if short <= 0:
                break
            take = min(short, v.alloc)
            if take <= 0:
                continue
            got = take
            if v.on_force_release is not None:
                got = min(v.on_force_release(take), take)
            v.alloc -= got
            t.alloc += got
            short -= got
        self.check()
        return n - short

    def release(self, name: str, n: int):
        """A tenant returns idle nodes; they flow to batch tenants.

        provision_idle runs before check(): the freed nodes must first
        flow to batch tenants with unmet demand or the demand-capped
        invariant would trip mid-transition."""
        t = self.tenants[name]
        n = min(n, t.alloc)
        t.alloc -= n
        self.free += n
        self.provision_idle()
        self.check()

    def set_batch_demand(self, name: str, demand: int):
        self.tenants[name].demand = max(0, demand)
        self.provision_idle()

    def provision_idle(self):
        """Paper rule 2 generalized: idle flows to batch tenants by priority,
        each capped at its declared demand. Leftover stays free (default) or
        is dumped on the highest-priority batch tenant when ``greedy_idle``
        (the paper's literal 'all idle to ST')."""
        batch = self._batch_by_priority()
        if not batch:
            return
        for t in batch:
            if self.free <= 0:
                break
            want = max(0, t.demand - t.alloc)
            give = min(want, self.free)
            if give > 0:
                self.free -= give
                t.alloc += give
                if t.on_grant is not None:
                    t.on_grant(give)
        if self.greedy_idle and self.free > 0:
            t = batch[0]
            give = self.free
            self.free = 0
            t.alloc += give
            if t.on_grant is not None:
                t.on_grant(give)
        self.check()
