"""Two-phase cooperative policy engines for the N-department tenancy
framework.

The 2009 paper hard-codes one policy triple for exactly two departments:

  * WS demands have higher priority than ST demands;
  * ALL idle resources are provisioned to ST;
  * an urgent WS claim forcibly reclaims from ST.

``TenantProvisionService`` (core/provision.py) generalizes the state machine
to N registered tenants; THIS module supplies the :class:`PolicyEngine`
objects that decide the two halves of every provisioning action:

  * **phase 1 — reclaim planning** (``plan_reclaim``): given a node
    deficit, produce an *ordered reclaim plan* — which victims to drain,
    in what order, with what per-victim cap — from per-tenant runtime
    signals (:class:`~repro.core.types.TenantSignals`: latency headroom vs
    SLO, queue depth, preemption cost, declared weight/bid);
  * **phase 2 — idle distribution** (``idle_grants``): how freed/idle
    nodes flow back to batch-class tenants.

The paper's verbatim behaviour is the ``"paper"`` engine (its plan is the
fixed reverse-priority chain, its idle rule dumps everything on the top
batch tenant — bit-for-bit the seed semantics). ``demand_capped`` and
``proportional_share`` are phase-2-only variants sharing the same default
planner. Beyond them, ``slo_headroom`` plans reclaims from the latency
tenant furthest under its SLO target first and batch tenants by cheapest
preemption, and ``auction`` derives per-interval bids (weight x unmet
demand) whose clearing price decides both reclaim order and idle
distribution. ``budget_auction`` and ``second_price`` turn the auction
into a real market: tenants spend a finite ``budget`` over the horizon
(ledger in :class:`~repro.core.types.MarketState`), bids can be
SLO-elastic (rising as latency headroom shrinks), idle nodes clear at the
lowest winning (first-price) or highest losing (Vickrey) per-node bid,
and a broke tenant falls back to its floor (arXiv:1006.1401 frames
provisioning policies as exactly this resource-economy design space;
arXiv:1004.1276 motivates per-community budgets over multi-community
mixes).

An engine never mutates service state itself: it returns grant/reclaim
plans and the service applies them, so every engine inherits the same
conservation invariants — including the floor guarantee: a plan never asks
for nodes below a victim's declared ``floor``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.telemetry import NULL_TRACER
from repro.core.types import MarketState, TenantSignals

# per-engine cap on retained clearing-price / plan samples (aggregates are
# exact; samples are for inspection and the campaign artifact)
STATE_SAMPLES_MAX = 64
# slo_elastic bids scale between 1x (full latency headroom) and this cap
# (deep SLO violation); 2x corresponds to exactly-zero headroom
ELASTIC_BID_MAX = 4.0


@dataclasses.dataclass
class Tenant:
    """Runtime per-tenant record held by the provision service registry."""
    name: str
    kind: str                  # "latency" | "batch"
    priority: int              # lower number = higher priority
    alloc: int = 0
    # batch tenants: how many nodes they could still use (queue demand);
    # latency tenants: their current target demand
    demand: int = 0
    # proportional-share policies: relative share of idle capacity
    weight: float = 1.0
    # forced reclaim never takes this tenant below `floor` nodes
    floor: int = 0
    # auction engines: bid = bid_weight x unmet demand (None -> weight)
    bid_weight: Optional[float] = None
    # market engines: tokens spendable across the run (None = unlimited)
    budget: Optional[float] = None
    # "linear" | "slo_elastic" (bid rises as latency headroom shrinks)
    bid_policy: str = "linear"
    # batch tenants: called to release n nodes (kill/preempt); returns freed.
    # A batch tenant WITHOUT a release hook is not forcibly reclaimable
    # (matches the paper service, which skips reclaim when unwired).
    on_force_release: Optional[Callable[[int], int]] = None
    # called when nodes are granted
    on_grant: Optional[Callable[[int], None]] = None
    # runtime signal source (CMS / orchestrator); None -> derived snapshot
    signals: Optional[Callable[[], TenantSignals]] = None


def tenant_signals(t: Tenant) -> TenantSignals:
    """Resolve a tenant's runtime signals, falling back to a snapshot
    derived from the registry record when no CMS source is wired."""
    if t.signals is not None:
        s = t.signals()
        if s is not None:
            s.bid = compute_bid(t, s)
            return s
    s = TenantSignals(name=t.name, kind=t.kind, alloc=t.alloc,
                      demand=t.demand, weight=t.weight)
    s.bid = compute_bid(t, s)
    return s


def bid_elasticity(t: Tenant, s: Optional[TenantSignals]) -> float:
    """``slo_elastic`` multiplier: 1x at full latency headroom, 2x at zero
    headroom, up to ``ELASTIC_BID_MAX`` in deep violation. ``linear``
    tenants (and tenants without an SLO target) always get 1x."""
    if getattr(t, "bid_policy", "linear") != "slo_elastic" or s is None:
        return 1.0
    target = s.slo_target_s
    if target <= 0.0:
        return 1.0
    urgency = (target - s.latency_headroom_s) / target
    return 1.0 + min(max(urgency, 0.0), ELASTIC_BID_MAX - 1.0)


def compute_bid(t: Tenant, s: Optional[TenantSignals] = None) -> float:
    """Per-interval bid: bid_weight (default weight) x unmet demand,
    scaled by the ``slo_elastic`` urgency factor when the tenant opted in."""
    unmet = s.unmet if s is not None else max(0, t.demand - t.alloc)
    w = t.bid_weight if t.bid_weight is not None else t.weight
    return max(0.0, float(w)) * bid_elasticity(t, s) * float(unmet)


def unit_bid(t: Tenant, s: Optional[TenantSignals] = None) -> float:
    """Per-NODE bid price (the market engines' money unit): bid_weight
    (default weight) x the slo_elastic urgency factor. ``compute_bid`` is
    this price times unmet demand."""
    w = t.bid_weight if t.bid_weight is not None else t.weight
    return max(0.0, float(w)) * bid_elasticity(t, s)


@dataclasses.dataclass(frozen=True)
class ReclaimStep:
    """One entry of a reclaim plan: drain up to ``take`` nodes from
    ``victim`` (the service caps the actual take at the live deficit and
    allocation when it applies the plan)."""
    victim: str
    take: int
    reason: str = ""


class PolicyEngine:
    """Base two-phase engine: reclaim planning + idle distribution.

    ``plan_reclaim`` (phase 1) returns the ordered ``ReclaimStep`` list an
    urgent claim may drain; the default planner walks the legacy
    ``victim_order`` chain, capping each step at what the victim can give
    up without crossing its ``floor``. The plan covers EVERY eligible
    victim (not just enough to cover the deficit): a victim may release
    fewer nodes than asked, and the service must be able to continue down
    the chain exactly like the paper's loop did.

    ``idle_grants`` (phase 2) returns ``[(tenant, n), ...]`` for the
    service to apply. ``demand_driven`` tells callers (the simulator)
    whether batch demand must be kept up to date and surplus idle
    allocation voluntarily returned — the paper's engine ignores demand
    entirely, so the simulator skips that bookkeeping for it.

    Engines carry per-run state: how many plans were made, which victims
    were actually drained (reported back by the service via
    ``note_reclaimed``) and, for stateful engines like ``auction``,
    per-interval clearing prices. ``state_snapshot()`` serializes it for
    results/artifacts.
    """

    name = "base"
    demand_driven = True
    # demand-driven engines normally guarantee that nodes only sit free
    # once every batch tenant's declared demand is covered; budget engines
    # cannot (a broke tenant may be unable to BUY coverage), so they unset
    # this and the service relaxes the corresponding invariant check
    demand_satiating = True
    stateful = False

    def __init__(self):
        self.reclaim_plans = 0
        self.victim_counts: Dict[str, int] = {}
        self.victim_nodes: Dict[str, int] = {}
        self.last_plan: List[str] = []
        self.plan_samples: List[List[str]] = []
        # plans beyond the sample cap (aggregates above stay exact); kept
        # as an attribute so capped sample lists are distinguishable from
        # short runs without changing the serialized snapshot
        self.plan_samples_dropped = 0
        # telemetry sink; the provision service swaps in its live Tracer
        # at wiring time (core/telemetry.py) — NULL_TRACER costs a branch
        self.tracer = NULL_TRACER

    # ------------------------------------------------------------- phase 1
    def plan_reclaim(self, deficit: int, tenants: Sequence[Tenant],
                     claimant: Tenant) -> List[ReclaimStep]:
        plan = [ReclaimStep(v.name, self.reclaimable(v), "victim-chain")
                for v in self.victim_order(tenants, claimant)
                if self.reclaimable(v) > 0]
        self._note_plan(plan)
        return plan

    def victim_order(self, tenants: Sequence[Tenant], claimant: Tenant
                     ) -> List[Tenant]:
        """Paper rule 3 generalized: batch tenants in REVERSE priority order
        (cheapest victim first), then lower-priority latency tenants."""
        batch = sorted((t for t in tenants if t.kind == "batch"),
                       key=lambda t: t.priority, reverse=True)
        latency = sorted(
            (t for t in tenants
             if t.kind == "latency" and t.name != claimant.name
             and t.priority > claimant.priority),
            key=lambda t: t.priority, reverse=True)
        return batch + latency

    @staticmethod
    def reclaimable(v: Tenant) -> int:
        """Nodes a plan may ask this victim for: never below its floor."""
        return max(0, v.alloc - max(0, v.floor))

    @staticmethod
    def eligible_victims(tenants: Sequence[Tenant], claimant: Tenant
                         ) -> Tuple[List[Tenant], List[Tenant]]:
        """(batch, latency) victims an urgent claim may legally drain:
        every batch tenant, and latency tenants strictly below the
        claimant's priority class (a lower-priority latency department can
        never preempt a higher-priority one)."""
        batch = [t for t in tenants if t.kind == "batch"]
        latency = [t for t in tenants
                   if t.kind == "latency" and t.name != claimant.name
                   and t.priority > claimant.priority]
        return batch, latency

    # ----------------------------------------------------------- bookkeeping
    def _note_plan(self, plan: List[ReclaimStep]):
        self.reclaim_plans += 1
        self.last_plan = [s.victim for s in plan]
        if len(self.plan_samples) < STATE_SAMPLES_MAX:
            self.plan_samples.append(self.last_plan)
        else:
            self.plan_samples_dropped += 1

    def reclaim_cap(self, victim: Tenant, take: int, claimant: Tenant
                    ) -> int:
        """Apply-time cap on one plan step (called by the service with the
        live ``take`` right before the victim's release hook runs). The
        default engine imposes nothing extra; budget engines cap at what
        the claimant can still afford at this victim's price."""
        return take

    def note_reclaimed(self, victim: str, n: int,
                       granted: Optional[int] = None):
        """The service reports nodes actually taken from a plan victim.

        ``n`` is the victim's full release (drain statistics); ``granted``
        is how many of them the claimant actually received — a victim may
        over-release (e.g. a trainer shrinking by whole DP groups), and
        the surplus flows back to the free pool, so money engines must
        charge on ``granted``, never ``n``. Defaults to ``n``."""
        if n <= 0:
            return
        self.victim_counts[victim] = self.victim_counts.get(victim, 0) + 1
        self.victim_nodes[victim] = self.victim_nodes.get(victim, 0) + n

    def state_snapshot(self) -> Dict:
        """JSON-safe per-run engine state for results and artifacts."""
        return {
            "engine": self.name,
            "reclaim_plans": self.reclaim_plans,
            "victim_counts": dict(self.victim_counts),
            "victim_nodes": dict(self.victim_nodes),
            "last_plan": list(self.last_plan),
        }

    # ------------------------------------------------------------- phase 2
    def idle_grants(self, free: int, batch: Sequence[Tenant]
                    ) -> List[Tuple[Tenant, int]]:
        raise NotImplementedError

    @staticmethod
    def _fill_demand(free: int, batch: Sequence[Tenant]) -> Dict[str, int]:
        """Priority-ordered fill of unmet demand, capped at ``free``."""
        grants: Dict[str, int] = {}
        for t in batch:
            if free <= 0:
                break
            give = min(max(0, t.demand - t.alloc), free)
            if give > 0:
                grants[t.name] = grants.get(t.name, 0) + give
                free -= give
        return grants


# back-compat alias: the pre-engine name for the policy base class
CooperativePolicy = PolicyEngine


class PaperPolicy(PolicyEngine):
    """The paper's verbatim configuration: WS preempts, ALL idle to ST.

    Phase 1 is the default reverse-priority victim chain; phase 2 first
    covers declared batch demand in priority order (a no-op in the paper's
    two-tenant wiring, where demand is never declared), then EVERYTHING
    left is dumped on the highest-priority batch tenant whether it asked
    or not."""

    name = "paper"
    demand_driven = False

    def idle_grants(self, free, batch):
        grants = self._fill_demand(free, batch)
        leftover = free - sum(grants.values())
        if leftover > 0 and batch:
            top = batch[0].name
            grants[top] = grants.get(top, 0) + leftover
        return [(t, grants[t.name]) for t in batch if grants.get(t.name)]


class DemandCappedIdlePolicy(PolicyEngine):
    """Idle flows to batch tenants by priority but stops at declared demand;
    the remainder stays free (cheap to claim later — no kills)."""

    name = "demand_capped"

    def idle_grants(self, free, batch):
        grants = self._fill_demand(free, batch)
        return [(t, grants[t.name]) for t in batch if grants.get(t.name)]


class ProportionalSharePolicy(PolicyEngine):
    """Idle is split across batch tenants with unmet demand in proportion to
    their ``weight`` (water-filling: a tenant whose demand saturates early
    frees its share for the others). Leftover beyond total demand stays
    free."""

    name = "proportional_share"

    def idle_grants(self, free, batch):
        want = {t.name: max(0, t.demand - t.alloc) for t in batch}
        grants = {t.name: 0 for t in batch}
        remaining = free
        while remaining > 0:
            active = [t for t in batch if want[t.name] > 0]
            if not active:
                break
            weights = {t.name: max(t.weight, 0.0) for t in active}
            wsum = sum(weights.values())
            if wsum <= 0:
                weights = {t.name: 1.0 for t in active}
                wsum = float(len(active))
            granted_round = 0
            for t in active:
                share = min(want[t.name],
                            int(remaining * weights[t.name] / wsum))
                if share > 0:
                    grants[t.name] += share
                    want[t.name] -= share
                    granted_round += share
            if granted_round == 0:
                # integer floors all rounded to zero: hand out single nodes
                # in priority order so the loop always makes progress
                for t in active:
                    if granted_round >= remaining:
                        break
                    grants[t.name] += 1
                    want[t.name] -= 1
                    granted_round += 1
            remaining -= granted_round
        return [(t, grants[t.name]) for t in batch if grants.get(t.name)]


class SLOHeadroomEngine(PolicyEngine):
    """SLO-aware reclaim planning over runtime signals (ROADMAP item).

    Phase-1 plan, three bands:

      1. latency victims' *surplus* replicas (allocation above demand),
         the tenant with the most latency headroom first — draining them
         costs nothing while their SLO is comfortably met;
      2. batch tenants by cheapest preemption (idle-absorbing or
         just-started jobs before long-running ones), ties by reverse
         priority;
      3. latency victims below their demand (down to their floor, never
         further), again most-headroom-first — the last resort, ordered so
         the department with the most slack to its SLO target absorbs the
         violation risk.

    Phase 2 is demand-capped (idle stays free beyond declared demand, so
    future claims are cheap)."""

    name = "slo_headroom"

    def plan_reclaim(self, deficit, tenants, claimant):
        batch, latency = self.eligible_victims(tenants, claimant)
        sig = {t.name: tenant_signals(t) for t in tenants}
        plan: List[ReclaimStep] = []
        # band 1: free surplus above demand, most headroom first (demand
        # comes from the CMS signal — latency demand is not mirrored on the
        # registry record, which only tracks batch demand). The WS proxy
        # headroom clamps at zero, so replica-short tenants tie with
        # exactly-met ones; the RELATIVE-shortfall tiebreak (shortfall as a
        # fraction of demand — the quantity the pre-clamp proxy scaled by)
        # keeps the most relatively starved department drained LAST in
        # band 3, preserving the pre-clamp protection order.
        def shortfall_frac(t):
            s = sig[t.name]
            return s.queue_depth / max(s.demand, 1)

        by_headroom = sorted(
            latency, key=lambda t: (-sig[t.name].latency_headroom_s,
                                    shortfall_frac(t),
                                    -t.priority))
        surplus_taken: Dict[str, int] = {}
        for v in by_headroom:
            surplus = min(self.reclaimable(v),
                          max(0, v.alloc - max(sig[v.name].demand, v.floor)))
            if surplus > 0:
                surplus_taken[v.name] = surplus
                plan.append(ReclaimStep(
                    v.name, surplus,
                    f"surplus headroom={sig[v.name].latency_headroom_s:.1f}s"))
        # band 2: batch by cheapest preemption
        for v in sorted(batch,
                        key=lambda t: (sig[t.name].preemption_cost_s,
                                       -t.priority)):
            take = self.reclaimable(v)
            if take > 0:
                plan.append(ReclaimStep(
                    v.name, take,
                    f"preempt cost={sig[v.name].preemption_cost_s:.1f}s"))
        # band 3: dig into latency demand down to the floor
        for v in by_headroom:
            take = self.reclaimable(v) - surplus_taken.get(v.name, 0)
            if take > 0:
                plan.append(ReclaimStep(
                    v.name, take,
                    f"drain headroom={sig[v.name].latency_headroom_s:.1f}s"))
        self._note_plan(plan)
        return plan

    def idle_grants(self, free, batch):
        grants = self._fill_demand(free, batch)
        return [(t, grants[t.name]) for t in batch if grants.get(t.name)]


class AuctionEngine(PolicyEngine):
    """Market-style engine: per-interval bids clear both phases.

    Every decision interval each tenant's bid is ``bid_weight x unmet
    demand`` (recomputed from live signals, so bids track load). Phase 2
    sells idle nodes to batch tenants in descending-bid order, capped at
    demand; the *clearing price* is the lowest winning bid and is recorded
    per interval in the engine state. Phase 1 drains victims in
    ASCENDING-bid order (the tenant that values marginal nodes least sells
    first) — batch victims before latency victims, so the market reorders
    the paper's chain without letting a cheap bid strip a latency
    department of replicas while batch capacity remains — still respecting
    priority-class eligibility and floors, and records the marginal
    (clearing) bid of each plan."""

    name = "auction"
    stateful = True

    def __init__(self):
        super().__init__()
        self.intervals = 0
        self.price_sum = 0.0
        self.price_max = 0.0
        self.price_samples: List[float] = []
        self.price_samples_dropped = 0
        self.last_bids: Dict[str, float] = {}
        self.last_clearing_price: Optional[float] = None
        self.reclaim_price_sum = 0.0
        self.reclaim_price_n = 0

    def _record_price(self, price: float):
        self.intervals += 1
        self.price_sum += price
        self.price_max = max(self.price_max, price)
        self.last_clearing_price = price
        if len(self.price_samples) < STATE_SAMPLES_MAX:
            self.price_samples.append(price)
        else:
            self.price_samples_dropped += 1
        if self.tracer.enabled:
            self.tracer.emit("auction_clear", price=float(price),
                             interval=self.intervals, engine=self.name)

    def _note_reclaim_price(self, plan: List[ReclaimStep],
                            prices: Dict[str, float], deficit: int):
        """Record the claim's clearing price: the marginal victim bid
        needed to cover the deficit (0 when the chain cannot cover it)."""
        need, price = deficit, 0.0
        for step in plan:
            if need <= 0:
                break
            price = prices[step.victim]
            need -= step.take
        if need > 0:
            price = 0.0          # chain cannot cover the deficit: no clear
        self.reclaim_price_sum += price
        self.reclaim_price_n += 1

    def plan_reclaim(self, deficit, tenants, claimant):
        batch, latency = self.eligible_victims(tenants, claimant)
        bids = {t.name: tenant_signals(t).bid for t in tenants}
        self.last_bids = dict(bids)
        victims = sorted(
            batch + latency,
            key=lambda t: (0 if t.kind == "batch" else 1, bids[t.name],
                           -t.priority))
        plan = [ReclaimStep(v.name, self.reclaimable(v),
                            f"bid={bids[v.name]:.2f}")
                for v in victims if self.reclaimable(v) > 0]
        self._note_reclaim_price(plan, bids, deficit)
        self._note_plan(plan)
        return plan

    def idle_grants(self, free, batch):
        bids = {t.name: tenant_signals(t).bid for t in batch}
        self.last_bids.update(bids)
        order = sorted(batch, key=lambda t: (-bids[t.name], t.priority))
        grants: Dict[str, int] = {}
        price = 0.0
        remaining = free
        for t in order:
            if remaining <= 0:
                break
            give = min(max(0, t.demand - t.alloc), remaining)
            if give > 0:
                grants[t.name] = give
                remaining -= give
                price = bids[t.name]          # lowest winning bid so far
        if grants:
            self._record_price(price)
        return [(t, grants[t.name]) for t in batch if grants.get(t.name)]

    def state_snapshot(self) -> Dict:
        out = super().state_snapshot()
        out.update({
            "intervals": self.intervals,
            "clearing_price_mean":
                self.price_sum / self.intervals if self.intervals else 0.0,
            "clearing_price_max": self.price_max,
            "clearing_price_samples": list(self.price_samples),
            "reclaim_price_mean":
                self.reclaim_price_sum / self.reclaim_price_n
                if self.reclaim_price_n else 0.0,
            "last_bids": dict(self.last_bids),
        })
        return out


class BudgetAuctionEngine(AuctionEngine):
    """Budget-constrained market engine, first-price clearing (the ROADMAP
    market item: budgets spendable over time + SLO-elastic bids).

    Every tenant starts with ``budget`` tokens (None = unlimited), held in
    a :class:`~repro.core.types.MarketState` that the engine threads
    through both phases and serializes into ``policy_state["market"]``.
    Bids are per-NODE prices: ``bid_weight`` (default ``weight``), scaled
    by the ``slo_elastic`` urgency factor when the tenant opted in.

    Phase 2 sells idle nodes per interval: highest per-node bidders first,
    each capped at unmet demand AND at what it can afford at its own bid;
    every winner pays the interval's *clearing price* per node — the
    lowest winning bid (the winning side's "first price") — debited from
    its budget. A broke tenant wins nothing and erodes toward its floor.

    Phase 1 (urgent claims) drains victims in ascending per-node-bid
    order, batch before latency, floors respected; the claimant pays each
    victim's per-node bid for every node it RECEIVES beyond its own floor
    entitlement (nodes up to ``floor`` are a free guarantee — a broke
    claimant "falls back to its floor"; an over-releasing victim's
    surplus reflows to the free pool unpaid and is sold there instead).
    The plan lists every victim at its full floor-capped take — the same
    under-release resilience as the plain auction — and affordability is
    enforced exactly at APPLY time: the service asks ``reclaim_cap`` for
    each step's allowance against the claimant's LIVE remaining budget,
    and the debit lands in ``note_reclaimed`` at the same price, so
    budgets can never be overspent and a victim that refuses to release
    never starves affordable victims later in the plan.
    """

    name = "budget_auction"
    demand_satiating = False

    def __init__(self):
        super().__init__()
        self.market = MarketState()
        self.last_unit_bids: Dict[str, float] = {}
        # pending-claim charge book: per-victim per-node prices + the
        # claimant's free floor quota, consumed by reclaim_cap /
        # note_reclaimed as the service applies the plan step by step
        self._claimant: Optional[str] = None
        self._claim_prices: Dict[str, float] = {}
        self._claim_free_left = 0

    def _sync_market(self, tenants: Sequence[Tenant]):
        for t in tenants:
            self.market.register(t.name, getattr(t, "budget", None))

    def _record_price(self, price: float):
        super()._record_price(price)
        self.market.note_price(price)

    # ------------------------------------------------------------- phase 1
    def plan_reclaim(self, deficit, tenants, claimant):
        self._sync_market(tenants)
        batch, latency = self.eligible_victims(tenants, claimant)
        sig = {t.name: tenant_signals(t) for t in tenants}
        prices = {t.name: unit_bid(t, sig[t.name]) for t in tenants}
        self.last_bids = {n: s.bid for n, s in sig.items()}
        self.last_unit_bids.update(prices)
        victims = sorted(
            batch + latency,
            key=lambda t: (0 if t.kind == "batch" else 1, prices[t.name],
                           -t.priority))
        plan = [ReclaimStep(v.name, self.reclaimable(v),
                            f"price={prices[v.name]:.2f}")
                for v in victims if self.reclaimable(v) > 0]
        # open the claim's charge book: nodes up to the claimant's floor
        # are free; everything further is capped and debited at apply time
        self._claimant = claimant.name
        self._claim_prices = {s.victim: prices[s.victim] for s in plan}
        self._claim_free_left = max(0, claimant.floor - claimant.alloc)
        self._note_reclaim_price(plan, prices, deficit)
        self._note_plan(plan)
        return plan

    def reclaim_cap(self, victim, take, claimant):
        """Live affordability cap for one plan step: the claimant's free
        floor quota plus what its remaining budget buys at this victim's
        per-node price (previous steps' debits already reflected)."""
        if self._claimant != claimant.name or \
                victim.name not in self._claim_prices:
            return take
        price = self._claim_prices[victim.name]
        can_pay = self.market.affordable_nodes(claimant.name, price)
        return min(take, self._claim_free_left + can_pay)

    def note_reclaimed(self, victim: str, n: int,
                       granted: Optional[int] = None):
        super().note_reclaimed(victim, n, granted)
        granted = n if granted is None else granted
        if granted <= 0 or self._claimant is None or \
                victim not in self._claim_prices:
            return
        # free floor-entitled nodes first (apply order == plan order),
        # then charge the claimant at this victim's per-node bid — only
        # for nodes it actually received (an over-releasing victim's
        # surplus reflows to the free pool and is sold there, not here)
        free_used = min(self._claim_free_left, granted)
        self._claim_free_left -= free_used
        paid = granted - free_used
        if paid > 0:
            price = self._claim_prices[victim]
            # a victim over-releasing past the reclaim_cap (DP-group
            # rounding) can hand the claimant more than it can afford;
            # the debit clamps at the live budget so it can never go
            # negative — the bounded excess rides free
            paid = min(paid, self.market.affordable_nodes(
                self._claimant, price))
            if paid > 0:
                self.market.debit(self._claimant, paid, price, "reclaim",
                                  self.intervals)

    # ------------------------------------------------------------- phase 2
    def _clearing_price(self, winner_prices: List[float],
                        loser_prices: List[float]) -> float:
        """First-price clearing: the lowest winning per-node bid."""
        return min(winner_prices) if winner_prices else 0.0

    def idle_grants(self, free, batch):
        self._sync_market(batch)
        sig = {t.name: tenant_signals(t) for t in batch}
        prices = {t.name: unit_bid(t, sig[t.name]) for t in batch}
        self.last_bids.update({n: s.bid for n, s in sig.items()})
        self.last_unit_bids.update(prices)
        order = sorted(batch, key=lambda t: (-prices[t.name], t.priority))
        grants: Dict[str, int] = {}
        winner_prices: List[float] = []
        loser_prices: List[float] = []
        remaining = free
        for t in order:
            want = max(0, t.demand - t.alloc)
            if want <= 0:
                continue
            # affordability is judged at the tenant's own bid; the actual
            # debit happens at the clearing price, which never exceeds it
            can_pay = self.market.affordable_nodes(t.name, prices[t.name])
            give = min(want, can_pay, remaining)
            if give > 0:
                grants[t.name] = give
                winner_prices.append(prices[t.name])
                remaining -= give
            if give < min(want, can_pay):
                loser_prices.append(prices[t.name])
        if grants:
            price = self._clearing_price(winner_prices, loser_prices)
            self._record_price(price)
            for name, n in grants.items():
                self.market.debit(name, n, price, "idle", self.intervals)
        return [(t, grants[t.name]) for t in batch if grants.get(t.name)]

    def state_snapshot(self) -> Dict:
        out = super().state_snapshot()
        out["market"] = self.market.snapshot()
        out["last_unit_bids"] = dict(self.last_unit_bids)
        return out


class SecondPriceEngine(BudgetAuctionEngine):
    """Vickrey variant of :class:`BudgetAuctionEngine`: idle winners pay
    the highest LOSING per-node bid (0 when every bidder is fully served).

    Truthful ``bid_weight``s become dominant for the idle sale: a fully
    served winner's payment is set by the best rejected bid, not its own,
    so inflating a bid can only change *whether* it wins, never what it
    pays — pinned by the golden tests. Second-price payments are ≤
    first-price payments on identical bids (property-tested): the highest
    losing bid can never exceed the lowest winning one. The reclaim side
    (budgets, floor entitlements, victim pricing) is inherited unchanged.
    """

    name = "second_price"

    def _clearing_price(self, winner_prices, loser_prices):
        return max(loser_prices) if loser_prices else 0.0


POLICIES: Dict[str, Callable[[], PolicyEngine]] = {
    PaperPolicy.name: PaperPolicy,
    DemandCappedIdlePolicy.name: DemandCappedIdlePolicy,
    ProportionalSharePolicy.name: ProportionalSharePolicy,
    SLOHeadroomEngine.name: SLOHeadroomEngine,
    AuctionEngine.name: AuctionEngine,
    BudgetAuctionEngine.name: BudgetAuctionEngine,
    SecondPriceEngine.name: SecondPriceEngine,
}
# alias: the registry IS the engine registry
ENGINES = POLICIES


def get_policy(policy) -> PolicyEngine:
    """Resolve an engine name, class or instance to a PolicyEngine."""
    if isinstance(policy, PolicyEngine):
        return policy
    if isinstance(policy, type) and issubclass(policy, PolicyEngine):
        return policy()
    try:
        return POLICIES[policy]()
    except KeyError:
        raise ValueError(
            f"unknown cooperative policy {policy!r}; "
            f"have {sorted(POLICIES)}") from None


# alias kept so call sites can say what they mean
get_engine = get_policy


def __getattr__(name):
    # Historical home of the multi-tenant service (now built on the registry
    # state machine in core/provision.py); re-exported lazily so the two
    # modules can import in either order.
    if name == "MultiTenantProvisionService":
        from repro.core.provision import MultiTenantProvisionService
        return MultiTenantProvisionService
    raise AttributeError(name)
