"""Resource Provision Service — the organization's proxy (paper §II-B),
generalized from the paper's fixed ST/WS pair to an N-tenant registry.

``TenantProvisionService`` is a pure state machine over node *counts*
(nodes are fungible; ``runtime/device_pool.py`` maps counts to concrete
device slices). Departments register as :class:`~repro.core.policies.Tenant`
records; a pluggable two-phase :class:`~repro.core.policies.PolicyEngine`
decides how idle nodes are distributed (phase 2) and plans the ordered
reclaim chain when a latency-class tenant claims urgently (phase 1, from
per-tenant runtime signals):

  * latency tenants claim urgently; the free pool is drained first, then the
    engine's reclaim plan (paper default: batch tenants in reverse priority
    order, then lower-priority latency tenants; ``slo_headroom``/``auction``
    order by latency headroom / bids instead) is applied step by step —
    never taking a victim below its declared ``floor``;
  * released nodes flow back to batch tenants per the policy's idle rule;
  * node failures shrink capacity until repair, attributed to the pool that
    lost the node (with deterministic reattribution if the named pool is
    empty — a misattributed failure must never desync ``total`` from the
    pool sum).

``ResourceProvisionService`` keeps the paper's literal two-tenant API
(``st_alloc``/``ws_alloc``, ``on_grant_st``, ``force_st_release``, …) as a
thin facade over a 2-tenant registry running the ``"paper"`` policy, so the
2009 experiment stays reproducible bit-for-bit as the degenerate case.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional

from repro.core.policies import (CooperativePolicy, PaperPolicy,
                                 PolicyEngine, Tenant, get_policy)
from repro.core.telemetry import NULL_TRACER, Tracer
from repro.core.types import TenantSignals, TenantSpec


class TenantProvisionService:
    """Registry state machine with per-tenant allocations and a pluggable
    cooperative policy."""

    def __init__(self, total_nodes: int, *, policy="paper",
                 tracer: Optional[Tracer] = None):
        self.total = total_nodes
        self.free = total_nodes
        self.policy: PolicyEngine = get_policy(policy)
        # insertion-ordered: registration order is the deterministic
        # attribution order for node failures and timeline columns
        self.tenants: Dict[str, Tenant] = {}
        self.tracer = NULL_TRACER
        self.set_tracer(tracer or NULL_TRACER)

    def set_tracer(self, tracer: Tracer) -> None:
        """Point the service AND its engine (and the engine's market, for
        budget engines) at one event bus; the clock owner (simulator /
        orchestrator) keeps ``tracer.now`` current."""
        self.tracer = tracer
        self.policy.tracer = tracer
        market = getattr(self.policy, "market", None)
        if market is not None:
            market.tracer = tracer

    # ------------------------------------------------------------- wiring
    def register(self, tenant: Tenant) -> Tenant:
        assert tenant.name not in self.tenants, tenant.name
        assert tenant.name != "free", "'free' is the reserved pool name"
        self.tenants[tenant.name] = tenant
        return tenant

    def register_spec(self, spec: TenantSpec, *,
                      on_grant: Optional[Callable[[int], None]] = None,
                      on_force_release: Optional[Callable[[int], int]] = None,
                      signals: Optional[Callable[[], TenantSignals]] = None
                      ) -> Tenant:
        """Register a declarative ``TenantSpec`` (core/types.py)."""
        return self.register(Tenant(
            name=spec.name, kind=spec.kind, priority=spec.priority,
            weight=spec.weight, floor=getattr(spec, "floor", 0),
            bid_weight=getattr(spec, "bid_weight", None),
            budget=getattr(spec, "budget", None),
            bid_policy=getattr(spec, "bid_policy", "linear"),
            on_grant=on_grant, on_force_release=on_force_release,
            signals=signals))

    # ----------------------------------------------------------- invariants
    def check(self):
        used = sum(t.alloc for t in self.tenants.values())
        assert used + self.free == self.total, (used, self.free, self.total)
        assert self.free >= 0
        assert all(t.alloc >= 0 for t in self.tenants.values()), \
            {t.name: t.alloc for t in self.tenants.values()}
        if self.policy.demand_driven and self.policy.demand_satiating:
            # demand-capped invariant: nodes sit free only when every batch
            # tenant's declared demand is already covered (claims only drain
            # `free`, and every demand/release change reruns provision_idle,
            # so this holds at every quiescent point). Budget engines unset
            # demand_satiating: a broke tenant legitimately leaves demand
            # uncovered while nodes sit free (it cannot pay for them).
            assert self.free == 0 or all(
                t.alloc >= t.demand for t in self.tenants.values()
                if t.kind == "batch"), \
                (self.free, {t.name: (t.alloc, t.demand)
                             for t in self.tenants.values()
                             if t.kind == "batch"})

    def _batch_by_priority(self) -> List[Tenant]:
        return sorted((t for t in self.tenants.values()
                       if t.kind == "batch"), key=lambda t: t.priority)

    # ------------------------------------------------------------ requests
    def claim(self, name: str, n: int) -> int:
        """A latency tenant urgently claims n more nodes (paper rules 1/3).

        Drains the free pool first; the shortfall is forcibly reclaimed
        along the engine's phase-1 reclaim plan (``PolicyEngine.
        plan_reclaim``): an ordered list of per-victim caps the service
        applies step by step, never exceeding the live deficit, a victim's
        allocation, or the plan's floor-respecting cap. Batch victims
        release through their ``on_force_release`` hook (kill/preempt
        happens synchronously inside it); a batch tenant without the hook
        is skipped — the service never silently confiscates nodes it
        cannot make the CMS give up. Latency victims are reclaimed by
        count (their replicas are fungible); their hook, when present, is
        still notified. Returns the number of nodes actually granted.
        """
        t = self.tenants[name]
        assert t.kind == "latency", f"{name} is not a latency tenant"
        if n <= 0:
            return 0
        tr = self.tracer
        traced = tr.enabled
        claim_span = tr.new_span() if traced else 0
        granted = min(self.free, n)
        self.free -= granted
        t.alloc += granted
        short = n - granted
        deficit = short
        surplus = 0
        plan_span = 0
        if short > 0:
            plan = self.policy.plan_reclaim(
                short, list(self.tenants.values()), t)
            if traced:
                # claim-path emits are fully inlined (dict literal +
                # bounds-checked list append) — this is the hottest traced
                # region and the < 5 % bench gate rides on it
                plan_span = tr.new_span()
                evs = tr.events
                if len(evs) < tr.max_events:
                    evs.append({"type": "reclaim_plan", "ts": tr.now,
                                "span": plan_span, "parent": claim_span,
                                "tenant": name,
                                "engine": self.policy.name,
                                "deficit": short,
                                "steps": [{"victim": s.victim,
                                           "take": s.take,
                                           "reason": s.reason}
                                          for s in plan]})
                else:
                    tr.dropped_events += 1
            for step in plan:
                if short <= 0:
                    break
                v = self.tenants[step.victim]
                # the floor cap is re-derived at apply time: a reentrant
                # node_failed inside an earlier victim's hook may have
                # shrunk this victim's alloc since the plan was made
                take = min(short, step.take, self.policy.reclaimable(v))
                # engine apply-time cap (budget engines: what the claimant
                # can still afford at this victim's price, live — earlier
                # steps' debits are already reflected)
                take = min(take, self.policy.reclaim_cap(v, take, t))
                if take <= 0:
                    continue
                if v.on_force_release is not None:
                    # a victim may release MORE than asked (e.g. a trainer
                    # shrinks by whole DP groups): credit the full release
                    # so counts never desync from the devices it gave up
                    got = min(v.on_force_release(take), v.alloc)
                elif v.kind == "latency":
                    got = take
                else:
                    continue        # unwired batch tenant: not reclaimable
                v.alloc -= got
                give = min(got, short)
                t.alloc += give
                short -= give
                surplus += got - give
                # full release for drain stats, `give` for money engines
                self.policy.note_reclaimed(v.name, got, granted=give)
                if traced:
                    evs = tr.events
                    if len(evs) < tr.max_events:
                        evs.append({"type": "reclaim_step", "ts": tr.now,
                                    "parent": plan_span, "tenant": v.name,
                                    "claimant": name, "asked": take,
                                    "released": got, "granted": give})
                    else:
                        tr.dropped_events += 1
        if traced:
            # emitted after the plan/steps so the whole chain shares one
            # decision instant; `short` here is the FINAL unmet remainder
            evs = tr.events
            if len(evs) < tr.max_events:
                evs.append({"type": "claim", "ts": tr.now,
                            "span": claim_span, "tenant": name,
                            "requested": n, "from_free": granted,
                            "deficit": deficit, "granted": n - short,
                            "short": short})
            else:
                tr.dropped_events += 1
            tr.last_claim_span[name] = claim_span
        if surplus > 0:
            # over-released nodes go back through the idle policy (they are
            # typically re-granted to the very tenant that shed them)
            self.free += surplus
            if traced:
                tr.append({"type": "surplus_reflow", "parent": claim_span,
                           "nodes": surplus})
            self.provision_idle()
        self.check()
        return n - short

    def release(self, name: str, n: int, *, reprovision: bool = True):
        """A tenant returns idle nodes; they flow back per the idle policy.

        provision_idle runs before check(): the freed nodes must first
        flow to batch tenants with unmet demand or the demand-capped
        invariant would trip mid-transition."""
        t = self.tenants[name]
        n = min(n, t.alloc)
        t.alloc -= n
        self.free += n
        if self.tracer.enabled and n > 0:
            self.tracer.append({"type": "release", "tenant": name,
                                "nodes": n})
        if reprovision:
            self.provision_idle()
        self.check()

    def set_demand(self, name: str, demand: int, *, provision: bool = True):
        self.tenants[name].demand = max(0, demand)
        if provision:
            self.provision_idle()

    # alias kept for the original multi-tenant API
    set_batch_demand = set_demand

    def provision_idle(self):
        """Distribute free nodes to batch tenants per the cooperative
        policy (paper rule 2 is the ``"paper"`` policy's version)."""
        batch = self._batch_by_priority()
        if not batch or self.free <= 0:
            self.check()
            return
        for t, give in self.policy.idle_grants(self.free, batch):
            if give <= 0:
                continue
            give = min(give, self.free)
            self.free -= give
            t.alloc += give
            if self.tracer.enabled:
                self.tracer.append({"type": "idle_grant", "tenant": t.name,
                                    "nodes": give})
            if t.on_grant is not None:
                t.on_grant(give)
        self.check()

    # ------------------------------------------------- failures (runtime)
    def node_failed(self, owner: str):
        """A node died; capacity shrinks until repair.

        ``owner`` is a tenant name or ``"free"``. If the attributed pool is
        empty the failure is deterministically reattributed (free pool
        first, then tenants in registration order) so ``total`` can never
        desync from the pool sum; with no node anywhere a failure is
        impossible and raises."""
        pools = [("free", self.free)] + \
            [(t.name, t.alloc) for t in self.tenants.values()]
        by_name = dict(pools)
        if owner not in by_name:
            raise KeyError(f"unknown pool {owner!r}; have "
                           f"{[p for p, _ in pools]}")
        requested_owner = owner
        if by_name[owner] <= 0:
            owner = next((p for p, alloc in pools if alloc > 0), None)
            if owner is None:
                raise ValueError("node_failed on an empty cluster "
                                 f"(total={self.total})")
        if owner == "free":
            self.free -= 1
        else:
            self.tenants[owner].alloc -= 1
        self.total -= 1
        if self.tracer.enabled:
            self.tracer.emit("node_fail", owner=owner,
                             requested=requested_owner, total=self.total)
        if self.policy.demand_driven:
            # a failure can drop a batch tenant below its declared demand
            # while nodes sit free; rebalance to restore the invariant
            self.provision_idle()
        self.check()

    def node_repaired(self):
        self.total += 1
        self.free += 1
        if self.tracer.enabled:
            self.tracer.emit("node_repair", total=self.total)
        self.provision_idle()   # re-provision before the invariant check:
        self.check()            # the repaired node may cover unmet demand


class MultiTenantProvisionService(TenantProvisionService):
    """Original multi-tenant API (strict priorities, greedy/demand-capped
    idle) expressed over the policy framework. ``greedy_idle=True``
    reproduces the paper's two-tenant rule verbatim (ALL leftover idle
    nodes are dumped on the highest-priority batch tenant, demand or not);
    the default caps grants at declared demand and leaves the remainder
    free."""

    def __init__(self, total_nodes: int, *, greedy_idle: bool = False):
        super().__init__(
            total_nodes,
            policy="paper" if greedy_idle else "demand_capped")
        self.greedy_idle = greedy_idle


class ResourceProvisionService(TenantProvisionService):
    """The paper's two-tenant service (§II-B), verbatim policy:

      * WS demands have higher priority than ST demands.
      * All idle resources are provisioned to ST.
      * If WS claims urgent resources, the provision service FORCES ST to
        return the claimed amount and reallocates it to WS.

    Implemented as a fixed 2-tenant registry under the ``"paper"`` policy;
    the legacy attribute/callback API is preserved so the simulator, the
    runtime orchestrator and the seed experiments are bit-for-bit
    unchanged.
    """

    def __init__(self, total_nodes: int, *,
                 tracer: Optional[Tracer] = None):
        super().__init__(total_nodes, policy=PaperPolicy(), tracer=tracer)
        # registration order (st, ws) is a compatibility contract: node
        # failures and timeline columns attribute in this order
        self._st = self.register(Tenant("st", "batch", priority=1))
        self._ws = self.register(Tenant("ws", "latency", priority=0))
        self.on_grant_ws: Optional[Callable[[int], None]] = None

    # ------------------------------------------------- legacy attributes
    @property
    def st_alloc(self) -> int:
        return self._st.alloc

    @property
    def ws_alloc(self) -> int:
        return self._ws.alloc

    @property
    def on_grant_st(self) -> Optional[Callable[[int], None]]:
        return self._st.on_grant

    @on_grant_st.setter
    def on_grant_st(self, fn: Optional[Callable[[int], None]]):
        self._st.on_grant = fn

    @property
    def force_st_release(self) -> Optional[Callable[[int], int]]:
        return self._st.on_force_release

    @force_st_release.setter
    def force_st_release(self, fn: Optional[Callable[[int], int]]):
        self._st.on_force_release = fn

    # --------------------------------------------------- legacy verbs
    def ws_request(self, n: int) -> int:
        """WS claims n more nodes (urgent, highest priority)."""
        return self.claim("ws", n)

    def ws_release(self, n: int):
        """WS releases idle nodes immediately (paper's WS policy)."""
        self.release("ws", n)

    def provision_idle_to_st(self):
        """All idle resources go to ST (paper's provision policy, rule 2)."""
        self.provision_idle()

    def st_release(self, n: int):
        """ST voluntarily returns nodes (idle beyond need); they stay free
        until the next provisioning decision."""
        self.release("st", n, reprovision=False)
