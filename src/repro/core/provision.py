"""Resource Provision Service — the organization's proxy (paper §II-B),
generalized from the paper's fixed ST/WS pair to an N-tenant registry.

``TenantProvisionService`` is a pure state machine over node *counts*
(nodes are fungible; ``runtime/device_pool.py`` maps counts to concrete
device slices). Departments register as :class:`~repro.core.policies.Tenant`
records; a pluggable two-phase :class:`~repro.core.policies.PolicyEngine`
decides how idle nodes are distributed (phase 2) and plans the ordered
reclaim chain when a latency-class tenant claims urgently (phase 1, from
per-tenant runtime signals):

  * latency tenants claim urgently; the free pool is drained first, then the
    engine's reclaim plan (paper default: batch tenants in reverse priority
    order, then lower-priority latency tenants; ``slo_headroom``/``auction``
    order by latency headroom / bids instead) is applied step by step —
    never taking a victim below its declared ``floor``;
  * released nodes flow back to batch tenants per the policy's idle rule;
  * node failures shrink capacity until repair, attributed to the pool that
    lost the node (with deterministic reattribution if the named pool is
    empty — a misattributed failure must never desync ``total`` from the
    pool sum).

``ResourceProvisionService`` keeps the paper's literal two-tenant API
(``st_alloc``/``ws_alloc``, ``on_grant_st``, ``force_st_release``, …) as a
thin facade over a 2-tenant registry running the ``"paper"`` policy, so the
2009 experiment stays reproducible bit-for-bit as the degenerate case.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional

from repro.core.nodes import DRAIN_POOL, NodeInventory, NodeState
from repro.core.policies import (CooperativePolicy, PaperPolicy,
                                 PolicyEngine, Tenant, get_policy)
from repro.core.telemetry import NULL_TRACER, Tracer
from repro.core.types import TenantSignals, TenantSpec


class TenantProvisionService:
    """Registry state machine with per-tenant allocations and a pluggable
    cooperative policy."""

    def __init__(self, total_nodes: int, *, policy="paper",
                 tracer: Optional[Tracer] = None):
        self.total = total_nodes
        self.free = total_nodes
        self.policy: PolicyEngine = get_policy(policy)
        # insertion-ordered: registration order is the deterministic
        # attribution order for node failures and timeline columns
        self.tenants: Dict[str, Tenant] = {}
        self.tracer = NULL_TRACER
        self.set_tracer(tracer or NULL_TRACER)
        # node-lifecycle layer (optional): an attached NodeInventory
        # mirrors every count move with identified nodes; None keeps the
        # pure count machine (zero overhead, the paper's model)
        self.inventory: Optional[NodeInventory] = None
        # forced-reclaim drain windows: nodes mid-drain serve neither the
        # victim nor the claimant; configure_drain wires the clock owner
        self.draining = 0
        self.drain_time_s = 0.0
        self._drain_schedule: Optional[
            Callable[[float, Callable[[], None]], None]] = None
        # FIFO of open node_fail spans for the count-only path (constant
        # repair delay => FIFO pairing is exact); with an inventory the
        # span rides on the Node record instead
        self._fail_span_fifo: List[int] = []

    def set_tracer(self, tracer: Tracer) -> None:
        """Point the service AND its engine (and the engine's market, for
        budget engines) at one event bus; the clock owner (simulator /
        orchestrator) keeps ``tracer.now`` current."""
        self.tracer = tracer
        self.policy.tracer = tracer
        market = getattr(self.policy, "market", None)
        if market is not None:
            market.tracer = tracer

    # ------------------------------------------------------------- wiring
    def register(self, tenant: Tenant) -> Tenant:
        assert tenant.name not in self.tenants, tenant.name
        assert tenant.name not in ("free", DRAIN_POOL), \
            f"{tenant.name!r} is a reserved pool name"
        self.tenants[tenant.name] = tenant
        return tenant

    def attach_inventory(self, inventory: NodeInventory) -> None:
        """Mirror every count move into an identified-node inventory.
        Must happen before any provisioning (all nodes free) so pools and
        counts start — and stay — in lockstep."""
        assert inventory.total == self.total, \
            (inventory.total, self.total)
        assert self.free == self.total, \
            "attach_inventory before any provisioning"
        self.inventory = inventory

    def configure_drain(self, drain_time_s: float,
                        schedule: Callable[[float, Callable[[], None]],
                                           None]) -> None:
        """Enable reclaim drain windows: each forced reclaim step's nodes
        sit in the drain pool for ``drain_time_s`` (serving neither
        tenant) before the claimant receives them. ``schedule(delay, fn)``
        is the clock owner's callback (the simulator pushes a DRAIN_DONE
        event). 0 disables (instant handover, the paper's assumption)."""
        self.drain_time_s = float(drain_time_s)
        self._drain_schedule = schedule if drain_time_s > 0 else None

    def register_spec(self, spec: TenantSpec, *,
                      on_grant: Optional[Callable[[int], None]] = None,
                      on_force_release: Optional[Callable[[int], int]] = None,
                      signals: Optional[Callable[[], TenantSignals]] = None
                      ) -> Tenant:
        """Register a declarative ``TenantSpec`` (core/types.py)."""
        return self.register(Tenant(
            name=spec.name, kind=spec.kind, priority=spec.priority,
            weight=spec.weight, floor=getattr(spec, "floor", 0),
            bid_weight=getattr(spec, "bid_weight", None),
            budget=getattr(spec, "budget", None),
            bid_policy=getattr(spec, "bid_policy", "linear"),
            on_grant=on_grant, on_force_release=on_force_release,
            signals=signals))

    # ----------------------------------------------------------- invariants
    def check(self):
        used = sum(t.alloc for t in self.tenants.values())
        assert used + self.free + self.draining == self.total, \
            (used, self.free, self.draining, self.total)
        assert self.free >= 0 and self.draining >= 0
        assert all(t.alloc >= 0 for t in self.tenants.values()), \
            {t.name: t.alloc for t in self.tenants.values()}
        if self.policy.demand_driven and self.policy.demand_satiating:
            # demand-capped invariant: nodes sit free only when every batch
            # tenant's declared demand is already covered (claims only drain
            # `free`, and every demand/release change reruns provision_idle,
            # so this holds at every quiescent point). Budget engines unset
            # demand_satiating: a broke tenant legitimately leaves demand
            # uncovered while nodes sit free (it cannot pay for them).
            assert self.free == 0 or all(
                t.alloc >= t.demand for t in self.tenants.values()
                if t.kind == "batch"), \
                (self.free, {t.name: (t.alloc, t.demand)
                             for t in self.tenants.values()
                             if t.kind == "batch"})

    def _batch_by_priority(self) -> List[Tenant]:
        return sorted((t for t in self.tenants.values()
                       if t.kind == "batch"), key=lambda t: t.priority)

    # ------------------------------------------------------------ requests
    def claim(self, name: str, n: int) -> int:
        """A latency tenant urgently claims n more nodes (paper rules 1/3).

        Drains the free pool first; the shortfall is forcibly reclaimed
        along the engine's phase-1 reclaim plan (``PolicyEngine.
        plan_reclaim``): an ordered list of per-victim caps the service
        applies step by step, never exceeding the live deficit, a victim's
        allocation, or the plan's floor-respecting cap. Batch victims
        release through their ``on_force_release`` hook (kill/preempt
        happens synchronously inside it); a batch tenant without the hook
        is skipped — the service never silently confiscates nodes it
        cannot make the CMS give up. Latency victims are reclaimed by
        count (their replicas are fungible); their hook, when present, is
        still notified. Returns the number of nodes actually granted.
        """
        t = self.tenants[name]
        assert t.kind == "latency", f"{name} is not a latency tenant"
        if n <= 0:
            return 0
        tr = self.tracer
        traced = tr.enabled
        inv = self.inventory
        # drain windows apply to forced reclaims only: free-pool nodes are
        # already idle and hand over instantly
        drain_s = self.drain_time_s if self._drain_schedule is not None \
            else 0.0
        claim_span = tr.new_span() if traced else 0
        granted = min(self.free, n)
        self.free -= granted
        t.alloc += granted
        if inv is not None and granted > 0:
            inv.transfer("free", name, granted)
        short = n - granted
        deficit = short
        surplus = 0
        pending = 0
        plan_span = 0
        if short > 0:
            plan = self.policy.plan_reclaim(
                short, list(self.tenants.values()), t)
            if traced:
                # claim-path emits are fully inlined (dict literal +
                # bounds-checked list append) — this is the hottest traced
                # region and the < 5 % bench gate rides on it
                plan_span = tr.new_span()
                evs = tr.events
                if len(evs) < tr.max_events:
                    evs.append({"type": "reclaim_plan", "ts": tr.now,
                                "span": plan_span, "parent": claim_span,
                                "tenant": name,
                                "engine": self.policy.name,
                                "deficit": short,
                                "steps": [{"victim": s.victim,
                                           "take": s.take,
                                           "reason": s.reason}
                                          for s in plan]})
                else:
                    tr.dropped_events += 1
            for step in plan:
                if short <= 0:
                    break
                v = self.tenants[step.victim]
                # the floor cap is re-derived at apply time: a reentrant
                # node_failed inside an earlier victim's hook may have
                # shrunk this victim's alloc since the plan was made
                take = min(short, step.take, self.policy.reclaimable(v))
                # engine apply-time cap (budget engines: what the claimant
                # can still afford at this victim's price, live — earlier
                # steps' debits are already reflected)
                take = min(take, self.policy.reclaim_cap(v, take, t))
                if take <= 0:
                    continue
                if v.on_force_release is not None:
                    # a victim may release MORE than asked (e.g. a trainer
                    # shrinks by whole DP groups): credit the full release
                    # so counts never desync from the devices it gave up
                    got = min(v.on_force_release(take), v.alloc)
                elif v.kind == "latency":
                    got = take
                else:
                    continue        # unwired batch tenant: not reclaimable
                v.alloc -= got
                give = min(got, short)
                short -= give
                surplus += got - give
                # full release for drain stats, `give` for money engines
                self.policy.note_reclaimed(v.name, got, granted=give)
                step_span = 0
                if drain_s > 0.0 and give > 0:
                    # reclaimed nodes pay the drain window before the
                    # claimant sees them: they serve neither tenant until
                    # _drain_done fires (the deficit is committed — short
                    # already dropped — but delivery is delayed)
                    self.draining += give
                    pending += give
                    step_span = tr.new_span() if traced else 0
                    ids = None
                    if inv is not None:
                        ids = inv.transfer(v.name, DRAIN_POOL, give,
                                           state=NodeState.DRAINING,
                                           parent=step_span or None)
                    self._drain_schedule(
                        drain_s,
                        lambda c=name, g=give, i=ids, s=step_span:
                            self._drain_done(c, g, i, s))
                else:
                    t.alloc += give
                    if inv is not None and give > 0:
                        inv.transfer(v.name, name, give)
                if inv is not None and got - give > 0:
                    inv.transfer(v.name, "free", got - give)
                if traced:
                    evs = tr.events
                    if len(evs) < tr.max_events:
                        ev = {"type": "reclaim_step", "ts": tr.now,
                              "parent": plan_span, "tenant": v.name,
                              "claimant": name, "asked": take,
                              "released": got, "granted": give}
                        if step_span:
                            # drain-delayed step: its span is the parent
                            # the eventual drain_complete links back to
                            ev["span"] = step_span
                            ev["drain_s"] = drain_s
                        evs.append(ev)
                    else:
                        tr.dropped_events += 1
        if traced:
            # emitted after the plan/steps so the whole chain shares one
            # decision instant; `short` here is the FINAL unmet remainder
            evs = tr.events
            if len(evs) < tr.max_events:
                ev = {"type": "claim", "ts": tr.now,
                      "span": claim_span, "tenant": name,
                      "requested": n, "from_free": granted,
                      "deficit": deficit, "granted": n - short,
                      "short": short}
                if pending:
                    # committed but still draining — delivered later by
                    # drain_complete events (granted includes pending)
                    ev["pending"] = pending
                evs.append(ev)
            else:
                tr.dropped_events += 1
            tr.last_claim_span[name] = claim_span
        if surplus > 0:
            # over-released nodes go back through the idle policy (they are
            # typically re-granted to the very tenant that shed them)
            self.free += surplus
            if traced:
                tr.append({"type": "surplus_reflow", "parent": claim_span,
                           "nodes": surplus})
            self.provision_idle()
        self.check()
        return n - short - pending

    def _drain_done(self, claimant: str, n: int,
                    ids: Optional[List[int]], step_span: int) -> None:
        """A reclaim step's drain window elapsed: deliver the surviving
        nodes to the claimant. With an inventory attached, nodes that
        failed mid-drain (drain_node_failed) are skipped — only ids still
        in the drain pool are credited."""
        inv = self.inventory
        if inv is not None:
            ids = [i for i in ids if inv.nodes[i].owner == DRAIN_POOL]
            n = len(ids)
            if n:
                inv.move_nodes(ids, claimant, state=NodeState.HEALTHY,
                               parent=step_span or None)
        self.draining -= n
        t = self.tenants[claimant]
        t.alloc += n
        if self.tracer.enabled:
            self.tracer.append({"type": "drain_complete",
                                "tenant": claimant, "nodes": n,
                                "parent": step_span or None})
        if n > 0 and t.on_grant is not None:
            t.on_grant(n)
        self.check()

    def release(self, name: str, n: int, *, reprovision: bool = True):
        """A tenant returns idle nodes; they flow back per the idle policy.

        provision_idle runs before check(): the freed nodes must first
        flow to batch tenants with unmet demand or the demand-capped
        invariant would trip mid-transition."""
        t = self.tenants[name]
        n = min(n, t.alloc)
        t.alloc -= n
        self.free += n
        if self.inventory is not None and n > 0:
            self.inventory.transfer(name, "free", n)
        if self.tracer.enabled and n > 0:
            self.tracer.append({"type": "release", "tenant": name,
                                "nodes": n})
        if reprovision:
            self.provision_idle()
        self.check()

    def set_demand(self, name: str, demand: int, *, provision: bool = True):
        self.tenants[name].demand = max(0, demand)
        if provision:
            self.provision_idle()

    # alias kept for the original multi-tenant API
    set_batch_demand = set_demand

    def provision_idle(self):
        """Distribute free nodes to batch tenants per the cooperative
        policy (paper rule 2 is the ``"paper"`` policy's version)."""
        batch = self._batch_by_priority()
        if not batch or self.free <= 0:
            self.check()
            return
        for t, give in self.policy.idle_grants(self.free, batch):
            if give <= 0:
                continue
            give = min(give, self.free)
            self.free -= give
            t.alloc += give
            if self.inventory is not None:
                self.inventory.transfer("free", t.name, give)
            if self.tracer.enabled:
                self.tracer.append({"type": "idle_grant", "tenant": t.name,
                                    "nodes": give})
            if t.on_grant is not None:
                t.on_grant(give)
        self.check()

    # ------------------------------------------------- failures (runtime)
    def node_failed(self, owner: str, *, node: Optional[int] = None,
                    cause: Optional[str] = None) -> Optional[int]:
        """A node died; capacity shrinks until repair.

        ``owner`` is a tenant name or ``"free"``. If the attributed pool is
        empty the failure is deterministically reattributed (free pool
        first, then tenants in registration order) so ``total`` can never
        desync from the pool sum; with no node anywhere a failure is
        impossible and raises. ``node`` names the failed node when an
        inventory is attached (lowest-id of the pool otherwise). Returns
        the failed node id (None without an inventory). The failure's
        telemetry span parents the eventual ``node_repair`` — one causal
        chain per outage."""
        pools = [("free", self.free)] + \
            [(t.name, t.alloc) for t in self.tenants.values()]
        by_name = dict(pools)
        if owner not in by_name:
            raise KeyError(f"unknown pool {owner!r}; have "
                           f"{[p for p, _ in pools]}")
        requested_owner = owner
        if by_name[owner] <= 0:
            owner = next((p for p, alloc in pools if alloc > 0), None)
            if owner is None:
                raise ValueError("node_failed on an empty cluster "
                                 f"(total={self.total})")
        if owner == "free":
            self.free -= 1
        else:
            self.tenants[owner].alloc -= 1
        self.total -= 1
        tr = self.tracer
        span = tr.new_span() if tr.enabled else 0
        if self.inventory is not None:
            if node is None:
                node = self.inventory.pick(owner)
            self.inventory.fail(node, span=span, cause=cause)
        elif tr.enabled:
            # count-only path: repair delay is constant, so FIFO pairing
            # of open failure spans with repairs is exact
            self._fail_span_fifo.append(span)
        if tr.enabled:
            ev = {"type": "node_fail", "owner": owner, "span": span,
                  "requested": requested_owner, "total": self.total}
            if node is not None:
                ev["node"] = node
            if cause is not None:
                ev["cause"] = cause
            tr.append(ev)
        if self.policy.demand_driven:
            # a failure can drop a batch tenant below its declared demand
            # while nodes sit free; rebalance to restore the invariant
            self.provision_idle()
        self.check()
        return node

    def drain_node_failed(self, node: int, *,
                          cause: Optional[str] = None) -> int:
        """A node died mid-drain: it was serving neither tenant, so only
        the drain pool and ``total`` shrink; the scheduled ``_drain_done``
        will skip it and credit the claimant only the survivors."""
        assert self.inventory is not None, \
            "drain_node_failed requires an attached inventory"
        assert self.draining > 0, self.draining
        self.draining -= 1
        self.total -= 1
        tr = self.tracer
        span = tr.new_span() if tr.enabled else 0
        self.inventory.fail(node, span=span, cause=cause)
        if tr.enabled:
            ev = {"type": "node_fail", "owner": DRAIN_POOL, "span": span,
                  "requested": DRAIN_POOL, "total": self.total,
                  "node": node}
            if cause is not None:
                ev["cause"] = cause
            tr.append(ev)
        if self.policy.demand_driven:
            self.provision_idle()
        self.check()
        return node

    def node_repaired(self, *, node: Optional[int] = None
                      ) -> Optional[int]:
        """Capacity returns after repair. ``node`` names the repaired node
        (lowest-id down node otherwise, with an inventory); the telemetry
        event parents the node's original ``node_fail`` span. Returns the
        repaired node id (None without an inventory)."""
        self.total += 1
        self.free += 1
        parent = None
        if self.inventory is not None:
            nd = self.inventory.repair(node)
            node = nd.id
            parent = nd.fail_span or None
        elif self._fail_span_fifo:
            parent = self._fail_span_fifo.pop(0)
        if self.tracer.enabled:
            ev = {"type": "node_repair", "parent": parent,
                  "total": self.total}
            if node is not None:
                ev["node"] = node
            self.tracer.append(ev)
        self.provision_idle()   # re-provision before the invariant check:
        self.check()            # the repaired node may cover unmet demand
        return node


class MultiTenantProvisionService(TenantProvisionService):
    """Original multi-tenant API (strict priorities, greedy/demand-capped
    idle) expressed over the policy framework. ``greedy_idle=True``
    reproduces the paper's two-tenant rule verbatim (ALL leftover idle
    nodes are dumped on the highest-priority batch tenant, demand or not);
    the default caps grants at declared demand and leaves the remainder
    free."""

    def __init__(self, total_nodes: int, *, greedy_idle: bool = False):
        super().__init__(
            total_nodes,
            policy="paper" if greedy_idle else "demand_capped")
        self.greedy_idle = greedy_idle


class ResourceProvisionService(TenantProvisionService):
    """The paper's two-tenant service (§II-B), verbatim policy:

      * WS demands have higher priority than ST demands.
      * All idle resources are provisioned to ST.
      * If WS claims urgent resources, the provision service FORCES ST to
        return the claimed amount and reallocates it to WS.

    Implemented as a fixed 2-tenant registry under the ``"paper"`` policy;
    the legacy attribute/callback API is preserved so the simulator, the
    runtime orchestrator and the seed experiments are bit-for-bit
    unchanged.
    """

    def __init__(self, total_nodes: int, *,
                 tracer: Optional[Tracer] = None):
        super().__init__(total_nodes, policy=PaperPolicy(), tracer=tracer)
        # registration order (st, ws) is a compatibility contract: node
        # failures and timeline columns attribute in this order
        self._st = self.register(Tenant("st", "batch", priority=1))
        self._ws = self.register(Tenant("ws", "latency", priority=0))
        self.on_grant_ws: Optional[Callable[[int], None]] = None

    # ------------------------------------------------- legacy attributes
    @property
    def st_alloc(self) -> int:
        return self._st.alloc

    @property
    def ws_alloc(self) -> int:
        return self._ws.alloc

    @property
    def on_grant_st(self) -> Optional[Callable[[int], None]]:
        return self._st.on_grant

    @on_grant_st.setter
    def on_grant_st(self, fn: Optional[Callable[[int], None]]):
        self._st.on_grant = fn

    @property
    def force_st_release(self) -> Optional[Callable[[int], int]]:
        return self._st.on_force_release

    @force_st_release.setter
    def force_st_release(self, fn: Optional[Callable[[int], int]]):
        self._st.on_force_release = fn

    # --------------------------------------------------- legacy verbs
    def ws_request(self, n: int) -> int:
        """WS claims n more nodes (urgent, highest priority)."""
        return self.claim("ws", n)

    def ws_release(self, n: int):
        """WS releases idle nodes immediately (paper's WS policy)."""
        self.release("ws", n)

    def provision_idle_to_st(self):
        """All idle resources go to ST (paper's provision policy, rule 2)."""
        self.provision_idle()

    def st_release(self, n: int):
        """ST voluntarily returns nodes (idle beyond need); they stay free
        until the next provisioning decision."""
        self.release("st", n, reprovision=False)
