"""Resource Provision Service — the organization's proxy (paper §II-B).

Policy (verbatim from the paper):
  * WS demands have higher priority than ST demands.
  * All idle resources are provisioned to ST.
  * If WS claims urgent resources, the provision service FORCES ST to return
    the claimed amount and reallocates it to WS.

The service is a pure state machine over node *counts* (nodes are fungible);
``runtime/device_pool.py`` maps counts to concrete device slices.
"""
from __future__ import annotations

from typing import Callable, Optional


class ResourceProvisionService:
    def __init__(self, total_nodes: int):
        self.total = total_nodes
        self.free = total_nodes
        self.st_alloc = 0
        self.ws_alloc = 0
        # wired by the simulator / runtime
        self.on_grant_st: Optional[Callable[[int], None]] = None
        self.on_grant_ws: Optional[Callable[[int], None]] = None
        self.force_st_release: Optional[Callable[[int], int]] = None

    # ----------------------------------------------------------- invariants
    def check(self):
        assert self.free >= 0 and self.st_alloc >= 0 and self.ws_alloc >= 0, \
            (self.free, self.st_alloc, self.ws_alloc)
        assert self.free + self.st_alloc + self.ws_alloc == self.total, \
            (self.free, self.st_alloc, self.ws_alloc, self.total)

    # ------------------------------------------------------------- WS side
    def ws_request(self, n: int) -> int:
        """WS claims n more nodes (urgent, highest priority).

        Returns the number of nodes granted immediately from the free pool;
        any shortfall is forcibly reclaimed from ST (the ST CMS kills /
        preempts jobs synchronously via ``force_st_release``).
        """
        if n <= 0:
            return 0
        granted = min(self.free, n)
        self.free -= granted
        self.ws_alloc += granted
        short = n - granted
        if short > 0 and self.force_st_release is not None:
            got = self.force_st_release(short)
            got = min(got, short)
            self.st_alloc -= got
            self.ws_alloc += got
            granted += got
        self.check()
        return granted

    def ws_release(self, n: int):
        """WS releases idle nodes immediately (paper's WS management policy)."""
        n = min(n, self.ws_alloc)
        self.ws_alloc -= n
        self.free += n
        self.check()
        self.provision_idle_to_st()

    # ------------------------------------------------------------- ST side
    def provision_idle_to_st(self):
        """All idle resources go to ST (paper's provision policy, rule 2)."""
        if self.free > 0:
            n = self.free
            self.free = 0
            self.st_alloc += n
            self.check()
            if self.on_grant_st is not None:
                self.on_grant_st(n)

    def st_release(self, n: int):
        """ST voluntarily returns nodes (idle beyond need)."""
        n = min(n, self.st_alloc)
        self.st_alloc -= n
        self.free += n
        self.check()

    # ------------------------------------------------- failures (runtime)
    def node_failed(self, owner: str):
        """A node died; capacity shrinks until repair."""
        if owner == "free" and self.free > 0:
            self.free -= 1
        elif owner == "st" and self.st_alloc > 0:
            self.st_alloc -= 1
        elif owner == "ws" and self.ws_alloc > 0:
            self.ws_alloc -= 1
        self.total -= 1
        self.check()

    def node_repaired(self):
        self.total += 1
        self.free += 1
        self.check()
        self.provision_idle_to_st()
