"""Fault-injection subsystem: pluggable injectors over the node inventory.

``SimConfig.node_mtbf`` (the legacy knob) injects anonymous exponential
single-node failures from the simulator's shared RNG stream. This module
generalizes that into declarative :class:`FaultSpec` profiles with three
injector families, all operating on identified nodes
(:class:`~repro.core.nodes.NodeInventory`):

  * ``independent`` — cluster-wide exponential single-node failures. With
    ``seed=None`` it *is* the legacy path: same shared RNG stream, same
    draw order, same pool-proportional victim attribution — bit-for-bit
    identical to ``SimConfig(node_mtbf=...)`` (pinned by
    tests/test_faults.py). With an explicit ``seed`` it switches to the
    isolated stream + node-uniform selection described below.
  * ``rack_corr`` — correlated rack blasts: an epicenter node is drawn
    uniformly over up nodes, then up to ``blast_radius`` nodes of its
    failure domain go down together, all repairing after
    ``repair_time_s``.
  * ``flapping`` — a designated fraction of nodes cycle up/down on their
    own exponential clocks (short ``flap_repair_s`` outages), returning
    to the FLAPPING state after each repair.

**Policy-axis independence** (the campaign contract): every profile other
than the degenerate legacy-compatible one draws from its own
``random.Random(f"phoenix-faults:{profile}:{seed}")`` stream and selects
victims uniformly over the inventory's *up* set — which depends only on
prior fault/repair events, never on which tenant owns a node. Changing
``--policy`` or ``--budget`` therefore cannot perturb the injected fault
sequence within a cell (pinned cross-axis determinism test).

:data:`FAULT_PROFILES` holds the named presets used by the campaign's
``fault_profile`` axis (severity calibrated for the ``mix_tiny`` cells:
96 nodes over a 7200 s horizon).
"""
from __future__ import annotations

import random
from dataclasses import dataclass
from typing import Dict, Optional

from repro.core.nodes import NodeState


@dataclass(frozen=True)
class FaultSpec:
    """Declarative fault-injection profile (a ``SimConfig.faults`` value
    and the payload behind a campaign cell's ``fault_profile`` axis)."""
    profile: str = "independent"   # independent | rack_corr | flapping
    # independent / rack_corr: cluster-wide MTBF in seconds — the event
    # rate is total_nodes / mtbf_s (legacy node_mtbf semantics); 0
    # disables the exponential clock (flapping ignores it).
    mtbf_s: float = 0.0
    repair_time_s: float = 3600.0
    # failure domains: node i belongs to rack i // rack_size
    rack_size: int = 16
    # rack_corr: nodes taken down per blast (epicenter + rack neighbours)
    blast_radius: int = 8
    # flapping: fraction of nodes designated flappers, mean up-time
    # between flaps, and the (short) per-flap outage
    flap_fraction: float = 0.04
    flap_period_s: float = 1200.0
    flap_repair_s: float = 120.0
    # drain window charged on every forced reclaim step while this profile
    # is active (0 = instant reclaim, the legacy behaviour); see
    # TenantProvisionService.configure_drain
    drain_time_s: float = 0.0
    # fault-stream seed. None on the "independent" profile means "share
    # the simulator's RNG stream" (the bit-for-bit legacy degenerate
    # case); None elsewhere derives the isolated stream from the sim seed.
    seed: Optional[int] = None


#: named presets for the campaign's ``fault_profile`` axis. "none" keeps
#: the cell fault-free (the pre-existing behaviour — every committed
#: artifact reproduces bit-for-bit). Severity is calibrated for mix_tiny
#: (96 nodes x 7200 s): independent ~4.6 single failures, rack_corr ~1.7
#: blasts x 8 nodes with a 30 s drain tax on reclaims, flapping ~5
#: flappers x ~5 short outages each.
FAULT_PROFILES: Dict[str, Optional[FaultSpec]] = {
    "none": None,
    "independent": FaultSpec(profile="independent", mtbf_s=150_000.0,
                             repair_time_s=1800.0),
    "rack_corr": FaultSpec(profile="rack_corr", mtbf_s=400_000.0,
                           repair_time_s=3600.0, rack_size=16,
                           blast_radius=8, drain_time_s=30.0),
    "flapping": FaultSpec(profile="flapping", flap_fraction=0.05,
                          flap_period_s=1500.0, flap_repair_s=120.0),
}


def get_fault_spec(name: str) -> Optional[FaultSpec]:
    if name not in FAULT_PROFILES:
        raise ValueError(f"unknown fault profile {name!r}; "
                         f"have {sorted(FAULT_PROFILES)}")
    return FAULT_PROFILES[name]


def fault_rng(spec: FaultSpec, sim_seed: int) -> random.Random:
    """The isolated, policy-axis-independent fault stream: seeded from the
    profile name + the cell/sim seed (or the spec's explicit seed), never
    from anything a policy or budget knob can reach."""
    seed = spec.seed if spec.seed is not None else sim_seed
    return random.Random(f"phoenix-faults:{spec.profile}:{seed}")


class FaultInjector:
    """Injector protocol: ``start(sim)`` schedules the first fault
    event(s); the simulator routes every NODE_FAIL event's payload back
    through ``fire(sim, payload)``. Injectors own all fault RNG and talk
    to the sim through its fault API (``schedule_fault``,
    ``schedule_repair``, ``apply_node_failure``, ``emit_suppressed``,
    ``fail_pool_proportional``)."""

    profile = "base"

    def __init__(self, spec: FaultSpec, rng: random.Random):
        self.spec = spec
        self.rng = rng

    def start(self, sim) -> None:
        raise NotImplementedError

    def fire(self, sim, payload) -> None:
        raise NotImplementedError


class IndependentInjector(FaultInjector):
    """Exponential single-node failures.

    ``legacy_pick=True`` (spec.seed is None): victims are attributed by
    pool share with the exact legacy draw order — bit-for-bit compatible
    with the ``node_mtbf`` path (the injector's ``rng`` IS the sim's
    shared stream then). Otherwise victims are uniform over up nodes from
    the isolated fault stream."""

    profile = "independent"

    def __init__(self, spec: FaultSpec, rng: random.Random,
                 legacy_pick: bool):
        super().__init__(spec, rng)
        self.legacy_pick = legacy_pick

    def _next(self, sim) -> float:
        return self.rng.expovariate(sim.cfg.total_nodes / self.spec.mtbf_s)

    def start(self, sim) -> None:
        if self.spec.mtbf_s > 0:
            sim.schedule_fault(self._next(sim))

    def fire(self, sim, payload) -> None:
        if self.legacy_pick:
            sim.fail_pool_proportional(self.rng, self.spec.repair_time_s,
                                       cause="independent")
        else:
            up = sim.inventory.up_ids()
            if len(up) <= 1:
                sim.emit_suppressed("cluster_at_minimum", up=len(up))
            else:
                node = up[int(self.rng.random() * len(up))]
                sim.apply_node_failure(node, cause="independent")
                sim.schedule_repair(self.spec.repair_time_s, node)
        sim.schedule_fault(self._next(sim))


class RackBlastInjector(FaultInjector):
    """Correlated failures: each event picks an epicenter uniformly over
    up nodes and takes down up to ``blast_radius`` up nodes of its rack
    (epicenter first, then ascending id), all repairing together. One
    up node always survives cluster-wide."""

    profile = "rack_corr"

    def _next(self, sim) -> float:
        return self.rng.expovariate(sim.cfg.total_nodes / self.spec.mtbf_s)

    def start(self, sim) -> None:
        if self.spec.mtbf_s > 0:
            sim.schedule_fault(self._next(sim))

    def fire(self, sim, payload) -> None:
        inv = sim.inventory
        up = inv.up_ids()
        if len(up) <= 1:
            sim.emit_suppressed("cluster_at_minimum", up=len(up))
        else:
            epicenter = up[int(self.rng.random() * len(up))]
            domain = inv.nodes[epicenter].domain
            targets = [epicenter] + [i for i in inv.domain_up_ids(domain)
                                     if i != epicenter]
            targets = targets[:min(self.spec.blast_radius, len(up) - 1)]
            for node in targets:
                sim.apply_node_failure(node, cause="rack_blast",
                                       domain=domain)
                sim.schedule_repair(self.spec.repair_time_s, node)
        sim.schedule_fault(self._next(sim))


class FlappingInjector(FaultInjector):
    """Designated flappers cycle up/down on independent exponential
    clocks: mean ``flap_period_s`` up-time, ``flap_repair_s`` outage.
    Repair returns a flapper to FLAPPING (not HEALTHY) — it stays
    unreliable for the whole run."""

    profile = "flapping"

    def start(self, sim) -> None:
        total = sim.cfg.total_nodes
        k = max(1, round(self.spec.flap_fraction * total))
        k = min(k, total)
        flappers = sorted(self.rng.sample(range(total), k))
        sim.inventory.designate_flappers(flappers)
        for node in flappers:
            sim.schedule_fault(
                self.rng.expovariate(1.0 / self.spec.flap_period_s), node)

    def fire(self, sim, payload) -> None:
        node = payload
        state = sim.inventory.state_of(node)
        up = sim.inventory.up_ids()
        if state in (NodeState.FAILED, NodeState.REPAIRING) or len(up) <= 1:
            # already down (e.g. the whole cluster shrank to one node) —
            # the flap is suppressed, the clock keeps ticking
            sim.emit_suppressed("flapper_unavailable", node=node,
                                state=state.value)
            delay = self.rng.expovariate(1.0 / self.spec.flap_period_s)
        else:
            sim.apply_node_failure(node, cause="flap")
            sim.schedule_repair(self.spec.flap_repair_s, node)
            delay = self.spec.flap_repair_s + \
                self.rng.expovariate(1.0 / self.spec.flap_period_s)
        sim.schedule_fault(delay, node)


def make_injector(spec: FaultSpec, sim_seed: int,
                  sim_rng: random.Random) -> FaultInjector:
    """Build the injector for a spec. The degenerate independent profile
    (seed=None) shares ``sim_rng`` — the legacy stream — so it reproduces
    the ``node_mtbf`` path bit-for-bit; everything else gets the isolated
    ``fault_rng`` stream."""
    if spec.profile == "independent":
        if spec.seed is None:
            return IndependentInjector(spec, sim_rng, legacy_pick=True)
        return IndependentInjector(spec, fault_rng(spec, sim_seed),
                                   legacy_pick=False)
    if spec.profile == "rack_corr":
        return RackBlastInjector(spec, fault_rng(spec, sim_seed))
    if spec.profile == "flapping":
        return FlappingInjector(spec, fault_rng(spec, sim_seed))
    raise ValueError(f"unknown fault profile {spec.profile!r}")
