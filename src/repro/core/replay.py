"""Trace-driven decision replay and divergence bisection.

PR 6/8 made every control-plane decision a typed, causally-linked trace
event (core/telemetry.py). This module *consumes* those traces:

  * :func:`replay_events` reconstructs a run's decision sequence — free
    pool drains, reclaim plans and their per-victim drains, idle grants,
    releases, drain-window deliveries, node failures/repairs, market
    debits — and re-applies it step-lockstep against fresh count books
    (per-tenant alloc, free pool, drain pool, total, market spend). The
    replayed books are verified against every recorded ``metrics``
    checkpoint (the simulator samples its live state into the trace on
    the same clock), against every ``slo_violation``'s recorded alloc,
    and against each claim's own arithmetic (``from_free`` + step grants
    == ``granted``). A clean replay *proves the trace is a complete
    causal record*: the end-of-run books are derivable from the decision
    events alone, with nothing moved off the record.

  * :func:`bisect_traces` walks two traces of the SAME scenario (same
    arrivals/jobs/seed) under different policy engines and localizes the
    first divergent *decision*: the sim-time, event type, tenant, and
    both sides' payloads (for reclaims: the full planned-victim lists),
    turning "engine A completes 69 jobs vs B's 33" into an explainable
    first cause. Span ids, engine labels and free-text reasons are
    normalized away so the comparison is behavioral, not cosmetic.

Both are surfaced by the analyzer CLI: ``python -m repro.trace replay``
and ``python -m repro.trace bisect`` (src/repro/trace.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.nodes import DRAIN_POOL

# replayed floating-point books (market spend) accumulate in the exact
# order the live run debited them, so they should round-trip bitwise; the
# tolerance only absorbs JSON float formatting of pathological values
SPEND_RTOL = 1e-9

# event types that ARE control-plane decisions (replayed / bisected), in
# contrast to sampled state (`metrics`), inventory mirrors (`node_state`)
# and the header. `slo_violation`/`slo_recovery` ride along: they are
# consequences the simulator commits to the record at decision points and
# carry cross-checkable alloc/demand.
DECISION_TYPES = frozenset({
    "claim", "reclaim_plan", "reclaim_step", "surplus_reflow",
    "idle_grant", "release", "autoscale", "auction_clear", "debit",
    "node_fail", "node_repair", "fault_suppressed", "drain_complete",
    "slo_violation", "slo_recovery",
})


@dataclasses.dataclass
class ReplayResult:
    """Outcome of one :func:`replay_events` pass."""
    events: int = 0               # trace events consumed (header included)
    decisions: int = 0            # decision events applied to the books
    checkpoints: int = 0          # metrics samples verified against books
    problems: List[str] = dataclasses.field(default_factory=list)
    # final count books
    total: int = 0
    free: int = 0
    draining: int = 0
    alloc: Dict[str, int] = dataclasses.field(default_factory=dict)
    spend: Dict[str, float] = dataclasses.field(default_factory=dict)
    demand: Dict[str, int] = dataclasses.field(default_factory=dict)

    @property
    def ok(self) -> bool:
        return not self.problems

    def books(self) -> Dict:
        """JSON-safe snapshot of the replayed count books."""
        return {
            "total": self.total, "free": self.free,
            "draining": self.draining,
            "alloc": dict(sorted(self.alloc.items())),
            "spend": {k: float(v)
                      for k, v in sorted(self.spend.items())},
            "demand": dict(sorted(self.demand.items())),
        }


def replay_events(events: Sequence[Dict]) -> ReplayResult:
    """Re-apply a trace's decision sequence against fresh count books.

    The books start from the header's ``total_nodes`` (everything free —
    exactly the provision service's initial state) and every decision
    event moves counts the way ``TenantProvisionService`` did live:

    ====================  =============================================
    event                 book transition
    ====================  =============================================
    ``idle_grant``        free -> tenant
    ``claim``             free -> claimant (the ``from_free`` part)
    ``reclaim_step``      victim -> claimant (or the drain pool when the
                          step pays a drain window); the over-released
                          remainder is held until its ``surplus_reflow``
    ``surplus_reflow``    held surplus -> free
    ``release``           tenant -> free
    ``drain_complete``    drain pool -> claimant (survivors only)
    ``node_fail``         owner pool and total shrink by one
    ``node_repair``       total and free grow by one
    ``debit``             market spend book grows by ``cost``
    ``autoscale``         demand book updated (no count move)
    ====================  =============================================

    Verification is step-lockstep: every ``metrics`` event must match the
    replayed free pool and per-tenant allocs exactly (and per-tenant
    spend within float round-trip), every ``slo_violation`` must match
    the replayed victim alloc, conservation (``sum(alloc) + free +
    draining == total``) must hold at every checkpoint, and every claim's
    ``from_free`` + step grants must equal its recorded ``granted``.
    Problems are collected, never raised — a corrupt or incomplete trace
    yields a non-empty ``problems`` list (the CLI exits non-zero on it).
    """
    res = ReplayResult()
    alloc = res.alloc
    spend = res.spend
    # reclaim bookkeeping for the per-claim arithmetic cross-check:
    # plan span -> claimant, claim span -> plan, plan span -> sum(granted)
    plan_claim_parent: Dict[int, int] = {}       # plan span -> claim span
    step_granted_by_plan: Dict[int, int] = {}
    surplus_held = 0

    def note(i: int, ev: Dict, msg: str) -> None:
        res.problems.append(
            f"event {i} ({ev.get('type')}, t={ev.get('ts', 0.0)}): {msg}")

    for i, ev in enumerate(events):
        res.events += 1
        t = ev.get("type")
        if t == "trace_header":
            res.total = int(ev.get("total_nodes", 0))
            res.free = res.total
            if res.total <= 0:
                note(i, ev, "header lacks a positive total_nodes; "
                            "count books cannot be seeded")
            continue
        if t == "metrics":
            res.checkpoints += 1
            if surplus_held != 0:
                note(i, ev, f"{surplus_held} over-released node(s) never "
                            "reflowed before the metrics sample")
            if int(ev.get("free", -1)) != res.free:
                note(i, ev, f"replayed free={res.free} but the live run "
                            f"recorded free={ev.get('free')}")
            for name, m in ev.get("tenants", {}).items():
                if int(m.get("alloc", -1)) != alloc.get(name, 0):
                    note(i, ev,
                         f"replayed alloc[{name}]={alloc.get(name, 0)} "
                         f"but the live run recorded {m.get('alloc')}")
                want = float(m.get("spend", 0.0))
                got = spend.get(name, 0.0)
                if abs(got - want) > SPEND_RTOL * max(abs(want), 1.0):
                    note(i, ev, f"replayed spend[{name}]={got} but the "
                                f"live run recorded {want}")
            used = sum(alloc.values())
            if used + res.free + res.draining != res.total:
                note(i, ev, "conservation broken: "
                            f"alloc={used} + free={res.free} + "
                            f"draining={res.draining} != total={res.total}")
            if any(a < 0 for a in alloc.values()) or res.free < 0 \
                    or res.draining < 0:
                note(i, ev, f"negative book: free={res.free} "
                            f"draining={res.draining} alloc={alloc}")
            continue
        if t not in DECISION_TYPES:
            continue                    # node_state / unknown: no counts
        res.decisions += 1
        if t == "idle_grant":
            n = int(ev["nodes"])
            res.free -= n
            alloc[ev["tenant"]] = alloc.get(ev["tenant"], 0) + n
        elif t == "claim":
            name = ev["tenant"]
            from_free = int(ev["from_free"])
            res.free -= from_free
            alloc[name] = alloc.get(name, 0) + from_free
            # arithmetic cross-check: free-pool part + reclaim-step
            # grants (immediate AND drain-committed) == granted
            plan_span = next(
                (ps for ps, cs in plan_claim_parent.items()
                 if cs == ev.get("span")), None)
            steps = step_granted_by_plan.pop(plan_span, 0) \
                if plan_span is not None else 0
            if from_free + steps != int(ev["granted"]):
                note(i, ev,
                     f"claim arithmetic: from_free={from_free} + step "
                     f"grants={steps} != granted={ev['granted']}")
        elif t == "reclaim_plan":
            plan_claim_parent[ev["span"]] = ev.get("parent")
        elif t == "reclaim_step":
            victim, claimant = ev["tenant"], ev["claimant"]
            released, granted = int(ev["released"]), int(ev["granted"])
            alloc[victim] = alloc.get(victim, 0) - released
            if "span" in ev:            # drain-delayed delivery
                res.draining += granted
            else:
                alloc[claimant] = alloc.get(claimant, 0) + granted
            surplus_held += released - granted
            plan = ev.get("parent")
            step_granted_by_plan[plan] = \
                step_granted_by_plan.get(plan, 0) + granted
        elif t == "surplus_reflow":
            n = int(ev["nodes"])
            res.free += n
            surplus_held -= n
            if surplus_held < 0:
                note(i, ev, f"surplus_reflow of {n} exceeds the "
                            "over-released nodes on the books")
        elif t == "release":
            n = int(ev["nodes"])
            alloc[ev["tenant"]] = alloc.get(ev["tenant"], 0) - n
            res.free += n
        elif t == "drain_complete":
            n = int(ev["nodes"])
            res.draining -= n
            alloc[ev["tenant"]] = alloc.get(ev["tenant"], 0) + n
        elif t == "node_fail":
            owner = ev["owner"]
            if owner == "free":
                res.free -= 1
            elif owner == DRAIN_POOL:
                res.draining -= 1
            else:
                alloc[owner] = alloc.get(owner, 0) - 1
            res.total -= 1
        elif t == "node_repair":
            res.total += 1
            res.free += 1
        elif t == "debit":
            spend[ev["tenant"]] = \
                spend.get(ev["tenant"], 0.0) + float(ev["cost"])
        elif t == "autoscale":
            res.demand[ev["tenant"]] = int(ev["demand"])
        elif t == "slo_violation":
            name = ev["tenant"]
            if int(ev.get("alloc", -1)) != alloc.get(name, 0):
                note(i, ev,
                     f"replayed alloc[{name}]={alloc.get(name, 0)} but "
                     f"the violation recorded alloc={ev.get('alloc')}")
        # slo_recovery / auction_clear / fault_suppressed: decisions on
        # the record, but they move no counts

    if surplus_held != 0:
        res.problems.append(
            f"end of trace: {surplus_held} over-released node(s) never "
            "reflowed to the free pool")
    used = sum(alloc.values())
    if used + res.free + res.draining != res.total:
        res.problems.append(
            f"end of trace: conservation broken — alloc={used} + "
            f"free={res.free} + draining={res.draining} "
            f"!= total={res.total}")
    return res


# ------------------------------------------------------------- bisection


def decision_stream(events: Sequence[Dict]) -> List[Tuple[int, Dict]]:
    """The (original_index, event) sequence of decision events — the unit
    :func:`bisect_traces` compares. ``metrics`` samples, ``node_state``
    inventory mirrors and the header are excluded: they restate decisions
    already on the stream (a divergence there is never the FIRST one)."""
    return [(i, ev) for i, ev in enumerate(events)
            if ev.get("type") in DECISION_TYPES]


# comparison-irrelevant keys: span ids are allocation-order artifacts,
# engine labels differ by construction when bisecting two engines, and
# auction intervals restate clearing order
_NORMALIZE_DROP = ("span", "parent", "engine", "interval")


def normalize_decision(ev: Dict) -> Dict:
    """Strip cosmetic fields so two engines' decisions compare on
    *behavior*: sim-time, type, tenant and the quantitative payload.
    Reclaim-plan steps keep (victim, take) but drop the engine-specific
    free-text ``reason``."""
    out = {k: v for k, v in ev.items() if k not in _NORMALIZE_DROP}
    if ev.get("type") == "reclaim_plan":
        out["steps"] = [{"victim": s["victim"], "take": s["take"]}
                        for s in ev.get("steps", [])]
    return out


def bisect_traces(a: Sequence[Dict], b: Sequence[Dict]) -> Optional[Dict]:
    """Localize the first divergent decision between two traces of the
    same scenario (returns None when the decision streams are
    behaviorally identical).

    The report pins the divergence to its sim-time, decision index,
    event types and tenants on both sides, the raw events themselves,
    and — when either side is mid-reclaim — the *planned* victim lists
    (``plan_a``/``plan_b``) so "planned vs taken" is visible in one
    place. ``context`` carries the trailing common decisions leading up
    to the split."""
    sa, sb = decision_stream(a), decision_stream(b)
    limit = min(len(sa), len(sb))
    div = None
    for k in range(limit):
        if normalize_decision(sa[k][1]) != normalize_decision(sb[k][1]):
            div = k
            break
    if div is None:
        if len(sa) == len(sb):
            return None
        div = limit                  # one stream is a strict prefix

    def side(stream, k):
        if k >= len(stream):
            return {"exhausted": True, "event": None, "index": None,
                    "ts": None, "type": None, "tenant": None}
        idx, ev = stream[k]
        return {"exhausted": False, "event": ev, "index": idx,
                "ts": ev.get("ts"), "type": ev.get("type"),
                "tenant": ev.get("tenant")}

    def last_plan(stream, k):
        """Most recent reclaim plan at or before the divergence: the
        'planned' half of planned-vs-taken."""
        for j in range(min(k, len(stream) - 1), -1, -1):
            ev = stream[j][1]
            if ev.get("type") == "reclaim_plan":
                return {"ts": ev.get("ts"), "tenant": ev.get("tenant"),
                        "engine": ev.get("engine"),
                        "steps": [{"victim": s["victim"],
                                   "take": s["take"]}
                                  for s in ev.get("steps", [])]}
        return None

    ctx = [sa[j][1] for j in range(max(0, div - 3), div)]
    report = {
        "decision_index": div,
        "common_decisions": div,
        "a": side(sa, div),
        "b": side(sb, div),
        "context": ctx,
    }
    types = {report["a"]["type"], report["b"]["type"]}
    if types & {"reclaim_plan", "reclaim_step", "claim"}:
        report["plan_a"] = last_plan(sa, div)
        report["plan_b"] = last_plan(sb, div)
    return report
