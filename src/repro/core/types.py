"""Core entity types of Phoenix Cloud (paper §II).

The unit of provisioning is a *node*: in the 2009 paper a Xen VM / physical
node, in the runtime bridge a TPU device slice (``runtime/device_pool.py``).
All times are virtual seconds in the discrete-event simulator.
"""
from __future__ import annotations

import dataclasses
import enum
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Protocol, Tuple, runtime_checkable


class JobState(enum.Enum):
    QUEUED = "queued"
    RUNNING = "running"
    COMPLETED = "completed"
    KILLED = "killed"
    PREEMPTED = "preempted"   # beyond-paper checkpoint-preempt mode


@dataclass
class Job:
    """An HPC batch job (ST CMS workload)."""
    job_id: int
    submit_time: float
    size: int                 # nodes requested
    runtime: float            # required service seconds (on `size` nodes)
    state: JobState = JobState.QUEUED
    start_time: Optional[float] = None
    end_time: Optional[float] = None
    done_work: float = 0.0    # completed service seconds (checkpoint mode)
    kills: int = 0
    # set in checkpoint-preempt mode: work surviving the last preemption
    checkpointed_work: float = 0.0

    @property
    def turnaround(self) -> Optional[float]:
        if self.end_time is None or self.state is not JobState.COMPLETED:
            return None
        return self.end_time - self.submit_time

    def remaining(self) -> float:
        return max(0.0, self.runtime - self.checkpointed_work)


@dataclass
class Request:
    """One WS request (request-level workload model, ``repro.workloads``).

    The 2009 paper models WS load as an instance-demand timeseries; the
    follow-up PhoenixCloud evaluation (arXiv:1006.1401) is per-request. A
    request carries token counts so continuous-batching service times can be
    derived from ``serving/batching.py``'s model.
    """
    req_id: int
    arrival: float            # virtual seconds
    prompt_tokens: int
    decode_tokens: int
    start: Optional[float] = None
    finish: Optional[float] = None

    @property
    def latency(self) -> Optional[float]:
        if self.finish is None:
            return None
        return self.finish - self.arrival


@dataclass(frozen=True)
class SLOConfig:
    """Latency service-level objective for the WS department.

    The SLO is stated on a latency percentile (default p99): the autoscaler
    provisions so the predicted percentile stays under ``latency_target_s``,
    and the queue simulator reports the fraction of requests exceeding it
    (``violation`` = request latency > latency_target_s).
    """
    latency_target_s: float = 30.0
    percentile: float = 99.0
    # campaign bookkeeping: a scenario cell "meets SLO" iff the realized
    # violation rate stays under this fraction.
    max_violation_rate: float = 0.01


@runtime_checkable
class WSDemandProvider(Protocol):
    """Anything that can stand in for the raw ``ws_demand`` timeseries.

    ``ConsolidationSim`` accepts either a plain ``[(t, n), ...]`` list or a
    provider. Providers that also implement ``realized_metrics`` get called
    back with the realized WS allocation timeline so request-level latency
    can be measured against what the cluster actually granted.
    """

    def demand_events(self, horizon: float) -> List[Tuple[float, int]]:
        """Planned node-demand change events over [0, horizon)."""
        ...


@dataclass
class TenantSignals:
    """Per-tenant runtime snapshot consumed by reclaim planners.

    The two-phase ``PolicyEngine`` (core/policies.py) plans *who gives up
    nodes* from these signals instead of a fixed priority chain: a latency
    department far under its SLO target is a cheap victim, a batch
    department about to checkpoint a huge job is an expensive one, and an
    auction engine turns ``bid`` into both the reclaim order and the idle
    clearing price. Signals are produced by the CMSes (``CMSBase.signals``)
    in the simulator and by ``MultiTenantOrchestrator`` from real
    serving-pool latency in the runtime — the same vocabulary either way.
    """
    name: str
    kind: str = "batch"               # "batch" | "latency"
    alloc: int = 0
    demand: int = 0
    weight: float = 1.0
    # latency tenants: seconds of slack between the SLO target and the
    # currently observed/predicted latency percentile (positive = under
    # target, safe to drain; negative = already violating)
    latency_headroom_s: float = 0.0
    slo_target_s: float = 0.0
    # batch tenants: queued jobs; latency tenants: replica shortfall
    queue_depth: int = 0
    # estimated seconds of work lost per node freed by forced reclaim
    # (0 while idle nodes can absorb the reclaim)
    preemption_cost_s: float = 0.0
    # auction engines: this interval's bid (default weight x unmet demand)
    bid: float = 0.0

    @property
    def unmet(self) -> int:
        return max(0, self.demand - self.alloc)


# MarketState.ledger / .clearing_prices retain at most this many samples
# (aggregates — spend, remaining, transactions — are always exact)
MARKET_SAMPLES_MAX = 64


@dataclass
class MarketState:
    """Per-run money bookkeeping of the budget-constrained market engines.

    Tenants declare a ``budget`` (tokens spendable across the horizon;
    ``None`` = unlimited). The market engines (``budget_auction``,
    ``second_price`` in core/policies.py) debit it whenever acquiring a
    node displaces someone else's claim on it: idle purchases at the
    interval's clearing price, forced reclaims at the displaced victim's
    per-node bid (beyond the claimant's free ``floor`` entitlement).
    Nodes granted straight from the free pool are free — nobody was
    outbid for them. The state is threaded through ``claim()``/
    ``provision_idle`` (the engine carries it across both phases) and
    lands, JSON-safe, in ``SimResult.policy_state["market"]`` and the v5
    campaign artifact.
    """
    budgets: Dict[str, Optional[float]] = field(default_factory=dict)
    remaining: Dict[str, float] = field(default_factory=dict)  # inf = no cap
    spend: Dict[str, float] = field(default_factory=dict)
    transactions: int = 0
    # capped inspection samples; aggregates above are exact, and entries
    # dropped past the cap are COUNTED (no silent caps: a capped trace
    # must be distinguishable from a short one)
    ledger: List[Dict] = field(default_factory=list)
    clearing_prices: List[float] = field(default_factory=list)
    ledger_dropped: int = 0
    clearing_prices_dropped: int = 0
    # telemetry sink (core/telemetry.py); every debit lands in the trace
    # even after the ledger sample cap. Excluded from ==/repr: two runs
    # with identical money flows are equal regardless of tracing.
    tracer: object = field(default=None, repr=False, compare=False)

    def register(self, name: str, budget: Optional[float]) -> None:
        """First sight of a tenant: seed its remaining budget. Later calls
        are no-ops — the pot never refills mid-run."""
        if name in self.budgets:
            return
        self.budgets[name] = None if budget is None else float(budget)
        self.remaining[name] = math.inf if budget is None else float(budget)
        self.spend[name] = 0.0

    def affordable_nodes(self, name: str, unit_price: float) -> int:
        """How many nodes this tenant can pay for at ``unit_price``."""
        rem = self.remaining.get(name, math.inf)
        if unit_price <= 0.0 or math.isinf(rem):
            return 1 << 30
        return int(math.floor(rem / unit_price + 1e-9))

    def debit(self, name: str, nodes: int, unit_price: float,
              kind: str, interval: int) -> float:
        """Charge ``nodes x unit_price`` against the tenant's budget and
        record it in the (capped) ledger. Returns the cost."""
        cost = float(nodes) * float(unit_price)
        if nodes <= 0 or cost <= 0.0:
            return 0.0
        self.remaining[name] -= cost          # inf stays inf (unlimited)
        self.spend[name] = self.spend.get(name, 0.0) + cost
        self.transactions += 1
        if len(self.ledger) < MARKET_SAMPLES_MAX:
            self.ledger.append({"tenant": name, "nodes": int(nodes),
                                "unit_price": float(unit_price),
                                "cost": cost, "kind": kind,
                                "interval": int(interval)})
        else:
            self.ledger_dropped += 1
        if self.tracer is not None and self.tracer.enabled:
            self.tracer.emit("debit", tenant=name, nodes=int(nodes),
                             unit_price=float(unit_price), cost=cost,
                             kind=kind, interval=int(interval))
        return cost

    def note_price(self, price: float) -> None:
        if len(self.clearing_prices) < MARKET_SAMPLES_MAX:
            self.clearing_prices.append(float(price))
        else:
            self.clearing_prices_dropped += 1

    def snapshot(self) -> Dict:
        """JSON-safe snapshot (unlimited budgets serialize as null)."""
        return {
            "budgets": dict(self.budgets),
            "remaining": {n: (None if math.isinf(v) else v)
                          for n, v in self.remaining.items()},
            "spend": dict(self.spend),
            "transactions": self.transactions,
            "ledger": [dict(e) for e in self.ledger],
            "clearing_prices": list(self.clearing_prices),
            "dropped_entries": {"ledger": self.ledger_dropped,
                                "clearing_prices":
                                    self.clearing_prices_dropped},
        }


@dataclass
class TenantSpec:
    """Declaration of one department (tenant) sharing the cluster.

    The 2009 paper wires exactly two departments — one HPC/batch (ST) and
    one Web-service (WS). ``TenantSpec`` is the N-department generalization:
    a registry of these specs drives ``TenantProvisionService``
    (core/provision.py), ``ConsolidationSim`` and the runtime orchestrator.

    kind:
      * ``"batch"``    — throughput-oriented CMS (an ST department): demand
        comes from a job trace (``jobs``); receives idle nodes passively.
      * ``"latency"``  — latency-sensitive CMS (a WS department): demand
        comes from a node-demand timeseries or a ``WSDemandProvider``
        (``demand``); claims urgently, preempting lower-priority tenants.

    priority: lower number = higher priority, used both for urgent claims
    (who may preempt whom) and for idle distribution order. A best-effort
    department is simply a batch tenant with the largest priority number.

    weight: relative share for proportional-share policies (ignored by the
    paper's policy).

    floor: nodes forced reclaim may never take (a latency department's
    minimum replica set survives any preemption chain; 0 = fully drainable,
    the paper's behaviour).

    bid_weight: auction engines bid ``bid_weight x unmet demand`` per
    interval; defaults to ``weight`` when unset, so a department can value
    marginal nodes differently from its proportional share.

    budget: tokens this department may spend across the whole horizon
    under the budget-constrained market engines (``budget_auction``,
    ``second_price``): idle purchases and forced reclaims debit it (see
    :class:`MarketState`); once broke the department falls back to its
    ``floor``. ``None`` = unlimited (every non-market engine ignores it).

    bid_policy: how the per-interval bid is derived from runtime signals —
    ``"linear"`` (bid_weight x unmet demand, the default) or
    ``"slo_elastic"`` (the bid rises as latency headroom shrinks: scaled
    by 1x at full headroom up to 2x at zero headroom and beyond when the
    SLO is violated, so a department under latency pressure outbids
    comfortable ones).
    """
    name: str
    kind: str = "batch"                    # "batch" | "latency"
    priority: int = 0
    weight: float = 1.0
    floor: int = 0
    bid_weight: Optional[float] = None
    budget: Optional[float] = None
    bid_policy: str = "linear"             # "linear" | "slo_elastic"
    # demand sources --------------------------------------------------
    jobs: Optional[List["Job"]] = None     # batch: HPC job trace
    demand: object = None                  # latency: [(t, n), ...] or provider
    slo: Optional[SLOConfig] = None        # latency: SLO for the autoscaler

    def __post_init__(self):
        assert self.kind in ("batch", "latency"), self.kind
        assert self.bid_policy in ("linear", "slo_elastic"), self.bid_policy


class EventKind(enum.Enum):
    JOB_SUBMIT = 1
    JOB_FINISH = 2
    WS_DEMAND = 3
    REALLOC_DONE = 4
    NODE_FAIL = 5
    NODE_REPAIR = 6
    HEARTBEAT = 7
    DRAIN_DONE = 8     # a reclaim step's drain window elapsed


@dataclass(order=True)
class Event:
    time: float
    seq: int
    kind: EventKind = field(compare=False)
    payload: object = field(compare=False, default=None)


@dataclass
class SimConfig:
    """Knobs of the consolidation simulation (paper §III + beyond-paper)."""
    total_nodes: int = 208
    # seconds to repurpose a node ST->WS (paper: "only seconds" — software
    # pre-deployed); charged before WS can use reclaimed nodes.
    reallocation_latency: float = 5.0
    # kill (paper) loses all work; checkpoint (beyond-paper) requeues the job
    # with checkpointed progress, paying checkpoint_cost seconds.
    preempt_mode: str = "kill"            # kill | checkpoint
    checkpoint_cost: float = 30.0
    scheduler: str = "first_fit"          # first_fit | fcfs | easy_backfill
    # fault injection (large-scale runnability): mean time between node
    # failures across the whole cluster; 0 disables. The legacy anonymous
    # path; `faults` below supersedes it when set.
    node_mtbf: float = 0.0
    node_repair_time: float = 3600.0
    # declarative fault injection (core/faults.py FaultSpec): builds a
    # NodeInventory (identified nodes, failure domains, per-node state
    # machines) and the profile's injector. The degenerate
    # FaultSpec("independent", seed=None) reproduces the node_mtbf path
    # bit-for-bit. Typed as object to keep core/types dependency-free.
    faults: Optional[object] = None
    # forced-reclaim drain window in seconds: every reclaim step's nodes
    # serve NEITHER tenant for this long before the claimant gets them
    # (0 = instant handover, the paper's assumption). The active window is
    # max(drain_time_s, faults.drain_time_s).
    drain_time_s: float = 0.0
    # straggler mitigation: fraction of job launches that straggle, slowdown
    # factor, and whether speculative relaunch is enabled.
    straggler_frac: float = 0.0
    straggler_slowdown: float = 2.0
    speculative_relaunch: bool = True
    seed: int = 0
