"""Core entity types of Phoenix Cloud (paper §II).

The unit of provisioning is a *node*: in the 2009 paper a Xen VM / physical
node, in the runtime bridge a TPU device slice (``runtime/device_pool.py``).
All times are virtual seconds in the discrete-event simulator.
"""
from __future__ import annotations

import dataclasses
import enum
from dataclasses import dataclass, field
from typing import Optional


class JobState(enum.Enum):
    QUEUED = "queued"
    RUNNING = "running"
    COMPLETED = "completed"
    KILLED = "killed"
    PREEMPTED = "preempted"   # beyond-paper checkpoint-preempt mode


@dataclass
class Job:
    """An HPC batch job (ST CMS workload)."""
    job_id: int
    submit_time: float
    size: int                 # nodes requested
    runtime: float            # required service seconds (on `size` nodes)
    state: JobState = JobState.QUEUED
    start_time: Optional[float] = None
    end_time: Optional[float] = None
    done_work: float = 0.0    # completed service seconds (checkpoint mode)
    kills: int = 0
    # set in checkpoint-preempt mode: work surviving the last preemption
    checkpointed_work: float = 0.0

    @property
    def turnaround(self) -> Optional[float]:
        if self.end_time is None or self.state is not JobState.COMPLETED:
            return None
        return self.end_time - self.submit_time

    def remaining(self) -> float:
        return max(0.0, self.runtime - self.checkpointed_work)


class EventKind(enum.Enum):
    JOB_SUBMIT = 1
    JOB_FINISH = 2
    WS_DEMAND = 3
    REALLOC_DONE = 4
    NODE_FAIL = 5
    NODE_REPAIR = 6
    HEARTBEAT = 7


@dataclass(order=True)
class Event:
    time: float
    seq: int
    kind: EventKind = field(compare=False)
    payload: object = field(compare=False, default=None)


@dataclass
class SimConfig:
    """Knobs of the consolidation simulation (paper §III + beyond-paper)."""
    total_nodes: int = 208
    # seconds to repurpose a node ST->WS (paper: "only seconds" — software
    # pre-deployed); charged before WS can use reclaimed nodes.
    reallocation_latency: float = 5.0
    # kill (paper) loses all work; checkpoint (beyond-paper) requeues the job
    # with checkpointed progress, paying checkpoint_cost seconds.
    preempt_mode: str = "kill"            # kill | checkpoint
    checkpoint_cost: float = 30.0
    scheduler: str = "first_fit"          # first_fit | fcfs | easy_backfill
    # fault injection (large-scale runnability): mean time between node
    # failures across the whole cluster; 0 disables.
    node_mtbf: float = 0.0
    node_repair_time: float = 3600.0
    # straggler mitigation: fraction of job launches that straggle, slowdown
    # factor, and whether speculative relaunch is enabled.
    straggler_frac: float = 0.0
    straggler_slowdown: float = 2.0
    speculative_relaunch: bool = True
    seed: int = 0
