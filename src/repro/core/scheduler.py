"""Job schedulers for the ST CMS.

``first_fit`` is the paper's policy (§III-D). ``fcfs`` and ``easy_backfill``
are beyond-paper options for the scheduler ablation (EXPERIMENTS.md).
"""
from __future__ import annotations

from typing import Callable, List, Optional

from repro.core.types import Job, JobState


def first_fit(queue: List[Job], free_nodes: int, now: float) -> List[Job]:
    """Scan the queue in submit order; start every job that fits."""
    started = []
    for job in queue:
        if job.state is not JobState.QUEUED:
            continue
        if job.size <= free_nodes:
            free_nodes -= job.size
            started.append(job)
        if free_nodes <= 0:
            break
    return started


def fcfs(queue: List[Job], free_nodes: int, now: float) -> List[Job]:
    """Strict FCFS: head of queue blocks everything behind it."""
    started = []
    for job in queue:
        if job.state is not JobState.QUEUED:
            continue
        if job.size <= free_nodes:
            free_nodes -= job.size
            started.append(job)
        else:
            break
    return started


def easy_backfill(queue: List[Job], free_nodes: int, now: float,
                  running_release: Optional[List] = None) -> List[Job]:
    """EASY backfill: FCFS head gets a reservation; later jobs may jump the
    queue iff they do not delay the head's reservation.

    ``running_release``: sorted [(finish_time, size), ...] of running jobs.
    """
    started = []
    pending = [j for j in queue if j.state is JobState.QUEUED]
    if not pending:
        return started
    head = pending[0]
    if head.size <= free_nodes:
        # head fits: behave like first-fit from the head onwards
        return first_fit(queue, free_nodes, now)
    # compute the shadow time: when enough nodes free up for the head
    avail = free_nodes
    shadow_time = float("inf")
    extra_at_shadow = 0
    for ft, sz in (running_release or []):
        avail += sz
        if avail >= head.size:
            shadow_time = ft
            extra_at_shadow = avail - head.size
            break
    for job in pending[1:]:
        if job.size > free_nodes:
            continue
        # backfill if it finishes before the shadow time, or fits in the
        # spare capacity at the shadow time
        if now + job.remaining() <= shadow_time or job.size <= extra_at_shadow:
            if job.size <= extra_at_shadow:
                extra_at_shadow -= job.size
            free_nodes -= job.size
            started.append(job)
            if free_nodes <= 0:
                break
    return started


SCHEDULERS: dict = {
    "first_fit": first_fit,
    "fcfs": fcfs,
    "easy_backfill": easy_backfill,
}
