"""Workload traces (paper §III-B).

The original inputs — SDSC BLUE (2 weeks from 2000-04-25, 144 nodes, 2672
jobs) and the 1998 World Cup HTTP trace (2 weeks from June 7, scaled 2.22x)
— are not redistributable offline. This module provides:

  * ``parse_swf`` — a Standard Workload Format parser, so the real SDSC BLUE
    log drops in unchanged if available;
  * calibrated synthetic generators matching the published summary statistics
    (job count, node count, utilization regime; peak:normal load ratio ~8,
    peak WS demand 64 instances). EXPERIMENTS.md validates the paper's
    *relative* SC-vs-DC claims on these.

All generators are deterministic in `seed`.
"""
from __future__ import annotations

import math
from typing import List, Optional, Tuple

import numpy as np

from repro.core.types import Job
from repro.core.ws_cms import demand_events, demand_from_load

TWO_WEEKS_S = 14 * 24 * 3600.0
SDSC_BLUE_NODES = 144
SDSC_BLUE_JOBS_2W = 2672
WORLDCUP_PEAK_INSTANCES = 64
WS_CAPACITY_RPS = 100.0          # req/s per instance at 100% util


# ------------------------------------------------------------------- SWF


def parse_swf(path: str, *, max_nodes: int = SDSC_BLUE_NODES,
              start: float = 0.0, horizon: float = TWO_WEEKS_S) -> List[Job]:
    """Parse a Standard Workload Format file into Jobs.

    SWF fields: 1 job id, 2 submit, 4 run time, 5 allocated processors.
    Processor counts are mapped to nodes (SDSC BLUE: 8 CPUs/node).
    """
    jobs: List[Job] = []
    cpus_per_node = 8
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line or line.startswith(";"):
                continue
            parts = line.split()
            jid, submit = int(parts[0]), float(parts[1])
            runtime = float(parts[3])
            procs = int(parts[4])
            if runtime <= 0 or procs <= 0:
                continue
            t = submit - start
            if t < 0 or t > horizon:
                continue
            size = max(1, math.ceil(procs / cpus_per_node))
            jobs.append(Job(job_id=jid, submit_time=t,
                            size=min(size, max_nodes), runtime=runtime))
    return jobs


# -------------------------------------------------------------- HPC synth


def synthetic_sdsc_blue(seed: int = 0, n_jobs: int = SDSC_BLUE_JOBS_2W,
                        horizon: float = TWO_WEEKS_S,
                        max_nodes: int = SDSC_BLUE_NODES) -> List[Job]:
    """SDSC-BLUE-like synthetic batch trace.

    Calibration targets: `n_jobs` over `horizon`; node-size distribution
    favoring powers of two <= 144; log-normal runtimes with a heavy tail;
    diurnal arrivals. Total demand ~= 60-65% of 144 nodes x 2 weeks, the
    regime in which a 144-node dedicated system is busy but feasible.
    """
    rng = np.random.default_rng(seed)
    # --- arrivals: nonhomogeneous Poisson via thinning over a diurnal rate
    base_rate = n_jobs / horizon
    t, times = 0.0, []
    while len(times) < n_jobs:
        t += rng.exponential(1.0 / (base_rate * 1.8))
        if t >= horizon:
            t = horizon * rng.random()  # wrap: keep exactly n_jobs
        hour = (t / 3600.0) % 24.0
        accept = 0.55 + 0.45 * math.sin((hour - 6.0) / 24.0 * 2 * math.pi)
        if rng.random() < accept:
            times.append(t)
    times = np.sort(np.asarray(times[:n_jobs]))

    # --- sizes: chunky powers of two (4..~96) with jitter, capped. SDSC BLUE
    # allocations were multi-node (8-way SMP nodes); tiny 1-node jobs are
    # rare. Chunky sizes also produce First-Fit fragmentation — idle-but-
    # queued nodes — which is what absorbs most WS +1 ramps without kills.
    exps = rng.uniform(2.0, 6.6, size=n_jobs)
    sizes = np.power(2.0, np.round(exps)).astype(int)
    jitter = rng.random(n_jobs) < 0.25
    sizes[jitter] = np.maximum(
        4, (sizes[jitter] * rng.uniform(0.6, 1.4, jitter.sum())).astype(int))
    sizes = np.minimum(sizes, max_nodes)

    # --- runtimes: log-normal, capped at 36 h
    runtimes = rng.lognormal(mean=math.log(1500.0), sigma=1.25, size=n_jobs)
    runtimes = np.clip(runtimes, 30.0, 36 * 3600.0)

    # --- calibrate total demand to ~101% of the dedicated system: the real
    # SDSC BLUE machine ran saturated with deep queues — SC cannot complete
    # everything in-window, which is what makes the consolidated capacity
    # worth having (paper Fig. 7)
    target = 1.01 * max_nodes * horizon
    scale = target / float(np.sum(sizes * runtimes))
    runtimes = np.clip(runtimes * scale, 30.0, 48 * 3600.0)

    return [Job(job_id=i + 1, submit_time=float(times[i]),
                size=int(sizes[i]), runtime=float(runtimes[i]))
            for i in range(n_jobs)]


# --------------------------------------------------------------- WS synth


def synthetic_worldcup_load(seed: int = 0, horizon: float = TWO_WEEKS_S,
                            dt: float = 20.0) -> Tuple[np.ndarray, float]:
    """World-Cup-98-like request-rate trace (req/s sampled every dt).

    Diurnal base + evening match bursts on match days; peak:normal ~ 8:1.
    Scaled (the paper's 2.22x analog) so the §III-C autoscaler peaks at 64
    instances. Returns (load, dt).
    """
    rng = np.random.default_rng(seed + 1)
    n = int(horizon / dt)
    tt = np.arange(n) * dt
    hours = (tt / 3600.0) % 24.0
    days = (tt / 86400.0).astype(int)

    base = 700.0 * (0.75 + 0.45 * np.sin((hours - 9.0) / 24.0 * 2 * np.pi))
    # a few HUGE match days (the famous peak days) + moderate match days —
    # this is the World-Cup-98 shape: peak:normal ~ 8:1 driven by 2-3 days
    big_days = {3, 10}
    moderate_days = {2, 5, 7, 8, 12}
    burst = np.zeros(n)
    for d, amp in [(d, 5200.0) for d in sorted(big_days)] + \
                  [(d, 1400.0) for d in sorted(moderate_days)]:
        # two matches: ~15:30 and ~20:30 local, 2.5 h each, sharp ramp
        for center in (15.5, 20.5):
            mask = days == d
            x = (hours - center) / 1.25
            burst += np.where(mask, amp * np.exp(-x * x), 0.0)
    noise = rng.normal(1.0, 0.015, n)
    load = np.maximum(20.0, (base + burst) * noise)
    # light EMA (~3 min) — per-20s request rates are already aggregates; the
    # published World Cup curves are smooth at this resolution
    alpha = dt / 180.0
    for i in range(1, n):
        load[i] = (1 - alpha) * load[i - 1] + alpha * load[i]

    # scale so that the autoscaled instance demand peaks at exactly 64.
    # The autoscaler is nonlinear in the scale (its +1/-1 windowed walk),
    # so one rescale is not enough in general: iterate multiplicative
    # corrections, with the exponent damped every few rounds so a 63<->65
    # oscillation cannot cycle forever (the peak is a monotone step
    # function of the scale, so a damped walk settles inside the
    # peak==64 plateau).
    demand = demand_from_load(load, dt, WS_CAPACITY_RPS)
    scale = WORLDCUP_PEAK_INSTANCES / demand.max()
    load = load * scale
    demand = demand_from_load(load, dt, WS_CAPACITY_RPS)
    for i in range(32):
        peak = int(demand.max())
        if peak == WORLDCUP_PEAK_INSTANCES:
            break
        ratio = (WORLDCUP_PEAK_INSTANCES / max(peak, 1)) \
            ** (1.0 / (1 + i // 4))
        load = load * ratio
        demand = demand_from_load(load, dt, WS_CAPACITY_RPS)
    return load, dt


def worldcup_demand_events(seed: int = 0, horizon: float = TWO_WEEKS_S
                           ) -> List[Tuple[float, int]]:
    load, dt = synthetic_worldcup_load(seed, horizon)
    demand = demand_from_load(load, dt, WS_CAPACITY_RPS)
    return demand_events(demand, dt)
