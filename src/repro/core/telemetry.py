"""Control-plane telemetry: causal decision traces + metric timeseries.

The consolidation stack's policy claims ("enough resources for the web
department", "HPC benefit improved") were previously asserted from
end-of-run aggregates; nothing could show *why* a reclaim fired, how long
an SLO shortfall lasted before the engine reacted, or which auction
clearing starved which tenant. This module is the measurement substrate:
a zero-dependency structured event bus (:class:`Tracer`) that the whole
control plane emits into —

  * every ``claim`` / ``release`` / ``idle_grant`` of the provision
    service, each applied ``ReclaimStep`` of a ``plan_reclaim`` plan,
    auction clearings and per-winner market debits, SLO shortfall
    episodes (violation -> recovery), node failures/repairs, and
    autoscaler decisions — as typed events stamped with **sim-time** and
    **causal span ids**, so a ``claim -> reclaim plan -> per-victim
    drains -> SLO recovery`` chain is one linked trace;
  * a per-interval metric timeseries (free pool, per-tenant alloc /
    demand / latency headroom / queue depth / market spend), emitted as
    ``metrics`` events on the same clock.

Design constraints (enforced by the ``policy_engine`` bench gate):

  * **off by default, ~0 overhead when off** — every emission site guards
    on ``tracer.enabled`` (one attribute load + branch); the shared
    :data:`NULL_TRACER` singleton is the disabled default everywhere;
  * **< 5 % overhead when on**, measured on a deployment-representative
    consolidation cell (request-level latency tenants, the configuration
    campaign cells run; true cost ~1-2 %). Events are small dicts
    appended to a list — no I/O, no formatting until ``to_jsonl``. The
    adversarial bound is the pure control-plane microbench (~17 us of
    sim work per event, nothing to amortize against) where full-detail
    tracing costs ~13 %; the bench records that number too;
  * **deterministic** — events carry only sim-time and control-plane
    state, never wall-clock, so same-seed runs emit identical traces
    (pinned by tests/test_telemetry.py);
  * **no silent caps** — the event buffer is bounded by ``max_events``
    and the header records ``dropped_events`` when it overflows.

Analysis helpers live here too (summaries, causality report, validation,
Perfetto/Chrome trace-event export); ``python -m repro.trace`` is the CLI
over them. The campaign runner's ``--trace`` flag spools one JSONL trace
per cell and folds ``summarize_events`` output into the artifact.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional

TRACE_VERSION = 1
# event-buffer bound: a half-day 5-department bench run emits ~100k
# events; the cap only exists so a runaway loop cannot eat the host, and
# overflow is RECORDED (header.dropped_events), never silent
DEFAULT_MAX_EVENTS = 2_000_000
# metric-timeseries sampling period in sim-seconds (Tracer arg overrides);
# 300 s keeps multi-hour traces readable AND sampling cost inside the
# policy_engine bench's < 5 % overhead envelope
DEFAULT_METRIC_INTERVAL_S = 300.0

# required payload fields per event type (beyond the universal "type" and
# "ts"); the validator — and CI's trace schema check — enforce these
EVENT_SCHEMA: Dict[str, tuple] = {
    "trace_header": ("version",),
    "claim": ("tenant", "requested", "from_free", "deficit", "granted",
              "short", "span"),
    "reclaim_plan": ("tenant", "engine", "deficit", "steps", "span",
                     "parent"),
    "reclaim_step": ("tenant", "claimant", "asked", "released", "granted",
                     "parent"),
    "surplus_reflow": ("nodes", "parent"),
    "idle_grant": ("tenant", "nodes"),
    "auction_clear": ("price", "interval"),
    "debit": ("tenant", "nodes", "unit_price", "cost", "kind", "interval"),
    "release": ("tenant", "nodes"),
    "node_fail": ("owner", "span"),
    "node_repair": ("parent",),
    "node_state": ("node", "from", "to"),
    "fault_suppressed": ("reason",),
    "drain_complete": ("tenant", "nodes", "parent"),
    "slo_violation": ("tenant", "demand", "alloc", "shortfall", "span"),
    "slo_recovery": ("tenant", "duration_s", "parent"),
    "autoscale": ("tenant", "prev", "demand", "source"),
    "metrics": ("free", "tenants"),
}


class Tracer:
    """Structured control-plane event bus with causal span ids.

    One instance per run. The owner of the virtual clock (simulator /
    orchestrator) keeps ``now`` current; emitters (provision service,
    engines, market) just call :meth:`emit` — they never need to know the
    time. Span ids are plain monotonically increasing ints: an event that
    *opens* a causal context carries ``span``, events caused by it carry
    ``parent`` pointing back, so chains survive serialization with no
    object graph.
    """

    __slots__ = ("enabled", "events", "dropped_events", "max_events",
                 "now", "metric_interval_s", "last_claim_span", "meta",
                 "_next_span")

    def __init__(self, enabled: bool = True,
                 max_events: int = DEFAULT_MAX_EVENTS,
                 metric_interval_s: float = DEFAULT_METRIC_INTERVAL_S,
                 meta: Optional[Dict] = None):
        self.enabled = enabled
        self.events: List[Dict] = []
        self.dropped_events = 0
        self.max_events = max_events
        self.now = 0.0
        self.metric_interval_s = metric_interval_s
        # tenant -> span of its most recent claim; SLO shortfall episodes
        # opened right after an under-granted claim parent to it, closing
        # the claim -> ... -> recovery causal chain
        self.last_claim_span: Dict[str, int] = {}
        self.meta: Dict = dict(meta or {})
        self._next_span = 0

    # ------------------------------------------------------------- core
    def new_span(self) -> int:
        self._next_span += 1
        return self._next_span

    def emit(self, type_: str, **fields) -> None:
        """Append one typed event stamped with the current sim-time.

        Callers pass ``span=`` / ``parent=`` / ``tenant=`` plus the
        type's payload fields. A full buffer drops the event and counts
        it (``dropped_events``) — capped traces are distinguishable from
        short ones. Hot path: the kwargs dict IS the stored event (one
        allocation per emit — the < 5 % bench gate rides on this)."""
        if not self.enabled:
            return
        events = self.events
        if len(events) >= self.max_events:
            self.dropped_events += 1
            return
        fields["type"] = type_
        fields["ts"] = self.now
        events.append(fields)

    def append(self, ev: Dict) -> None:
        """Hot-path emit: the caller hand-built the event dict (with its
        ``"type"``) — this just stamps ``ts`` and appends. ~2x cheaper
        than :meth:`emit` (no kwargs repacking); the instrumented claim
        path and the simulator's per-event sites use it so the bench
        gate's < 5 % envelope holds. Callers must already have checked
        ``enabled``."""
        events = self.events
        if len(events) < self.max_events:
            ev["ts"] = self.now
            events.append(ev)
        else:
            self.dropped_events += 1

    # ---------------------------------------------------- serialization
    def header(self) -> Dict:
        return {"type": "trace_header", "ts": 0.0,
                "version": TRACE_VERSION, "events": len(self.events),
                "dropped_events": self.dropped_events, **self.meta}

    def lines(self) -> List[str]:
        """Canonical JSONL lines (header first); the unit of the
        same-seed determinism guarantee."""
        out = [json.dumps(self.header(), sort_keys=True, default=float)]
        out.extend(json.dumps(ev, sort_keys=True, default=float)
                   for ev in self.events)
        return out

    def to_jsonl(self, path: str) -> None:
        with open(path, "w") as f:
            for line in self.lines():
                f.write(line + "\n")


#: shared disabled tracer — the default everywhere tracing is optional.
#: ``emit`` on it is a no-op, and emission sites additionally guard on
#: ``tracer.enabled`` so the disabled path costs one branch.
NULL_TRACER = Tracer(enabled=False)


def load_events(path: str) -> List[Dict]:
    """Read a JSONL trace back (header line included, in position 0)."""
    events: List[Dict] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                events.append(json.loads(line))
    return events


# ---------------------------------------------------------------- analysis


def percentile(sorted_vals: List[float], q: float) -> float:
    """Nearest-rank percentile over a pre-sorted list (no numpy — this
    module stays dependency-free)."""
    if not sorted_vals:
        return 0.0
    k = max(0, min(len(sorted_vals) - 1,
                   int(round(q / 100.0 * (len(sorted_vals) - 1)))))
    return float(sorted_vals[k])


def _dist(vals: List[float]) -> Dict:
    vals = sorted(vals)
    return {
        "n": len(vals),
        "p50": percentile(vals, 50.0),
        "p99": percentile(vals, 99.0),
        "max": vals[-1] if vals else 0.0,
        "total": float(sum(vals)),
    }


def summarize_events(events: List[Dict]) -> Dict:
    """Compact per-run trace summary (the campaign artifact's
    ``trace_summary`` and the analyzer's ``summarize`` output).

    * ``reclaim_latency_s``: per claimant, the sim-time from each claim
      that triggered forced reclaim (``deficit > 0``) to the moment its
      shortfall cleared — 0 when the reclaim chain covered it
      synchronously, the linked SLO-recovery delay otherwise; claims
      whose shortfall never cleared are counted in ``unrecovered``
      (never silently dropped).
    * ``slo_violations``: per tenant, shortfall-episode count and
      duration distribution (open episodes counted separately).
    * ``spend``: per tenant, market debits attributed idle vs reclaim.
    """
    by_type: Dict[str, int] = {}
    claims_by_span: Dict[int, Dict] = {}
    recovery_by_parent: Dict[int, Dict] = {}
    violations: List[Dict] = []
    spend: Dict[str, Dict[str, float]] = {}
    clear_prices: List[float] = []
    fail_by_cause: Dict[str, int] = {}
    drained_nodes = 0
    for ev in events:
        t = ev.get("type")
        by_type[t] = by_type.get(t, 0) + 1
        if t == "claim":
            claims_by_span[ev["span"]] = ev
        elif t == "slo_violation":
            violations.append(ev)
        elif t == "slo_recovery":
            recovery_by_parent[ev["parent"]] = ev
        elif t == "debit":
            d = spend.setdefault(ev["tenant"], {"idle": 0.0, "reclaim": 0.0})
            d[ev["kind"]] = d.get(ev["kind"], 0.0) + float(ev["cost"])
        elif t == "auction_clear":
            clear_prices.append(float(ev["price"]))
        elif t == "node_fail":
            cause = str(ev.get("cause", "mtbf"))
            fail_by_cause[cause] = fail_by_cause.get(cause, 0) + 1
        elif t == "drain_complete":
            drained_nodes += int(ev.get("nodes", 0))

    # violation span -> the claim span it descends from (direct parent)
    viol_claim: Dict[int, Optional[int]] = {
        v["span"]: v.get("parent") for v in violations}

    reclaim_lat: Dict[str, List[float]] = {}
    unrecovered: Dict[str, int] = {}
    for span, c in claims_by_span.items():
        if c.get("deficit", 0) <= 0:
            continue                      # free-pool grant: no reclaim
        tenant = c["tenant"]
        if c.get("short", 0) == 0:
            reclaim_lat.setdefault(tenant, []).append(0.0)
            continue
        # under-granted: find the shortfall episode this claim opened and
        # its recovery; the episode's parent IS this claim's span
        lat = None
        for vspan, cspan in viol_claim.items():
            if cspan == span and vspan in recovery_by_parent:
                rec = recovery_by_parent[vspan]
                lat = float(rec["ts"]) - float(c["ts"])
                break
        if lat is None:
            unrecovered[tenant] = unrecovered.get(tenant, 0) + 1
        else:
            reclaim_lat.setdefault(tenant, []).append(lat)

    episodes: Dict[str, Dict] = {}
    for v in violations:
        e = episodes.setdefault(v["tenant"],
                                {"count": 0, "open": 0, "durations": []})
        e["count"] += 1
        rec = recovery_by_parent.get(v["span"])
        if rec is None:
            e["open"] += 1
        else:
            e["durations"].append(float(rec["duration_s"]))

    all_lat = sorted(x for v in reclaim_lat.values() for x in v)
    return {
        "events": len(events),
        "by_type": dict(sorted(by_type.items())),
        "reclaim_latency_s": {
            "overall": _dist(all_lat),
            "by_tenant": {k: _dist(v)
                          for k, v in sorted(reclaim_lat.items())},
            "unrecovered": dict(sorted(unrecovered.items())),
        },
        "slo_violations": {
            name: {"count": e["count"], "open": e["open"],
                   "duration_s": _dist(e["durations"])}
            for name, e in sorted(episodes.items())},
        "spend": {k: dict(v) for k, v in sorted(spend.items())},
        "auction": {"clearings": len(clear_prices),
                    "clearing_price": _dist(clear_prices)},
        "faults": {
            "failures": by_type.get("node_fail", 0),
            "repairs": by_type.get("node_repair", 0),
            "unrepaired": by_type.get("node_fail", 0)
            - by_type.get("node_repair", 0),
            "suppressed": by_type.get("fault_suppressed", 0),
            "by_cause": dict(sorted(fail_by_cause.items())),
            "drain_completes": by_type.get("drain_complete", 0),
            "drained_nodes": drained_nodes,
        },
    }


def validate_events(events: List[Dict]) -> List[str]:
    """Schema + referential-integrity check; returns a list of problems
    (empty = valid). Checked: known type, required fields present,
    numeric ``ts``, and every ``parent`` resolving to a ``span`` defined
    somewhere in the trace (two-pass: a claim's children legally appear
    before the claim event itself)."""
    problems: List[str] = []
    spans = {ev["span"] for ev in events if "span" in ev}
    for i, ev in enumerate(events):
        t = ev.get("type")
        if t not in EVENT_SCHEMA:
            problems.append(f"event {i}: unknown type {t!r}")
            continue
        if not isinstance(ev.get("ts"), (int, float)):
            problems.append(f"event {i} ({t}): missing/bad ts")
        for key in EVENT_SCHEMA[t]:
            if key not in ev:
                problems.append(f"event {i} ({t}): missing field {key!r}")
        parent = ev.get("parent")
        if parent is not None and parent not in spans:
            problems.append(
                f"event {i} ({t}): parent span {parent} never defined")
    return problems


def check_causal_chains(events: List[Dict]) -> List[str]:
    """Causal-integrity check for the reclaim and fault chains (empty =
    intact): every ``reclaim_plan`` parents to a ``claim`` span, every
    ``reclaim_step`` to a ``reclaim_plan`` span, every ``slo_recovery``
    to an ``slo_violation`` span, every ``node_repair`` to the
    ``node_fail`` that took the node down, and every ``drain_complete``
    to the ``reclaim_step`` whose drain window it closes."""
    kind_by_span: Dict[int, str] = {}
    for ev in events:
        if "span" in ev:
            kind_by_span[ev["span"]] = ev["type"]
    want_parent = {"reclaim_plan": "claim", "reclaim_step": "reclaim_plan",
                   "slo_recovery": "slo_violation",
                   "node_repair": "node_fail",
                   "drain_complete": "reclaim_step"}
    problems: List[str] = []
    for i, ev in enumerate(events):
        need = want_parent.get(ev.get("type"))
        if need is None:
            continue
        parent = ev.get("parent")
        got = kind_by_span.get(parent)
        if got != need:
            problems.append(
                f"event {i} ({ev['type']}): parent span {parent!r} is "
                f"{got!r}, expected a {need} span")
    return problems


def causality_report(events: List[Dict],
                     tenant: Optional[str] = None) -> Dict:
    """Per-tenant causality report: each forced-reclaim claim with its
    plan, applied drains, and the linked shortfall episode (if any)."""
    plans_by_parent: Dict[int, Dict] = {}
    steps_by_parent: Dict[int, List[Dict]] = {}
    viol_by_parent: Dict[int, Dict] = {}
    recovery_by_parent: Dict[int, Dict] = {}
    for ev in events:
        t = ev.get("type")
        if t == "reclaim_plan":
            plans_by_parent[ev["parent"]] = ev
        elif t == "reclaim_step":
            steps_by_parent.setdefault(ev["parent"], []).append(ev)
        elif t == "slo_violation" and ev.get("parent") is not None:
            viol_by_parent[ev["parent"]] = ev
        elif t == "slo_recovery":
            recovery_by_parent[ev["parent"]] = ev

    chains: List[Dict] = []
    for ev in events:
        if ev.get("type") != "claim" or ev.get("deficit", 0) <= 0:
            continue
        if tenant is not None and ev["tenant"] != tenant:
            continue
        span = ev["span"]
        plan = plans_by_parent.get(span)
        steps = steps_by_parent.get(plan["span"], []) if plan else []
        chain = {
            "ts": ev["ts"], "tenant": ev["tenant"], "span": span,
            "requested": ev["requested"], "from_free": ev["from_free"],
            "granted": ev["granted"], "short": ev["short"],
            "engine": plan["engine"] if plan else None,
            "planned_victims": [s["victim"] for s in plan["steps"]]
            if plan else [],
            "drains": [{"victim": s["tenant"], "released": s["released"],
                        "granted": s["granted"]} for s in steps],
        }
        viol = viol_by_parent.get(span)
        if viol is not None:
            rec = recovery_by_parent.get(viol["span"])
            chain["shortfall_episode"] = {
                "start": viol["ts"],
                "recovered": rec is not None,
                "duration_s": rec["duration_s"] if rec else None,
            }
        chains.append(chain)
    return {"tenant": tenant, "forced_claims": len(chains),
            "chains": chains,
            "broken_chains": check_causal_chains(events)}


def diff_summaries(a: Dict, b: Dict) -> Dict:
    """Structural diff of two ``summarize_events`` outputs (analyzer
    ``diff`` and the ``regress`` gate): event-count deltas per type,
    reclaim-latency and SLO-duration shifts per tenant, spend deltas,
    never-recovered claim counts, and the fault ledger
    (failures/repairs/suppressions/drain deliveries)."""
    def num_delta(x, y):
        return {"a": x, "b": y, "delta": (y or 0) - (x or 0)}

    types = sorted(set(a.get("by_type", {})) | set(b.get("by_type", {})))
    out: Dict = {
        "events": num_delta(a.get("events", 0), b.get("events", 0)),
        "by_type": {t: num_delta(a.get("by_type", {}).get(t, 0),
                                 b.get("by_type", {}).get(t, 0))
                    for t in types},
    }
    la = a.get("reclaim_latency_s", {}).get("overall", {})
    lb = b.get("reclaim_latency_s", {}).get("overall", {})
    out["reclaim_latency_s"] = {
        k: num_delta(la.get(k, 0.0), lb.get(k, 0.0))
        for k in ("n", "p50", "p99", "max")}
    va, vb = a.get("slo_violations", {}), b.get("slo_violations", {})
    out["slo_violations"] = {
        name: {"count": num_delta(va.get(name, {}).get("count", 0),
                                  vb.get(name, {}).get("count", 0)),
               "p99_duration_s": num_delta(
                   va.get(name, {}).get("duration_s", {}).get("p99", 0.0),
                   vb.get(name, {}).get("duration_s", {}).get("p99", 0.0))}
        for name in sorted(set(va) | set(vb))}
    sa, sb = a.get("spend", {}), b.get("spend", {})
    out["spend"] = {
        name: {k: num_delta(sa.get(name, {}).get(k, 0.0),
                            sb.get(name, {}).get(k, 0.0))
               for k in ("idle", "reclaim")}
        for name in sorted(set(sa) | set(sb))}
    ua = a.get("reclaim_latency_s", {}).get("unrecovered", {})
    ub = b.get("reclaim_latency_s", {}).get("unrecovered", {})
    out["unrecovered"] = {
        name: num_delta(ua.get(name, 0), ub.get(name, 0))
        for name in sorted(set(ua) | set(ub))}
    fa, fb = a.get("faults", {}), b.get("faults", {})
    out["faults"] = {
        k: num_delta(fa.get(k, 0), fb.get(k, 0))
        for k in ("failures", "repairs", "unrepaired", "suppressed",
                  "drain_completes", "drained_nodes")}
    causes = sorted(set(fa.get("by_cause", {})) | set(fb.get("by_cause", {})))
    out["faults"]["by_cause"] = {
        c: num_delta(fa.get("by_cause", {}).get(c, 0),
                     fb.get("by_cause", {}).get(c, 0))
        for c in causes}
    return out


# ------------------------------------------------------- Perfetto export


def to_perfetto(events: List[Dict]) -> Dict:
    """Chrome trace-event JSON (loadable in Perfetto / chrome://tracing).

    Mapping: one process (pid 1); one thread per tenant (tid by first
    appearance) plus tid 0 for cluster-level events. Shortfall episodes
    render as duration slices ("X"), everything else as instant events
    ("i"), and ``metrics`` events as counter tracks ("C": free pool and
    per-tenant alloc/demand). Sim seconds map to trace microseconds.
    """
    tids: Dict[str, int] = {}

    def tid(name: Optional[str]) -> int:
        if name is None:
            return 0
        if name not in tids:
            tids[name] = len(tids) + 1
        return tids[name]

    def us(ts: float) -> float:
        return float(ts) * 1e6

    out: List[Dict] = []
    open_viol: Dict[int, Dict] = {}
    last_ts = 0.0
    for ev in events:
        t = ev.get("type")
        ts = float(ev.get("ts", 0.0))
        last_ts = max(last_ts, ts)
        if t in ("trace_header",):
            continue
        if t == "metrics":
            out.append({"ph": "C", "name": "free_nodes", "pid": 1, "tid": 0,
                        "ts": us(ts), "args": {"free": ev["free"]}})
            for name, m in ev["tenants"].items():
                out.append({"ph": "C", "name": f"nodes/{name}", "pid": 1,
                            "tid": 0, "ts": us(ts),
                            "args": {"alloc": m["alloc"],
                                     "demand": m["demand"]}})
                if m.get("spend"):
                    out.append({"ph": "C", "name": f"spend/{name}",
                                "pid": 1, "tid": 0, "ts": us(ts),
                                "args": {"spend": m["spend"]}})
            continue
        if t == "slo_violation":
            open_viol[ev["span"]] = ev
            continue
        if t == "slo_recovery":
            viol = open_viol.pop(ev.get("parent"), None)
            start = float(viol["ts"]) if viol else ts - ev["duration_s"]
            out.append({"ph": "X", "name": "slo_shortfall", "pid": 1,
                        "tid": tid(ev.get("tenant")), "ts": us(start),
                        "dur": us(ts - start),
                        "args": {"shortfall": viol["shortfall"]
                                 if viol else None,
                                 "duration_s": ev["duration_s"]}})
            continue
        args = {k: v for k, v in ev.items() if k not in ("type", "ts")}
        out.append({"ph": "i", "s": "t", "name": t, "pid": 1,
                    "tid": tid(ev.get("tenant")), "ts": us(ts),
                    "args": args})
    # episodes still open at trace end: emit slices to the last timestamp
    for viol in open_viol.values():
        out.append({"ph": "X", "name": "slo_shortfall (open)", "pid": 1,
                    "tid": tid(viol.get("tenant")), "ts": us(viol["ts"]),
                    "dur": us(max(0.0, last_ts - float(viol["ts"]))),
                    "args": {"shortfall": viol["shortfall"]}})
    meta = [{"ph": "M", "name": "process_name", "pid": 1,
             "args": {"name": "phoenix-control-plane"}},
            {"ph": "M", "name": "thread_name", "pid": 1, "tid": 0,
             "args": {"name": "cluster"}}]
    meta.extend({"ph": "M", "name": "thread_name", "pid": 1, "tid": v,
                 "args": {"name": k}} for k, v in sorted(
                     tids.items(), key=lambda kv: kv[1]))
    return {"traceEvents": meta + out, "displayTimeUnit": "ms"}
