"""ST CMS — cloud management service for scientific computing (paper §II).

ST Server resource-management policy (verbatim):
  * passively receives resources provisioned by the Resource Provision Service;
  * on forced return, releases immediately with the demanded size;
  * if idle nodes are insufficient, kills jobs in turn starting from the job
    with MINIMUM SIZE and SHORTEST RUNNING TIME, until enough nodes are free.

``preempt_mode="checkpoint"`` (beyond-paper) checkpoints instead of killing:
the job is requeued with its completed work preserved (plus a checkpoint
overhead), which materially improves the ST benefit curve (EXPERIMENTS.md).

The grant / force-release / node-lost protocol itself lives in
``core/cms.py`` (shared with every other tenant kind); this class supplies
the batch-specific parts: the job queue, the paper's kill order, and the
scheduler hookup.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional

from repro.core.cms import CMSBase
from repro.core.scheduler import SCHEDULERS
from repro.core.types import Job, JobState, SimConfig, TenantSignals


class STServer(CMSBase):
    kind = "batch"

    def __init__(self, cfg: SimConfig,
                 schedule_finish: Callable[[Job, float], None],
                 cancel_finish: Callable[[Job], None]):
        super().__init__()
        self.cfg = cfg
        self.queue: List[Job] = []
        self.running: Dict[int, Job] = {}
        self._schedule_finish = schedule_finish
        self._cancel_finish = cancel_finish
        self.scheduler = SCHEDULERS[cfg.scheduler]
        self.killed: List[Job] = []
        self.preemptions = 0
        self._finish_at: Dict[int, float] = {}

    # ------------------------------------------------------------ capacity
    @property
    def used(self) -> int:
        return sum(j.size for j in self.running.values())

    @property
    def idle(self) -> int:
        return self.alloc - self.used

    def demand_nodes(self) -> int:
        """Declared demand: nodes busy now plus everything queued could use
        (drives demand-aware cooperative policies; the paper's policy
        ignores it)."""
        return self.used + sum(j.size for j in self.queue)

    def preemption_cost_s(self, now: float) -> float:
        """Estimated seconds of work lost per node if one node is reclaimed
        right now: 0 while idle nodes can absorb it; otherwise the paper's
        kill order picks the cheapest running job, whose per-node cost is
        its elapsed work (kill mode) or the checkpoint overhead (checkpoint
        mode). Feeds the ``slo_headroom`` planner's cheapest-first band."""
        if self.idle > 0 or not self.running:
            return 0.0
        v = min(self.running.values(), key=self._kill_key(now))
        if self.cfg.preempt_mode == "checkpoint":
            return self.cfg.checkpoint_cost / max(v.size, 1)
        return max(0.0, now - v.start_time)

    def signals(self, now: float, name: str = "",
                weight: float = 1.0) -> TenantSignals:
        return TenantSignals(
            name=name, kind=self.kind, alloc=self.alloc,
            demand=self.demand_nodes(), weight=weight,
            queue_depth=len(self.queue),
            preemption_cost_s=self.preemption_cost_s(now))

    # ------------------------------------------------------------ events
    def submit(self, job: Job, now: float):
        self.queue.append(job)
        self.try_schedule(now)

    def job_finished(self, job: Job, now: float):
        if job.job_id in self.running:
            del self.running[job.job_id]
            self._finish_at.pop(job.job_id, None)
            job.state = JobState.COMPLETED
            job.end_time = now
            if job in self.queue:
                self.queue.remove(job)
            self.try_schedule(now)

    # ------------------------------------------------------------ scheduling
    def _running_release(self, now: float):
        return sorted((self._finish_at[j.job_id], j.size)
                      for j in self.running.values())

    def try_schedule(self, now: float):
        free = self.idle
        if free <= 0 or not self.queue:
            return
        kw = {}
        if self.cfg.scheduler == "easy_backfill":
            kw["running_release"] = self._running_release(now)
        started = self.scheduler(self.queue, free, now, **kw)
        for job in started:
            self.queue.remove(job)
            job.state = JobState.RUNNING
            job.start_time = now
            self.running[job.job_id] = job
            finish = now + job.remaining()
            self._finish_at[job.job_id] = finish
            self._schedule_finish(job, finish)

    # ------------------------------------------------------------ reclaim
    @staticmethod
    def _kill_key(now: float):
        """The paper's kill order: (size asc, running-time asc). Shared by
        the eviction path and the preemption-cost signal so the cost
        estimate can never drift from the actual eviction order."""
        return lambda j: (j.size, now - j.start_time)

    def _make_available(self, n: int, now: float):
        """Free n nodes: idle first, then kill/preempt jobs in the paper's
        kill order. Eviction may free more than needed; the surplus stays
        idle in ST."""
        still_needed = n - self.idle
        if still_needed > 0:
            victims = sorted(self.running.values(), key=self._kill_key(now))
            got = 0
            for v in victims:
                if got >= still_needed:
                    break
                got += v.size
                self._evict(v, now)

    def _after_change(self, now: float):
        self.try_schedule(now)

    def release_idle(self, n: int) -> int:
        """Voluntarily give back up to n idle nodes (demand-aware policies);
        returns the count actually freed. Never touches running jobs."""
        n = max(0, min(n, self.idle))
        self.alloc -= n
        return n

    def _evict(self, job: Job, now: float):
        self._cancel_finish(job)
        del self.running[job.job_id]
        self._finish_at.pop(job.job_id, None)
        job.kills += 1
        if self.cfg.preempt_mode == "checkpoint":
            elapsed = now - job.start_time
            job.checkpointed_work = min(
                job.runtime,
                job.checkpointed_work + max(0.0, elapsed
                                            - self.cfg.checkpoint_cost))
            job.state = JobState.QUEUED
            job.start_time = None
            self.preemptions += 1
            self.queue.insert(0, job)       # resume first (it lost its slot)
        else:
            job.state = JobState.KILLED
            job.end_time = now
            self.killed.append(job)
