"""ST CMS — cloud management service for scientific computing (paper §II).

ST Server resource-management policy (verbatim):
  * passively receives resources provisioned by the Resource Provision Service;
  * on forced return, releases immediately with the demanded size;
  * if idle nodes are insufficient, kills jobs in turn starting from the job
    with MINIMUM SIZE and SHORTEST RUNNING TIME, until enough nodes are free.

``preempt_mode="checkpoint"`` (beyond-paper) checkpoints instead of killing:
the job is requeued with its completed work preserved (plus a checkpoint
overhead), which materially improves the ST benefit curve (EXPERIMENTS.md).
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional

from repro.core.scheduler import SCHEDULERS
from repro.core.types import Job, JobState, SimConfig


class STServer:
    def __init__(self, cfg: SimConfig,
                 schedule_finish: Callable[[Job, float], None],
                 cancel_finish: Callable[[Job], None]):
        self.cfg = cfg
        self.alloc = 0                 # nodes currently provisioned to ST
        self.queue: List[Job] = []
        self.running: Dict[int, Job] = {}
        self._schedule_finish = schedule_finish
        self._cancel_finish = cancel_finish
        self.scheduler = SCHEDULERS[cfg.scheduler]
        self.killed: List[Job] = []
        self.preemptions = 0
        self._finish_at: Dict[int, float] = {}

    # ------------------------------------------------------------ capacity
    @property
    def used(self) -> int:
        return sum(j.size for j in self.running.values())

    @property
    def idle(self) -> int:
        return self.alloc - self.used

    # ------------------------------------------------------------ events
    def submit(self, job: Job, now: float):
        self.queue.append(job)
        self.try_schedule(now)

    def grant(self, n: int, now: float):
        """Resource Provision Service pushes n nodes (passive receipt)."""
        self.alloc += n
        self.try_schedule(now)

    def job_finished(self, job: Job, now: float):
        if job.job_id in self.running:
            del self.running[job.job_id]
            self._finish_at.pop(job.job_id, None)
            job.state = JobState.COMPLETED
            job.end_time = now
            if job in self.queue:
                self.queue.remove(job)
            self.try_schedule(now)

    # ------------------------------------------------------------ scheduling
    def _running_release(self, now: float):
        return sorted((self._finish_at[j.job_id], j.size)
                      for j in self.running.values())

    def try_schedule(self, now: float):
        free = self.idle
        if free <= 0 or not self.queue:
            return
        kw = {}
        if self.cfg.scheduler == "easy_backfill":
            kw["running_release"] = self._running_release(now)
        started = self.scheduler(self.queue, free, now, **kw)
        for job in started:
            self.queue.remove(job)
            job.state = JobState.RUNNING
            job.start_time = now
            self.running[job.job_id] = job
            finish = now + job.remaining()
            self._finish_at[job.job_id] = finish
            self._schedule_finish(job, finish)

    # ------------------------------------------------------------ reclaim
    def force_release(self, n: int, now: float) -> int:
        """Forced reclaim of n nodes (provision policy rule 3).

        Frees idle nodes first, then kills/preempts jobs ordered by
        (size asc, running-time asc) — the paper's kill order. Returns the
        number of nodes actually released (== n unless alloc < n).
        """
        release = min(n, self.alloc)
        freed = min(self.idle, release)
        still_needed = release - freed
        if still_needed > 0:
            victims = sorted(self.running.values(),
                             key=lambda j: (j.size, now - j.start_time))
            got = 0
            for v in victims:
                if got >= still_needed:
                    break
                got += v.size
                self._evict(v, now)
            # eviction may free more than needed; the surplus stays idle in ST
        self.alloc -= release
        self.try_schedule(now)
        return release

    def node_lost(self, now: float):
        """A provisioned node died (fault injection / runtime failure).

        The loss goes through the server's own grant/release bookkeeping —
        never decrement ``alloc`` from outside — so the provision service's
        ``st_alloc`` and this counter cannot diverge. Idle nodes absorb the
        loss first; only if every allocated node is busy does a job get
        evicted (kill or checkpoint per ``preempt_mode``).
        """
        if self.alloc <= 0:
            return
        if self.idle <= 0 and self.running:
            victim = min(self.running.values(),
                         key=lambda j: (j.size, now - j.start_time))
            self._evict(victim, now)
        self.alloc -= 1
        self.try_schedule(now)

    def _evict(self, job: Job, now: float):
        self._cancel_finish(job)
        del self.running[job.job_id]
        self._finish_at.pop(job.job_id, None)
        job.kills += 1
        if self.cfg.preempt_mode == "checkpoint":
            elapsed = now - job.start_time
            job.checkpointed_work = min(
                job.runtime,
                job.checkpointed_work + max(0.0, elapsed
                                            - self.cfg.checkpoint_cost))
            job.state = JobState.QUEUED
            job.start_time = None
            self.preemptions += 1
            self.queue.insert(0, job)       # resume first (it lost its slot)
        else:
            job.state = JobState.KILLED
            job.end_time = now
            self.killed.append(job)
