"""Shared CMS protocol base (paper §II).

Every department's cloud-management service — the ST batch scheduler, the WS
replica manager, any future tenant kind — speaks the same three-verb
protocol to the Resource Provision Service:

  * ``grant(n, now)``          — passively receive n nodes;
  * ``force_release(n, now)``  — give up n nodes NOW (urgent reclaim by a
    higher-priority tenant); returns the count actually released;
  * ``node_lost(now)``         — one provisioned node died;
  * ``signals(now, ...)``      — a ``TenantSignals`` snapshot (latency
    headroom, queue depth, preemption cost) for phase-1 reclaim planners;
    the policy layer derives per-interval bids from it (``compute_bid`` /
    ``unit_bid`` in core/policies.py — linear, or slo_elastic where the
    bid rises as the reported latency headroom shrinks, which is why the
    WS proxy headroom is clamped at zero when no real latency feed is
    wired).

``CMSBase`` owns the ``alloc`` bookkeeping and the release skeleton; the
concrete CMS only says how to *make nodes available* (ST: free idle first,
then kill/preempt jobs in the paper's order; WS: replicas are fungible, so
just account the shortfall) and what to do *after* an allocation change
(ST: try to schedule; WS: log the realized-allocation timeline). Keeping the
skeleton here means every tenant kind inherits the same can't-desync
property: ``alloc`` only ever moves inside these verbs, in lockstep with the
provision service's per-tenant record.
"""
from __future__ import annotations

from repro.core.types import TenantSignals


def proxy_headroom_s(alloc: int, demand: int, target_s: float) -> float:
    """Latency-headroom proxy for a tenant WITHOUT a real latency feed:
    spare replicas scale the SLO target positively; a replica shortfall is
    NOT yet a measured violation, so the proxy clamps at zero (a negative
    prediction would inflate slo_elastic bids while the shortfall is
    already reported through ``queue_depth``/``unmet``). Shared by the
    simulator's WS CMS and the runtime orchestrator so their bids can
    never diverge."""
    surplus = max(0, alloc - demand)
    if target_s <= 0.0:
        return float(surplus)
    return target_s * surplus / max(demand, 1)


class CMSBase:
    """Common grant / force-release / node-lost protocol of a tenant CMS."""

    kind: str = "batch"

    def __init__(self):
        self.alloc = 0                 # nodes currently provisioned to us

    # ------------------------------------------------------------- hooks
    def _before_change(self, now: float):
        """Runs before ``alloc`` moves (accounting cut-off point)."""

    def _make_available(self, n: int, now: float):
        """Ensure n of our nodes hold no work (evict/stop as needed)."""

    def _after_change(self, now: float):
        """Runs after ``alloc`` moved (reschedule, timeline logging)."""

    def demand_nodes(self) -> int:
        """How many nodes this CMS could currently use (declared demand)."""
        return 0

    def signals(self, now: float, name: str = "",
                weight: float = 1.0) -> TenantSignals:
        """Runtime snapshot for reclaim planners (subclasses enrich it with
        headroom / queue depth / preemption cost)."""
        return TenantSignals(name=name, kind=self.kind, alloc=self.alloc,
                             demand=self.demand_nodes(), weight=weight)

    # ---------------------------------------------------------- protocol
    def grant(self, n: int, now: float):
        """Resource Provision Service pushes n nodes (passive receipt)."""
        self._before_change(now)
        self.alloc += n
        self._after_change(now)

    def force_release(self, n: int, now: float) -> int:
        """Forced reclaim of n nodes (provision policy rule 3). Returns the
        number actually released (== n unless alloc < n)."""
        release = min(n, self.alloc)
        if release <= 0:
            return 0
        self._before_change(now)
        self._make_available(release, now)
        self.alloc -= release
        self._after_change(now)
        return release

    def node_lost(self, now: float):
        """A provisioned node died (fault injection / runtime failure).

        The loss goes through the CMS's own bookkeeping — never decrement
        ``alloc`` from outside — so the provision service's per-tenant
        record and this counter cannot diverge.
        """
        if self.alloc <= 0:
            return
        self._before_change(now)
        self._make_available(1, now)
        self.alloc -= 1
        self._after_change(now)
