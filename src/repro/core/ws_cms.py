"""WS CMS — cloud management service for Web services (paper §II/§III-C).

WS Server resource-management policy (verbatim): release idle nodes to the
Resource Provision Service immediately; request more when needed.

The instance autoscaler implements the paper's §III-C rule: with n current
instances, +1 instance if avg CPU utilization > 80% over the past 20 s,
-1 instance if it drops below 80%·(n-1)/n, floor n = 1. ``demand_from_load``
turns a request-rate trace into the instance-demand curve of Fig. 5; the
same rule drives real serving replicas in ``runtime/serving_pool.py``.

The grant / force-release / node-lost protocol lives in ``core/cms.py``;
this class adds the latency-tenant specifics: demand tracking against the
provision service, shortfall accounting, and the realized-allocation log.
"""
from __future__ import annotations

from typing import Callable, List, Optional, Tuple

import numpy as np

from repro.core.cms import CMSBase, proxy_headroom_s
from repro.core.types import SimConfig, SLOConfig, TenantSignals

UTIL_WINDOW_S = 20.0
UTIL_UP = 0.80


def demand_from_load(load: np.ndarray, dt: float,
                     capacity_per_instance: float,
                     n0: int = 1, n_max: int = 10_000) -> np.ndarray:
    """Apply the paper's autoscaling rule to a request-rate trace.

    load[t]: requests/s sampled every `dt` seconds. An instance saturates at
    `capacity_per_instance` req/s (util = served_load / (n * capacity)).
    Decisions are taken every UTIL_WINDOW_S using the window-average util.
    Returns the instance-demand curve (same sampling as `load`).
    """
    steps_per_win = max(1, int(round(UTIL_WINDOW_S / dt)))
    n = n0
    out = np.empty(len(load), dtype=np.int64)
    acc, cnt = 0.0, 0
    for i, lam in enumerate(load):
        util = min(lam / (n * capacity_per_instance), 1.5)
        acc += util
        cnt += 1
        if cnt >= steps_per_win:
            avg = acc / cnt
            if avg > UTIL_UP and n < n_max:
                n += 1
            elif n > 1 and avg < UTIL_UP * (n - 1) / n:
                n -= 1
            acc, cnt = 0.0, 0
        out[i] = n
    return out


def resolve_demand_events(ws_demand, horizon: float):
    """Accept either a raw [(t, n), ...] timeseries or a WSDemandProvider.

    Returns (events, provider) — provider is None for plain timeseries.
    """
    if hasattr(ws_demand, "demand_events"):
        return list(ws_demand.demand_events(horizon)), ws_demand
    return list(ws_demand), None


def demand_events(demand: np.ndarray, dt: float) -> List[Tuple[float, int]]:
    """Compress a sampled demand curve into (time, new_level) change events."""
    ev: List[Tuple[float, int]] = [(0.0, int(demand[0]))]
    for i in range(1, len(demand)):
        if demand[i] != demand[i - 1]:
            ev.append((i * dt, int(demand[i])))
    return ev


class WSServer(CMSBase):
    """Tracks instance demand vs allocation; talks to the provision service."""

    kind = "latency"

    def __init__(self, cfg: SimConfig,
                 request: Callable[[int], int],
                 release: Callable[[int], None],
                 slo: Optional[SLOConfig] = None):
        super().__init__()
        self.cfg = cfg
        self.demand = 0
        self._request = request
        self._release = release
        self.slo = slo
        # most recent latency observation (runtime feeds real serving-pool
        # percentiles through observe_latency; the simulator leaves it None
        # and signals() falls back to an allocation-surplus proxy)
        self.observed_latency_s: Optional[float] = None
        # diagnostics
        self.unmet_node_seconds = 0.0
        self.reclaim_events = 0
        self.preempted_nodes = 0       # nodes lost to higher-priority claims
        self._last_t = 0.0
        # realized-allocation change log: (time, alloc) whenever alloc moves.
        # Request-level workloads replay this through the queue simulator to
        # measure the latency the WS department actually experienced.
        self.alloc_events: List[Tuple[float, int]] = [(0.0, 0)]

    def demand_nodes(self) -> int:
        return self.demand

    # -------------------------------------------------------------- signals
    def observe_latency(self, latency_s: float):
        """Feed a measured/predicted latency percentile (runtime path)."""
        self.observed_latency_s = latency_s

    def latency_headroom_s(self) -> float:
        """Seconds of slack to the SLO target. With a real observation this
        is ``target - observed`` (negative = measured violation); otherwise
        the shared zero-clamped surplus proxy (``cms.proxy_headroom_s`` —
        an unclamped negative prediction made slo_elastic bids overshoot;
        the shortfall already drives ``queue_depth``/``unmet``, so it must
        not be double-counted as urgency)."""
        target = self.slo.latency_target_s if self.slo else 0.0
        if self.observed_latency_s is not None:
            return target - self.observed_latency_s
        return proxy_headroom_s(self.alloc, self.demand, target)

    def signals(self, now: float, name: str = "",
                weight: float = 1.0) -> TenantSignals:
        return TenantSignals(
            name=name, kind=self.kind, alloc=self.alloc, demand=self.demand,
            weight=weight,
            latency_headroom_s=self.latency_headroom_s(),
            slo_target_s=self.slo.latency_target_s if self.slo else 0.0,
            queue_depth=max(0, self.demand - self.alloc))

    def _log_alloc(self, now: float):
        if self.alloc_events[-1][1] != self.alloc:
            self.alloc_events.append((now, self.alloc))

    def _account(self, now: float):
        short = max(0, self.demand - self.alloc)
        self.unmet_node_seconds += short * (now - self._last_t)
        self._last_t = now

    # ------------------------------------------- CMS protocol (core/cms.py)
    def _before_change(self, now: float):
        self._account(now)

    def _after_change(self, now: float):
        self._log_alloc(now)

    def force_release(self, n: int, now: float) -> int:
        """A higher-priority tenant preempts n of our nodes. Replicas are
        fungible, so no per-node work is lost beyond the in-flight requests
        the queue simulator will re-run; the shortfall shows up in
        ``unmet_node_seconds`` until demand is next re-claimed."""
        got = super().force_release(n, now)
        self.preempted_nodes += got
        return got

    # ---------------------------------------------------- demand tracking
    def set_demand(self, n: int, now: float):
        self._account(now)
        self.demand = n
        if n > self.alloc:
            need = n - self.alloc
            granted = self._request(need)
            if granted < need:
                pass  # shortfall tracked by _account on the next event
            if granted > 0:
                self.reclaim_events += 1
            self.alloc += granted
        elif n < self.alloc:
            # release idle nodes immediately (paper's WS policy)
            give = self.alloc - n
            self.alloc -= give
            self._release(give)
        self._log_alloc(now)
