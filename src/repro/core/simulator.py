"""Discrete-event consolidation simulator (paper §III-D).

Wires ResourceProvisionService + ST CMS + WS CMS over a virtual-time event
queue. Exact event ordering in virtual seconds — the paper's 100x wall-clock
acceleration is irrelevant here (no wall-clock dependence at all).

Supports the paper's experiment (kill-mode, first-fit, SC vs DC) plus the
beyond-paper knobs in ``SimConfig``: checkpoint-preemption, EASY backfill,
node failures/repairs, stragglers with speculative relaunch.
"""
from __future__ import annotations

import dataclasses
import heapq
import random
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.provision import ResourceProvisionService
from repro.core.st_cms import STServer
from repro.core.types import Event, EventKind, Job, JobState, SimConfig
from repro.core.ws_cms import WSServer, resolve_demand_events


@dataclass
class SimResult:
    total_nodes: int
    submitted: int
    completed: int
    killed: int
    preemptions: int
    avg_turnaround: float
    median_turnaround: float
    ws_unmet_node_seconds: float
    ws_reclaim_events: int
    st_node_seconds_used: float
    st_avg_alloc: float
    ws_avg_alloc: float
    util_timeline: List[Tuple[float, int, int, int]] = field(repr=False,
                                                             default_factory=list)
    # request-level WS metrics (only when ws_demand is a WSDemandProvider
    # with realized_metrics): p50/p95/p99 latency, violation rate, ...
    ws_latency: Optional[Dict[str, float]] = None

    @property
    def benefit_provider(self) -> int:
        """Paper §III-A: ST provider benefit = completed jobs."""
        return self.completed

    @property
    def benefit_user(self) -> float:
        """Paper §III-A: end-user benefit = 1 / avg turnaround."""
        return 1.0 / self.avg_turnaround if self.avg_turnaround > 0 else 0.0


class ConsolidationSim:
    def __init__(self, cfg: SimConfig, jobs: List[Job],
                 ws_demand, horizon: float):
        """ws_demand: [(t, n), ...] node-demand events OR a
        ``WSDemandProvider`` (e.g. ``workloads.RequestWorkload``), in which
        case demand comes from its SLO autoscaler and request-level latency
        metrics are attached to the result."""
        self.cfg = cfg
        self.jobs = [dataclasses.replace(j) for j in jobs]
        self.ws_demand, self.ws_provider = \
            resolve_demand_events(ws_demand, horizon)
        self.horizon = horizon
        self.now = 0.0
        self.rng = random.Random(cfg.seed)
        self._q: List[Event] = []
        self._seq = 0
        self._job_epoch: Dict[int, int] = {}

        self.rps = ResourceProvisionService(cfg.total_nodes)
        self.st = STServer(cfg, self._schedule_finish, self._cancel_finish)
        self.ws = WSServer(cfg, self._ws_request, self._ws_release)
        self.rps.on_grant_st = lambda n: self.st.grant(n, self.now)
        self.rps.force_st_release = \
            lambda n: self.st.force_release(n, self.now)

        # timeline accounting
        self._last_t = 0.0
        self._st_node_seconds = 0.0
        self._st_alloc_seconds = 0.0
        self._ws_alloc_seconds = 0.0
        self.timeline: List[Tuple[float, int, int, int]] = []

    # --------------------------------------------------------------- events
    def _push(self, t: float, kind: EventKind, payload=None):
        self._seq += 1
        heapq.heappush(self._q, Event(t, self._seq, kind, payload))

    def _schedule_finish(self, job: Job, t: float):
        epoch = self._job_epoch.get(job.job_id, 0) + 1
        self._job_epoch[job.job_id] = epoch
        t_eff = t
        if self.cfg.straggler_frac > 0 and \
                self.rng.random() < self.cfg.straggler_frac:
            slow = t + (self.cfg.straggler_slowdown - 1.0) * job.remaining()
            if self.cfg.speculative_relaunch:
                # detect at 1.2x nominal, relaunch a copy: finishes at
                # detection + fresh remaining work
                spec = self.now + 1.2 * job.remaining() + job.remaining()
                t_eff = min(slow, spec)
            else:
                t_eff = slow
        self._push(t_eff, EventKind.JOB_FINISH, (job, epoch))

    def _cancel_finish(self, job: Job):
        self._job_epoch[job.job_id] = self._job_epoch.get(job.job_id, 0) + 1

    # ------------------------------------------------------------- WS wiring
    def _ws_request(self, n: int) -> int:
        return self.rps.ws_request(n)

    def _ws_release(self, n: int):
        self.rps.ws_release(n)

    # ---------------------------------------------------------- accounting
    def _account(self, t: float):
        dt = t - self._last_t
        if dt > 0:
            self._st_node_seconds += self.st.used * dt
            self._st_alloc_seconds += self.st.alloc * dt
            self._ws_alloc_seconds += self.ws.alloc * dt
            self._last_t = t

    # ---------------------------------------------------------------- run
    def run(self) -> SimResult:
        for job in self.jobs:
            self._push(job.submit_time, EventKind.JOB_SUBMIT, job)
        for t, n in self.ws_demand:
            self._push(t, EventKind.WS_DEMAND, n)
        if self.cfg.node_mtbf > 0:
            self._push(self.rng.expovariate(
                self.cfg.total_nodes / self.cfg.node_mtbf),
                EventKind.NODE_FAIL)

        # initial provision: everything idle goes to ST
        self.rps.provision_idle_to_st()

        while self._q:
            ev = heapq.heappop(self._q)
            if ev.time > self.horizon:
                break
            self._account(ev.time)
            self.now = ev.time
            if ev.kind is EventKind.JOB_SUBMIT:
                self.st.submit(ev.payload, self.now)
            elif ev.kind is EventKind.JOB_FINISH:
                job, epoch = ev.payload
                if self._job_epoch.get(job.job_id) == epoch and \
                        job.state is JobState.RUNNING:
                    self.st.job_finished(job, self.now)
            elif ev.kind is EventKind.WS_DEMAND:
                self.ws.set_demand(ev.payload, self.now)
            elif ev.kind is EventKind.NODE_FAIL:
                self._node_fail()
                self._push(self.now + self.rng.expovariate(
                    self.cfg.total_nodes / self.cfg.node_mtbf),
                    EventKind.NODE_FAIL)
            elif ev.kind is EventKind.NODE_REPAIR:
                self.rps.node_repaired()
            self.timeline.append((self.now, self.st.alloc, self.ws.alloc,
                                  self.rps.free))
        self._account(self.horizon)
        res = self._result()
        if self.ws_provider is not None and \
                hasattr(self.ws_provider, "realized_metrics"):
            res.ws_latency = self.ws_provider.realized_metrics(
                self.ws.alloc_events, horizon=self.horizon)
        return res

    def _node_fail(self):
        total_alloc = self.rps.free + self.rps.st_alloc + self.rps.ws_alloc
        if total_alloc <= 1:
            return
        r = self.rng.random() * total_alloc
        if r < self.rps.free:
            self.rps.node_failed("free")
        elif r < self.rps.free + self.rps.st_alloc:
            # an ST node dies: route the loss through the ST server's own
            # eviction path so st.alloc and rps.st_alloc cannot diverge
            # (idle nodes absorb the loss before any job is evicted)
            self.st.node_lost(self.now)
            self.rps.node_failed("st")
        else:
            self.ws.node_lost(self.now)
            self.rps.node_failed("ws")
            # WS immediately re-requests to cover its demand
            self.ws.set_demand(self.ws.demand, self.now)
        self._push(self.now + self.cfg.node_repair_time, EventKind.NODE_REPAIR)

    def _result(self) -> SimResult:
        completed = [j for j in self.jobs if j.state is JobState.COMPLETED]
        killed = [j for j in self.jobs if j.state is JobState.KILLED]
        tats = sorted(j.turnaround for j in completed)
        horizon = self.horizon
        return SimResult(
            total_nodes=self.cfg.total_nodes,
            submitted=len(self.jobs),
            completed=len(completed),
            killed=len(killed),
            preemptions=self.st.preemptions,
            avg_turnaround=float(np.mean(tats)) if tats else 0.0,
            median_turnaround=float(np.median(tats)) if tats else 0.0,
            ws_unmet_node_seconds=self.ws.unmet_node_seconds,
            ws_reclaim_events=self.ws.reclaim_events,
            st_node_seconds_used=self._st_node_seconds,
            st_avg_alloc=self._st_alloc_seconds / horizon,
            ws_avg_alloc=self._ws_alloc_seconds / horizon,
            util_timeline=self.timeline[-2000:],
        )
