"""Discrete-event consolidation simulator (paper §III-D), N-department.

Wires a tenant-registry provision service (core/provision.py) + one CMS per
department over a virtual-time event queue. Exact event ordering in virtual
seconds — the paper's 100x wall-clock acceleration is irrelevant here (no
wall-clock dependence at all).

The paper's experiment is the degenerate 2-department case (one ST batch
department + one WS latency department under the ``"paper"`` policy) and is
what the legacy ``ConsolidationSim(cfg, jobs, ws_demand, horizon)`` call
builds — bit-for-bit identical to the seed simulator (the regression test
in tests/test_tenancy.py pins its numbers). Passing ``tenants=[TenantSpec,
...]`` instead runs any department mix — e.g. 2 HPC + 2 request-level WS +
1 best-effort batch tenant — under any cooperative policy from
core/policies.py, with per-department accounting in ``SimResult.tenants``.

Supports the paper's experiment (kill-mode, first-fit, SC vs DC) plus the
beyond-paper knobs in ``SimConfig``: checkpoint-preemption, EASY backfill,
node failures/repairs, stragglers with speculative relaunch.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
import random
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.faults import FaultSpec, make_injector
from repro.core.nodes import DRAIN_POOL, NodeInventory
from repro.core.provision import (ResourceProvisionService,
                                  TenantProvisionService)
from repro.core.st_cms import STServer
from repro.core.telemetry import NULL_TRACER, Tracer
from repro.core.types import (Event, EventKind, Job, JobState, SimConfig,
                              TenantSpec)
from repro.core.ws_cms import WSServer, resolve_demand_events

# util_timeline rows beyond this are stride-downsampled (never truncated:
# long-horizon runs keep early history at reduced resolution)
TIMELINE_MAX_POINTS = 2000


def downsample_timeline(timeline: List[tuple],
                        max_points: int = TIMELINE_MAX_POINTS) -> List[tuple]:
    """Stride-based downsampling preserving first and last rows."""
    n = len(timeline)
    if n <= max_points:
        return list(timeline)
    stride = math.ceil(n / max_points)
    out = list(timeline[::stride])
    if out[-1] != timeline[-1]:
        out.append(timeline[-1])
    return out


@dataclass
class TenantResult:
    """Per-department outcome of one consolidation run."""
    name: str
    kind: str                         # "batch" | "latency"
    priority: int
    avg_alloc: float = 0.0
    # batch departments
    submitted: int = 0
    completed: int = 0
    killed: int = 0
    preemptions: int = 0
    avg_turnaround: float = 0.0
    median_turnaround: float = 0.0
    node_seconds_used: float = 0.0
    # latency departments
    unmet_node_seconds: float = 0.0
    reclaim_events: int = 0
    preempted_nodes: int = 0
    latency: Optional[Dict[str, float]] = None
    # two-phase engine accounting: how often / how many nodes the reclaim
    # planner drained FROM this department, and its last auction bid
    reclaimed_events: int = 0
    reclaimed_nodes: int = 0
    last_bid: float = 0.0
    # market engine accounting: tokens spent over the run and what is left
    # of the declared budget (None = unlimited or no market engine)
    spend: float = 0.0
    budget_remaining: Optional[float] = None

    @property
    def benefit(self) -> Dict[str, float]:
        """Paper §III-A benefit metrics, per department.

        Batch: provider benefit = completed jobs, user benefit = 1/avg
        turnaround. Latency: demand coverage (plus SLO attainment when the
        demand source is request-level)."""
        if self.kind == "batch":
            return {
                "provider_completed_jobs": float(self.completed),
                "user_inv_turnaround":
                    1.0 / self.avg_turnaround if self.avg_turnaround > 0
                    else 0.0,
            }
        out = {"unmet_node_seconds": self.unmet_node_seconds,
               "demand_met": 1.0 if self.unmet_node_seconds == 0.0 else 0.0}
        if self.latency:
            out["p99_s"] = float(self.latency.get("p99_s", 0.0))
            out["violation_rate"] = \
                float(self.latency.get("violation_rate", 0.0))
            out["slo_met"] = float(bool(self.latency.get("slo_met", False)))
        return out


@dataclass
class SimResult:
    total_nodes: int
    submitted: int
    completed: int
    killed: int
    preemptions: int
    avg_turnaround: float
    median_turnaround: float
    ws_unmet_node_seconds: float
    ws_reclaim_events: int
    st_node_seconds_used: float
    st_avg_alloc: float
    ws_avg_alloc: float
    util_timeline: List[Tuple[float, ...]] = field(repr=False,
                                                   default_factory=list)
    # request-level WS metrics (only when ws_demand is a WSDemandProvider
    # with realized_metrics): p50/p95/p99 latency, violation rate, ...
    ws_latency: Optional[Dict[str, float]] = None
    # N-department accounting: one TenantResult per registered department
    # (the legacy scalar fields above are the batch/latency aggregates)
    tenants: Dict[str, TenantResult] = field(default_factory=dict)
    policy: str = "paper"
    # engine state snapshot: reclaim plans made, per-victim drain counts,
    # and (auction) per-interval clearing prices
    policy_state: Dict = field(default_factory=dict)

    @property
    def benefit_provider(self) -> int:
        """Paper §III-A: ST provider benefit = completed jobs."""
        return self.completed

    @property
    def benefit_user(self) -> float:
        """Paper §III-A: end-user benefit = 1 / avg turnaround."""
        return 1.0 / self.avg_turnaround if self.avg_turnaround > 0 else 0.0

    def benefits(self) -> Dict[str, Dict[str, float]]:
        """Per-department benefit metrics (paper §III-A generalized)."""
        return {name: t.benefit for name, t in self.tenants.items()}


class _TenantRuntime:
    """One department wired into the simulator: spec + CMS + accounting."""

    def __init__(self, spec: TenantSpec):
        self.spec = spec
        self.server = None             # STServer | WSServer
        self.record = None             # Tenant record inside the service
        self.jobs: List[Job] = []      # batch: this department's job copies
        self.demand: List[Tuple[float, int]] = []     # latency: events
        self.provider = None           # latency: WSDemandProvider or None
        self.alloc_seconds = 0.0
        self.used_seconds = 0.0

    @property
    def name(self) -> str:
        return self.spec.name

    @property
    def is_batch(self) -> bool:
        return self.spec.kind == "batch"


class ConsolidationSim:
    def __init__(self, cfg: SimConfig, jobs: Optional[List[Job]] = None,
                 ws_demand=None, horizon: float = 0.0, *,
                 tenants: Optional[Sequence[TenantSpec]] = None,
                 policy=None, tracer: Optional[Tracer] = None,
                 defer_queue: bool = False):
        """Two calling conventions:

        * legacy / paper (degenerate 2-department): ``ConsolidationSim(cfg,
          jobs, ws_demand, horizon)``. ws_demand: [(t, n), ...] node-demand
          events OR a ``WSDemandProvider`` (e.g. ``workloads.
          RequestWorkload``), in which case demand comes from its SLO
          autoscaler and request-level latency metrics are attached.
        * N-department: ``ConsolidationSim(cfg, horizon=..., tenants=[...],
          policy="paper"|"demand_capped"|"proportional_share"|instance)``.
          Each batch spec carries a job trace; each latency spec a demand
          timeseries or provider.

        ``defer_queue=True`` skips the per-tenant request-queue simulation
        in the results: each would-be ``realized_metrics`` call is recorded
        in ``self.deferred_queue`` as ``(tenant_name, provider,
        alloc_events)`` and the tenant's ``latency`` stays None, so a
        caller owning many sims can dispatch every queue as one batched
        device program (see ``workloads.campaign``). Queue metrics never
        feed back into the consolidation dynamics, so deferral changes
        nothing else about the run.
        """
        self.cfg = cfg
        self.defer_queue = defer_queue
        self.deferred_queue: List[Tuple[str, object, list]] = []
        self.horizon = horizon
        self.now = 0.0
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.rng = random.Random(cfg.seed)
        self._q: List[Event] = []
        self._seq = 0
        self._job_epoch: Dict[Tuple[str, int], int] = {}

        self._degenerate = tenants is None
        if self._degenerate:
            # the paper's fixed wiring; registration order (st, ws) is part
            # of the reproducibility contract (failure attribution order,
            # timeline columns)
            tenants = [
                TenantSpec("st", "batch", priority=1,
                           jobs=list(jobs) if jobs is not None else []),
                TenantSpec("ws", "latency", priority=0,
                           demand=[] if ws_demand is None else ws_demand),
            ]
            assert policy is None or str(getattr(
                policy, "name", policy)) == "paper", \
                "the legacy 2-tenant call runs the paper policy; pass " \
                "tenants=[...] to choose another"
            policy = "paper"
        else:
            assert jobs is None and ws_demand is None, \
                "pass demand sources inside TenantSpec when using tenants=[]"
            policy = policy if policy is not None else "paper"
        names = [s.name for s in tenants]
        assert len(set(names)) == len(names), f"duplicate tenants: {names}"

        if self._degenerate:
            self.svc: TenantProvisionService = \
                ResourceProvisionService(cfg.total_nodes,
                                         tracer=self.tracer)
        else:
            self.svc = TenantProvisionService(cfg.total_nodes, policy=policy,
                                              tracer=self.tracer)
        self.rps = self.svc            # legacy attribute name
        self.policy_name = self.svc.policy.name
        self._demand_driven = self.svc.policy.demand_driven

        # fault-injection wiring: a FaultSpec supersedes the legacy
        # node_mtbf knob; it brings the identified-node inventory (and
        # with it per-node lifecycle telemetry + failure domains)
        spec_f: Optional[FaultSpec] = cfg.faults
        self.inventory: Optional[NodeInventory] = None
        self._injector = None
        if spec_f is not None:
            self.inventory = NodeInventory(cfg.total_nodes,
                                           rack_size=spec_f.rack_size,
                                           tracer=self.tracer)
            self.svc.attach_inventory(self.inventory)
            self._injector = make_injector(spec_f, cfg.seed,
                                           sim_rng=self.rng)
        # reclaim drain windows (SimConfig.drain_time_s or the profile's):
        # the service schedules DRAIN_DONE through our event queue
        drain_s = max(cfg.drain_time_s,
                      spec_f.drain_time_s if spec_f is not None else 0.0)
        if drain_s > 0:
            self.svc.configure_drain(
                drain_s,
                lambda dt, fn: self._push(self.now + dt,
                                          EventKind.DRAIN_DONE, fn))

        if self.tracer.enabled:
            self.tracer.meta.setdefault("policy", self.policy_name)
            self.tracer.meta.setdefault("total_nodes", cfg.total_nodes)
            self.tracer.meta.setdefault("horizon", horizon)
            self.tracer.meta.setdefault("seed", cfg.seed)
            if spec_f is not None:
                self.tracer.meta.setdefault("fault_profile", spec_f.profile)
        # open SLO-shortfall episodes: tenant -> (violation span, start ts)
        self._episodes: Dict[str, Tuple[int, float]] = {}
        self._next_sample = 0.0

        self._runtimes: List[_TenantRuntime] = []
        for spec in tenants:
            rt = _TenantRuntime(spec)
            if spec.kind == "batch":
                rt.jobs = [dataclasses.replace(j) for j in (spec.jobs or [])]
                rt.server = STServer(
                    cfg,
                    (lambda job, t, rt=rt: self._schedule_finish(rt, job, t)),
                    (lambda job, rt=rt: self._cancel_finish(rt, job)))
                on_grant = (lambda n, s=rt.server: s.grant(n, self.now))
                on_force = (lambda n, s=rt.server:
                            s.force_release(n, self.now))
            else:
                rt.demand, rt.provider = \
                    resolve_demand_events(spec.demand or [], horizon)
                rt.server = WSServer(
                    cfg,
                    request=(lambda n, name=spec.name:
                             self.svc.claim(name, n)),
                    release=(lambda n, name=spec.name:
                             self.svc.release(name, n)),
                    slo=spec.slo)
                # deferred drain-window deliveries land via on_grant
                # (plain claims credit synchronously through the claim()
                # return value, so this only fires when drains are active)
                on_grant = (lambda n, s=rt.server: s.grant(n, self.now))
                on_force = (lambda n, s=rt.server:
                            s.force_release(n, self.now))
            if spec.name in self.svc.tenants:   # degenerate: pre-registered
                rt.record = self.svc.tenants[spec.name]
                rt.record.on_grant = on_grant
                rt.record.on_force_release = on_force
                rt.record.weight = spec.weight
                rt.record.floor = spec.floor
                rt.record.bid_weight = spec.bid_weight
                rt.record.budget = spec.budget
                rt.record.bid_policy = spec.bid_policy
            else:
                rt.record = self.svc.register_spec(
                    spec, on_grant=on_grant, on_force_release=on_force)
            # live CMS signals feed the phase-1 reclaim planner
            rt.record.signals = (
                lambda rt=rt: rt.server.signals(
                    self.now, name=rt.name, weight=rt.record.weight))
            self._runtimes.append(rt)

        self._batch = [rt for rt in self._runtimes if rt.is_batch]
        self._latency = [rt for rt in self._runtimes if not rt.is_batch]
        self._rt_by_name = {rt.name: rt for rt in self._runtimes}
        # metric-sample fast path: the per-runtime attribute walk is
        # hoisted once (runtimes are fixed after construction), as is the
        # engine's market handle — _trace_sample runs inside the < 5 %
        # bench envelope
        self._sample_rows = [
            (rt.name, rt.record, rt.server, rt.is_batch,
             rt.is_batch and hasattr(rt.server, "queue"))
            for rt in self._runtimes]
        self._trace_market = getattr(self.svc.policy, "market", None)
        # legacy aliases (the paper wiring); first of each class otherwise
        self.st = self._batch[0].server if self._batch else None
        self.ws = self._latency[0].server if self._latency else None
        self.jobs: List[Job] = [j for rt in self._batch for j in rt.jobs]
        self.ws_demand = self._latency[0].demand if self._latency else []
        self.ws_provider = self._latency[0].provider if self._latency \
            else None

        # timeline accounting
        self._last_t = 0.0
        self.timeline: List[Tuple[float, ...]] = []

    # --------------------------------------------------------------- events
    def _push(self, t: float, kind: EventKind, payload=None):
        self._seq += 1
        heapq.heappush(self._q, Event(t, self._seq, kind, payload))

    def _schedule_finish(self, rt: _TenantRuntime, job: Job, t: float):
        key = (rt.name, job.job_id)
        epoch = self._job_epoch.get(key, 0) + 1
        self._job_epoch[key] = epoch
        t_eff = t
        if self.cfg.straggler_frac > 0 and \
                self.rng.random() < self.cfg.straggler_frac:
            slow = t + (self.cfg.straggler_slowdown - 1.0) * job.remaining()
            if self.cfg.speculative_relaunch:
                # detect at 1.2x nominal, relaunch a copy: finishes at
                # detection + fresh remaining work
                spec = self.now + 1.2 * job.remaining() + job.remaining()
                t_eff = min(slow, spec)
            else:
                t_eff = slow
        self._push(t_eff, EventKind.JOB_FINISH, (rt, job, epoch))

    def _cancel_finish(self, rt: _TenantRuntime, job: Job):
        key = (rt.name, job.job_id)
        self._job_epoch[key] = self._job_epoch.get(key, 0) + 1

    # ---------------------------------------------------------- accounting
    def _account(self, t: float):
        dt = t - self._last_t
        if dt > 0:
            for rt in self._runtimes:
                rt.alloc_seconds += rt.record.alloc * dt
                if rt.is_batch:
                    rt.used_seconds += rt.server.used * dt
            self._last_t = t

    def _update_demands(self):
        """Demand-aware policies: keep each batch department's declared
        demand current and voluntarily return surplus idle allocation (the
        paper's policy ignores demand, so this is skipped for it)."""
        if not self._demand_driven:
            return
        for rt in self._batch:
            self.svc.set_demand(rt.name, rt.server.demand_nodes(),
                                provision=False)
        self.svc.provision_idle()   # one pass after ALL demands are current
        for rt in self._batch:
            surplus = rt.record.alloc - max(rt.record.demand,
                                            rt.server.used)
            if surplus > 0:
                freed = rt.server.release_idle(surplus)
                if freed > 0:
                    self.svc.release(rt.name, freed)

    # ---------------------------------------------------------------- run
    def run(self) -> SimResult:
        for rt in self._batch:
            for job in rt.jobs:
                self._push(job.submit_time, EventKind.JOB_SUBMIT, (rt, job))
        for rt in self._latency:
            for t, n in rt.demand:
                self._push(t, EventKind.WS_DEMAND, (rt, n))
        if self._injector is not None:
            self._injector.start(self)
        elif self.cfg.node_mtbf > 0:
            self._push(self.rng.expovariate(
                self.cfg.total_nodes / self.cfg.node_mtbf),
                EventKind.NODE_FAIL)

        # initial provision: everything idle flows per the policy (paper:
        # all of it to the highest-priority batch department)
        self._update_demands()
        self.svc.provision_idle()

        # telemetry fast path: the traced-loop additions must stay near
        # one dict-append per emitted event (< 5% bench gate); episode
        # checks run only on events that can move a latency department's
        # alloc/demand (WS_DEMAND, NODE_FAIL/REPAIR — job events and idle
        # reflows only ever touch batch allocations)
        tr = self.tracer
        traced = tr.enabled
        while self._q:
            ev = heapq.heappop(self._q)
            if ev.time > self.horizon:
                break
            self._account(ev.time)
            self.now = ev.time
            if traced:
                tr.now = ev.time
            if ev.kind is EventKind.JOB_SUBMIT:
                rt, job = ev.payload
                rt.server.submit(job, self.now)
            elif ev.kind is EventKind.JOB_FINISH:
                rt, job, epoch = ev.payload
                if self._job_epoch.get((rt.name, job.job_id)) == epoch and \
                        job.state is JobState.RUNNING:
                    rt.server.job_finished(job, self.now)
            elif ev.kind is EventKind.WS_DEMAND:
                rt, n = ev.payload
                if traced:
                    # the demand event IS the autoscaler's decision when
                    # the source is a provider (its SLO autoscaler planned
                    # the node-demand series); raw timeseries otherwise.
                    # Inlined append: hottest traced site in the loop.
                    evs = tr.events
                    if len(evs) < tr.max_events:
                        evs.append({"type": "autoscale", "ts": tr.now,
                                    "tenant": rt.name,
                                    "prev": rt.server.demand, "demand": n,
                                    "source": "provider"
                                    if rt.provider is not None
                                    else "timeseries"})
                    else:
                        tr.dropped_events += 1
                rt.server.set_demand(n, self.now)
                if traced:
                    self._trace_episodes()
            elif ev.kind is EventKind.NODE_FAIL:
                if self._injector is not None:
                    self._injector.fire(self, ev.payload)
                else:
                    self._node_fail()
                    self._push(self.now + self.rng.expovariate(
                        self.cfg.total_nodes / self.cfg.node_mtbf),
                        EventKind.NODE_FAIL)
                if traced:
                    self._trace_episodes()
            elif ev.kind is EventKind.NODE_REPAIR:
                self.svc.node_repaired(node=ev.payload)
                if traced:
                    self._trace_episodes()
            elif ev.kind is EventKind.DRAIN_DONE:
                ev.payload()   # service closure: deliver surviving nodes
                if traced:
                    self._trace_episodes()
            self._update_demands()     # no-op under the paper policy
            if traced and self.now >= self._next_sample:
                self._trace_sample()
            self.timeline.append(
                (self.now,
                 *(rt.record.alloc for rt in self._runtimes),
                 self.svc.free))
        self._account(self.horizon)
        if traced:
            tr.now = self.horizon
            self._trace_episodes()
            self._trace_sample()       # closing sample at the horizon
        return self._result()

    # ------------------------------------------------------------ telemetry
    def _trace_episodes(self):
        """SLO shortfall episodes: open a ``slo_violation`` span when a
        latency department's granted allocation falls below its demand
        (parented to its most recent claim so the whole ``claim ->
        reclaim -> recovery`` chain links up), close it with a
        ``slo_recovery`` when the shortfall clears."""
        tr = self.tracer
        eps = self._episodes
        for rt in self._latency:
            shortfall = rt.server.demand - rt.record.alloc
            if shortfall > 0:
                if rt.name not in eps:
                    span = tr.new_span()
                    eps[rt.name] = (span, self.now)
                    tr.append({"type": "slo_violation", "span": span,
                               "parent": tr.last_claim_span.get(rt.name),
                               "tenant": rt.name,
                               "demand": rt.server.demand,
                               "alloc": rt.record.alloc,
                               "shortfall": shortfall})
            elif rt.name in eps:
                span, start = eps.pop(rt.name)
                tr.append({"type": "slo_recovery", "parent": span,
                           "tenant": rt.name,
                           "duration_s": self.now - start})

    def _trace_sample(self):
        """One ``metrics`` timeseries point: free pool + per-department
        alloc/demand/queue/headroom/spend. Reads registry fields and cheap
        CMS attributes only — never ``signals()`` (batch demand_nodes
        walks the whole job queue, which would blow the overhead gate)."""
        tr = self.tracer
        tenants: Dict[str, Dict] = {}
        market = self._trace_market
        for name, rec, server, is_batch, has_queue in self._sample_rows:
            spend = market.spend.get(name, 0.0) if market is not None \
                else 0.0
            if is_batch:
                # under demand-driven policies rec.demand is kept current
                # by _update_demands; the paper engine never declares it
                tenants[name] = {
                    "alloc": rec.alloc, "demand": rec.demand,
                    "queue_depth": len(server.queue) if has_queue else 0,
                    "headroom_s": 0.0, "spend": spend}
            else:
                demand = server.demand
                alloc = rec.alloc
                tenants[name] = {
                    "alloc": alloc, "demand": demand,
                    "queue_depth": demand - alloc if demand > alloc else 0,
                    "headroom_s": server.latency_headroom_s(),
                    "spend": spend}
        evs = tr.events
        if len(evs) < tr.max_events:
            evs.append({"type": "metrics", "ts": tr.now,
                        "free": self.svc.free, "tenants": tenants})
        else:
            tr.dropped_events += 1
        interval = tr.metric_interval_s
        if interval > 0:
            while self._next_sample <= self.now:
                self._next_sample += interval
        else:
            self._next_sample = math.inf

    # ------------------------------------------------------ fault injection
    # The injector-facing API: injectors (core/faults.py) own all fault
    # RNG and scheduling decisions; the simulator owns the clock, the
    # event queue and the count/CMS bookkeeping.

    def schedule_fault(self, delay: float, payload=None):
        self._push(self.now + delay, EventKind.NODE_FAIL, payload)

    def schedule_repair(self, delay: float, node: Optional[int] = None):
        self._push(self.now + delay, EventKind.NODE_REPAIR, node)

    def emit_suppressed(self, reason: str, **fields):
        """A fault event fired but could not take a node down (cluster at
        its one-node minimum, flapper already dark, ...). Traced instead
        of silently dropped so fail/repair events always pair up."""
        tr = self.tracer
        if tr.enabled:
            tr.emit("fault_suppressed", reason=reason, **fields)

    def apply_node_failure(self, node_id: int, cause: str,
                           domain: Optional[int] = None):
        """Take one identified node down, routing the loss through
        whichever layer currently holds it (free pool, a tenant's CMS, or
        the drain pool)."""
        owner = self.inventory.owner_of(node_id)
        if owner == DRAIN_POOL:
            self.svc.drain_node_failed(node_id, cause=cause)
            return
        if owner == "free":
            self.svc.node_failed("free", node=node_id, cause=cause)
            return
        rt = self._rt_by_name[owner]
        # route the loss through the CMS's own eviction path so the
        # server's alloc and the service's record cannot diverge (idle
        # nodes absorb the loss before any job/replica is evicted)
        rt.server.node_lost(self.now)
        self.svc.node_failed(owner, node=node_id, cause=cause)
        if not rt.is_batch:
            # a latency department immediately re-requests to cover demand
            rt.server.set_demand(rt.server.demand, self.now)

    def fail_pool_proportional(self, rng: random.Random,
                               repair_time_s: float,
                               cause: Optional[str] = None):
        """Legacy victim selection: one anonymous node fails, attributed
        to pools proportionally to their size (free pool first, then
        departments in registration order — the paper wiring's order is
        st, ws). Draw order is the reproducibility contract: a suppressed
        fault consumes NO draw from ``rng``."""
        total_alloc = self.svc.free + sum(rt.record.alloc
                                          for rt in self._runtimes)
        if total_alloc <= 1:
            # the cluster is at its one-node minimum: taking the node
            # would zero it out. Traced (never silently dropped) so
            # fail/repair events stay paired and repairs can never
            # over-repair past the configured total.
            self.emit_suppressed("cluster_at_minimum",
                                 total_alloc=total_alloc)
            return
        r = rng.random() * total_alloc
        if r < self.svc.free:
            node = self.svc.node_failed("free", cause=cause)
        else:
            acc = self.svc.free
            victim = self._runtimes[-1]
            for rt in self._runtimes:
                acc += rt.record.alloc
                if r < acc:
                    victim = rt
                    break
            victim.server.node_lost(self.now)
            node = self.svc.node_failed(victim.name, cause=cause)
            if not victim.is_batch:
                victim.server.set_demand(victim.server.demand, self.now)
        self.schedule_repair(repair_time_s, node)

    def _node_fail(self):
        """Legacy ``node_mtbf`` fault path (no FaultSpec configured)."""
        self.fail_pool_proportional(self.rng, self.cfg.node_repair_time)

    # ------------------------------------------------------------- results
    def _tenant_result(self, rt: _TenantRuntime) -> TenantResult:
        horizon = self.horizon
        res = TenantResult(name=rt.name, kind=rt.spec.kind,
                           priority=rt.spec.priority,
                           avg_alloc=rt.alloc_seconds / horizon
                           if horizon > 0 else 0.0)
        engine = self.svc.policy
        res.reclaimed_events = engine.victim_counts.get(rt.name, 0)
        res.reclaimed_nodes = engine.victim_nodes.get(rt.name, 0)
        res.last_bid = float(getattr(engine, "last_bids", {})
                             .get(rt.name, 0.0))
        market = getattr(engine, "market", None)
        if market is not None:
            res.spend = float(market.spend.get(rt.name, 0.0))
            rem = market.remaining.get(rt.name, math.inf)
            res.budget_remaining = None if math.isinf(rem) else float(rem)
        if rt.is_batch:
            completed = [j for j in rt.jobs if j.state is JobState.COMPLETED]
            tats = sorted(j.turnaround for j in completed)
            res.submitted = len(rt.jobs)
            res.completed = len(completed)
            res.killed = sum(j.state is JobState.KILLED for j in rt.jobs)
            res.preemptions = rt.server.preemptions
            res.avg_turnaround = float(np.mean(tats)) if tats else 0.0
            res.median_turnaround = float(np.median(tats)) if tats else 0.0
            res.node_seconds_used = rt.used_seconds
        else:
            res.unmet_node_seconds = rt.server.unmet_node_seconds
            res.reclaim_events = rt.server.reclaim_events
            res.preempted_nodes = rt.server.preempted_nodes
            if rt.provider is not None and \
                    hasattr(rt.provider, "realized_metrics"):
                if self.defer_queue:
                    self.deferred_queue.append(
                        (rt.name, rt.provider,
                         list(rt.server.alloc_events)))
                else:
                    res.latency = rt.provider.realized_metrics(
                        rt.server.alloc_events, horizon=horizon)
        return res

    def _result(self) -> SimResult:
        horizon = self.horizon
        tenants = {rt.name: self._tenant_result(rt)
                   for rt in self._runtimes}
        batch = [tenants[rt.name] for rt in self._batch]
        latency = [tenants[rt.name] for rt in self._latency]

        # cross-department aggregates (for the degenerate paper wiring
        # these ARE the single ST/WS departments' numbers, bit-for-bit)
        completed = [j for rt in self._batch for j in rt.jobs
                     if j.state is JobState.COMPLETED]
        tats = sorted(j.turnaround for j in completed)
        return SimResult(
            total_nodes=self.cfg.total_nodes,
            submitted=sum(t.submitted for t in batch),
            completed=len(completed),
            killed=sum(t.killed for t in batch),
            preemptions=sum(t.preemptions for t in batch),
            avg_turnaround=float(np.mean(tats)) if tats else 0.0,
            median_turnaround=float(np.median(tats)) if tats else 0.0,
            ws_unmet_node_seconds=sum(t.unmet_node_seconds
                                      for t in latency),
            ws_reclaim_events=sum(t.reclaim_events for t in latency),
            st_node_seconds_used=sum(t.node_seconds_used for t in batch),
            st_avg_alloc=sum(rt.alloc_seconds for rt in self._batch)
            / horizon if horizon > 0 else 0.0,
            ws_avg_alloc=sum(rt.alloc_seconds for rt in self._latency)
            / horizon if horizon > 0 else 0.0,
            util_timeline=downsample_timeline(self.timeline),
            ws_latency=latency[0].latency if latency else None,
            tenants=tenants,
            policy=self.policy_name,
            policy_state=self.svc.policy.state_snapshot(),
        )
