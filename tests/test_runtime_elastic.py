"""Elastic runtime integration tests.

These need multiple host devices, so each test body runs in a subprocess
with XLA_FLAGS set before jax imports (the main test process keeps 1 device
— see the dry-run note in the assignment).
"""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(body: str, n: int = 8, timeout: int = 900):
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n}"
        import sys
        sys.path.insert(0, {REPO + "/src"!r})
    """) + textwrap.dedent(body)
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout)
    assert res.returncode == 0, f"stdout:\n{res.stdout}\nstderr:\n{res.stderr}"
    return res.stdout


def test_elastic_resize_preserves_training(tmp_path):
    out = run_with_devices(f"""
        import jax, numpy as np
        from repro.configs import ARCHS, reduced_config
        from repro.configs.base import TrainConfig
        from repro.runtime.elastic import ElasticTrainer

        cfg = reduced_config(ARCHS["deepseek-7b"])
        t = ElasticTrainer(cfg, TrainConfig(zero1=True), global_batch=8,
                           seq_len=16, ckpt_dir={str(tmp_path)!r},
                           model_size=2)
        devs = jax.devices()
        t.start(devs[:8])            # 4x2 mesh
        m1 = t.train_steps(3)
        t.resize(devs[:4])           # shrink to 2x2 (WS spike reclaimed 4)
        m2 = t.train_steps(2)
        t.resize(devs[:8])           # grow back
        m3 = t.train_steps(2)
        assert m3["step"] == 7, m3
        assert t.resizes == 2
        losses = [m["loss"] for m in t.metrics_log]
        assert all(np.isfinite(l) for l in losses), losses
        # training progresses: loss at the end lower than at the start
        print("LOSSES", losses)
        print("OK")
    """)
    assert "OK" in out


def test_restart_after_failure_resumes_from_checkpoint(tmp_path):
    body = f"""
        import jax
        from repro.configs import ARCHS, reduced_config
        from repro.configs.base import TrainConfig
        from repro.runtime.elastic import ElasticTrainer
        cfg = reduced_config(ARCHS["qwen2-7b"])
        t = ElasticTrainer(cfg, TrainConfig(), global_batch=4, seq_len=16,
                           ckpt_dir={str(tmp_path)!r}, model_size=1)
        t.start(jax.devices()[:4])
        t.train_steps(2)
        t.checkpoint()
        print("STEP", t.step)
    """
    out1 = run_with_devices(body, n=4)
    assert "STEP 2" in out1
    # "node failure": a fresh process restores and continues on FEWER devices
    out2 = run_with_devices(f"""
        import jax
        from repro.configs import ARCHS, reduced_config
        from repro.configs.base import TrainConfig
        from repro.runtime.elastic import ElasticTrainer
        cfg = reduced_config(ARCHS["qwen2-7b"])
        t = ElasticTrainer(cfg, TrainConfig(), global_batch=4, seq_len=16,
                           ckpt_dir={str(tmp_path)!r}, model_size=1)
        t.start(jax.devices()[:2])   # two devices lost
        assert t.step == 2, t.step
        m = t.train_steps(1)
        assert m["step"] == 3
        print("RESUMED", m["step"])
    """, n=4)
    assert "RESUMED 3" in out2


def test_orchestrator_policy_shrinks_and_grows_trainer(tmp_path):
    out = run_with_devices(f"""
        import jax, numpy as np
        from repro.configs import ARCHS, reduced_config
        from repro.configs.base import TrainConfig
        from repro.runtime.elastic import ElasticTrainer
        from repro.runtime.serving_pool import ServingPool
        from repro.runtime.orchestrator import PhoenixOrchestrator
        from repro.models import model as M

        cfg = reduced_config(ARCHS["deepseek-7b"])
        trainer = ElasticTrainer(cfg, TrainConfig(), global_batch=8,
                                 seq_len=16, ckpt_dir={str(tmp_path)!r},
                                 model_size=1)
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        pool = ServingPool(cfg, params, capacity_tokens_per_replica=100.0)
        orch = PhoenixOrchestrator(trainer, pool, min_st_devices=2)
        orch.start()                       # all 8 devices -> trainer
        assert len(orch.devs.st) == 8
        orch.train_steps(1)
        orch.ws_tick(offered_load_tokens=90.0)   # util>0.8 -> scale up
        assert len(pool.replicas) == 2
        assert len(orch.devs.st) == 6            # trainer shrank
        orch.train_steps(1)
        # serve a request through the balancer
        outp = pool.submit(np.array([[1,2,3,4]], dtype=np.int32), 4)
        assert outp.shape == (1, 4)
        orch.ws_tick(offered_load_tokens=0.0)    # scale down
        assert len(pool.replicas) == 1           # floor n=1
        m = orch.train_steps(1)
        assert np.isfinite(m["loss"])
        print("EVENTS", orch.events)
        print("OK")
    """)
    assert "OK" in out
