"""Partitioning-rule unit tests (spec shapes only — no devices needed)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import ARCHS, reduced_config
from repro.models import model as M
from repro.sharding import partitioning as pt


@pytest.fixture(scope="module")
def mesh():
    # abstract mesh over fake device objects: only .shape is consulted by
    # the spec builders, but Mesh wants real devices — use the CPU device
    # replicated via a 1x1 mesh and exercise the spec logic through a
    # mock-shaped mesh object instead.
    class FakeMesh:
        shape = {"data": 16, "model": 16}
    return FakeMesh()


def specs_for(arch, mesh, **kw):
    cfg = ARCHS[arch]
    shapes = jax.eval_shape(lambda k: M.init_params(k, cfg),
                            jax.random.PRNGKey(0))
    return cfg, shapes, pt.param_specs(shapes, cfg, mesh, **kw)


def leaves_with_paths(tree):
    return {"/".join(str(getattr(k, "key", k)) for k in path): v
            for path, v in jax.tree_util.tree_flatten_with_path(tree)[0]}


def test_dense_tp_rules(mesh):
    cfg, shapes, specs = specs_for("deepseek-7b", mesh)
    sp = leaves_with_paths(specs)
    shp = leaves_with_paths(shapes)
    # column-parallel: wq kernel last dim on model
    assert sp["repeats/b0/mixer/wq/kernel"][-1] == "model"
    # row-parallel: wo kernel penultimate dim on model
    assert sp["repeats/b0/mixer/wo/kernel"][-2] == "model"
    # embedding vocab-sharded
    assert sp["embed/table"][0] == "model"
    # norms replicated
    assert all(s is None for s in sp["repeats/b0/pre_norm/scale"])
    # leading repeat dim never sharded
    for k, s in sp.items():
        if k.startswith("repeats/"):
            assert s[0] is None, k


def test_fsdp_adds_data_dim(mesh):
    _, shapes, specs = specs_for("mistral-large-123b", mesh, fsdp=True)
    sp = leaves_with_paths(specs)
    assert "data" in tuple(sp["repeats/b0/mixer/wq/kernel"])
    assert "data" in tuple(sp["repeats/b0/mlp/wi_gate/kernel"])


def test_tp1_pure_fsdp_layout(mesh):
    _, shapes, specs = specs_for("qwen2-7b", mesh, fsdp=True, tp=1)
    sp = leaves_with_paths(specs)
    flat = [a for s in sp.values() for a in s if a is not None]
    # no model-only sharding: every sharded dim uses the combined axes
    assert all(isinstance(a, tuple) and set(a) == {"data", "model"}
               for a in flat)


def test_moe_expert_tp_vs_ep(mesh):
    _, _, specs = specs_for("qwen3-moe-30b-a3b", mesh, fsdp=True)
    sp = leaves_with_paths(specs)
    wi = sp["repeats/b0/moe/wi_gate"]          # [R, E, D, F]
    assert wi[-1] == "model"                   # expert-TP on ffn dim
    # EP variant shards the expert dim on data
    import dataclasses
    cfg = ARCHS["qwen3-moe-30b-a3b"]
    cfg_ep = cfg.with_(moe=dataclasses.replace(cfg.moe,
                                               expert_parallel=True))
    shapes = jax.eval_shape(lambda k: M.init_params(k, cfg_ep),
                            jax.random.PRNGKey(0))
    sp_ep = leaves_with_paths(pt.param_specs(shapes, cfg_ep, mesh))
    assert sp_ep["repeats/b0/moe/wi_gate"][1] == "data"


def test_zero1_shards_optimizer_over_data(mesh):
    cfg, shapes, specs = specs_for("deepseek-7b", mesh)
    z = pt.zero1_specs(specs, shapes, mesh)
    sp = leaves_with_paths(z)
    # norm scales [R, D]: D=4096 divisible by 16 -> data-sharded in opt state
    assert "data" in tuple(sp["repeats/b0/pre_norm/scale"])
    # already-TP'd dims keep model; a free dim gains data
    wq = tuple(sp["repeats/b0/mixer/wq/kernel"])
    assert "model" in wq and "data" in wq


def test_data_spec_fallback_chain():
    class FakeMultiMesh:
        shape = {"pod": 2, "data": 16, "model": 16}
    m = FakeMultiMesh()
    # 256 % 512 != 0 -> falls to (data, model) = 256
    s = pt.data_spec(m, (256, 128), tp=1)
    assert s[0] == ("data", "model")
    # 512 shards over all three
    s2 = pt.data_spec(m, (512, 128), tp=1)
    assert s2[0] == ("pod", "data", "model")
    # indivisible batch -> data only
    s3 = pt.data_spec(m, (48, 128), tp=1)
    assert s3[0] == "data"


def test_cache_specs_shard_heads_or_length(mesh):
    cfg = ARCHS["deepseek-7b"]           # kv=32 divisible by 16
    cache = M.init_cache(cfg, 128, 32768, abstract=True)
    cs = pt.cache_specs(cache, cfg, mesh)
    sp = leaves_with_paths(cs)
    k = sp["repeats/b0/k"]               # [R, B, L, K, hd]
    assert k[3] == "model" and k[1] is not None
    cfg2 = ARCHS["qwen2-7b"]             # kv=4: falls to length sharding
    cache2 = M.init_cache(cfg2, 128, 32768, abstract=True)
    sp2 = leaves_with_paths(pt.cache_specs(cache2, cfg2, mesh))
    assert sp2["repeats/b0/k"][2] == "model"
