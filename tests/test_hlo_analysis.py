"""HLO cost-model unit tests (synthetic HLO text + a real lowered program)."""
import textwrap

import pytest

from repro.hlo.analysis import (HloCostModel, analyze_text, parse_hlo,
                                shape_bytes)


SIMPLE = textwrap.dedent("""
    HloModule test

    %body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
      %p = (s32[], f32[8,16]) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %x = f32[8,16]{1,0} get-tuple-element(%p), index=1
      %w = f32[16,16]{1,0} constant({...})
      %dot.1 = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ar = f32[8,16]{1,0} all-reduce(%dot.1), replica_groups=[2,4]<=[8], to_apply=%add_c
      %one = s32[] constant(1)
      %ni = s32[] add(%i, %one)
      ROOT %t = (s32[], f32[8,16]) tuple(%ni, %ar)
    }

    %cond (p: (s32[], f32[8,16])) -> pred[] {
      %p = (s32[], f32[8,16]) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %n = s32[] constant(5)
      ROOT %lt = pred[] compare(%i, %n), direction=LT
    }

    %add_c (a: f32[], b: f32[]) -> f32[] {
      %a = f32[] parameter(0)
      %b = f32[] parameter(1)
      ROOT %s = f32[] add(%a, %b)
    }

    ENTRY %main (x: f32[8,16]) -> (s32[], f32[8,16]) {
      %x = f32[8,16]{1,0} parameter(0)
      %z = s32[] constant(0)
      %tup = (s32[], f32[8,16]) tuple(%z, %x)
      ROOT %w = (s32[], f32[8,16]) while(%tup), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
    }
""")


def test_shape_bytes():
    assert shape_bytes("f32[8,16]{1,0}") == 8 * 16 * 4
    assert shape_bytes("bf16[4,4]") == 32
    assert shape_bytes("(f32[2,2], s32[3])") == 16 + 12
    assert shape_bytes("pred[]") == 1


def test_while_trip_count_multiplies_flops_and_collectives():
    t = analyze_text(SIMPLE)
    # dot: 2*8*16*16 = 4096 flops x 5 trips
    assert t["flops"] == pytest.approx(5 * 2 * 8 * 16 * 16)
    # all-reduce wire bytes: 2 * (4-1)/4 * 512 bytes x 5
    assert t["collective_bytes"] == pytest.approx(5 * 2 * 0.75 * 512)
    assert t["unknown_trip_whiles"] == []


def test_unknown_trip_recorded():
    txt = SIMPLE.replace(', backend_config={"known_trip_count":{"n":"5"}}', "")
    t = analyze_text(txt)
    assert len(t["unknown_trip_whiles"]) == 1
    assert t["flops"] == pytest.approx(2 * 8 * 16 * 16)  # counted once


def test_typed_operands_parse():
    comps, entry = parse_hlo(SIMPLE)
    assert entry == "main"
    assert "body" in comps
    assert comps["body"].ops["dot.1"].operands == ["x", "w"]


def test_real_lowered_program_flops():
    import jax
    import jax.numpy as jnp

    def f(x, w1, w2):
        return jnp.sum(jnp.tanh(x @ w1) @ w2)

    shapes = (jax.ShapeDtypeStruct((32, 64), jnp.float32),
              jax.ShapeDtypeStruct((64, 128), jnp.float32),
              jax.ShapeDtypeStruct((128, 16), jnp.float32))
    compiled = jax.jit(f).lower(*shapes).compile()
    t = analyze_text(compiled.as_text())
    want = 2 * 32 * 64 * 128 + 2 * 32 * 128 * 16
    assert t["flops"] == pytest.approx(want, rel=0.01)


def test_scan_vs_unroll_parity():
    """The whole reason this module exists: scan == unroll FLOPs."""
    import jax
    import jax.numpy as jnp

    def body(x, w):
        return jnp.tanh(x @ w), None

    def f_scan(x, ws):
        return jax.lax.scan(body, x, ws)[0]

    def f_unroll(x, ws):
        for i in range(6):
            x, _ = body(x, ws[i])
        return x

    x = jax.ShapeDtypeStruct((16, 32), jnp.float32)
    ws = jax.ShapeDtypeStruct((6, 32, 32), jnp.float32)
    fs = analyze_text(jax.jit(f_scan).lower(x, ws).compile().as_text())
    fu = analyze_text(jax.jit(f_unroll).lower(x, ws).compile().as_text())
    assert fs["flops"] == pytest.approx(fu["flops"], rel=0.01)
