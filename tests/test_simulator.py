"""Integration tests for the consolidation simulator + paper-claim checks."""
import dataclasses

import pytest

from repro.core.experiment import (DC_SIZES, SC_TOTAL, run_dynamic,
                                   run_experiment, run_static, validate_claims)
from repro.core.simulator import ConsolidationSim
from repro.core.traces import (TWO_WEEKS_S, synthetic_sdsc_blue,
                               worldcup_demand_events)
from repro.core.types import Job, JobState, SimConfig

DAY = 86400.0


@pytest.fixture(scope="module")
def small_world():
    jobs = synthetic_sdsc_blue(seed=1, n_jobs=300, horizon=2 * DAY)
    ws = worldcup_demand_events(seed=1, horizon=2 * DAY)
    return jobs, ws


def test_deterministic(small_world):
    jobs, ws = small_world
    r1 = run_dynamic(jobs, ws, 160, horizon=2 * DAY)
    r2 = run_dynamic(jobs, ws, 160, horizon=2 * DAY)
    assert r1.completed == r2.completed
    assert r1.killed == r2.killed
    assert r1.avg_turnaround == pytest.approx(r2.avg_turnaround)


def test_ws_demand_always_met_when_capacity_suffices(small_world):
    jobs, ws = small_world
    r = run_dynamic(jobs, ws, 160, horizon=2 * DAY)
    assert r.ws_unmet_node_seconds == 0.0


def test_turnaround_at_least_runtime(small_world):
    jobs, ws = small_world
    cfg = SimConfig(total_nodes=160)
    sim = ConsolidationSim(cfg, jobs, ws, horizon=2 * DAY)
    sim.run()
    for j in sim.jobs:
        if j.state is JobState.COMPLETED:
            assert j.turnaround >= j.runtime - 1e-6


def test_more_nodes_never_hurt_completed(small_world):
    jobs, ws = small_world
    r_small = run_dynamic(jobs, ws, 150, horizon=2 * DAY)
    r_big = run_dynamic(jobs, ws, 200, horizon=2 * DAY)
    assert r_big.completed >= r_small.completed - 5  # small jitter tolerated


def test_checkpoint_mode_dominates_kill_mode(small_world):
    """Beyond-paper: checkpoint-preemption completes at least as many jobs."""
    jobs, ws = small_world
    kill = run_dynamic(jobs, ws, 160, horizon=2 * DAY)
    ck = run_dynamic(jobs, ws, 160, horizon=2 * DAY,
                     cfg=SimConfig(preempt_mode="checkpoint"))
    assert ck.killed == 0
    assert ck.completed >= kill.completed


def test_paper_claims_full_experiment():
    """The paper's §III-D claims on the full 2-week calibrated traces."""
    res = run_experiment(seed=0)
    claims = validate_claims(res)
    assert claims["dc160_completed_ge_sc"], claims
    assert claims["dc160_user_benefit_ge_sc"], claims
    assert claims["ws_demand_always_met"], claims
    assert claims["killed_grows_as_cluster_shrinks"], claims
    assert claims["cost_ratio_at_160"] == pytest.approx(160 / 208)


def test_node_failures_shrink_capacity_but_run(small_world):
    jobs, ws = small_world
    cfg = SimConfig(total_nodes=160, node_mtbf=50 * DAY,
                    node_repair_time=3600.0)
    r = run_dynamic(jobs, ws, 160, horizon=2 * DAY, cfg=cfg)
    assert r.completed > 0


def test_straggler_mitigation_improves_turnaround(small_world):
    jobs, ws = small_world
    slow = run_dynamic(jobs, ws, 180, horizon=2 * DAY, cfg=SimConfig(
        straggler_frac=0.15, straggler_slowdown=3.0,
        speculative_relaunch=False))
    spec = run_dynamic(jobs, ws, 180, horizon=2 * DAY, cfg=SimConfig(
        straggler_frac=0.15, straggler_slowdown=3.0,
        speculative_relaunch=True))
    assert spec.avg_turnaround <= slow.avg_turnaround


def test_easy_backfill_not_worse_than_fcfs(small_world):
    jobs, ws = small_world
    fcfs = run_dynamic(jobs, ws, 160, horizon=2 * DAY,
                       cfg=SimConfig(scheduler="fcfs"))
    easy = run_dynamic(jobs, ws, 160, horizon=2 * DAY,
                       cfg=SimConfig(scheduler="easy_backfill"))
    assert easy.completed >= fcfs.completed
