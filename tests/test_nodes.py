"""Node-lifecycle layer: state machine legality, deterministic pool
moves, failure domains, and count<->identity lockstep (core/nodes.py)."""
import pytest

from repro.core.nodes import (DRAIN_POOL, LEGAL_TRANSITIONS, NodeInventory,
                              NodeState)
from repro.core.provision import TenantProvisionService
from repro.core.policies import Tenant
from repro.core.telemetry import Tracer


def test_domains_partition_by_rack_size():
    inv = NodeInventory(40, rack_size=16)
    assert inv.domains() == [0, 1, 2]
    assert inv.nodes[0].domain == 0
    assert inv.nodes[15].domain == 0
    assert inv.nodes[16].domain == 1
    assert inv.domain_up_ids(2) == list(range(32, 40))


def test_transfer_moves_lowest_ids_deterministically():
    inv = NodeInventory(10)
    ids = inv.transfer("free", "a", 3)
    assert ids == [0, 1, 2]
    assert inv.pool("a") == [0, 1, 2]
    assert inv.pool("free") == [3, 4, 5, 6, 7, 8, 9]
    # moving back merges and the next take again picks lowest ids
    inv.transfer("a", "free", 2)
    assert inv.pool("free") == [0, 1, 3, 4, 5, 6, 7, 8, 9]
    assert inv.transfer("free", "b", 2) == [0, 1]


def test_illegal_transition_raises():
    inv = NodeInventory(4)
    node = inv.nodes[0]
    # healthy -> repairing is not in the lifecycle contract
    with pytest.raises(ValueError, match="illegal node transition"):
        inv._set_state(node, NodeState.REPAIRING)
    assert (NodeState.HEALTHY, NodeState.REPAIRING) not in LEGAL_TRANSITIONS
    assert node.state is NodeState.HEALTHY      # unchanged on failure


def test_fail_and_repair_cycle_states_and_pools():
    inv = NodeInventory(6)
    inv.transfer("free", "t", 3)
    nd = inv.fail(1, span=7)
    assert nd.state is NodeState.REPAIRING      # FAILED -> REPAIRING
    assert nd.fail_span == 7
    assert inv.pool("t") == [0, 2]
    assert inv.up_ids() == [0, 2, 3, 4, 5]
    back = inv.repair()                          # lowest-id down node
    assert back.id == 1 and back.state is NodeState.HEALTHY
    assert 1 in inv.pools["free"]


def test_flappers_repair_back_to_flapping():
    inv = NodeInventory(8)
    inv.designate_flappers([2, 5])
    assert inv.state_of(2) is NodeState.FLAPPING
    inv.fail(2, span=1)
    nd = inv.repair(2)
    assert nd.state is NodeState.FLAPPING        # never "healthy" again
    # flappers are still up (selectable as fault victims)
    assert 2 in inv.up_ids()


def test_node_state_events_emitted_for_every_transition():
    tr = Tracer()
    inv = NodeInventory(4, tracer=tr)
    inv.transfer("free", "t", 2, state=NodeState.DRAINING, parent=9)
    inv.move_nodes([0, 1], "ws", state=NodeState.HEALTHY, parent=9)
    inv.fail(0, span=3)
    inv.repair(0)
    evs = [e for e in tr.events if e["type"] == "node_state"]
    # 2 drain-starts + 2 drain-completes + fail + repairing + repaired
    assert len(evs) == 7
    assert [(e["from"], e["to"]) for e in evs if e["node"] == 0] == [
        ("healthy", "draining"), ("draining", "healthy"),
        ("healthy", "failed"), ("failed", "repairing"),
        ("repairing", "healthy")]
    # transitions parent to their causal context
    assert evs[0]["parent"] == 9
    assert [e["parent"] for e in evs if e["to"] == "failed"] == [3]


def test_audit_locksteps_with_service_counts():
    svc = TenantProvisionService(12, policy="paper")
    inv = NodeInventory(12)
    svc.attach_inventory(inv)
    svc.register(Tenant("st", "batch", priority=1))
    svc.register(Tenant("ws", "latency", priority=0))
    svc.provision_idle()                   # paper: all idle -> st
    inv.audit(svc)
    svc.tenants["st"].on_force_release = lambda n: n
    svc.claim("ws", 5)
    inv.audit(svc)
    svc.release("ws", 2, reprovision=False)
    inv.audit(svc)
    svc.node_failed("st")
    inv.audit(svc)
    svc.node_repaired()
    inv.audit(svc)
    assert inv.total - svc.total == 0


def test_reserved_pool_names_rejected():
    svc = TenantProvisionService(4)
    with pytest.raises(AssertionError):
        svc.register(Tenant(DRAIN_POOL, "batch", priority=1))
    with pytest.raises(AssertionError):
        svc.register(Tenant("free", "batch", priority=1))
