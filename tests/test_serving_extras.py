"""Sampler + continuous batcher + data pipeline unit tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:       # container without hypothesis: property tests skip
    HAS_HYPOTHESIS = False

from repro.serving.sampler import SamplerConfig, sample
from repro.serving.batching import ContinuousBatcher, Request


KEY = jax.random.PRNGKey(0)


def test_greedy_sampling():
    logits = jnp.array([[0.1, 5.0, 0.2], [3.0, 0.0, -1.0]])
    out = sample(logits, KEY, SamplerConfig(greedy=True))
    assert out.tolist() == [1, 0]


def test_top_k_restricts_support():
    logits = jnp.array([10.0, 9.0, -50.0, -50.0])
    cfg = SamplerConfig(top_k=2, temperature=1.0)
    toks = [int(sample(logits, jax.random.PRNGKey(i), cfg))
            for i in range(50)]
    assert set(toks) <= {0, 1}


def test_top_p_restricts_support():
    logits = jnp.log(jnp.array([0.6, 0.3, 0.05, 0.05]))
    cfg = SamplerConfig(top_p=0.85)
    toks = [int(sample(logits, jax.random.PRNGKey(i), cfg))
            for i in range(80)]
    assert set(toks) <= {0, 1}


def test_temperature_zero_ish_is_greedy():
    logits = jnp.array([1.0, 1.5, 0.2])
    cfg = SamplerConfig(temperature=1e-5)
    toks = {int(sample(logits, jax.random.PRNGKey(i), cfg))
            for i in range(20)}
    assert toks == {1}


if not HAS_HYPOTHESIS:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_batcher_serves_everything():
        pass
else:
    @given(st.lists(st.integers(1, 63), min_size=1, max_size=20),
           st.integers(1, 8))
    @settings(max_examples=30, deadline=None)
    def test_batcher_serves_everything(prompt_lens, max_batch):
        batcher = ContinuousBatcher(max_batch=max_batch, bucket=64)
        for i, L in enumerate(prompt_lens):
            batcher.submit(Request(i, np.arange(L, dtype=np.int32), 4))
        served = []

        def gen(prompts, max_new):
            served.append(prompts.shape[0])
            return np.zeros((prompts.shape[0], max_new), np.int32)

        while batcher.queue:
            reqs = batcher.next_round()
            assert 0 < len(reqs) <= max_batch
            batcher.run_round(reqs, gen)
        assert len(batcher.completed) == len(prompt_lens)
        assert sum(served) == len(prompt_lens)


def test_data_pipeline_deterministic_and_resumable():
    from repro.configs import ARCHS, reduced_config
    from repro.data.pipeline import SyntheticLM
    cfg = reduced_config(ARCHS["deepseek-7b"])
    d1 = SyntheticLM(cfg, seed=3)
    d2 = SyntheticLM(cfg, seed=3)
    b1 = d1.batch(17, 4, 32)
    b2 = d2.batch(17, 4, 32)   # fresh instance, same step -> same batch
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = d1.batch(18, 4, 32)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))


def test_data_pipeline_host_sharding_partitions_batch():
    from repro.configs import ARCHS, reduced_config
    from repro.data.pipeline import SyntheticLM
    cfg = reduced_config(ARCHS["qwen2-7b"])
    d = SyntheticLM(cfg, seed=0)
    full_rows = 8
    shards = [d.batch(5, full_rows, 16, host_id=h, host_count=2)
              for h in range(2)]
    assert all(s["tokens"].shape == (4, 16) for s in shards)
    # different hosts draw different rows
    assert not np.array_equal(np.asarray(shards[0]["tokens"]),
                              np.asarray(shards[1]["tokens"]))
