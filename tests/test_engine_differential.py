"""Cross-engine differential test harness.

Runs EVERY engine in the policy registry over a shared pool of randomized
tenancy scenarios and asserts the universal invariants no engine may break:

  * node conservation: sum of per-tenant allocations + free == total;
  * floors: forced reclaim never takes a victim below min(floor, alloc);
  * idle is never granted beyond a batch tenant's unmet declared demand
    for demand-capped (``demand_driven``) engines;
  * budgets are never overspent (market engines);
  * the recorded clearing price never exceeds the interval's highest bid;
  * the identified-node inventory stays in lockstep with the count books
    through every op (audited after each one);
  * under fault injection (correlated rack blasts and flapping nodes)
    every engine preserves conservation and floors and emits a
    schema-valid trace whose causal chains all resolve.

Scenarios are generated deterministically from a seed (the fallback
corpus always runs); when ``hypothesis`` is installed the same runner is
additionally driven by drawn seeds. Engines are discovered through
``get_policy``/``POLICIES`` registry iteration, so any future engine gets
this coverage for free the moment it is registered.
"""
import math
import random

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

from repro.core.nodes import NodeInventory
from repro.core.policies import POLICIES, Tenant, get_policy
from repro.core.provision import TenantProvisionService

# deterministic fallback corpus (always runs, hypothesis or not)
CORPUS_SEEDS = list(range(10))


def build_scenario(seed: int) -> dict:
    """One randomized tenancy scenario: a cluster, a tenant mix (kinds,
    priorities, weights, floors, budgets, bid policies) and an op tape."""
    rng = random.Random(seed)
    total = rng.randint(12, 160)
    n = rng.randint(2, 6)
    rows = []
    for i in range(n):
        kind = rng.choice(["batch", "latency"])
        rows.append({
            "name": f"t{i}",
            "kind": kind,
            "priority": rng.randint(0, 5),
            "weight": round(rng.uniform(0.0, 4.0), 2),
            "bid_weight": rng.choice(
                [None, round(rng.uniform(0.0, 6.0), 2)]),
            "floor": rng.randint(0, 6) if kind == "latency" else 0,
            "budget": rng.choice(
                [None, round(rng.uniform(0.0, 60.0), 1),
                 round(rng.uniform(60.0, 2000.0), 1)]),
            "bid_policy": rng.choice(["linear", "slo_elastic"]),
        })
    # the ops need at least one of each kind to exercise both phases
    if not any(r["kind"] == "latency" for r in rows):
        rows[0]["kind"] = "latency"
        rows[0]["floor"] = rng.randint(0, 6)
    if not any(r["kind"] == "batch" for r in rows):
        rows[-1]["kind"] = "batch"
        rows[-1]["floor"] = 0
    ops = [(rng.choice(["claim", "release", "demand", "fail", "repair"]),
            rng.randrange(n), rng.randint(0, 100))
           for _ in range(50)]
    return {"total": total, "rows": rows, "ops": ops}


def run_scenario(policy_name: str, scen: dict, tracer=None):
    """Execute one scenario under one engine, auditing every invariant
    after every op (and inside every idle-grant decision)."""
    svc = TenantProvisionService(scen["total"], policy=policy_name,
                                 tracer=tracer)
    # identified-node mirror: every count move must keep the inventory's
    # pools in lockstep (audited after every op), whatever the engine
    inv = NodeInventory(scen["total"])
    svc.attach_inventory(inv)
    engine = svc.policy
    market = getattr(engine, "market", None)

    # --- wrap phase 2 so per-grant invariants are checked at decision time
    orig_idle = engine.idle_grants

    def audited_idle(free, batch):
        grants = orig_idle(free, batch)
        total_granted = 0
        for t, give in grants:
            assert give > 0, (engine.name, t.name, give)
            if engine.demand_driven:
                # demand-capped engines never grant beyond unmet demand
                assert give <= max(0, t.demand - t.alloc), \
                    (engine.name, t.name, give, t.demand, t.alloc)
            total_granted += give
        assert total_granted <= free, (engine.name, total_granted, free)
        price = getattr(engine, "last_clearing_price", None)
        if grants and price is not None:
            bids = getattr(engine, "last_unit_bids", None) or \
                getattr(engine, "last_bids", {})
            if bids:
                assert price <= max(bids.values()) + 1e-9, \
                    (engine.name, price, bids)
        return grants

    engine.idle_grants = audited_idle

    tenants = []
    for r in scen["rows"]:
        hook = (lambda name: lambda k: min(k, svc.tenants[name].alloc))(
            r["name"]) if r["kind"] == "batch" else None
        tenants.append(svc.register(Tenant(
            r["name"], r["kind"], priority=r["priority"],
            weight=r["weight"], bid_weight=r["bid_weight"],
            floor=r["floor"], budget=r["budget"],
            bid_policy=r["bid_policy"], on_force_release=hook)))

    def audit():
        svc.check()
        assert sum(t.alloc for t in tenants) + svc.free == svc.total
        assert svc.free >= 0
        assert all(t.alloc >= 0 for t in tenants)
        inv.audit(svc)
        if market is not None:
            for name, rem in market.remaining.items():
                assert rem >= -1e-6, (engine.name, name, rem)
                declared = market.budgets[name]
                if declared is not None:
                    assert market.spend[name] <= declared + 1e-6, \
                        (engine.name, name, market.spend[name], declared)

    repairs_due = 0
    for op, ti, amount in scen["ops"]:
        t = tenants[ti % len(tenants)]
        if op == "claim" and t.kind == "latency":
            before = {x.name: x.alloc for x in tenants if x.name != t.name}
            got = svc.claim(t.name, amount)
            assert 0 <= got <= amount
            for x in tenants:
                if x.name != t.name:
                    # floors hold for every victim class
                    assert x.alloc >= min(x.floor, before[x.name]), \
                        (engine.name, x.name, x.alloc, x.floor,
                         before[x.name])
        elif op == "release":
            svc.release(t.name, amount)
        elif op == "demand" and t.kind == "batch":
            svc.set_demand(t.name, amount % 64)
        elif op == "fail":
            if svc.total > max(1, scen["total"] // 2):
                svc.node_failed(t.name)      # may reattribute
                repairs_due += 1
        elif op == "repair" and repairs_due > 0:
            svc.node_repaired()
            repairs_due -= 1
        audit()
    return svc


def test_registry_iteration_covers_all_engines():
    """The harness (and anything else iterating the registry) sees every
    engine, and each resolves through get_policy with the full two-phase
    interface."""
    assert len(POLICIES) >= 7
    for name in POLICIES:
        eng = get_policy(name)
        assert eng.name == name
        assert callable(eng.plan_reclaim) and callable(eng.idle_grants)
        assert hasattr(eng, "demand_driven")
        assert hasattr(eng, "demand_satiating")
        assert isinstance(eng.state_snapshot(), dict)


@pytest.mark.parametrize("policy", sorted(POLICIES))
@pytest.mark.parametrize("seed", CORPUS_SEEDS)
def test_engine_differential_corpus(policy, seed):
    """Deterministic fallback corpus: every registered engine over the
    shared scenario pool."""
    run_scenario(policy, build_scenario(seed))


def test_engines_agree_on_totals_across_corpus():
    """Differential cross-check: whatever the engine, the same scenario
    ends with the same cluster size and non-negative books — and the
    unlimited-budget market engines never charge more than an infinite
    bankroll can absorb (spend is finite)."""
    for seed in CORPUS_SEEDS[:4]:
        scen = build_scenario(seed)
        totals = {}
        for policy in sorted(POLICIES):
            svc = run_scenario(policy, scen)
            totals[policy] = svc.total
            market = getattr(svc.policy, "market", None)
            if market is not None:
                assert all(math.isfinite(v) for v in market.spend.values())
        assert len(set(totals.values())) == 1, totals


@pytest.mark.parametrize("policy", sorted(POLICIES))
def test_causal_chains_intact_under_every_engine(policy):
    """Telemetry rides the same differential harness: whatever the engine,
    the emitted trace must schema-validate and every causal link
    (claim -> reclaim_plan -> reclaim_step) must resolve — the engines
    cannot break the observability contract."""
    from repro.core.telemetry import (Tracer, check_causal_chains,
                                      validate_events)
    for seed in CORPUS_SEEDS[:4]:
        tr = Tracer()
        run_scenario(policy, build_scenario(seed), tracer=tr)
        events = [tr.header()] + tr.events
        assert validate_events(events) == []
        assert check_causal_chains(events) == []
        # forced reclaims happened and were traced for engines that plan
        kinds = {e["type"] for e in events}
        assert "claim" in kinds


@pytest.mark.parametrize("profile", ["rack_corr", "flapping"])
@pytest.mark.parametrize("policy", sorted(POLICIES))
def test_engines_survive_fault_injection(policy, profile):
    """Every registered engine under each non-degenerate fault injector:
    conservation holds through correlated blasts and flapping outages,
    no reclaim ever takes a victim below its floor, and the trace stays
    schema-valid with every causal chain (including node_fail ->
    node_repair) resolving."""
    import dataclasses

    from repro.core.faults import get_fault_spec
    from repro.core.simulator import ConsolidationSim
    from repro.core.telemetry import (Tracer, check_causal_chains,
                                      validate_events)
    from repro.core.traces import synthetic_sdsc_blue
    from repro.core.types import SimConfig, TenantSpec

    # campaign-scale MTBFs target multi-day horizons; compress them so
    # every profile fires repeatedly inside this short differential run
    spec = get_fault_spec(profile)
    spec = dataclasses.replace(spec, mtbf_s=min(spec.mtbf_s, 600.0)
                               if spec.mtbf_s else spec.mtbf_s,
                               repair_time_s=300.0, flap_period_s=400.0)

    for seed in CORPUS_SEEDS[:2]:
        rng = random.Random(seed)
        horizon = 3600.0
        dem = [(t * 180.0, rng.randint(4, 20)) for t in range(20)]
        tenants = [
            TenantSpec("ws-0", "latency", priority=0, floor=2, demand=dem),
            TenantSpec("hpc-0", "batch", priority=1,
                       jobs=synthetic_sdsc_blue(seed=seed, n_jobs=20,
                                                horizon=horizon,
                                                max_nodes=16)),
            TenantSpec("hpc-1", "batch", priority=2, weight=0.5,
                       jobs=synthetic_sdsc_blue(seed=seed + 5, n_jobs=12,
                                                horizon=horizon,
                                                max_nodes=12)),
        ]
        tr = Tracer()
        cfg = SimConfig(total_nodes=48, seed=seed, faults=spec)
        sim = ConsolidationSim(cfg, horizon=horizon, tenants=tenants,
                               policy=policy, tracer=tr)
        # floor audit at every claim: within one event no failure can
        # interleave, so any dip below min(floor, pre-claim alloc) is the
        # engine's reclaim plan violating the floor contract
        svc = sim.svc
        orig_claim = svc.claim
        def checked_claim(name, n):
            before = {t.name: t.alloc for t in svc.tenants.values()}
            got = orig_claim(name, n)
            for t in svc.tenants.values():
                if t.name != name:
                    assert t.alloc >= min(t.floor, before[t.name]), \
                        (policy, profile, t.name, t.alloc, t.floor)
            return got
        svc.claim = checked_claim
        sim.run()                       # svc.check() audits every transition
        sim.inventory.audit(svc)        # books and pools end in lockstep
        events = [tr.header()] + tr.events
        assert validate_events(events) == []
        assert check_causal_chains(events) == []
        fails = [e for e in tr.events if e["type"] == "node_fail"]
        repairs = [e for e in tr.events if e["type"] == "node_repair"]
        assert fails, (policy, profile, seed)
        assert len(repairs) <= len(fails)


if not HAS_HYPOTHESIS:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_engine_differential_hypothesis():
        pass
else:
    @given(policy=st.sampled_from(sorted(POLICIES)),
           seed=st.integers(0, 10_000))
    @settings(max_examples=50, deadline=None)
    def test_engine_differential_hypothesis(policy, seed):
        """Hypothesis widens the corpus: same runner, drawn seeds."""
        run_scenario(policy, build_scenario(seed))
