"""Pallas kernel validation: shape/dtype sweeps, interpret mode vs oracle."""
import jax
import jax.numpy as jnp
import pytest

KEY = jax.random.PRNGKey(7)


# ------------------------------------------------------------ flash attention


@pytest.mark.parametrize("B,S,H,K,hd,win", [
    (2, 256, 4, 2, 128, 0),
    (1, 512, 4, 4, 128, 0),
    (2, 256, 8, 2, 128, 128),
    (1, 256, 2, 1, 128, 64),      # MQA + window
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_oracle(B, S, H, K, hd, win, dtype):
    from repro.kernels.flash_attention.ops import flash_attention
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, K, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, K, hd), dtype)
    o_ref = flash_attention(q, k, v, impl="ref", window=win)
    o_pal = flash_attention(q, k, v, impl="interpret", window=win,
                            block_q=128, block_k=128)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    assert float(jnp.max(jnp.abs(o_ref.astype(jnp.float32)
                                 - o_pal.astype(jnp.float32)))) < tol


def test_flash_attention_block_shape_independent():
    from repro.kernels.flash_attention.ops import flash_attention
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 512, 4, 128), jnp.float32)
    k = jax.random.normal(ks[1], (1, 512, 2, 128), jnp.float32)
    v = jax.random.normal(ks[2], (1, 512, 2, 128), jnp.float32)
    a = flash_attention(q, k, v, impl="interpret", block_q=128, block_k=256)
    b = flash_attention(q, k, v, impl="interpret", block_q=256, block_k=128)
    assert float(jnp.max(jnp.abs(a - b))) < 2e-5


# ----------------------------------------------------------- decode attention


@pytest.mark.parametrize("B,H,K,hd,L,win,fill", [
    (2, 8, 2, 128, 1024, 0, 1024),
    (2, 8, 4, 128, 1024, 0, 700),       # partially-filled cache
    (1, 4, 1, 128, 512, 256, 512),      # MQA ring window
    (1, 2, 2, 128, 512, 0, 512),
])
def test_decode_attention_matches_oracle(B, H, K, hd, L, win, fill):
    from repro.kernels.decode_attention.ops import decode_attention
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, hd), jnp.float32)
    ck = jax.random.normal(ks[1], (B, L, K, hd), jnp.float32)
    cv = jax.random.normal(ks[2], (B, L, K, hd), jnp.float32)
    sp = jnp.where(jnp.arange(L) < fill, jnp.arange(L), -1)
    o_ref = decode_attention(q, ck, cv, sp, fill - 1, window=win, impl="ref")
    o_pal = decode_attention(q, ck, cv, sp, fill - 1, window=win,
                             impl="interpret", block_k=256)
    assert float(jnp.max(jnp.abs(o_ref - o_pal))) < 2e-5


# ----------------------------------------------------------------- rglru scan


@pytest.mark.parametrize("B,S,W,bs,bw", [
    (2, 512, 512, 128, 256),
    (1, 256, 1024, 256, 512),
    (3, 128, 512, 64, 512),
])
def test_rglru_scan_matches_oracle(B, S, W, bs, bw):
    from repro.kernels.rglru_scan.ops import rglru_scan
    ks = jax.random.split(KEY, 3)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, S, W))) * 0.2 + 0.79
    b = jax.random.normal(ks[1], (B, S, W)) * 0.1
    h0 = jax.random.normal(ks[2], (B, W))
    h_ref = rglru_scan(a, b, h0, impl="ref")
    h_pal = rglru_scan(a, b, h0, impl="interpret", block_s=bs, block_w=bw)
    assert float(jnp.max(jnp.abs(h_ref - h_pal))) < 2e-4


def test_rglru_scan_respects_initial_state():
    from repro.kernels.rglru_scan.ops import rglru_scan
    a = jnp.full((1, 4, 256), 0.5)
    b = jnp.zeros((1, 4, 256))
    h0 = jnp.ones((1, 256))
    h = rglru_scan(a, b, h0, impl="interpret", block_s=4, block_w=256)
    assert float(jnp.max(jnp.abs(h[:, 0] - 0.5))) < 1e-6     # 0.5 * h0
    assert float(jnp.max(jnp.abs(h[:, 3] - 0.5 ** 4))) < 1e-6


# ---------------------------------------------------------------- mlstm chunk


@pytest.mark.parametrize("B,S,H,dqk,dv,chunk", [
    (1, 256, 2, 128, 256, 128),
    (2, 512, 4, 128, 128, 128),
    (1, 256, 2, 256, 512, 64),
])
def test_mlstm_chunk_matches_oracle(B, S, H, dqk, dv, chunk):
    from repro.kernels.mlstm_chunk.ops import mlstm_chunk
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (B, S, H, dqk), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, dqk), jnp.float32) / dqk ** 0.5
    v = jax.random.normal(ks[2], (B, S, H, dv), jnp.float32)
    il = jax.random.normal(ks[3], (B, S, H), jnp.float32)
    fl = jax.nn.log_sigmoid(jax.random.normal(ks[4], (B, S, H)) + 2.0)
    o_ref = mlstm_chunk(q, k, v, il, fl, impl="ref", chunk=chunk)
    o_pal = mlstm_chunk(q, k, v, il, fl, impl="interpret", chunk=chunk)
    rel = float(jnp.max(jnp.abs(o_ref - o_pal))) / \
        max(float(jnp.max(jnp.abs(o_ref))), 1e-9)
    assert rel < 1e-4


def test_mlstm_chunkwise_matches_stepwise_decode():
    """Chunkwise train path == sequential decode recurrence (models/xlstm)."""
    from repro.configs import ARCHS, reduced_config
    from repro.models import xlstm as xl
    cfg = reduced_config(ARCHS["xlstm-1.3b"])
    p = xl.init_mlstm_block(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(KEY, (2, 16, cfg.d_model), jnp.float32)
    y_seq, cache = xl.mlstm_block_prefill(p, x, cfg, chunk=8)
    y_dec, cache2 = xl.mlstm_block_decode(
        p, x[:, -1:], {**{k: v for k, v in cache.items()}}, cfg)
    # decode of the last token from the prefix-(S-1) state:
    y_pre, cache_pre = xl.mlstm_block_prefill(p, x[:, :-1], cfg, chunk=5)
    y_last, _ = xl.mlstm_block_decode(p, x[:, -1:], cache_pre, cfg)
    assert float(jnp.max(jnp.abs(y_last - y_seq[:, -1:]))) < 1e-3
