"""N-department tenancy framework: policies, conservation, seed regression.

Covers the multi-layer refactor of the consolidation core:
  * the degenerate 2-tenant configuration reproduces the seed ST/WS
    simulator numbers EXACTLY (golden values recorded from the seed code
    before the refactor, including the RNG-sensitive fault-injection path);
  * property-based conservation invariant (sum of per-tenant alloc + free
    == total) over random N-tenant event sequences;
  * a >= 4-department mix (2 HPC + 2 WS + 1 best-effort) runs end-to-end
    with per-department benefit metrics under every cooperative policy;
  * node_failed reattribution can never desync total from the pool sum;
  * stride-based util_timeline downsampling keeps early history.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:       # container without hypothesis: property tests skip
    HAS_HYPOTHESIS = False

from repro.core.experiment import run_dynamic
from repro.core.policies import (AuctionEngine, DemandCappedIdlePolicy,
                                 PaperPolicy, POLICIES,
                                 ProportionalSharePolicy, SLOHeadroomEngine,
                                 Tenant, get_policy)
from repro.core.provision import (ResourceProvisionService,
                                  TenantProvisionService)
from repro.core.simulator import (ConsolidationSim, downsample_timeline)
from repro.core.traces import synthetic_sdsc_blue, worldcup_demand_events
from repro.core.types import SimConfig, TenantSignals, TenantSpec

DAY = 86400.0


# ------------------------------------------------------------- regression

# golden numbers recorded from the seed simulator (PR 1 tree) before the
# N-tenant refactor: the degenerate 2-tenant paper configuration must
# reproduce them bit-for-bit
GOLDEN = {
    ("kill", 160): dict(
        completed=268, killed=14, preemptions=0,
        avg_turnaround=8515.726519760798,
        median_turnaround=3870.290620908512,
        ws_unmet_node_seconds=0.0, ws_reclaim_events=279,
        st_node_seconds_used=16557597.830821756,
        st_avg_alloc=120.1109953703703, ws_avg_alloc=39.88900462962963),
    ("kill", 200): dict(
        completed=271, killed=16, preemptions=0,
        avg_turnaround=6460.359904890289,
        median_turnaround=2962.7737324380214,
        ws_unmet_node_seconds=0.0, ws_reclaim_events=279,
        st_node_seconds_used=21818117.363095924,
        st_avg_alloc=160.11099537037015, ws_avg_alloc=39.88900462962965),
}


@pytest.fixture(scope="module")
def seed_world():
    jobs = synthetic_sdsc_blue(seed=1, n_jobs=300, horizon=2 * DAY)
    ws = worldcup_demand_events(seed=1, horizon=2 * DAY)
    return jobs, ws


@pytest.mark.parametrize("size", [160, 200])
def test_degenerate_two_tenant_reproduces_seed_exactly(seed_world, size):
    jobs, ws = seed_world
    r = run_dynamic(jobs, ws, size, horizon=2 * DAY)
    for key, want in GOLDEN[("kill", size)].items():
        assert getattr(r, key) == want, (key, getattr(r, key), want)
    # the refactored result also carries per-department accounting
    assert set(r.tenants) == {"st", "ws"}
    assert r.tenants["st"].completed == r.completed
    assert r.tenants["ws"].unmet_node_seconds == r.ws_unmet_node_seconds
    assert r.policy == "paper"


def test_degenerate_checkpoint_and_faults_reproduce_seed(seed_world):
    jobs, ws = seed_world
    ck = run_dynamic(jobs, ws, 160, horizon=2 * DAY,
                     cfg=SimConfig(preempt_mode="checkpoint"))
    assert (ck.completed, ck.killed, ck.preemptions) == (281, 0, 26)
    assert ck.avg_turnaround == 9335.879255144253
    # fault injection exercises the RNG stream: identical numbers prove the
    # generalized _node_fail consumes randomness exactly like the seed
    fl = run_dynamic(jobs, ws, 160, horizon=2 * DAY,
                     cfg=SimConfig(node_mtbf=50 * DAY,
                                   node_repair_time=3600.0))
    assert (fl.completed, fl.killed) == (259, 15)
    assert fl.avg_turnaround == 9673.410274220416
    assert fl.st_avg_alloc == 120.00682870370359
    assert fl.ws_avg_alloc == 39.889004629629675


# --------------------------------------------------- 4-department end-to-end

def _mix_specs(horizon=DAY / 2, seed=0):
    return [
        TenantSpec("ws-a", "latency", priority=0,
                   demand=worldcup_demand_events(seed=seed, horizon=horizon)),
        TenantSpec("ws-b", "latency", priority=1,
                   demand=worldcup_demand_events(seed=seed + 7,
                                                 horizon=horizon)),
        TenantSpec("hpc-a", "batch", priority=2, weight=2.0,
                   jobs=synthetic_sdsc_blue(seed=seed, n_jobs=60,
                                            horizon=horizon, max_nodes=32)),
        TenantSpec("hpc-b", "batch", priority=3, weight=1.0,
                   jobs=synthetic_sdsc_blue(seed=seed + 1, n_jobs=60,
                                            horizon=horizon, max_nodes=32)),
        TenantSpec("be", "batch", priority=9, weight=0.5,
                   jobs=synthetic_sdsc_blue(seed=seed + 2, n_jobs=20,
                                            horizon=horizon, max_nodes=8)),
    ]


@pytest.mark.parametrize("policy", sorted(POLICIES))
def test_five_department_mix_end_to_end(policy):
    horizon = DAY / 2
    sim = ConsolidationSim(SimConfig(total_nodes=208), horizon=horizon,
                           tenants=_mix_specs(horizon), policy=policy)
    res = sim.run()
    assert set(res.tenants) == {"ws-a", "ws-b", "hpc-a", "hpc-b", "be"}
    assert res.policy == policy
    # per-department benefit metrics exist for every department
    bens = res.benefits()
    assert all(bens[n] for n in res.tenants)
    # conservation at every timeline row: allocs + free == total
    for row in sim.timeline:
        assert sum(row[1:]) == 208, row
    # every job accounted across departments
    assert res.submitted == 140
    # latency departments outrank batch: with 208 nodes their demand is met
    assert res.ws_unmet_node_seconds == 0.0
    # aggregates equal the per-department sums
    assert res.completed == sum(t.completed for t in res.tenants.values())


def test_demand_aware_policies_avoid_starving_lower_batch_departments():
    """Under the paper's greedy rule every idle node is dumped on the top
    batch department; demand-capped/proportional sharing let the others
    make progress too."""
    horizon = DAY / 2
    out = {}
    for policy in ("paper", "demand_capped", "proportional_share"):
        sim = ConsolidationSim(SimConfig(total_nodes=208), horizon=horizon,
                               tenants=_mix_specs(horizon), policy=policy)
        out[policy] = sim.run()
    assert out["paper"].tenants["hpc-b"].avg_alloc == 0.0
    for policy in ("demand_capped", "proportional_share"):
        assert out[policy].tenants["hpc-b"].completed > 0, policy
        assert out[policy].tenants["be"].completed > 0, policy


# ----------------------------------------------------------- policy units

def _tenants(*rows):
    ts = [Tenant(name, kind, priority=p, weight=w, demand=d, alloc=a)
          for name, kind, p, w, d, a in rows]
    return ts


def test_paper_policy_idle_is_single_grant_to_top_priority():
    pol = PaperPolicy()
    batch = _tenants(("a", "batch", 1, 1.0, 0, 0),
                     ("b", "batch", 2, 1.0, 0, 0))
    grants = pol.idle_grants(100, batch)
    assert grants == [(batch[0], 100)]


def test_demand_capped_policy_leaves_leftover_free():
    pol = DemandCappedIdlePolicy()
    batch = _tenants(("a", "batch", 1, 1.0, 30, 0),
                     ("b", "batch", 2, 1.0, 50, 0))
    grants = dict((t.name, n) for t, n in pol.idle_grants(100, batch))
    assert grants == {"a": 30, "b": 50}          # 20 stay free


def test_proportional_share_splits_by_weight():
    pol = ProportionalSharePolicy()
    batch = _tenants(("a", "batch", 1, 3.0, 1000, 0),
                     ("b", "batch", 2, 1.0, 1000, 0))
    grants = dict((t.name, n) for t, n in pol.idle_grants(100, batch))
    assert grants["a"] + grants["b"] == 100
    assert grants["a"] == 75 and grants["b"] == 25
    # saturation: a tenant whose demand is met frees its share
    batch = _tenants(("a", "batch", 1, 3.0, 10, 0),
                     ("b", "batch", 2, 1.0, 1000, 0))
    grants = dict((t.name, n) for t, n in pol.idle_grants(100, batch))
    assert grants == {"a": 10, "b": 90}


def test_get_policy_resolves_names_classes_instances():
    assert get_policy("paper").name == "paper"
    assert get_policy(PaperPolicy).name == "paper"
    assert get_policy(DemandCappedIdlePolicy()).name == "demand_capped"
    assert get_policy("slo_headroom").name == "slo_headroom"
    assert get_policy("auction").name == "auction"
    with pytest.raises(ValueError):
        get_policy("nope")


# --------------------------------------------------- two-phase engine units

def _wire_signals(t: Tenant, **kw):
    """Attach a fixed TenantSignals snapshot to a tenant record."""
    base = dict(name=t.name, kind=t.kind, alloc=t.alloc, demand=t.demand,
                weight=t.weight)
    base.update(kw)
    t.signals = lambda: TenantSignals(**base)
    return t


def test_slo_headroom_plan_orders_surplus_cheapest_then_drain():
    """Band order: latency surplus (most headroom first), batch by cheapest
    preemption, then latency drained down to the floor — never below it."""
    eng = SLOHeadroomEngine()
    claimant = Tenant("ws-hot", "latency", priority=0)
    ws_a = _wire_signals(Tenant("ws-a", "latency", priority=1, alloc=10,
                                floor=2),
                         demand=6, latency_headroom_s=20.0)
    ws_b = _wire_signals(Tenant("ws-b", "latency", priority=2, alloc=8,
                                floor=1),
                         demand=8, latency_headroom_s=5.0)
    hpc_cheap = _wire_signals(Tenant("hpc-cheap", "batch", priority=3,
                                     alloc=12),
                              demand=12, preemption_cost_s=30.0)
    hpc_dear = _wire_signals(Tenant("hpc-dear", "batch", priority=4,
                                    alloc=12),
                             demand=12, preemption_cost_s=900.0)
    tenants = [claimant, ws_a, ws_b, hpc_cheap, hpc_dear]
    plan = eng.plan_reclaim(100, tenants, claimant)
    order = [(s.victim, s.take) for s in plan]
    # band 1: only ws-a has surplus (10 alloc vs 6 demand)
    assert order[0] == ("ws-a", 4)
    # band 2: batch, cheapest preemption first
    assert order[1] == ("hpc-cheap", 12)
    assert order[2] == ("hpc-dear", 12)
    # band 3: latency drained most-headroom-first, down to floors only
    assert order[3] == ("ws-a", 4)       # 10 - floor 2 - surplus 4
    assert order[4] == ("ws-b", 7)       # 8 - floor 1
    # floors are never crossed by any step combination
    assert sum(n for v, n in order if v == "ws-a") == 10 - 2
    assert sum(n for v, n in order if v == "ws-b") == 8 - 1


def test_auction_reclaim_order_is_ascending_bid_batch_first():
    eng = AuctionEngine()
    claimant = Tenant("ws-hot", "latency", priority=0)
    # bids = weight x unmet demand
    hpc_busy = Tenant("hpc-busy", "batch", priority=3, alloc=10, demand=50,
                      weight=1.0)                       # bid 40
    hpc_idle = Tenant("hpc-idle", "batch", priority=2, alloc=10, demand=10,
                      weight=1.0)                       # bid 0
    ws_lo = Tenant("ws-lo", "latency", priority=1, alloc=6, demand=6,
                   weight=1.0)                          # bid 0
    tenants = [claimant, hpc_busy, hpc_idle, ws_lo]
    plan = eng.plan_reclaim(15, tenants, claimant)
    assert [s.victim for s in plan] == ["hpc-idle", "hpc-busy", "ws-lo"]
    # deficit 15 > hpc-idle's 10: the plan digs into hpc-busy, whose bid
    # (40) is the marginal price recorded for this claim
    assert eng.reclaim_price_n == 1
    assert eng.reclaim_price_sum == pytest.approx(40.0)
    snap = eng.state_snapshot()
    assert snap["engine"] == "auction"
    assert snap["last_plan"] == ["hpc-idle", "hpc-busy", "ws-lo"]


def test_auction_idle_grants_by_descending_bid_record_clearing_price():
    eng = AuctionEngine()
    a = Tenant("a", "batch", priority=1, alloc=0, demand=30, weight=1.0)
    b = Tenant("b", "batch", priority=2, alloc=0, demand=30, weight=3.0)
    grants = dict((t.name, n) for t, n in eng.idle_grants(40, [a, b]))
    # b bids 90, a bids 30: b is served first, a gets the remainder
    assert grants == {"b": 30, "a": 10}
    snap = eng.state_snapshot()
    assert snap["intervals"] == 1
    assert snap["clearing_price_mean"] == pytest.approx(30.0)  # lowest win
    assert snap["clearing_price_samples"] == [pytest.approx(30.0)]


def test_bid_weight_overrides_weight_in_bids():
    eng = AuctionEngine()
    a = Tenant("a", "batch", priority=1, alloc=0, demand=10, weight=1.0,
               bid_weight=9.0)
    b = Tenant("b", "batch", priority=2, alloc=0, demand=10, weight=5.0)
    grants = dict((t.name, n) for t, n in eng.idle_grants(10, [a, b]))
    assert grants == {"a": 10}           # a's bid 90 beats b's 50


@pytest.mark.parametrize("policy", sorted(POLICIES))
def test_claim_never_reclaims_below_latency_floor(policy):
    """Any engine's plan respects a latency victim's floor (the paper's
    behaviour is the floor=0 degenerate case)."""
    svc = TenantProvisionService(20, policy=policy)
    svc.register(Tenant("hot", "latency", priority=0))
    svc.register(Tenant("cold", "latency", priority=5, floor=3))
    svc.register(Tenant("hpc", "batch", priority=2,
                        on_force_release=lambda n: n))
    # fill: cold holds 8, hpc holds 12, nothing free
    got = svc.claim("cold", 8)
    assert got == 8
    svc.set_demand("hpc", 12)
    # hot claims everything: hpc fully drained, cold only down to floor 3
    got = svc.claim("hot", 20)
    assert svc.tenants["cold"].alloc >= 3
    assert got == 20 - 3
    svc.check()


def test_engine_reclaim_state_reaches_sim_results():
    horizon = DAY / 2
    sim = ConsolidationSim(SimConfig(total_nodes=96), horizon=horizon,
                           tenants=_mix_specs(horizon),
                           policy="slo_headroom")
    res = sim.run()
    ps = res.policy_state
    assert ps["engine"] == "slo_headroom"
    assert ps["reclaim_plans"] > 0
    # nodes drained per victim are attributed on the TenantResults too
    drained = {n: t.reclaimed_nodes for n, t in res.tenants.items()
               if t.reclaimed_nodes}
    assert drained and drained == {k: v for k, v in
                                   ps["victim_nodes"].items() if v}


def test_auction_clearing_prices_reach_sim_results():
    horizon = DAY / 2
    sim = ConsolidationSim(SimConfig(total_nodes=96), horizon=horizon,
                           tenants=_mix_specs(horizon), policy="auction")
    res = sim.run()
    ps = res.policy_state
    assert ps["engine"] == "auction"
    assert ps["intervals"] > 0
    assert ps["clearing_price_mean"] > 0.0
    assert ps["clearing_price_max"] >= ps["clearing_price_mean"]
    assert any(t.last_bid > 0 for t in res.tenants.values())


# ------------------------------------------- faults mid-reclaim (any engine)

@pytest.mark.parametrize("policy", sorted(POLICIES))
def test_node_failed_mid_reclaim_conserves_and_respects_floors(policy):
    """A node failure firing from INSIDE a victim's force-release hook (the
    runtime analogue: a host dies while the trainer checkpoints out) must
    not desync conservation, and the latency floor still holds."""
    svc = TenantProvisionService(24, policy=policy)
    svc.register(Tenant("hot", "latency", priority=0))
    svc.register(Tenant("cold", "latency", priority=5, floor=2))
    fired = {"n": 0}

    def flaky_release(n):
        # first reclaim round: a node dies mid-eviction, then release
        if fired["n"] == 0:
            fired["n"] = 1
            svc.node_failed("hpc")
        rec = svc.tenants["hpc"]
        return min(n, rec.alloc)

    svc.register(Tenant("hpc", "batch", priority=2,
                        on_force_release=flaky_release))
    assert svc.claim("cold", 6) == 6
    svc.set_demand("hpc", 18)
    got = svc.claim("hot", 24)           # forces hpc + cold reclaim
    assert fired["n"] == 1
    # node_failed fired inside the claim: total shrank by exactly 1
    assert svc.total == 23
    assert svc.tenants["cold"].alloc >= 2
    # conservation after the dust settles
    svc.check()
    assert got <= 24


@pytest.mark.parametrize("policy", sorted(POLICIES))
def test_mid_reclaim_failure_on_latency_victim_still_respects_floor(policy):
    """The floor cap is re-derived when a plan step is APPLIED: a node
    failure attributed to a latency victim mid-plan shrinks its alloc, and
    the stale plan-time cap must not drain it below its floor."""
    svc = TenantProvisionService(20, policy=policy)
    svc.register(Tenant("hot", "latency", priority=0))
    cold = svc.register(Tenant("cold", "latency", priority=5, floor=4))
    # cold's CMS reports its allocation fully used (no band-1 surplus for
    # slo_headroom), so every engine reclaims batch before touching it
    cold.signals = lambda: TenantSignals(
        name="cold", kind="latency", alloc=cold.alloc, demand=cold.alloc)

    def fail_on_cold_then_release(n):
        rec = svc.tenants["hpc"]
        if svc.tenants["cold"].alloc > 0:
            svc.node_failed("cold")      # dead node lands on the latency dept
        return min(n, rec.alloc)

    svc.register(Tenant("hpc", "batch", priority=2,
                        on_force_release=fail_on_cold_then_release))
    assert svc.claim("cold", 10) == 10
    svc.set_demand("hpc", 10)
    svc.claim("hot", 20)
    # cold lost 1 node to the failure (alloc 10 -> 9), then reclaim may
    # only take it down to its floor, not to plan-time (10 - 4 = 6) below it
    assert svc.tenants["cold"].alloc >= 4
    svc.check()


def test_auction_uncoverable_deficit_clears_at_zero():
    """Docstring contract: when the whole chain cannot cover the deficit
    the claim clears at price 0 (no marginal winning bid exists)."""
    eng = AuctionEngine()
    claimant = Tenant("hot", "latency", priority=0)
    hpc = Tenant("hpc", "batch", priority=2, alloc=5, demand=50, weight=1.0)
    eng.plan_reclaim(100, [claimant, hpc], claimant)
    assert eng.reclaim_price_n == 1
    assert eng.reclaim_price_sum == 0.0


def test_claim_credits_over_release_without_desync():
    """A victim that releases MORE than asked (e.g. a trainer shrinking by
    whole DP groups) must have the full release credited; the surplus flows
    back through the idle policy instead of desyncing counts."""
    svc = TenantProvisionService(16, policy="paper")
    released = []

    def dp_group_release(n):        # always sheds whole groups of 4
        take = -(-n // 4) * 4
        released.append(take)
        return take

    svc.register(Tenant("hpc", "batch", priority=1,
                        on_force_release=dp_group_release))
    svc.register(Tenant("ws", "latency", priority=0))
    svc.provision_idle()            # all 16 -> hpc
    got = svc.claim("ws", 2)        # forces a 4-device group release
    assert got == 2
    assert released == [4]
    # surplus 2 reflowed to hpc via the idle policy: 16 - 2 claimed
    assert svc.tenants["ws"].alloc == 2
    assert svc.tenants["hpc"].alloc == 14
    assert svc.free == 0
    svc.check()


# ------------------------------------------------- node_failed reattribution

def test_node_failed_empty_pool_reattributes_not_desyncs():
    svc = TenantProvisionService(10, policy="demand_capped")
    svc.register(Tenant("a", "batch", priority=1))
    svc.register(Tenant("b", "latency", priority=0))
    svc.set_demand("a", 10)                     # all 10 -> a
    assert svc.tenants["a"].alloc == 10 and svc.free == 0
    # failure attributed to the EMPTY free pool: reattributed (registration
    # order), never a silent total decrement
    svc.node_failed("free")
    assert svc.total == 9
    assert svc.tenants["a"].alloc == 9
    svc.check()
    # same for an empty tenant pool
    svc.node_failed("b")
    assert svc.total == 8 and svc.tenants["a"].alloc == 8
    svc.check()
    with pytest.raises(KeyError):
        svc.node_failed("zz")
    # empty cluster: impossible event raises instead of desyncing
    empty = TenantProvisionService(0)
    with pytest.raises(ValueError):
        empty.node_failed("free")


def test_legacy_facade_node_failed_empty_pool():
    rps = ResourceProvisionService(4)
    rps.provision_idle_to_st()
    rps.node_failed("ws")          # ws owns nothing -> reattributed to st
    assert rps.total == 3 and rps.st_alloc == 3 and rps.ws_alloc == 0
    rps.check()


# ------------------------------------------------------ timeline downsample

def test_downsample_timeline_keeps_early_history():
    rows = [(float(i), i, 0, 0) for i in range(10_000)]
    out = downsample_timeline(rows, max_points=2000)
    assert len(out) <= 2001
    assert out[0] == rows[0]                     # early history preserved
    assert out[-1] == rows[-1]                   # final state preserved
    # strictly increasing, evenly strided
    times = [r[0] for r in out]
    assert times == sorted(times)
    short = [(0.0, 1, 2, 3)] * 50
    assert downsample_timeline(short, max_points=2000) == short


def test_simresult_timeline_is_downsampled_not_truncated(seed_world):
    jobs, ws = seed_world
    r = run_dynamic(jobs, ws, 160, horizon=2 * DAY)
    assert len(r.util_timeline) <= 2001
    # the first recorded event survives (the seed code truncated to the
    # LAST 2000 rows, losing early history)
    assert r.util_timeline[0][0] <= DAY / 10


# -------------------------------------------------- runtime orchestrator

class _StubTrainer:
    """Duck-typed ElasticTrainer: counts device moves, no JAX."""

    def __init__(self, model_size=2, global_batch=8):
        self.model_size = model_size
        self.global_batch = global_batch
        self.step = 0
        self.devices = []
        self.resizes = 0

    def start(self, devices):
        self.devices = list(devices)

    def resize(self, devices):
        self.devices = list(devices)
        self.resizes += 1


class _StubPool:
    """Duck-typed ServingPool: one replica per device."""

    def __init__(self):
        self.replicas = []

    def scale_to(self, devices):
        self.replicas = list(devices)

    def desired_replicas(self, load):
        return int(load)


def test_multitenant_orchestrator_routes_counts_to_devices():
    from repro.runtime.orchestrator import MultiTenantOrchestrator

    devices = [f"dev{i}" for i in range(16)]
    orch = MultiTenantOrchestrator(devices=devices, policy="demand_capped")
    ta, tb = _StubTrainer(model_size=2, global_batch=4), \
        _StubTrainer(model_size=2, global_batch=2)
    pa, pb = _StubPool(), _StubPool()
    orch.add_latency("ws-a", pa, priority=0)
    orch.add_latency("ws-b", pb, priority=1)
    orch.add_batch("hpc-a", ta, priority=2, weight=2.0)
    orch.add_batch("hpc-b", tb, priority=3)
    orch.start()
    # demand-capped: trainers get their max useful scale (tp*batch), rest free
    assert len(ta.devices) == 8 and len(tb.devices) == 4
    assert len(orch.devs.free) == 4
    orch.devs.check()

    # WS spike: ws-a wants 6 replicas -> 4 free + forced trainer shrink
    orch.latency_tick("ws-a", 6.0)
    assert len(pa.replicas) == 6
    assert len(ta.devices) + len(tb.devices) + len(pa.replicas) + \
        len(orch.devs.free) == 16
    orch.devs.check()
    orch.svc.check()
    # trainer shrank by whole DP groups (multiples of model_size)
    assert len(ta.devices) % ta.model_size == 0
    assert len(tb.devices) % tb.model_size == 0

    # second department preempts the first? no — ws-b is LOWER priority, so
    # it can only drain batch tenants, never ws-a
    orch.latency_tick("ws-b", 20.0)
    assert len(pa.replicas) == 6
    orch.devs.check()

    # load falls: replicas released, idle reflows to the trainers
    orch.latency_tick("ws-a", 0.0)
    orch.latency_tick("ws-b", 0.0)
    assert len(pa.replicas) == 0 and len(pb.replicas) == 0
    assert len(ta.devices) == 8 and len(tb.devices) == 4
    orch.devs.check()
    orch.svc.check()


def test_multitenant_orchestrator_feeds_latency_signals_to_engine():
    """The runtime twin of the simulator's signal path: measured serving
    latency becomes TenantSignals headroom, and the slo_headroom engine
    drains the pool with the most headroom first."""
    from repro.runtime.orchestrator import MultiTenantOrchestrator

    devices = [f"dev{i}" for i in range(12)]
    orch = MultiTenantOrchestrator(devices=devices, policy="slo_headroom")
    hot, cozy = _StubPool(), _StubPool()
    tr = _StubTrainer(model_size=2, global_batch=2)
    orch.add_latency("ws-hot", hot, priority=0, floor=1)
    orch.add_latency("ws-cozy", cozy, priority=1, floor=1)
    orch.add_batch("hpc", tr, priority=2)
    orch.start()
    orch.latency_tick("ws-cozy", 4.0)
    assert len(cozy.replicas) == 4

    # real latency observations flow into the signals channel
    orch.observe_latency("ws-cozy", 0.5)
    sig = orch.svc.tenants["ws-cozy"].signals()
    assert sig.kind == "latency" and sig.alloc == 4
    assert sig.latency_headroom_s == 0.0    # no SLO autoscaler -> target 0

    # a hot claim bigger than free+trainer drains ws-cozy, but only down
    # to its floor
    orch.latency_tick("ws-hot", 11.0)
    assert len(cozy.replicas) >= 1
    assert len(hot.replicas) >= 8
    orch.devs.check()
    orch.svc.check()
    state = orch.svc.policy.state_snapshot()
    assert state["engine"] == "slo_headroom"
    assert "ws-cozy" in state["victim_nodes"]


# ------------------------------------------------------- property invariant

if not HAS_HYPOTHESIS:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_conservation_over_random_n_tenant_sequences():
        pass
else:
    @st.composite
    def tenant_sets(draw):
        n = draw(st.integers(2, 6))
        rows = []
        for i in range(n):
            kind = draw(st.sampled_from(["batch", "latency"]))
            rows.append((f"t{i}", kind, draw(st.integers(0, 5)),
                         draw(st.floats(0.0, 4.0))))
        if not any(k == "latency" for _, k, _, _ in rows):
            rows[0] = (rows[0][0], "latency", rows[0][2], rows[0][3])
        return rows

    @given(total=st.integers(10, 300),
           policy=st.sampled_from(sorted(POLICIES)),
           rows=tenant_sets(),
           ops=st.lists(
               st.tuples(st.sampled_from(["claim", "release", "demand",
                                          "fail", "repair"]),
                         st.integers(0, 5),      # tenant index
                         st.integers(0, 120)),   # amount
               max_size=60))
    @settings(max_examples=80, deadline=None)
    def test_conservation_over_random_n_tenant_sequences(
            total, policy, rows, ops):
        svc = TenantProvisionService(total, policy=policy)
        tenants = []
        for name, kind, prio, weight in rows:
            cb = (lambda k: lambda n: n)(kind)
            tenants.append(svc.register(Tenant(
                name, kind, priority=prio, weight=weight,
                on_force_release=cb if kind == "batch" else None)))
        repairs_due = 0
        for op, ti, n in ops:
            t = tenants[ti % len(tenants)]
            if op == "claim" and t.kind == "latency":
                got = svc.claim(t.name, n)
                assert 0 <= got <= n
            elif op == "release":
                svc.release(t.name, n)
            elif op == "demand" and t.kind == "batch":
                svc.set_demand(t.name, n)
            elif op == "fail":
                if svc.total > 0:
                    svc.node_failed(t.name)     # may reattribute
                    repairs_due += 1
                else:
                    with pytest.raises(ValueError):
                        svc.node_failed(t.name)
            elif op == "repair" and repairs_due > 0:
                svc.node_repaired()
                repairs_due -= 1
            # THE invariant: per-tenant allocations + free == total
            svc.check()
            assert sum(x.alloc for x in tenants) + svc.free == svc.total
            assert svc.free >= 0
            assert all(x.alloc >= 0 for x in tenants)
