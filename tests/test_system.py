"""End-to-end behaviour tests for the paper's system (public API surface)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DAY = 86400.0


def test_consolidation_end_to_end_small():
    """Shared-cluster run: policies + simulator + traces wired together."""
    from repro.core.experiment import run_dynamic, run_static
    from repro.core.traces import synthetic_sdsc_blue, worldcup_demand_events
    jobs = synthetic_sdsc_blue(seed=3, n_jobs=200, horizon=DAY)
    ws = worldcup_demand_events(seed=3, horizon=DAY)
    dc = run_dynamic(jobs, ws, 180, horizon=DAY)
    assert dc.completed > 0
    assert dc.ws_unmet_node_seconds == 0.0
    sc = run_static(jobs, horizon=DAY)
    assert sc.completed > 0


def test_train_and_serve_roundtrip():
    """Train a tiny model a few steps, then serve it with batched requests."""
    from repro.configs import ARCHS, reduced_config
    from repro.configs.base import TrainConfig
    from repro.data.pipeline import SyntheticLM
    from repro.runtime.serving_pool import ServingPool
    from repro.serving.batching import ContinuousBatcher, Request
    from repro.training.train_step import init_state, make_train_step

    cfg = reduced_config(ARCHS["qwen2-7b"])
    state = init_state(jax.random.PRNGKey(0), cfg)
    step = jax.jit(make_train_step(cfg, TrainConfig(learning_rate=1e-3)),
                   donate_argnums=(0,))
    data = SyntheticLM(cfg, seed=1)
    losses = []
    for i in range(4):
        state, m = step(state, data.batch(i, 4, 32))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]

    pool = ServingPool(cfg, state.params, capacity_tokens_per_replica=1e9)
    pool.scale_to(jax.devices()[:1])
    batcher = ContinuousBatcher(max_batch=4)
    for i in range(4):
        batcher.submit(Request(i, np.arange(6, dtype=np.int32) + 1, 4))
    reqs = batcher.next_round()
    batcher.run_round(reqs, pool.submit)
    assert len(batcher.completed) == 4
    assert all(r.done.shape == (4,) for r in batcher.completed)


def test_dryrun_small_mesh_subprocess():
    """The dry-run driver works end-to-end on a test-scale mesh."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "qwen2-7b", "--shape", "decode_32k", "--mesh", "single",
         "--devices", "8", "--mesh-shape", "2,4",
         "--out", "/tmp/dryrun_pytest", "--no-hlo"],
        capture_output=True, text=True, timeout=900, env=env, cwd=REPO)
    assert res.returncode == 0, res.stdout + res.stderr
    assert "ok lower=" in res.stdout


def test_input_specs_cover_all_cells():
    """Every (arch x applicable shape) produces well-formed abstract inputs."""
    from repro.configs import ARCHS, shapes_for
    from repro.launch.specs import input_specs
    cells = 0
    for cfg in ARCHS.values():
        for shape in shapes_for(cfg):
            specs = input_specs(cfg, shape)
            assert all(hasattr(v, "shape") for v in specs.values())
            cells += 1
    assert cells == 33
