"""Trace generator calibration tests (paper §III-B targets)."""
import numpy as np
import pytest

from repro.core.traces import (SDSC_BLUE_JOBS_2W, SDSC_BLUE_NODES,
                               TWO_WEEKS_S, WORLDCUP_PEAK_INSTANCES,
                               WS_CAPACITY_RPS, parse_swf,
                               synthetic_sdsc_blue, synthetic_worldcup_load,
                               worldcup_demand_events)
from repro.core.ws_cms import demand_from_load


def test_sdsc_job_count_and_bounds():
    jobs = synthetic_sdsc_blue(seed=0)
    assert len(jobs) == SDSC_BLUE_JOBS_2W == 2672
    assert all(1 <= j.size <= SDSC_BLUE_NODES for j in jobs)
    assert all(0 <= j.submit_time <= TWO_WEEKS_S for j in jobs)
    assert all(j.runtime > 0 for j in jobs)


def test_sdsc_demand_saturates_dedicated_system():
    jobs = synthetic_sdsc_blue(seed=0)
    node_s = sum(j.size * j.runtime for j in jobs)
    u = node_s / (SDSC_BLUE_NODES * TWO_WEEKS_S)
    assert 0.9 < u < 1.1   # saturation regime of the real machine


def test_worldcup_peak_is_64_instances():
    load, dt = synthetic_worldcup_load(seed=0)
    demand = demand_from_load(load, dt, WS_CAPACITY_RPS)
    assert demand.max() == WORLDCUP_PEAK_INSTANCES


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 5, 7])
@pytest.mark.parametrize("horizon_days", [2, 14])
def test_worldcup_peak_calibration_invariant(seed, horizon_days):
    """The generator iterates the rescale to a fixed point: the autoscaled
    peak must hit exactly 64 instances for ANY seed/horizon, not just the
    ones where a single extra rescale happened to land (the old exact
    float `!=` + one-shot correction did not guarantee this)."""
    horizon = horizon_days * 86400.0
    load, dt = synthetic_worldcup_load(seed=seed, horizon=horizon)
    demand = demand_from_load(load, dt, WS_CAPACITY_RPS)
    assert int(demand.max()) == WORLDCUP_PEAK_INSTANCES


def test_worldcup_peak_to_normal_ratio_high():
    load, _ = synthetic_worldcup_load(seed=0)
    ratio = load.max() / np.median(load)
    assert ratio > 5.0   # paper: "ratio of peak loads to normal loads is high"


def test_demand_events_compression_roundtrip():
    ev = worldcup_demand_events(seed=0)
    assert ev[0][0] == 0.0
    levels = [n for _, n in ev]
    assert max(levels) == WORLDCUP_PEAK_INSTANCES
    # consecutive events always change the level
    assert all(levels[i] != levels[i - 1] for i in range(1, len(levels)))


def test_swf_parser(tmp_path):
    p = tmp_path / "trace.swf"
    p.write_text("""; SWF test
; comment
1 100 0 3600 16 -1 -1 16 -1 -1 1 1 1 1 -1 -1 -1 -1
2 200 5 1800 8 -1 -1 8 -1 -1 1 1 1 1 -1 -1 -1 -1
3 300 5 -1 8 -1 -1 8 -1 -1 1 1 1 1 -1 -1 -1 -1
""")
    jobs = parse_swf(str(p))
    assert len(jobs) == 2            # negative-runtime row dropped
    assert jobs[0].size == 2         # 16 cpus / 8 per node
    assert jobs[0].runtime == 3600.0
    assert jobs[1].submit_time == 200.0
