"""Equivalence of the queue-simulator implementations.

The vectorized fast paths (no-wait check + constant-capacity
Kiefer–Wolfowitz recurrence), the event-merged piecewise sweep, and the
original per-request reference loop must produce *identical*
``QueueMetrics`` — bit-for-bit, since all exact paths do the same float64
arithmetic — across constant and stepped capacity traces, including the
unserved / horizon-cutoff edge cases. The jax batched core runs in float32
and is held to golden tolerance instead.
"""
import numpy as np
import pytest

from repro.core.types import SLOConfig
from repro.serving.batching import ServiceTimeModel
from repro.workloads.arrivals import make_trace
from repro.workloads.queueing import (SIM_COUNTERS, QueueJob, capacity_steps,
                                      counters_delta, plan_queue_buckets,
                                      simulate_queue, simulate_queue_batch,
                                      simulate_queue_many,
                                      simulate_queue_reference,
                                      snapshot_counters)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:       # container without hypothesis: property tests skip
    HAVE_HYPOTHESIS = False

MODEL = ServiceTimeModel()
SLO = SLOConfig(latency_target_s=30.0)
KINDS = ("poisson", "mmpp", "diurnal", "flash_crowd")


def random_capacity(rng, horizon, max_nodes=10, max_steps=12):
    """Random piecewise capacity, deliberately including zero levels."""
    ev = [(0.0, int(rng.integers(0, max_nodes)))]
    for _ in range(int(rng.integers(0, max_steps))):
        ev.append((float(rng.uniform(0.0, horizon)),
                   int(rng.integers(0, max_nodes))))
    return ev


def assert_same(a, b, ctx=""):
    assert a == b, f"{ctx}\n  {a}\n  {b}"


def assert_golden(m, ref, ctx="", rtol=3e-4, atol=2e-3):
    """float32 batched metrics vs a float64 exact oracle.

    float32 drift can flip borderline served/unserved decisions right at
    capacity-window and horizon edges; tolerate a small flip count, and
    when a flip did occur the percentile stats straddle different request
    sets, so only the count is compared."""
    assert m.n_requests == ref.n_requests, ctx
    flip_tol = max(2, int(0.002 * max(ref.n_requests, 1)))
    assert abs(m.unserved - ref.unserved) <= flip_tol, \
        (ctx, m.unserved, ref.unserved)
    if m.unserved != ref.unserved:
        return
    for f in ("p50_s", "p95_s", "p99_s", "mean_s", "max_s", "mean_wait_s",
              "violation_rate"):
        a, b = getattr(m, f), getattr(ref, f)
        ok = (np.isinf(a) and np.isinf(b)) or np.isclose(a, b, rtol=rtol,
                                                         atol=atol)
        assert ok, (ctx, f, a, b)


# ----------------------------------------------------- randomized sweeps


@pytest.mark.parametrize("seed", range(6))
def test_all_impls_agree_on_random_piecewise(seed):
    rng = np.random.default_rng(seed)
    kind = KINDS[seed % len(KINDS)]
    horizon = 3600.0
    tr = make_trace(kind, float(rng.uniform(0.3, 4.0)), horizon, seed)
    for _ in range(4):
        ev = random_capacity(rng, horizon)
        for hz in (horizon, 0.5 * horizon, None):
            ref = simulate_queue_reference(tr, ev, MODEL, SLO, horizon=hz)
            auto = simulate_queue(tr, ev, MODEL, SLO, horizon=hz)
            evn = simulate_queue(tr, ev, MODEL, SLO, horizon=hz,
                                 impl="event")
            assert_same(ref, auto, f"auto {kind} {ev[:3]} hz={hz}")
            assert_same(ref, evn, f"event {kind} {ev[:3]} hz={hz}")


@pytest.mark.parametrize("seed", range(4))
def test_all_impls_agree_on_constant_capacity(seed):
    rng = np.random.default_rng(100 + seed)
    tr = make_trace(KINDS[seed % len(KINDS)],
                    float(rng.uniform(0.5, 3.0)), 3600.0, seed)
    for nodes in (0, 1, int(rng.integers(2, 8)), 500):
        ev = [(0.0, nodes)]
        ref = simulate_queue_reference(tr, ev, MODEL, SLO, horizon=3600.0)
        auto = simulate_queue(tr, ev, MODEL, SLO, horizon=3600.0)
        assert_same(ref, auto, f"constant k={nodes}")
        if nodes > 0:
            fast = simulate_queue(tr, ev, MODEL, SLO, horizon=3600.0,
                                  impl="fast")
            assert_same(ref, fast, f"fast k={nodes}")


# ----------------------------------------------------------- edge cases


def test_unserved_horizon_cutoff_agrees():
    tr = make_trace("poisson", 1.0, 600.0, seed=0)
    # starvation window then rescue, cut at a horizon inside the backlog
    ev = [(0.0, 0), (300.0, 1), (450.0, 0), (500.0, 2)]
    ref = simulate_queue_reference(tr, ev, MODEL, SLO, horizon=550.0)
    auto = simulate_queue(tr, ev, MODEL, SLO, horizon=550.0)
    assert_same(ref, auto)
    assert ref.unserved > 0


def test_zero_capacity_all_unserved_agrees():
    tr = make_trace("poisson", 1.0, 600.0, seed=0)
    for impl in ("auto", "event", "reference"):
        m = simulate_queue(tr, [(0.0, 0)], MODEL, SLO, horizon=600.0,
                           impl=impl)
        assert m.unserved == len(tr)
        assert m.violation_rate == 1.0 and not m.slo_met


def test_empty_trace():
    tr = make_trace("poisson", 1.0, 600.0, seed=0)
    empty = type(tr)(np.empty(0), np.empty(0, np.int64),
                     np.empty(0, np.int64))
    for impl in ("auto", "event", "reference"):
        m = simulate_queue(empty, [(0.0, 4)], MODEL, SLO, impl=impl)
        assert m.n_requests == 0 and m.slo_met


def test_fast_impl_rejects_contended_piecewise():
    tr = make_trace("poisson", 2.0, 3600.0, seed=0)
    with pytest.raises(ValueError):
        simulate_queue(tr, [(0.0, 1), (600.0, 2)], MODEL, SLO,
                       horizon=3600.0, impl="fast")
    with pytest.raises(ValueError):
        simulate_queue(tr, [(0.0, 4)], MODEL, SLO, impl="nope")


def test_no_wait_path_used_and_counted():
    tr = make_trace("poisson", 0.5, 1800.0, seed=0)
    before = snapshot_counters()
    m = simulate_queue(tr, [(0.0, 1000)], MODEL, SLO, horizon=1800.0)
    d = counters_delta(before)
    assert d["no_wait"] == 1 and d["requests"] == len(tr)
    assert d["seconds"] > 0
    assert m.mean_wait_s == 0.0
    ref = simulate_queue_reference(tr, [(0.0, 1000)], MODEL, SLO,
                                   horizon=1800.0)
    assert_same(ref, m)


def test_capacity_steps_unchanged_semantics():
    t, k = capacity_steps([(5.0, 2), (0.0, 1), (5.0, 3)], slots_per_node=4)
    assert list(t) == [0.0, 5.0]
    assert list(k) == [4, 12]


# ------------------------------------------------------------ jax batched


def test_simulate_queue_many_matches_exact_paths():
    traces = [make_trace(k, 1.5, 1800.0, s)
              for s, k in enumerate(("poisson", "mmpp", "flash_crowd"))]
    caps = [[(0.0, 2)], [(0.0, 4)], [(0.0, 1), (600.0, 3)]]  # mixed const/pw
    many = simulate_queue_many(traces, caps, MODEL, SLO, horizon=1800.0)
    assert len(many) == len(traces)
    for tr, ev, m in zip(traces, caps, many):
        ex = simulate_queue(tr, ev, MODEL, SLO, horizon=1800.0)
        assert m.n_requests == ex.n_requests
        assert m.unserved == ex.unserved
        for f in ("p50_s", "p95_s", "p99_s", "mean_s", "mean_wait_s",
                  "violation_rate"):
            a, b = getattr(m, f), getattr(ex, f)
            assert (np.isinf(a) and np.isinf(b)) or \
                np.isclose(a, b, rtol=2e-4, atol=1e-3), (f, a, b)


def test_simulate_queue_many_numpy_backend_exact():
    traces = [make_trace("poisson", 1.0, 900.0, s) for s in range(2)]
    caps = [[(0.0, 2)], [(0.0, 3)]]
    many = simulate_queue_many(traces, caps, MODEL, SLO, horizon=900.0,
                               backend="numpy")
    for tr, ev, m in zip(traces, caps, many):
        assert_same(simulate_queue_reference(tr, ev, MODEL, SLO,
                                             horizon=900.0), m)


# ------------------------------------------- piecewise jax batched path


def _pw_jobs(seed, n_cells=8, horizon=1800.0, max_steps=10):
    rng = np.random.default_rng(seed)
    jobs = []
    for i in range(n_cells):
        tr = make_trace(KINDS[i % len(KINDS)],
                        float(rng.uniform(0.4, 3.0)), horizon, seed + i)
        ev = random_capacity(rng, horizon, max_steps=max_steps)
        if len(ev) == 1:               # force a genuinely piecewise cell
            ev.append((horizon / 2, int(rng.integers(0, 10))))
        jobs.append(QueueJob(tr, ev, MODEL, SLO, horizon=horizon))
    return jobs


@pytest.mark.parametrize("seed", range(4))
def test_batched_piecewise_matches_reference(seed):
    jobs = _pw_jobs(seed)
    tags: list = []
    many = simulate_queue_batch(jobs, stats_out=tags)
    assert tags.count("jax_batched") in (0, len(jobs))  # all or no-JAX
    for job, m in zip(jobs, many):
        ref = simulate_queue_reference(job.trace, job.capacity_events,
                                       job.model, job.slo,
                                       horizon=job.horizon)
        assert_golden(m, ref, f"seed={seed} ev={job.capacity_events[:3]}")


def test_batched_piecewise_edge_cases():
    """Zero-capacity windows, capacity drop mid-queue, horizon cutoff in
    the backlog — the drain semantics of the blocked-search oracle."""
    tr = make_trace("poisson", 1.0, 600.0, seed=0)
    cases = [
        ([(0.0, 0)], 600.0),                               # never serves
        ([(0.0, 0), (300.0, 1), (450.0, 0), (500.0, 2)], 550.0),
        ([(0.0, 5), (100.0, 1)], 600.0),                   # drop mid-queue
        ([(0.0, 2), (200.0, 0), (400.0, 2)], 600.0),       # outage window
        ([(0.0, 1), (590.0, 8)], 595.0),                   # cutoff at edge
    ]
    jobs = [QueueJob(tr, ev, MODEL, SLO, horizon=hz) for ev, hz in cases]
    many = simulate_queue_batch(jobs)
    for job, m in zip(jobs, many):
        ref = simulate_queue_reference(tr, job.capacity_events, MODEL, SLO,
                                       horizon=job.horizon)
        assert_golden(m, ref, f"ev={job.capacity_events}")
    assert many[0].unserved == len(tr)


def test_batched_composition_independent():
    """A cell's batched metrics must not depend on what it was co-batched
    with: bucket shapes are pure per-cell functions (n_pad) or value
    invariant (e/k padded to batch max), so solo == co-batched exactly."""
    jobs = _pw_jobs(42, n_cells=6)
    solo = [simulate_queue_batch([j])[0] for j in jobs]
    grouped = simulate_queue_batch(jobs)
    for a, b in zip(solo, grouped):
        assert a == b      # bitwise, not golden-tolerance


def test_batched_mixed_const_and_piecewise_buckets():
    horizon = 1200.0
    tr1 = make_trace("poisson", 1.5, horizon, seed=1)
    tr2 = make_trace("mmpp", 1.5, horizon, seed=2)
    jobs = [QueueJob(tr1, [(0.0, 2)], MODEL, SLO, horizon),
            QueueJob(tr2, [(0.0, 1), (600.0, 3)], MODEL, SLO, horizon),
            QueueJob(tr1, [(0.0, 4)], MODEL, SLO, horizon),
            QueueJob(tr2, [(0.0, 3), (300.0, 0), (700.0, 2)], MODEL, SLO,
                     horizon)]
    kinds = {k[0] for k in plan_queue_buckets(jobs)}
    many = simulate_queue_batch(jobs)
    assert kinds <= {"const", "pw"} and len(kinds) in (1, 2)
    for job, m in zip(jobs, many):
        ref = simulate_queue_reference(job.trace, job.capacity_events,
                                       MODEL, SLO, horizon=horizon)
        assert_golden(m, ref, f"ev={job.capacity_events}")


def test_batched_counter_attribution():
    jobs = _pw_jobs(7, n_cells=3)
    before = snapshot_counters()
    tags: list = []
    simulate_queue_batch(jobs, stats_out=tags)
    d = counters_delta(before)
    assert d["calls"] == 3 and d["requests"] == sum(len(j.trace)
                                                    for j in jobs)
    if tags.count("jax_batched") == 3:
        assert d["jax_batched"] == 3
    assert "jax_batched" in SIM_COUNTERS


# ------------------------------------------------- bucket plan regression


def test_bucket_padding_stays_proportional():
    """Regression for the old global-pad behaviour: one huge trace used to
    inflate every cell to its padded length. With shape buckets the total
    padded element count must stay within a constant factor of the sum of
    the actual cell sizes — regardless of size skew in the batch."""
    rng = np.random.default_rng(3)
    horizon = 1800.0
    jobs = []
    sizes = [60, 120, 450, 900, 1800, 3600, 7000, 14000]
    for i, n_target in enumerate(sizes):
        rate = n_target / horizon
        tr = make_trace("poisson", rate, horizon, seed=i)
        ev = random_capacity(rng, horizon) if i % 2 else [(0.0, 4)]
        jobs.append(QueueJob(tr, ev, MODEL, SLO, horizon))
    buckets = plan_queue_buckets(jobs)
    total_padded = sum(len(rows) * key[1] for key, rows in buckets.items())
    total_actual = sum(len(j.trace) for j in jobs)
    # floor=256 means tiny cells pad hard; everything else is <2x. Under
    # the old single global pad this ratio was ~len(jobs) for skewed sets.
    floor_slack = sum(max(256 - len(j.trace), 0) for j in jobs)
    assert total_padded <= 2 * total_actual + floor_slack
    # and every job with a non-empty trace is planned exactly once
    planned = sorted(i for rows in buckets.values() for i in rows)
    assert planned == list(range(len(jobs)))


def test_bucket_key_is_per_cell_pure():
    """n_pad must depend only on the cell itself (fold reduction-tree
    shape), never on batch company — shard merges rely on it."""
    jobs = _pw_jobs(11, n_cells=5)
    solo_keys = {}
    for i, j in enumerate(jobs):
        (key, rows), = plan_queue_buckets([j]).items()
        solo_keys[i] = key
    grouped = plan_queue_buckets(jobs)
    for key, rows in grouped.items():
        for i in rows:
            assert solo_keys[i] == key


# ------------------------------------------------- hypothesis (optional)


if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 10_000),
           rate=st.floats(0.1, 5.0),
           nodes=st.integers(0, 8),
           steps=st.integers(0, 8))
    def test_property_impls_identical(seed, rate, nodes, steps):
        rng = np.random.default_rng(seed)
        tr = make_trace(KINDS[seed % len(KINDS)], rate, 1200.0, seed)
        ev = [(0.0, nodes)]
        for _ in range(steps):
            ev.append((float(rng.uniform(0, 1200.0)),
                       int(rng.integers(0, 8))))
        ref = simulate_queue_reference(tr, ev, MODEL, SLO, horizon=1200.0)
        auto = simulate_queue(tr, ev, MODEL, SLO, horizon=1200.0)
        assert ref == auto

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 10_000),
           rate=st.floats(0.2, 3.0),
           nodes=st.integers(0, 8),
           steps=st.integers(1, 10),
           hz_frac=st.floats(0.3, 1.0))
    def test_property_batched_piecewise_golden(seed, rate, nodes, steps,
                                               hz_frac):
        """The jax piecewise batched core vs the reference oracle under
        random capacity schedules (incl. zero windows) and horizon cuts."""
        rng = np.random.default_rng(seed)
        tr = make_trace(KINDS[seed % len(KINDS)], rate, 1200.0, seed)
        ev = [(0.0, nodes)]
        for _ in range(steps):
            ev.append((float(rng.uniform(0, 1200.0)),
                       int(rng.integers(0, 8))))
        hz = 1200.0 * hz_frac
        m = simulate_queue_batch([QueueJob(tr, ev, MODEL, SLO, hz)])[0]
        ref = simulate_queue_reference(tr, ev, MODEL, SLO, horizon=hz)
        assert_golden(m, ref, f"ev={ev[:4]} hz={hz:.0f}")
else:

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_property_impls_identical():
        pass

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_property_batched_piecewise_golden():
        pass
