"""Equivalence of the queue-simulator implementations.

The vectorized fast paths (no-wait check + constant-capacity
Kiefer–Wolfowitz recurrence), the event-merged piecewise sweep, and the
original per-request reference loop must produce *identical*
``QueueMetrics`` — bit-for-bit, since all exact paths do the same float64
arithmetic — across constant and stepped capacity traces, including the
unserved / horizon-cutoff edge cases. The jax batched core runs in float32
and is held to golden tolerance instead.
"""
import numpy as np
import pytest

from repro.core.types import SLOConfig
from repro.serving.batching import ServiceTimeModel
from repro.workloads.arrivals import make_trace
from repro.workloads.queueing import (SIM_COUNTERS, capacity_steps,
                                      counters_delta, simulate_queue,
                                      simulate_queue_many,
                                      simulate_queue_reference,
                                      snapshot_counters)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:       # container without hypothesis: property tests skip
    HAVE_HYPOTHESIS = False

MODEL = ServiceTimeModel()
SLO = SLOConfig(latency_target_s=30.0)
KINDS = ("poisson", "mmpp", "diurnal", "flash_crowd")


def random_capacity(rng, horizon, max_nodes=10, max_steps=12):
    """Random piecewise capacity, deliberately including zero levels."""
    ev = [(0.0, int(rng.integers(0, max_nodes)))]
    for _ in range(int(rng.integers(0, max_steps))):
        ev.append((float(rng.uniform(0.0, horizon)),
                   int(rng.integers(0, max_nodes))))
    return ev


def assert_same(a, b, ctx=""):
    assert a == b, f"{ctx}\n  {a}\n  {b}"


# ----------------------------------------------------- randomized sweeps


@pytest.mark.parametrize("seed", range(6))
def test_all_impls_agree_on_random_piecewise(seed):
    rng = np.random.default_rng(seed)
    kind = KINDS[seed % len(KINDS)]
    horizon = 3600.0
    tr = make_trace(kind, float(rng.uniform(0.3, 4.0)), horizon, seed)
    for _ in range(4):
        ev = random_capacity(rng, horizon)
        for hz in (horizon, 0.5 * horizon, None):
            ref = simulate_queue_reference(tr, ev, MODEL, SLO, horizon=hz)
            auto = simulate_queue(tr, ev, MODEL, SLO, horizon=hz)
            evn = simulate_queue(tr, ev, MODEL, SLO, horizon=hz,
                                 impl="event")
            assert_same(ref, auto, f"auto {kind} {ev[:3]} hz={hz}")
            assert_same(ref, evn, f"event {kind} {ev[:3]} hz={hz}")


@pytest.mark.parametrize("seed", range(4))
def test_all_impls_agree_on_constant_capacity(seed):
    rng = np.random.default_rng(100 + seed)
    tr = make_trace(KINDS[seed % len(KINDS)],
                    float(rng.uniform(0.5, 3.0)), 3600.0, seed)
    for nodes in (0, 1, int(rng.integers(2, 8)), 500):
        ev = [(0.0, nodes)]
        ref = simulate_queue_reference(tr, ev, MODEL, SLO, horizon=3600.0)
        auto = simulate_queue(tr, ev, MODEL, SLO, horizon=3600.0)
        assert_same(ref, auto, f"constant k={nodes}")
        if nodes > 0:
            fast = simulate_queue(tr, ev, MODEL, SLO, horizon=3600.0,
                                  impl="fast")
            assert_same(ref, fast, f"fast k={nodes}")


# ----------------------------------------------------------- edge cases


def test_unserved_horizon_cutoff_agrees():
    tr = make_trace("poisson", 1.0, 600.0, seed=0)
    # starvation window then rescue, cut at a horizon inside the backlog
    ev = [(0.0, 0), (300.0, 1), (450.0, 0), (500.0, 2)]
    ref = simulate_queue_reference(tr, ev, MODEL, SLO, horizon=550.0)
    auto = simulate_queue(tr, ev, MODEL, SLO, horizon=550.0)
    assert_same(ref, auto)
    assert ref.unserved > 0


def test_zero_capacity_all_unserved_agrees():
    tr = make_trace("poisson", 1.0, 600.0, seed=0)
    for impl in ("auto", "event", "reference"):
        m = simulate_queue(tr, [(0.0, 0)], MODEL, SLO, horizon=600.0,
                           impl=impl)
        assert m.unserved == len(tr)
        assert m.violation_rate == 1.0 and not m.slo_met


def test_empty_trace():
    tr = make_trace("poisson", 1.0, 600.0, seed=0)
    empty = type(tr)(np.empty(0), np.empty(0, np.int64),
                     np.empty(0, np.int64))
    for impl in ("auto", "event", "reference"):
        m = simulate_queue(empty, [(0.0, 4)], MODEL, SLO, impl=impl)
        assert m.n_requests == 0 and m.slo_met


def test_fast_impl_rejects_contended_piecewise():
    tr = make_trace("poisson", 2.0, 3600.0, seed=0)
    with pytest.raises(ValueError):
        simulate_queue(tr, [(0.0, 1), (600.0, 2)], MODEL, SLO,
                       horizon=3600.0, impl="fast")
    with pytest.raises(ValueError):
        simulate_queue(tr, [(0.0, 4)], MODEL, SLO, impl="nope")


def test_no_wait_path_used_and_counted():
    tr = make_trace("poisson", 0.5, 1800.0, seed=0)
    before = snapshot_counters()
    m = simulate_queue(tr, [(0.0, 1000)], MODEL, SLO, horizon=1800.0)
    d = counters_delta(before)
    assert d["no_wait"] == 1 and d["requests"] == len(tr)
    assert d["seconds"] > 0
    assert m.mean_wait_s == 0.0
    ref = simulate_queue_reference(tr, [(0.0, 1000)], MODEL, SLO,
                                   horizon=1800.0)
    assert_same(ref, m)


def test_capacity_steps_unchanged_semantics():
    t, k = capacity_steps([(5.0, 2), (0.0, 1), (5.0, 3)], slots_per_node=4)
    assert list(t) == [0.0, 5.0]
    assert list(k) == [4, 12]


# ------------------------------------------------------------ jax batched


def test_simulate_queue_many_matches_exact_paths():
    traces = [make_trace(k, 1.5, 1800.0, s)
              for s, k in enumerate(("poisson", "mmpp", "flash_crowd"))]
    caps = [[(0.0, 2)], [(0.0, 4)], [(0.0, 1), (600.0, 3)]]  # mixed const/pw
    many = simulate_queue_many(traces, caps, MODEL, SLO, horizon=1800.0)
    assert len(many) == len(traces)
    for tr, ev, m in zip(traces, caps, many):
        ex = simulate_queue(tr, ev, MODEL, SLO, horizon=1800.0)
        assert m.n_requests == ex.n_requests
        assert m.unserved == ex.unserved
        for f in ("p50_s", "p95_s", "p99_s", "mean_s", "mean_wait_s",
                  "violation_rate"):
            a, b = getattr(m, f), getattr(ex, f)
            assert (np.isinf(a) and np.isinf(b)) or \
                np.isclose(a, b, rtol=2e-4, atol=1e-3), (f, a, b)


def test_simulate_queue_many_numpy_backend_exact():
    traces = [make_trace("poisson", 1.0, 900.0, s) for s in range(2)]
    caps = [[(0.0, 2)], [(0.0, 3)]]
    many = simulate_queue_many(traces, caps, MODEL, SLO, horizon=900.0,
                               backend="numpy")
    for tr, ev, m in zip(traces, caps, many):
        assert_same(simulate_queue_reference(tr, ev, MODEL, SLO,
                                             horizon=900.0), m)


# ------------------------------------------------- hypothesis (optional)


if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 10_000),
           rate=st.floats(0.1, 5.0),
           nodes=st.integers(0, 8),
           steps=st.integers(0, 8))
    def test_property_impls_identical(seed, rate, nodes, steps):
        rng = np.random.default_rng(seed)
        tr = make_trace(KINDS[seed % len(KINDS)], rate, 1200.0, seed)
        ev = [(0.0, nodes)]
        for _ in range(steps):
            ev.append((float(rng.uniform(0, 1200.0)),
                       int(rng.integers(0, 8))))
        ref = simulate_queue_reference(tr, ev, MODEL, SLO, horizon=1200.0)
        auto = simulate_queue(tr, ev, MODEL, SLO, horizon=1200.0)
        assert ref == auto
else:

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_property_impls_identical():
        pass
