"""Deep unit tests: MoE dispatch semantics and chunked attention oracles."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced_config
from repro.configs.base import MoEConfig
from repro.models.moe import _capacity, _combine_group, _dispatch_group, \
    moe_forward

KEY = jax.random.PRNGKey(11)


# ------------------------------------------------------------------- MoE


def dense_moe_oracle(p, x, cfg, act_name="silu"):
    """Compute-every-expert oracle: y = sum_k prob_k * expert_k(x)."""
    from repro.models.layers import activation
    act = activation(act_name)
    logits = x.astype(jnp.float32) @ p["router"]["kernel"]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, cfg.moe.top_k)
    top_p = top_p / top_p.sum(-1, keepdims=True)
    # all experts on all tokens: [B,S,E,ff]
    h = act(jnp.einsum("bsd,edf->bsef", x, p["wi_gate"])) * \
        jnp.einsum("bsd,edf->bsef", x, p["wi_up"])
    y_all = jnp.einsum("bsef,efd->bsed", h, p["wo"])
    onehot = jax.nn.one_hot(top_i, cfg.moe.num_experts)       # [B,S,k,E]
    w = jnp.einsum("bske,bsk->bse", onehot, top_p)
    return jnp.einsum("bsed,bse->bsd", y_all, w)


def test_moe_matches_dense_oracle_when_dropless():
    cfg = reduced_config(ARCHS["qwen3-moe-30b-a3b"])  # cf=8 => dropless here
    from repro.models.moe import init_moe
    p = init_moe(KEY, cfg, jnp.float32)
    x = jax.random.normal(KEY, (2, 16, cfg.d_model), jnp.float32)
    y, aux = moe_forward(p, x, cfg, num_groups=2)
    y_ref = dense_moe_oracle(p, x, cfg, cfg.act)
    assert float(jnp.max(jnp.abs(y - y_ref))) < 2e-4
    assert float(aux["moe_lb"]) > 0.0


def test_dispatch_drops_beyond_capacity():
    E, cap, d = 4, 2, 8
    n, k = 6, 1
    xg = jnp.arange(n * d, dtype=jnp.float32).reshape(n, d)
    # all tokens want expert 0: only `cap` survive
    eidx = jnp.zeros((n, k), jnp.int32)
    probs = jnp.ones((n, k), jnp.float32)
    buf, coords = _dispatch_group(xg, probs, eidx, E, cap)
    keep = coords[3]
    assert int(keep.sum()) == cap
    # kept tokens are the FIRST cap tokens (stable sort preserves order)
    np.testing.assert_array_equal(np.asarray(buf[0, 0]), np.asarray(xg[0]))
    np.testing.assert_array_equal(np.asarray(buf[0, 1]), np.asarray(xg[1]))
    # combine returns zeros for dropped tokens
    y = _combine_group(buf, coords, n)
    assert float(jnp.abs(y[cap:]).max()) == 0.0


def test_capacity_is_mxu_aligned():
    m = MoEConfig(num_experts=8, top_k=2, d_ff_expert=16, capacity_factor=1.0)
    assert _capacity(100, m) % 8 == 0
    assert _capacity(1, m) == 8              # floor


def test_moe_group_invariance():
    """Group count changes dispatch locality, not (dropless) results."""
    cfg = reduced_config(ARCHS["dbrx-132b"])
    from repro.models.moe import init_moe
    p = init_moe(KEY, cfg, jnp.float32)
    x = jax.random.normal(KEY, (4, 8, cfg.d_model), jnp.float32)
    y1, _ = moe_forward(p, x, cfg, num_groups=1)
    y2, _ = moe_forward(p, x, cfg, num_groups=4)
    assert float(jnp.max(jnp.abs(y1 - y2))) < 2e-4


# ------------------------------------------------ chunked attention oracle


def naive_causal_attention(q, k, v, positions, window=0):
    B, S, H, hd = q.shape
    K = k.shape[2]
    g = H // K
    kk = jnp.repeat(k, g, axis=2)
    vv = jnp.repeat(v, g, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kk) / math.sqrt(hd)
    qp, kp = positions[:, None], positions[None, :]
    mask = kp <= qp
    if window:
        mask &= kp > qp - window
    s = jnp.where(mask[None, None], s.astype(jnp.float32), -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", w.astype(vv.dtype), vv)


@pytest.mark.parametrize("S,window,qc", [(64, 0, 16), (128, 0, 64),
                                         (64, 24, 16), (128, 32, 32)])
def test_chunked_attention_matches_naive(S, window, qc):
    from repro.models.attention import chunked_causal_attention
    B, H, K, hd = 2, 4, 2, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, K, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, K, hd), jnp.float32)
    pos = jnp.arange(S)
    out = chunked_causal_attention(q, k, v, pos, window=window, q_chunk=qc)
    ref = naive_causal_attention(q, k, v, pos, window=window)
    assert float(jnp.max(jnp.abs(out - ref))) < 2e-5


def test_chunked_attention_chunk_size_invariance():
    from repro.models.attention import chunked_causal_attention
    B, S, H, K, hd = 1, 128, 2, 1, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, K, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, K, hd), jnp.float32)
    pos = jnp.arange(S)
    a = chunked_causal_attention(q, k, v, pos, q_chunk=32)
    b = chunked_causal_attention(q, k, v, pos, q_chunk=128)
    assert float(jnp.max(jnp.abs(a - b))) < 2e-5


# --------------------------------------------------------- optimizer units


def test_int8_grad_compression_bounded_error():
    from repro.training.optimizer import quantize_int8
    g = {"w": jax.random.normal(KEY, (64, 64)) * 0.01}
    gq = quantize_int8(g)
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127.0
    assert float(jnp.max(jnp.abs(gq["w"] - g["w"]))) <= scale * 0.5 + 1e-9


def test_adamw_decreases_loss_on_quadratic():
    from repro.configs.base import TrainConfig
    from repro.training.optimizer import adamw_update, init_opt_state
    tcfg = TrainConfig(learning_rate=0.1, weight_decay=0.0, z_loss=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    opt = init_opt_state(params)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}       # d/dw ||w||^2
        params, opt, _ = adamw_update(opt, grads, params, tcfg)
    assert float(jnp.abs(params["w"]).max()) < 0.3


def test_moe_expert_parallel_same_math_on_single_device():
    """EP changes sharding, not semantics: identical outputs on one device."""
    import dataclasses
    cfg = reduced_config(ARCHS["qwen3-moe-30b-a3b"])
    cfg_ep = cfg.with_(moe=dataclasses.replace(cfg.moe,
                                               expert_parallel=True))
    from repro.models.moe import init_moe
    p = init_moe(KEY, cfg, jnp.float32)
    x = jax.random.normal(KEY, (2, 16, cfg.d_model), jnp.float32)
    y1, _ = moe_forward(p, x, cfg, num_groups=2)
    y2, _ = moe_forward(p, x, cfg_ep, num_groups=2)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-6)
