"""Unit tests for the paper's provisioning / CMS policies (§II-B)."""
import pytest

from repro.core.provision import ResourceProvisionService
from repro.core.st_cms import STServer
from repro.core.types import Job, JobState, SimConfig, SLOConfig
from repro.core.ws_cms import WSServer, demand_from_load

import numpy as np


def make_st(cfg=None):
    finishes = []
    st = STServer(cfg or SimConfig(), lambda j, t: finishes.append((j, t)),
                  lambda j: None)
    return st, finishes


def test_provision_idle_goes_to_st():
    rps = ResourceProvisionService(100)
    granted = []
    rps.on_grant_st = granted.append
    rps.provision_idle_to_st()
    assert rps.st_alloc == 100 and rps.free == 0 and granted == [100]


def test_ws_priority_forces_st_release():
    rps = ResourceProvisionService(10)
    rps.provision_idle_to_st()
    released = []

    def force(n):
        released.append(n)
        return n

    rps.force_st_release = force
    got = rps.ws_request(4)
    assert got == 4 and released == [4]
    assert rps.ws_alloc == 4 and rps.st_alloc == 6
    rps.check()


def test_ws_release_reprovisions_to_st():
    rps = ResourceProvisionService(10)
    rps.force_st_release = lambda n: n
    rps.provision_idle_to_st()
    rps.ws_request(5)
    assert rps.ws_alloc == 5
    rps.ws_release(3)
    # released nodes must flow straight back to ST (rule 2)
    assert rps.free == 0 and rps.st_alloc == 8 and rps.ws_alloc == 2


def test_kill_order_min_size_then_shortest_running():
    st, _ = make_st()
    st.grant(16, now=0.0)   # exactly 8+4+4: no idle to absorb the reclaim
    jobs = [Job(1, 0.0, 8, 1000.0), Job(2, 0.0, 4, 1000.0),
            Job(3, 0.0, 4, 1000.0)]
    st.submit(jobs[0], 0.0)
    st.submit(jobs[1], 0.0)   # starts at t=0
    # make job 3 start later => shorter running time at kill
    st.submit(jobs[2], 0.0)
    # all three fit (8+4+4=16 <= 20); simulate kill at t=10 after j3
    # restarted at t=5
    jobs[2].start_time = 5.0
    st.force_release(2, now=10.0)
    # min size is 4 (jobs 2,3); shortest running = job 3 (started at 5)
    assert jobs[2].state is JobState.KILLED
    assert jobs[1].state is JobState.RUNNING
    assert jobs[0].state is JobState.RUNNING


def test_force_release_uses_idle_first():
    st, _ = make_st()
    st.grant(10, 0.0)
    j = Job(1, 0.0, 4, 100.0)
    st.submit(j, 0.0)
    assert st.idle == 6
    got = st.force_release(5, 0.0)
    assert got == 5
    assert j.state is JobState.RUNNING          # idle covered the reclaim
    assert st.alloc == 5 and st.idle == 1


def test_checkpoint_preempt_requeues_with_progress():
    cfg = SimConfig(preempt_mode="checkpoint", checkpoint_cost=10.0)
    st, _ = make_st(cfg)
    st.grant(4, 0.0)
    j = Job(1, 0.0, 4, 1000.0)
    st.submit(j, 0.0)
    st.force_release(4, now=500.0)
    assert j.state is JobState.QUEUED
    assert j.kills == 1
    # 500s elapsed - 10s checkpoint cost preserved
    assert j.checkpointed_work == pytest.approx(490.0)
    assert j.remaining() == pytest.approx(510.0)


def test_autoscaler_rule_up_and_down():
    # constant high load -> scale up by one per 20s window
    load = np.full(10, 1000.0)   # dt=20 -> one decision per sample
    d = demand_from_load(load, 20.0, capacity_per_instance=100.0)
    assert list(d[:5]) == [2, 3, 4, 5, 6]   # util>0.8 each window -> +1
    # low load -> scale down to floor 1
    load = np.full(10, 10.0)
    d2 = demand_from_load(load, 20.0, 100.0, n0=5)
    assert d2[-1] == 1 and d2[0] <= 5


def test_ws_server_tracks_unmet_demand():
    cfg = SimConfig()
    granted = {"n": 3}
    ws = WSServer(cfg, request=lambda n: min(n, granted["n"]),
                  release=lambda n: None)
    ws.set_demand(5, now=0.0)      # only 3 granted
    assert ws.alloc == 3
    ws.set_demand(5, now=10.0)     # 10s with shortfall 2
    assert ws.unmet_node_seconds == pytest.approx(20.0)


def test_ws_headroom_proxy_clamps_at_zero_without_latency_feed():
    """Regression (market PR): a replica shortfall made the surplus proxy
    predict NEGATIVE headroom, which inflated slo_elastic bids beyond the
    zero-headroom level — without any measured violation. The proxy must
    clamp at 0; a real observe_latency feed may still go negative."""
    from repro.core.policies import Tenant, unit_bid

    ws = WSServer(SimConfig(), request=lambda n: 0,   # nothing ever granted
                  release=lambda n: None,
                  slo=SLOConfig(latency_target_s=30.0))
    ws.set_demand(10, now=0.0)
    assert ws.alloc == 0                   # shortfall of 10 replicas
    assert ws.latency_headroom_s() == 0.0  # proxy clamped, not -300
    sig = ws.signals(0.0, name="ws")
    assert sig.latency_headroom_s == 0.0
    assert sig.queue_depth == 10           # shortfall still visible here
    # slo_elastic bid tops out at the zero-headroom level (2x), instead of
    # overshooting toward the violation cap on a mere prediction
    t = Tenant("ws", "latency", priority=0, bid_weight=2.0,
               bid_policy="slo_elastic")
    assert unit_bid(t, sig) == pytest.approx(4.0)
    # surplus still reports positive headroom (scaled by the target)
    ws.alloc = 15
    assert ws.latency_headroom_s() == pytest.approx(30.0 * 5 / 10)
    # a measured violation is real and stays negative
    ws.observe_latency(45.0)
    assert ws.latency_headroom_s() == pytest.approx(-15.0)
    assert unit_bid(t, ws.signals(0.0, name="ws")) == pytest.approx(5.0)
    # and without an SLO the proxy is the clamped surplus itself
    ws_no_slo = WSServer(SimConfig(), request=lambda n: 0,
                         release=lambda n: None)
    ws_no_slo.set_demand(4, now=0.0)
    assert ws_no_slo.latency_headroom_s() == 0.0
