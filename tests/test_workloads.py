"""Request-level WS workload subsystem: arrivals, queueing, autoscaler,
campaign, and the simulator integration."""
import numpy as np
import pytest

from repro.core.simulator import ConsolidationSim
from repro.core.traces import synthetic_sdsc_blue
from repro.core.types import Request, SimConfig, SLOConfig, WSDemandProvider
from repro.serving.batching import ContinuousBatcher, ServiceTimeModel
from repro.serving.batching import Request as BatchRequest
from repro.workloads import (RequestWorkload, SLOAutoscaler, burstiness_index,
                             capacity_steps, make_trace, simulate_queue)
from repro.workloads.campaign import (METRIC_KEYS, ScenarioCell, make_grid,
                                      reduce_metrics, run_campaign, run_cell)

HOUR = 3600.0
MODEL = ServiceTimeModel()
SLO = SLOConfig(latency_target_s=30.0)


# ------------------------------------------------------------- arrivals


@pytest.mark.parametrize("kind", ["poisson", "mmpp", "diurnal",
                                  "flash_crowd"])
def test_arrivals_deterministic_and_sorted(kind):
    a = make_trace(kind, 2.0, HOUR, seed=3)
    b = make_trace(kind, 2.0, HOUR, seed=3)
    np.testing.assert_array_equal(a.t, b.t)
    np.testing.assert_array_equal(a.decode_tokens, b.decode_tokens)
    assert np.all(np.diff(a.t) >= 0)
    assert a.t[-1] < HOUR and a.t[0] >= 0
    assert len(a.prompt_tokens) == len(a.t) == len(a.decode_tokens)
    assert a.prompt_tokens.min() >= 1 and a.decode_tokens.min() >= 1


def test_poisson_rate_and_dispersion_within_tolerance():
    # long window so the estimators concentrate
    tr = make_trace("poisson", 5.0, 6 * HOUR, seed=0)
    rate = len(tr) / (6 * HOUR)
    assert rate == pytest.approx(5.0, rel=0.05)
    # Poisson: index of dispersion ~ 1
    assert 0.8 < burstiness_index(tr, window_s=60.0) < 1.3


def test_mmpp_burstier_than_poisson():
    poi = make_trace("poisson", 2.0, 6 * HOUR, seed=1)
    mmpp = make_trace("mmpp", 2.0, 6 * HOUR, seed=1)
    assert burstiness_index(mmpp) > 3.0 * burstiness_index(poi)
    # mean rate between the lo and hi modulated rates
    rate = len(mmpp) / (6 * HOUR)
    assert 0.4 * 2.0 < rate < 1.6 * 2.0


def test_flash_crowd_adds_spikes_over_base():
    base = make_trace("diurnal", 2.0, 6 * HOUR, seed=2)
    flash = make_trace("flash_crowd", 2.0, 6 * HOUR, seed=2)
    assert len(flash) > len(base)
    assert burstiness_index(flash) > 5.0


def test_trace_to_requests_roundtrip():
    tr = make_trace("poisson", 1.0, 600.0, seed=0)
    reqs = tr.to_requests()
    assert all(isinstance(r, Request) for r in reqs)
    assert [r.arrival for r in reqs] == list(tr.t)
    assert reqs[0].latency is None


# ------------------------------------------------------------- queueing


def test_queue_no_contention_latency_equals_service():
    tr = make_trace("poisson", 0.5, HOUR, seed=0)
    m = simulate_queue(tr, [(0.0, 1000)], MODEL, SLO)
    svc = MODEL.service_times(tr.prompt_tokens, tr.decode_tokens)
    assert m.mean_wait_s == pytest.approx(0.0, abs=1e-9)
    assert m.mean_s == pytest.approx(float(svc.mean()), rel=1e-6)
    assert m.n_served == len(tr)


def test_queue_undersized_cluster_builds_backlog():
    tr = make_trace("poisson", 2.0, HOUR, seed=0)
    small = simulate_queue(tr, [(0.0, 1)], MODEL, SLO)
    big = simulate_queue(tr, [(0.0, 50)], MODEL, SLO)
    assert small.p99_s > big.p99_s
    assert small.violation_rate > big.violation_rate
    assert not small.slo_met and big.slo_met


def test_queue_zero_capacity_counts_unserved():
    tr = make_trace("poisson", 1.0, 600.0, seed=0)
    m = simulate_queue(tr, [(0.0, 0)], MODEL, SLO, horizon=600.0)
    assert m.unserved == len(tr)
    assert m.violation_rate == 1.0 and not m.slo_met


def test_queue_capacity_rise_rescues_waiting_requests():
    tr = make_trace("poisson", 1.0, 600.0, seed=0)
    # no capacity for 300 s, then plenty: everything queued at t<300 starts
    # at 300 and still finishes
    m = simulate_queue(tr, [(0.0, 0), (300.0, 100)], MODEL, SLO)
    assert m.unserved == 0
    early = tr.t < 300.0
    assert m.mean_wait_s > 0


def test_capacity_steps_normalizes_events():
    t, k = capacity_steps([(5.0, 2), (0.0, 1), (5.0, 3)], slots_per_node=4)
    assert list(t) == [0.0, 5.0]
    assert list(k) == [4, 12]          # last level at t=5 wins, x4 slots


def test_batcher_round_time_matches_model():
    model = ServiceTimeModel(prefill_tokens_per_s=1000.0,
                             decode_tokens_per_s=100.0,
                             batch_interference=0.1, max_batch=4)
    b = ContinuousBatcher(max_batch=4)
    reqs = [BatchRequest(i, np.zeros(50, np.int32), 20) for i in range(2)]
    t = b.estimate_round_time(reqs, model)
    # 2 * 50 / 1000 prefill + 20 * 1.1 / 100 decode
    assert t == pytest.approx(0.1 + 0.22)


# ------------------------------------------------------------ autoscaler


def test_autoscaler_scales_with_rate_and_slo():
    asc = SLOAutoscaler(MODEL, SLO)
    svc_mean, svc_p99 = 8.0, 20.0
    lo = asc.desired_nodes(1.0, svc_mean, 0.3, svc_p99)
    hi = asc.desired_nodes(10.0, svc_mean, 0.3, svc_p99)
    assert hi > lo >= 1
    tight = SLOAutoscaler(MODEL, SLOConfig(latency_target_s=21.0))
    loose = SLOAutoscaler(MODEL, SLOConfig(latency_target_s=120.0))
    assert tight.desired_nodes(10.0, svc_mean, 0.3, svc_p99) >= \
        loose.desired_nodes(10.0, svc_mean, 0.3, svc_p99)


def test_autoscaler_infeasible_slo_provisions_for_zero_queueing():
    asc = SLOAutoscaler(MODEL, SLOConfig(latency_target_s=5.0))
    n = asc.desired_nodes(10.0, 8.0, 0.3, p99_service_s=20.0)
    # service alone busts the target: still provisions ~offered load
    offered_nodes = 10.0 * 8.0 / MODEL.slots_per_replica
    assert n >= offered_nodes
    assert n < 10 * offered_nodes


def test_workload_provider_plan_meets_slo_when_granted():
    tr = make_trace("flash_crowd", 1.5, 2 * HOUR, seed=0)
    ws = RequestWorkload(trace=tr, model=MODEL, slo=SLO)
    assert isinstance(ws, WSDemandProvider)
    ev = ws.demand_events(2 * HOUR)
    assert ev and all(n >= 0 for _, n in ev)
    m = ws.planned_metrics(2 * HOUR)
    assert m["slo_met"]
    assert m["p99_s"] <= SLO.latency_target_s


# --------------------------------------------------- simulator integration


def test_consolidation_sim_with_request_workload():
    tr = make_trace("poisson", 1.5, 2 * HOUR, seed=0)
    ws = RequestWorkload(trace=tr, model=MODEL, slo=SLO)
    jobs = synthetic_sdsc_blue(seed=0, n_jobs=60, horizon=2 * HOUR,
                               max_nodes=32)
    cfg = SimConfig(total_nodes=64)
    res = ConsolidationSim(cfg, jobs, ws, horizon=2 * HOUR).run()
    assert res.ws_latency is not None
    assert res.ws_latency["n_requests"] == len(tr)
    # WS has strict priority and the cluster is big enough: SLO holds
    assert res.ws_unmet_node_seconds == 0.0
    assert res.ws_latency["slo_met"]
    assert res.completed > 0


def test_consolidation_sim_request_workload_deterministic():
    tr = make_trace("mmpp", 1.0, HOUR, seed=4)
    jobs = synthetic_sdsc_blue(seed=4, n_jobs=40, horizon=HOUR,
                               max_nodes=16)
    outs = []
    for _ in range(2):
        ws = RequestWorkload(trace=tr, model=MODEL, slo=SLO)
        res = ConsolidationSim(SimConfig(total_nodes=48), jobs, ws,
                               horizon=HOUR).run()
        outs.append((res.completed, res.ws_latency["p99_s"]))
    assert outs[0] == outs[1]


def test_node_fail_accounting_stays_consistent():
    """Satellite fix: ST node loss routes through STServer, so st.alloc and
    rps.st_alloc can never diverge — audited at every event."""
    tr = make_trace("poisson", 0.5, 2 * HOUR, seed=5)
    ws = RequestWorkload(trace=tr, model=MODEL, slo=SLO)
    jobs = synthetic_sdsc_blue(seed=5, n_jobs=80, horizon=2 * HOUR,
                               max_nodes=32)
    cfg = SimConfig(total_nodes=48, node_mtbf=20 * HOUR,
                    node_repair_time=600.0)
    sim = ConsolidationSim(cfg, jobs, ws, horizon=2 * HOUR)
    orig = sim._account

    def audited(t):
        orig(t)
        sim.rps.check()
        assert sim.st.alloc == sim.rps.st_alloc, \
            (sim.st.alloc, sim.rps.st_alloc)
        assert sim.ws.alloc == sim.rps.ws_alloc
        assert sim.st.used <= sim.st.alloc

    sim._account = audited
    res = sim.run()
    assert res.submitted == 80


def test_node_fail_prefers_idle_over_eviction():
    """Idle ST nodes absorb a node loss before any job is evicted."""
    from repro.core.st_cms import STServer
    st = STServer(SimConfig(), lambda j, t: None, lambda j: None)
    st.grant(10, 0.0)
    from repro.core.types import Job
    j = Job(job_id=1, submit_time=0.0, size=4, runtime=100.0)
    st.submit(j, 0.0)
    assert st.idle == 6
    st.node_lost(1.0)
    assert st.alloc == 9 and len(st.running) == 1       # no eviction
    for _ in range(5):
        st.node_lost(2.0)
    assert st.alloc == 4
    st.node_lost(3.0)                                    # now a job must die
    assert st.alloc == 3 and len(st.running) == 0


# -------------------------------------------------------------- campaign


def test_campaign_tiny_grid_shape():
    cells = make_grid("tiny")
    assert len(cells) >= 8
    assert len({c.cell_id() for c in cells}) == len(cells)


def test_campaign_cell_and_reduction(tmp_path):
    cells = [ScenarioCell(preempt=p, scheduler="first_fit",
                          arrival="poisson", total_nodes=48,
                          slo_target_s=30.0, horizon_s=1800.0, n_jobs=20,
                          rate_rps=1.0)
             for p in ("kill", "checkpoint")]
    out = tmp_path / "campaign.json"
    art = run_campaign(cells, workers=1, out_path=str(out),
                       grid_name="unit")
    assert out.exists()
    assert art["n_cells"] == 2
    for r in art["cells"]:
        assert set(METRIC_KEYS) <= set(r["metrics"])
    red = art["reductions"]
    assert "overall" in red and "by_preempt" in red
    assert red["overall"]["cells"] == 2
    import json
    disk = json.loads(out.read_text())
    assert disk["schema"] == "phoenix-campaign-v7"
    assert disk["throughput"]["queue_requests_per_s"] > 0
