"""Control-plane telemetry tests (core/telemetry.py + repro.trace CLI).

Covers the PR-6 observability contract:

  * same-seed runs emit byte-identical traces (the determinism unit is
    ``Tracer.lines()`` — canonical JSONL);
  * a pinned golden trace for the paper engine on the degenerate
    two-tenant (2009) scenario — the exact causal story of §II-B's three
    rules (idle -> ST, WS claim forces ST release, release reflows);
  * causal-chain integrity (claim -> reclaim_plan -> reclaim_step,
    slo_violation -> slo_recovery) and schema validation;
  * the analyzer surface: summarize / diff / causality / validate /
    perfetto, plus the ``python -m repro.trace`` CLI;
  * no silent caps: the Tracer buffer and the MarketState ledgers count
    what they drop;
  * campaign integration: ``--trace`` spools an analyzable per-cell
    trace and folds a summary into the row WITHOUT changing any other
    row content (v5 artifact bit-exactness with tracing off).
"""
import json
import os

import pytest

from repro.core.simulator import ConsolidationSim
from repro.core.telemetry import (NULL_TRACER, Tracer, causality_report,
                                  check_causal_chains, diff_summaries,
                                  load_events, summarize_events,
                                  to_perfetto, validate_events)
from repro.core.traces import synthetic_sdsc_blue
from repro.core.types import (MarketState, Job, SimConfig, SLOConfig,
                              TenantSpec)
from repro.serving.batching import ServiceTimeModel
from repro.workloads.arrivals import make_trace
from repro.workloads.autoscaler import RequestWorkload

HOUR = 3600.0


# ------------------------------------------------------------ scenarios

def paper_two_tenant_trace(metric_interval_s=600.0):
    """The degenerate 2009 two-tenant scenario, tiny and fully pinned."""
    jobs = [Job(job_id=0, submit_time=0.0, size=6, runtime=1200.0),
            Job(job_id=1, submit_time=300.0, size=4, runtime=900.0)]
    ws = [(0.0, 2), (600.0, 8), (1200.0, 3)]
    tr = Tracer(metric_interval_s=metric_interval_s)
    sim = ConsolidationSim(SimConfig(total_nodes=10, seed=0), jobs, ws,
                           horizon=1800.0, tracer=tr)
    sim.run()
    return tr


def request_level_trace(seed=3, policy="slo_headroom", tracer=None):
    """A consolidation cell with a request-level latency tenant (the
    deployment configuration): SLO autoscaler drives demand, reclaims
    fire, shortfall episodes open and close."""
    horizon = 2 * HOUR
    specs = [
        TenantSpec("ws", "latency", priority=0,
                   slo=SLOConfig(latency_target_s=1.0),
                   demand=RequestWorkload(
                       trace=make_trace("diurnal", 10.0, horizon,
                                        seed=seed),
                       model=ServiceTimeModel(),
                       slo=SLOConfig(latency_target_s=1.0))),
        TenantSpec("hpc", "batch", priority=1,
                   jobs=synthetic_sdsc_blue(seed=seed, n_jobs=60,
                                            horizon=horizon,
                                            max_nodes=12)),
    ]
    tr = tracer or Tracer()
    sim = ConsolidationSim(SimConfig(total_nodes=32, seed=seed),
                           horizon=horizon, tenants=specs, policy=policy,
                           tracer=tr)
    sim.run()
    return tr


# ------------------------------------------------------------- golden

GOLDEN_PAPER_TRACE = [
    '{"dropped_events": 0, "events": 16, "horizon": 1800.0, "policy": "paper", "seed": 0, "total_nodes": 10, "ts": 0.0, "type": "trace_header", "version": 1}',
    '{"nodes": 10, "tenant": "st", "ts": 0.0, "type": "idle_grant"}',
    '{"free": 0, "tenants": {"st": {"alloc": 10, "demand": 0, "headroom_s": 0.0, "queue_depth": 0, "spend": 0.0}, "ws": {"alloc": 0, "demand": 0, "headroom_s": 0.0, "queue_depth": 0, "spend": 0.0}}, "ts": 0.0, "type": "metrics"}',
    '{"demand": 2, "prev": 0, "source": "timeseries", "tenant": "ws", "ts": 0.0, "type": "autoscale"}',
    '{"deficit": 2, "engine": "paper", "parent": 1, "span": 2, "steps": [{"reason": "victim-chain", "take": 10, "victim": "st"}], "tenant": "ws", "ts": 0.0, "type": "reclaim_plan"}',
    '{"asked": 2, "claimant": "ws", "granted": 2, "parent": 2, "released": 2, "tenant": "st", "ts": 0.0, "type": "reclaim_step"}',
    '{"deficit": 2, "from_free": 0, "granted": 2, "requested": 2, "short": 0, "span": 1, "tenant": "ws", "ts": 0.0, "type": "claim"}',
    '{"demand": 8, "prev": 2, "source": "timeseries", "tenant": "ws", "ts": 600.0, "type": "autoscale"}',
    '{"deficit": 6, "engine": "paper", "parent": 3, "span": 4, "steps": [{"reason": "victim-chain", "take": 8, "victim": "st"}], "tenant": "ws", "ts": 600.0, "type": "reclaim_plan"}',
    '{"asked": 6, "claimant": "ws", "granted": 6, "parent": 4, "released": 6, "tenant": "st", "ts": 600.0, "type": "reclaim_step"}',
    '{"deficit": 6, "from_free": 0, "granted": 6, "requested": 6, "short": 0, "span": 3, "tenant": "ws", "ts": 600.0, "type": "claim"}',
    '{"free": 0, "tenants": {"st": {"alloc": 2, "demand": 0, "headroom_s": 0.0, "queue_depth": 1, "spend": 0.0}, "ws": {"alloc": 8, "demand": 8, "headroom_s": 0.0, "queue_depth": 0, "spend": 0.0}}, "ts": 600.0, "type": "metrics"}',
    '{"demand": 3, "prev": 8, "source": "timeseries", "tenant": "ws", "ts": 1200.0, "type": "autoscale"}',
    '{"nodes": 5, "tenant": "ws", "ts": 1200.0, "type": "release"}',
    '{"nodes": 5, "tenant": "st", "ts": 1200.0, "type": "idle_grant"}',
    '{"free": 0, "tenants": {"st": {"alloc": 7, "demand": 0, "headroom_s": 0.0, "queue_depth": 0, "spend": 0.0}, "ws": {"alloc": 3, "demand": 3, "headroom_s": 0.0, "queue_depth": 0, "spend": 0.0}}, "ts": 1200.0, "type": "metrics"}',
    '{"free": 0, "tenants": {"st": {"alloc": 7, "demand": 0, "headroom_s": 0.0, "queue_depth": 0, "spend": 0.0}, "ws": {"alloc": 3, "demand": 3, "headroom_s": 0.0, "queue_depth": 0, "spend": 0.0}}, "ts": 1800.0, "type": "metrics"}',
]


def test_golden_trace_paper_two_tenant():
    """The paper engine's causal story on the 2009 scenario is pinned
    line-for-line: rule 2 (all idle -> ST), rule 1/3 (WS claim plans and
    forces ST release), then WS release reflowing to ST."""
    assert paper_two_tenant_trace().lines() == GOLDEN_PAPER_TRACE


def test_same_seed_traces_identical():
    a = request_level_trace().lines()
    b = request_level_trace().lines()
    assert a == b
    assert len(a) > 100          # a real trace, not a stub


def test_trace_schema_and_chains_valid():
    tr = request_level_trace()
    events = [tr.header()] + tr.events
    assert validate_events(events) == []
    assert check_causal_chains(events) == []


# ------------------------------------------------------------ analysis

def test_summarize_counts_and_latency():
    tr = request_level_trace()
    events = [tr.header()] + tr.events
    s = summarize_events(events)
    assert s["events"] == len(events)
    from collections import Counter
    counted = Counter(e["type"] for e in events)
    assert s["by_type"] == dict(counted)
    # every traced claim either recovered (latency dist) or is counted
    rl = s["reclaim_latency_s"]
    n_claims = counted.get("claim", 0)
    assert rl["overall"]["n"] + sum(s["reclaim_latency_s"]
                                    ["unrecovered"].values()) <= n_claims
    assert rl["overall"]["p50"] <= rl["overall"]["p99"] \
        <= rl["overall"]["max"]


def test_causality_report_walks_chains():
    tr = request_level_trace()
    events = [tr.header()] + tr.events
    rep = causality_report(events, tenant="ws")
    assert rep["broken_chains"] == []
    assert rep["forced_claims"] == len(
        [e for e in events if e["type"] == "reclaim_plan"])
    for chain in rep["chains"]:
        assert chain["tenant"] == "ws"
        assert chain["planned_victims"], chain
        for drain in chain["drains"]:
            assert drain["released"] >= drain["granted"] >= 0


def test_diff_summaries_between_engines():
    full_a = request_level_trace(policy="paper")
    full_b = request_level_trace(policy="slo_headroom")
    sa = summarize_events([full_a.header()] + full_a.events)
    sb = summarize_events([full_b.header()] + full_b.events)
    d = diff_summaries(sa, sb)
    assert d["events"]["a"] == sa["events"]
    assert d["events"]["b"] == sb["events"]
    assert d["events"]["delta"] == sb["events"] - sa["events"]


def test_validate_catches_corruption():
    tr = paper_two_tenant_trace()
    events = [tr.header()] + tr.events
    missing = [dict(e) for e in events]
    del missing[4]["steps"]               # reclaim_plan loses its steps
    assert any("steps" in p for p in validate_events(missing))
    dangling = [dict(e) for e in events]
    dangling[5]["parent"] = 9999          # reclaim_step points nowhere
    problems = validate_events(dangling) + check_causal_chains(dangling)
    assert problems, "dangling parent must be reported"


def test_perfetto_export_structure():
    tr = request_level_trace()
    doc = to_perfetto([tr.header()] + tr.events)
    evs = doc["traceEvents"]
    assert evs, "empty perfetto export"
    phases = {e["ph"] for e in evs}
    assert "M" in phases          # thread metadata
    assert "C" in phases          # metric counters
    assert "i" in phases          # decision instants
    for e in evs:
        assert e.get("ts", 0) >= 0
        assert e["pid"] == 1


# ------------------------------------------------------- no silent caps

def test_tracer_buffer_cap_counts_drops():
    tr = Tracer(max_events=3)
    for i in range(10):
        tr.emit("release", tenant="t", nodes=1)
    assert len(tr.events) == 3
    assert tr.dropped_events == 7
    assert tr.header()["dropped_events"] == 7


def test_claim_path_counts_drops_at_cap():
    """The inlined hot-path emits in claim() honor the cap too."""
    from repro.core.policies import Tenant
    from repro.core.provision import TenantProvisionService
    tr = Tracer(max_events=0)
    svc = TenantProvisionService(8, policy="paper", tracer=tr)
    svc.register(Tenant("st", "batch", priority=1,
                        on_force_release=lambda n: n))
    svc.register(Tenant("ws", "latency", priority=0))
    svc.provision_idle()
    svc.claim("ws", 4)
    assert tr.events == []
    assert tr.dropped_events >= 3  # idle_grant + plan + step + claim


def test_market_ledger_caps_are_recorded():
    from repro.core.types import MARKET_SAMPLES_MAX
    m = MarketState()
    m.register("t", 1e9)
    for i in range(MARKET_SAMPLES_MAX + 5):
        m.debit("t", 1, 1.0, "idle", interval=i)
        m.note_price(1.0)
    snap = m.snapshot()
    assert snap["dropped_entries"]["ledger"] == 5
    assert snap["dropped_entries"]["clearing_prices"] == 5
    assert len(m.ledger) == MARKET_SAMPLES_MAX


def test_market_debit_lands_in_trace_past_ledger_cap():
    """Every debit reaches the tracer even after the inspection-sample
    cap — the trace is the uncapped record."""
    tr = Tracer()
    m = MarketState(tracer=tr)
    m.register("t", 1e9)
    from repro.core.types import MARKET_SAMPLES_MAX
    n = MARKET_SAMPLES_MAX + 3
    for i in range(n):
        m.debit("t", 1, 1.0, "idle", interval=i)
    debits = [e for e in tr.events if e["type"] == "debit"]
    assert len(debits) == n


# ------------------------------------------------------- off-by-default

def test_null_tracer_is_disabled_and_shared():
    assert NULL_TRACER.enabled is False
    NULL_TRACER.emit("claim", tenant="x")       # must be a no-op
    assert NULL_TRACER.events == []
    sim_tr = ConsolidationSim(SimConfig(total_nodes=4, seed=0), [], [],
                              horizon=10.0).tracer
    assert sim_tr is NULL_TRACER


# ----------------------------------------------------------- round trip

def test_jsonl_round_trip(tmp_path):
    tr = paper_two_tenant_trace()
    path = str(tmp_path / "t.trace.jsonl")
    tr.to_jsonl(path)
    events = load_events(path)
    assert [json.dumps(e, sort_keys=True, default=float)
            for e in events] == tr.lines()


def test_trace_cli(tmp_path):
    from repro.trace import main
    tr = request_level_trace()
    path = str(tmp_path / "cell.trace.jsonl")
    tr.to_jsonl(path)
    out = str(tmp_path / "cell.perfetto.json")
    assert main(["validate", path]) == 0
    assert main(["summarize", path]) == 0
    assert main(["summarize", path, "--json"]) == 0
    assert main(["causality", path, "--tenant", "ws"]) == 0
    assert main(["diff", path, path]) == 0
    assert main(["perfetto", path, "--out", out]) == 0
    assert json.load(open(out))["traceEvents"]
    # a corrupted trace must fail validation with a non-zero exit
    bad = str(tmp_path / "bad.trace.jsonl")
    events = load_events(path)
    del events[-1]["type"]
    with open(bad, "w") as f:
        for e in events:
            f.write(json.dumps(e) + "\n")
    assert main(["validate", bad]) == 1


# ------------------------------------------------------------ runtime

def test_orchestrator_emits_autoscale_decisions():
    from repro.runtime.orchestrator import MultiTenantOrchestrator

    class _Pool:
        def __init__(self):
            self.replicas = []

        def scale_to(self, devices):
            self.replicas = list(devices)

        def desired_replicas(self, load):
            return int(load)

    class _Trainer:
        model_size, global_batch = 2, 4

        def __init__(self):
            self.devices = []
            self.step = 0

        def start(self, devices):
            self.devices = list(devices)

        def resize(self, devices):
            self.devices = list(devices)

    tr = Tracer()
    orch = MultiTenantOrchestrator(devices=[f"d{i}" for i in range(12)],
                                   policy="demand_capped", tracer=tr)
    orch.add_latency("ws", _Pool(), priority=0)
    orch.add_batch("hpc", _Trainer(), priority=1)
    orch.start()
    orch.latency_tick("ws", 6.0)
    orch.latency_tick("ws", 0.0)
    kinds = [e["type"] for e in tr.events]
    assert "autoscale" in kinds
    assert "claim" in kinds
    scale = [e for e in tr.events if e["type"] == "autoscale"]
    assert all(e["source"] in ("slo_autoscaler", "utilization")
               for e in scale)
    # ticks are the runtime's clock: timestamps are monotone
    ts = [e["ts"] for e in tr.events]
    assert ts == sorted(ts)
    assert validate_events([tr.header()] + tr.events) == []


# ------------------------------------------------------------ campaign

VOLATILE_METRICS = ("wall_s", "queue_sim_s")


def _strip_volatile(row):
    row = json.loads(json.dumps(row))          # deep copy
    for k in VOLATILE_METRICS:
        row["metrics"].pop(k, None)
    row.pop("queue_sim", None)
    row.pop("trace_file", None)
    row.pop("trace_summary", None)
    return row


def test_campaign_trace_flag_and_bit_exactness(tmp_path):
    """--trace spools an analyzable per-cell trace and folds a summary
    into the row; every OTHER row key is byte-identical to the untraced
    run (the v5 artifact contract)."""
    from repro.workloads.campaign import ScenarioCell, run_cell
    cell = ScenarioCell(preempt="kill", scheduler="first_fit",
                        arrival="poisson", total_nodes=24,
                        slo_target_s=30.0, horizon_s=1800.0,
                        n_jobs=10, rate_rps=1.0, mix="2hpc2ws",
                        policy="slo_headroom")
    plain = run_cell(cell)
    traced = run_cell(cell, trace_dir=str(tmp_path))
    assert "trace_file" not in plain
    assert os.path.exists(traced["trace_file"])
    # filename contract: cell_key (collision-proof hash), not cell_id
    assert os.path.basename(traced["trace_file"]) \
        == f"{cell.cell_key()}.trace.jsonl"
    assert traced["trace_summary"]["events"] > 0
    assert _strip_volatile(plain) == _strip_volatile(traced)
    # the spooled trace is analyzable and causally intact
    events = load_events(traced["trace_file"])
    assert validate_events(events) == []
    assert check_causal_chains(events) == []
    assert events[0]["cell_id"] == cell.cell_id()
    assert events[0]["schema"]
