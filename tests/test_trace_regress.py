"""Trace regression-gate tests (repro.trace regress + the PR-10 fixes).

Covers:

  * the self-diff property — ``diff_summaries(s, s)`` is all-zero
    deltas for every registry engine, and ``regress`` passes a golden
    dir against itself (the CI green path);
  * the committed golden mix_tiny baseline stays regress-clean and
    replayable;
  * drift detection — a different engine under the same cell identity
    breaches zero thresholds; widened thresholds tolerate it; a missing
    cell always fails;
  * the three pinned bugfix regressions: campaign trace filenames are
    ``<cell_key>.trace.jsonl``, ``diff_summaries`` carries ``faults``
    and ``unrecovered`` deltas (and the CLI prints the integer ``n``
    as an integer), and a traced ``--resume`` re-runs spooled cells
    whose traces are missing instead of emitting a partial trace set.
"""
import json
import os
import shutil

import pytest

from repro.core.policies import POLICIES
from repro.core.telemetry import diff_summaries, summarize_events
from repro.trace import RegressThresholds, check_regression, main
from test_telemetry import request_level_trace

ENGINES = sorted(POLICIES)
GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "..", "goldens",
                          "mix_tiny_traces")


def _summary(policy):
    tr = request_level_trace(policy=policy)
    return summarize_events([tr.header()] + tr.events)


def _walk_deltas(node, path=""):
    """Yield every {a, b, delta} leaf in a diff_summaries output."""
    if isinstance(node, dict):
        if set(node) == {"a", "b", "delta"}:
            yield path, node
        else:
            for k, v in node.items():
                yield from _walk_deltas(v, f"{path}.{k}")


# ----------------------------------------------------- self-diff property

@pytest.mark.parametrize("policy", ENGINES)
def test_self_diff_is_all_zero(policy):
    s = _summary(policy)
    d = diff_summaries(s, s)
    leaves = list(_walk_deltas(d))
    assert leaves, "diff produced no comparable leaves"
    for path, leaf in leaves:
        assert leaf["delta"] == 0, (path, leaf)
    assert check_regression(d, RegressThresholds()) == []


def test_diff_carries_faults_and_unrecovered():
    """Regression: the diff used to ignore the fault ledger and the
    never-recovered claim counts entirely — fault drift was invisible."""
    s = _summary("paper")
    d = diff_summaries(s, s)
    assert set(d["faults"]) == {"failures", "repairs", "unrepaired",
                               "suppressed", "drain_completes",
                               "drained_nodes", "by_cause"}
    assert "unrecovered" in d
    # a forged fault ledger must surface as a non-zero delta and breach
    import copy
    drifted = copy.deepcopy(s)
    drifted["faults"]["failures"] += 3
    drifted["faults"]["by_cause"] = dict(drifted["faults"]["by_cause"])
    drifted["faults"]["by_cause"]["rack"] = \
        drifted["faults"]["by_cause"].get("rack", 0) + 3
    d2 = diff_summaries(s, drifted)
    assert d2["faults"]["failures"]["delta"] == 3
    breaches = check_regression(d2, RegressThresholds())
    assert any("faults" in b for b in breaches)
    assert check_regression(d2, RegressThresholds(faults=3)) == []


def test_diff_cli_prints_integer_n(tmp_path, capsys):
    """Regression: _cmd_diff formatted the integer reclaim count with
    :.1f ('n=33.0->34.0'); it must print as an integer."""
    p = str(tmp_path / "c.trace.jsonl")
    request_level_trace(policy="paper").to_jsonl(p)
    assert main(["diff", p, p]) == 0
    out = capsys.readouterr().out
    line = next(ln for ln in out.splitlines()
                if ln.startswith("reclaim latency:"))
    n_field = next(f for f in line.split() if f.startswith("n="))
    assert "." not in n_field, line


# -------------------------------------------------------- regress gate

def test_regress_golden_baseline_against_itself():
    assert main(["regress", GOLDEN_DIR, GOLDEN_DIR]) == 0


@pytest.mark.parametrize("policy", ENGINES)
def test_regress_passes_self_for_every_engine(policy, tmp_path):
    d = str(tmp_path / "base")
    os.makedirs(d)
    request_level_trace(policy=policy).to_jsonl(
        os.path.join(d, "cell.trace.jsonl"))
    assert main(["regress", d, d]) == 0


def test_regress_flags_engine_drift_and_thresholds(tmp_path):
    """Two engines under the same cell identity: zero thresholds breach,
    generous thresholds pass (unless event counts themselves moved —
    those are gated via reclaim-n/slo-count only)."""
    base, fresh = str(tmp_path / "a"), str(tmp_path / "b")
    os.makedirs(base), os.makedirs(fresh)
    request_level_trace(policy="paper").to_jsonl(
        os.path.join(base, "cell.trace.jsonl"))
    request_level_trace(policy="slo_headroom").to_jsonl(
        os.path.join(fresh, "cell.trace.jsonl"))
    assert main(["regress", base, fresh]) == 1
    assert main(["regress", base, fresh,
                 "--reclaim-p99-s", "1e9", "--reclaim-n", "1000000",
                 "--slo-count", "1000000",
                 "--slo-p99-duration-s", "1e9", "--spend", "1e9",
                 "--faults", "1000000",
                 "--unrecovered", "1000000"]) == 0


def test_regress_missing_cell_fails(tmp_path):
    fresh = str(tmp_path / "fresh")
    os.makedirs(fresh)
    shutil.copy(os.path.join(GOLDEN_DIR, sorted(
        f for f in os.listdir(GOLDEN_DIR)
        if f.endswith(".trace.jsonl"))[0]), fresh)
    assert main(["regress", GOLDEN_DIR, fresh]) == 1
    # extra (unmatched) fresh cells alone never fail the gate
    assert main(["regress", fresh, GOLDEN_DIR]) == 0


def test_regress_json_report(tmp_path, capsys):
    assert main(["regress", GOLDEN_DIR, GOLDEN_DIR, "--json"]) == 0
    rep = json.loads(capsys.readouterr().out)
    assert rep["breaches"] == 0 and not rep["missing"]
    assert len(rep["cells"]) == 7
    for cell in rep["cells"].values():
        assert cell["breaches"] == []
        assert "faults" in cell["diff"]


# --------------------------------------------- golden baseline contract

def test_golden_baseline_replays_and_is_keyed_by_cell_key():
    """Every committed golden trace replays cleanly, and its filename is
    the header's cell_key (the collision-proof identity), with the
    human-readable cell_id preserved in the header."""
    from repro.core.replay import replay_events
    from repro.core.telemetry import load_events
    files = sorted(f for f in os.listdir(GOLDEN_DIR)
                   if f.endswith(".trace.jsonl"))
    assert len(files) == len(ENGINES)        # one mix_tiny cell per engine
    policies = set()
    for fn in files:
        events = load_events(os.path.join(GOLDEN_DIR, fn))
        header = events[0]
        assert fn == f"{header['cell_key']}.trace.jsonl"
        assert header["cell_id"]
        policies.add(header["policy"])
        res = replay_events(events)
        assert res.ok, (fn, res.problems[:3])
    assert policies == set(ENGINES)


# ----------------------------------------------- campaign bugfix pins

CELL_KW = dict(preempt="kill", scheduler="first_fit", arrival="poisson",
               total_nodes=24, slo_target_s=30.0, horizon_s=1800.0,
               n_jobs=10, rate_rps=1.0, mix="2hpc2ws")


def test_campaign_trace_filename_is_cell_key(tmp_path):
    """Regression: _cell_finish wrote <cell_id>.trace.jsonl, breaking
    the documented <cell_key>.trace.jsonl contract."""
    from repro.workloads.campaign import ScenarioCell, run_cell
    cell = ScenarioCell(policy="paper", **CELL_KW)
    row = run_cell(cell, trace_dir=str(tmp_path))
    assert os.path.basename(row["trace_file"]) \
        == f"{cell.cell_key()}.trace.jsonl"
    assert os.path.exists(row["trace_file"])


def test_traced_resume_reruns_untraced_spooled_cells(tmp_path):
    """Regression: --resume --trace skipped spooled cells outright, so a
    spool from an UNTRACED run yielded an incomplete trace dir and rows
    without trace_summary."""
    from repro.workloads.campaign import ScenarioCell, run_campaign
    cells = [ScenarioCell(policy=p, **CELL_KW)
             for p in ("paper", "slo_headroom")]
    spool = str(tmp_path / "spool.jsonl")
    tdir = str(tmp_path / "traces")
    art0 = run_campaign(cells, spool_path=spool)
    assert art0["n_cells"] == 2
    # traced resume must RE-RUN both spooled-but-untraced cells
    art1 = run_campaign(cells, spool_path=spool, resume=True,
                        trace_dir=tdir)
    assert art1["throughput"]["executed"] == 2
    assert art1["throughput"]["skipped"] == 0
    traces = {f for f in os.listdir(tdir) if f.endswith(".trace.jsonl")}
    assert traces == {f"{c.cell_key()}.trace.jsonl" for c in cells}
    assert all("trace_summary" in r for r in art1["cells"])
    # once traces exist, a traced resume skips as before
    art2 = run_campaign(cells, spool_path=spool, resume=True,
                        trace_dir=tdir)
    assert art2["throughput"]["executed"] == 0
    assert art2["throughput"]["skipped"] == 2
    # untraced resume behavior is unchanged by the fix
    art3 = run_campaign(cells, spool_path=spool, resume=True)
    assert art3["throughput"]["executed"] == 0
