"""Property-based tests (hypothesis) for the Phoenix Cloud invariants.

System invariants under arbitrary job sets and WS demand curves:
  * node conservation: free + st_alloc + ws_alloc == total, always;
  * WS priority: unmet demand only when demand exceeds total capacity;
  * ST never runs more nodes than allocated;
  * completed jobs have turnaround >= runtime;
  * every job ends in exactly one terminal/queue state.
"""
import math

import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.simulator import ConsolidationSim
from repro.core.types import Job, JobState, SimConfig

HOUR = 3600.0
HORIZON = 48 * HOUR


@st.composite
def job_sets(draw):
    n = draw(st.integers(1, 40))
    jobs = []
    for i in range(n):
        jobs.append(Job(
            job_id=i + 1,
            submit_time=draw(st.floats(0, HORIZON * 0.8)),
            size=draw(st.integers(1, 64)),
            runtime=draw(st.floats(60.0, 12 * HOUR)),
        ))
    return jobs


@st.composite
def demand_curves(draw):
    n = draw(st.integers(0, 25))
    times = sorted(draw(st.lists(st.floats(0, HORIZON), min_size=n,
                                 max_size=n)))
    return [(t, draw(st.integers(0, 80))) for t in times]


class AuditedSim(ConsolidationSim):
    """Checks conservation + allocation invariants after every event."""

    def run(self):
        # monkeypatch accounting hook to audit at every event boundary
        orig_account = self._account

        def audited(t):
            orig_account(t)
            self.rps.check()
            assert self.st.used <= self.st.alloc, \
                (self.st.used, self.st.alloc)
            assert self.st.alloc == self.rps.st_alloc
            assert self.ws.alloc == self.rps.ws_alloc

        self._account = audited
        return super().run()


@given(jobs=job_sets(), demand=demand_curves(),
       total=st.integers(80, 256),
       mode=st.sampled_from(["kill", "checkpoint"]))
@settings(max_examples=60, deadline=None)
def test_invariants_hold(jobs, demand, total, mode):
    cfg = SimConfig(total_nodes=total, preempt_mode=mode)
    sim = AuditedSim(cfg, jobs, demand, horizon=HORIZON)
    res = sim.run()

    # WS priority: unmet only when demand > total
    max_demand = max((n for _, n in demand), default=0)
    if max_demand <= total:
        assert res.ws_unmet_node_seconds == 0.0

    for j in sim.jobs:
        if j.state is JobState.COMPLETED:
            assert j.turnaround >= j.remaining() - 1e-6
            assert j.end_time >= j.submit_time
        if mode == "checkpoint":
            assert j.state is not JobState.KILLED

    n_terminal = sum(j.state in (JobState.COMPLETED, JobState.KILLED,
                                 JobState.QUEUED, JobState.RUNNING)
                     for j in sim.jobs)
    assert n_terminal == len(sim.jobs)
    assert res.completed + res.killed <= res.submitted


@given(total=st.integers(16, 300), req=st.lists(st.integers(1, 64),
                                                min_size=1, max_size=30))
@settings(max_examples=60, deadline=None)
def test_provision_service_conservation(total, req):
    from repro.core.provision import ResourceProvisionService
    rps = ResourceProvisionService(total)
    rps.force_st_release = lambda n: min(n, rps.st_alloc)
    rps.provision_idle_to_st()
    ws_alloc = 0
    for r in req:
        if ws_alloc > 0 and r % 3 == 0:
            give = min(ws_alloc, r)
            rps.ws_release(give)
            ws_alloc -= give
        else:
            got = rps.ws_request(r)
            assert got <= r
            ws_alloc += got
        rps.check()
        assert rps.ws_alloc == ws_alloc
