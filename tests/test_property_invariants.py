"""Property-based tests (hypothesis) for the Phoenix Cloud invariants.

System invariants under arbitrary job sets and WS demand curves:
  * node conservation: free + st_alloc + ws_alloc == total, always;
  * WS priority: unmet demand only when demand exceeds total capacity;
  * ST never runs more nodes than allocated;
  * completed jobs have turnaround >= runtime;
  * every job ends in exactly one terminal/queue state.
"""
import math

import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.policies import POLICIES
from repro.core.simulator import ConsolidationSim
from repro.core.types import Job, JobState, SimConfig

HOUR = 3600.0
HORIZON = 48 * HOUR


@st.composite
def job_sets(draw):
    n = draw(st.integers(1, 40))
    jobs = []
    for i in range(n):
        jobs.append(Job(
            job_id=i + 1,
            submit_time=draw(st.floats(0, HORIZON * 0.8)),
            size=draw(st.integers(1, 64)),
            runtime=draw(st.floats(60.0, 12 * HOUR)),
        ))
    return jobs


@st.composite
def demand_curves(draw):
    n = draw(st.integers(0, 25))
    times = sorted(draw(st.lists(st.floats(0, HORIZON), min_size=n,
                                 max_size=n)))
    return [(t, draw(st.integers(0, 80))) for t in times]


class AuditedSim(ConsolidationSim):
    """Checks conservation + allocation invariants after every event."""

    def run(self):
        # monkeypatch accounting hook to audit at every event boundary
        orig_account = self._account

        def audited(t):
            orig_account(t)
            self.rps.check()
            assert self.st.used <= self.st.alloc, \
                (self.st.used, self.st.alloc)
            assert self.st.alloc == self.rps.st_alloc
            assert self.ws.alloc == self.rps.ws_alloc

        self._account = audited
        return super().run()


@given(jobs=job_sets(), demand=demand_curves(),
       total=st.integers(80, 256),
       mode=st.sampled_from(["kill", "checkpoint"]))
@settings(max_examples=60, deadline=None)
def test_invariants_hold(jobs, demand, total, mode):
    cfg = SimConfig(total_nodes=total, preempt_mode=mode)
    sim = AuditedSim(cfg, jobs, demand, horizon=HORIZON)
    res = sim.run()

    # WS priority: unmet only when demand > total
    max_demand = max((n for _, n in demand), default=0)
    if max_demand <= total:
        assert res.ws_unmet_node_seconds == 0.0

    for j in sim.jobs:
        if j.state is JobState.COMPLETED:
            assert j.turnaround >= j.remaining() - 1e-6
            assert j.end_time >= j.submit_time
        if mode == "checkpoint":
            assert j.state is not JobState.KILLED

    n_terminal = sum(j.state in (JobState.COMPLETED, JobState.KILLED,
                                 JobState.QUEUED, JobState.RUNNING)
                     for j in sim.jobs)
    assert n_terminal == len(sim.jobs)
    assert res.completed + res.killed <= res.submitted


@st.composite
def engine_tenant_sets(draw):
    n = draw(st.integers(2, 6))
    rows = []
    for i in range(n):
        kind = draw(st.sampled_from(["batch", "latency"]))
        floor = draw(st.integers(0, 6)) if kind == "latency" else 0
        rows.append((f"t{i}", kind, draw(st.integers(0, 5)),
                     draw(st.floats(0.0, 4.0)), floor))
    if not any(k == "latency" for _, k, _, _, _ in rows):
        name, _, prio, w, _ = rows[0]
        rows[0] = (name, "latency", prio, w, draw(st.integers(0, 6)))
    return rows


@given(total=st.integers(10, 300),
       policy=st.sampled_from(sorted(POLICIES)),
       rows=engine_tenant_sets(),
       ops=st.lists(
           st.tuples(st.sampled_from(["claim", "release", "demand",
                                      "armfail", "repair"]),
                     st.integers(0, 5),       # tenant index
                     st.integers(0, 120)),    # amount
           max_size=50))
@settings(max_examples=60, deadline=None)
def test_any_engine_conserves_and_respects_floors_under_faults(
        total, policy, rows, ops):
    """ANY PolicyEngine: node conservation holds and forced reclaim never
    takes a latency tenant below its floor — including when ``node_failed``
    fires MID-RECLAIM from inside a victim's force-release hook."""
    from repro.core.policies import Tenant
    from repro.core.provision import TenantProvisionService

    svc = TenantProvisionService(total, policy=policy)
    arm = {"fail": False, "repairs_due": 0}
    tenants = []

    def release_hook(name):
        def hook(n):
            rec = svc.tenants[name]
            if arm["fail"] and svc.total > 0:
                arm["fail"] = False
                svc.node_failed(name)       # a node dies mid-eviction
                arm["repairs_due"] += 1
            return min(n, rec.alloc)
        return hook

    for name, kind, prio, weight, floor in rows:
        tenants.append(svc.register(Tenant(
            name, kind, priority=prio, weight=weight, floor=floor,
            on_force_release=release_hook(name)
            if kind == "batch" else None)))

    for op, ti, n in ops:
        t = tenants[ti % len(tenants)]
        if op == "claim" and t.kind == "latency":
            # forced reclaim must not push any OTHER latency tenant below
            # min(its floor, its current alloc)
            before = {x.name: x.alloc for x in tenants
                      if x.kind == "latency" and x.name != t.name}
            got = svc.claim(t.name, n)
            assert 0 <= got <= n
            for x in tenants:
                if x.kind == "latency" and x.name != t.name:
                    assert x.alloc >= min(x.floor, before[x.name]), \
                        (x.name, x.alloc, x.floor, before[x.name])
        elif op == "release":
            svc.release(t.name, n)
        elif op == "demand" and t.kind == "batch":
            svc.set_demand(t.name, n)
        elif op == "armfail":
            arm["fail"] = True
        elif op == "repair" and arm["repairs_due"] > 0:
            svc.node_repaired()
            arm["repairs_due"] -= 1
        svc.check()
        assert sum(x.alloc for x in tenants) + svc.free == svc.total
        assert svc.free >= 0
        assert all(x.alloc >= 0 for x in tenants)


@given(total=st.integers(16, 300), req=st.lists(st.integers(1, 64),
                                                min_size=1, max_size=30))
@settings(max_examples=60, deadline=None)
def test_provision_service_conservation(total, req):
    from repro.core.provision import ResourceProvisionService
    rps = ResourceProvisionService(total)
    rps.force_st_release = lambda n: min(n, rps.st_alloc)
    rps.provision_idle_to_st()
    ws_alloc = 0
    for r in req:
        if ws_alloc > 0 and r % 3 == 0:
            give = min(ws_alloc, r)
            rps.ws_release(give)
            ws_alloc -= give
        else:
            got = rps.ws_request(r)
            assert got <= r
            ws_alloc += got
        rps.check()
        assert rps.ws_alloc == ws_alloc
