"""Sharded / resumable campaign execution: cell keys, spools, merge.

The acceptance bar: --shard 0/2 + --shard 1/2 + merge must reproduce the
single-shot artifact's reductions *exactly*, and --resume must re-execute
only the missing cells.
"""
import dataclasses
import hashlib
import json

import pytest

from repro.workloads.campaign import (REDUCE_KEYS, SCHEMA, ScenarioCell,
                                      make_grid, merge_spools,
                                      reduce_metrics, run_campaign,
                                      shard_cells, spool_append, spool_load)

# a fast 4-cell grid (short horizon) for end-to-end runs
FAST_CELLS = [
    ScenarioCell(preempt=p, scheduler="first_fit", arrival=a,
                 total_nodes=48, slo_target_s=30.0, horizon_s=1800.0,
                 n_jobs=20, rate_rps=1.0)
    for p in ("kill", "checkpoint")
    for a in ("poisson", "flash_crowd")
]


# ------------------------------------------------------------- cell keys


def test_cell_key_covers_all_fields():
    """Regression: rate_rps / horizon_s / n_jobs / st_max_nodes were not in
    cell_id, so custom grids varying them collided — the spool key must
    hash every field."""
    base = ScenarioCell(preempt="kill", scheduler="first_fit",
                        arrival="poisson", total_nodes=48,
                        slo_target_s=30.0)
    for field in ("rate_rps", "horizon_s", "n_jobs", "st_max_nodes",
                  "preempt", "arrival", "total_nodes", "slo_target_s",
                  "policy", "mix", "budget", "queue_impl", "seed"):
        bumped = {"rate_rps": 3.5, "horizon_s": 999.0, "n_jobs": 7,
                  "st_max_nodes": 5, "preempt": "checkpoint",
                  "arrival": "mmpp", "total_nodes": 49,
                  "slo_target_s": 31.0, "policy": "demand_capped",
                  "mix": "2hpc2ws", "budget": 5000.0,
                  "queue_impl": "exact", "seed": 1}[field]
        other = dataclasses.replace(base, **{field: bumped})
        assert other.cell_key() != base.cell_key(), field
        assert other.cell_id() != base.cell_id(), field


def test_cell_key_deterministic_and_grid_unique():
    cells = make_grid("small") + make_grid("mix_tiny")
    keys = [c.cell_key() for c in cells]
    assert len(set(keys)) == len(cells)
    assert keys == [c.cell_key() for c in cells]        # stable


def test_shard_cells_partition_is_exact():
    cells = make_grid("small")
    parts = [shard_cells(cells, f"{i}/3") for i in range(3)]
    flat = [c for p in parts for c in p]
    assert sorted(c.cell_key() for c in flat) == \
        sorted(c.cell_key() for c in cells)
    assert all(len(p) >= len(cells) // 3 for p in parts)
    with pytest.raises(ValueError):
        shard_cells(cells, "3/3")
    with pytest.raises(ValueError):
        shard_cells(cells, "bogus")


# ---------------------------------------------------------------- spools


def test_spool_roundtrip_and_torn_line(tmp_path):
    path = str(tmp_path / "s.jsonl")
    rows = [{"cell_key": f"k{i}", "metrics": {"completed": i}}
            for i in range(3)]
    for r in rows:
        spool_append(path, r)
    with open(path, "a") as f:
        f.write('{"cell_key": "torn", "metr')        # killed mid-write
    loaded = spool_load(path)
    assert set(loaded) == {"k0", "k1", "k2"}
    assert loaded["k2"]["metrics"]["completed"] == 2


# ------------------------------------------------------- shard + merge


def test_shard_merge_reproduces_single_shot(tmp_path):
    single = run_campaign(FAST_CELLS, workers=1, grid_name="unit")
    spools = []
    for i in range(2):
        sp = str(tmp_path / f"s{i}.jsonl")
        spools.append(sp)
        run_campaign(FAST_CELLS, workers=1, grid_name="unit",
                     spool_path=sp, shard=f"{i}/2")
    merged, missing = merge_spools(spools, grid_cells=FAST_CELLS,
                                   grid_name="unit")
    assert missing == []
    assert merged["reductions"] == single["reductions"]
    assert [c["cell_key"] for c in merged["cells"]] == \
        [c["cell_key"] for c in single["cells"]]
    # non-timing metrics identical cell by cell
    for a, b in zip(single["cells"], merged["cells"]):
        for k in REDUCE_KEYS:
            assert a["metrics"][k] == b["metrics"][k], k


def test_merge_reports_missing_cells(tmp_path):
    sp = str(tmp_path / "s0.jsonl")
    run_campaign(FAST_CELLS, workers=1, spool_path=sp, shard="0/2")
    merged, missing = merge_spools([sp], grid_cells=FAST_CELLS)
    assert len(missing) == 2
    assert merged["n_cells"] == 2


def test_resume_runs_only_missing_cells(tmp_path):
    sp = str(tmp_path / "s.jsonl")
    # "interrupted" run: only shard 0's cells made it to the spool
    run_campaign(FAST_CELLS, workers=1, spool_path=sp, shard="0/2")
    art = run_campaign(FAST_CELLS, workers=1, spool_path=sp, resume=True,
                       grid_name="unit")
    assert art["throughput"]["skipped"] == 2
    assert art["throughput"]["executed"] == 2
    assert art["n_cells"] == 4
    # second resume: nothing left to do
    art2 = run_campaign(FAST_CELLS, workers=1, spool_path=sp, resume=True,
                        grid_name="unit")
    assert art2["throughput"]["executed"] == 0
    assert art2["throughput"]["skipped"] == 4
    assert art2["reductions"] == art["reductions"]


def test_run_campaign_writes_v7_artifact(tmp_path):
    out = tmp_path / "c.json"
    art = run_campaign(FAST_CELLS[:2], workers=1, out_path=str(out),
                       grid_name="unit")
    disk = json.loads(out.read_text())
    assert disk["schema"] == "phoenix-campaign-v7"
    assert "throughput" in disk and disk["throughput"]["executed"] == 2
    assert disk["cells"][0]["queue_sim"]["requests"] > 0
    assert disk["cells"][0]["metrics"]["queue_sim_s"] >= 0.0
    assert art["reductions"] == disk["reductions"]
    # v6: per-impl attribution on the row and aggregated in throughput
    assert disk["cells"][0]["queue_impl"] == "batched"
    impls = disk["throughput"]["queue_impls"]
    assert sum(impls.values()) >= 2 and "jax_batched" in impls


# ------------------------------------------------- v5 market artifact path

# market cells: budget engines over the non-degenerate tenant path, short
# horizon so the end-to-end shard+merge stays fast
MARKET_CELLS = [
    ScenarioCell(preempt="kill", scheduler="first_fit", arrival="poisson",
                 total_nodes=48, slo_target_s=30.0, horizon_s=1800.0,
                 n_jobs=20, rate_rps=1.0, policy=pol, budget=2000.0)
    for pol in ("budget_auction", "second_price")
]


def test_merge_refuses_stale_schema_spools(tmp_path):
    """Spools written under an older artifact schema hash to different
    cell keys, so a merge against the current grid reports every cell
    missing instead of silently folding stale rows in."""
    def old_key(cell):
        blob = json.dumps({"schema": "phoenix-campaign-v4",
                           **dataclasses.asdict(cell)}, sort_keys=True)
        return hashlib.sha256(blob.encode()).hexdigest()[:16]

    sp = str(tmp_path / "stale.jsonl")
    for c in FAST_CELLS:
        spool_append(sp, {"cell_key": old_key(c), "cell_id": c.cell_id(),
                          "metrics": {"completed": 1}})
    merged, missing = merge_spools([sp], grid_cells=FAST_CELLS)
    assert len(missing) == len(FAST_CELLS)
    assert merged["n_cells"] == 0
    # while a current-schema spool folds cleanly
    assert old_key(FAST_CELLS[0]) != FAST_CELLS[0].cell_key()
    assert SCHEMA == "phoenix-campaign-v7"


def test_market_policy_state_survives_shard_merge_bit_for_bit(tmp_path):
    """The v5 market fields (budgets, spend ledger, clearing prices in
    per-cell policy_state and spend/budget_remaining in tenant_metrics)
    must reduce identically through shard+merge and a single-shot run."""
    single = run_campaign(MARKET_CELLS, workers=1, grid_name="unit")
    spools = []
    for i in range(2):
        sp = str(tmp_path / f"m{i}.jsonl")
        spools.append(sp)
        run_campaign(MARKET_CELLS, workers=1, grid_name="unit",
                     spool_path=sp, shard=f"{i}/2")
    merged, missing = merge_spools(spools, grid_cells=MARKET_CELLS,
                                   grid_name="unit")
    assert missing == []
    for a, b in zip(single["cells"], merged["cells"]):
        assert a["cell_key"] == b["cell_key"]
        # market state bit-for-bit through the JSONL spool round-trip
        assert json.dumps(a["policy_state"], sort_keys=True, default=float) \
            == json.dumps(b["policy_state"], sort_keys=True, default=float)
        assert a["tenant_metrics"] == b["tenant_metrics"]
        ps = a["policy_state"]
        assert ps["engine"] in ("budget_auction", "second_price")
        market = ps["market"]
        assert market["transactions"] > 0
        for name, spent in market["spend"].items():
            declared = market["budgets"][name]
            assert declared == 2000.0
            assert 0.0 <= spent <= declared + 1e-6
        spends = {n: t["spend"] for n, t in a["tenant_metrics"].items()}
        assert spends == {n: market["spend"].get(n, 0.0)
                          for n in spends}, a["cell_id"]
    assert merged["reductions"] == single["reductions"]


# ------------------------------------------------- inf-masked reductions


def _row(key, p99, slo_met=False, unserved=0):
    m = {k: 1.0 for k in
         ("completed", "killed", "preemptions", "avg_turnaround_s",
          "ws_p50_s", "ws_p95_s", "ws_violation_rate",
          "ws_unmet_node_seconds", "ws_peak_nodes", "st_avg_alloc",
          "ws_avg_alloc", "queue_sim_s", "wall_s")}
    m["ws_p99_s"] = p99
    m["ws_unserved"] = unserved
    return {"preempt": "kill", "scheduler": "first_fit",
            "arrival": "poisson", "total_nodes": 48, "slo_target_s": 30.0,
            "policy": "paper", "mix": "paper2", "budget": 0.0,
            "cell_id": key, "cell_key": key, "slo_met": slo_met,
            "metrics": m}


def test_reduce_metrics_masks_inf_and_reports_rate():
    """Regression: one starved cell (inf percentiles) used to poison every
    marginal mean containing it."""
    rows = [_row("a", 10.0, slo_met=True), _row("b", 20.0, slo_met=True),
            _row("c", float("inf"), unserved=5)]
    red = reduce_metrics(rows)
    ov = red["overall"]
    assert ov["ws_p99_s"] == pytest.approx(15.0)        # finite-masked mean
    assert ov["inf_rate"] == pytest.approx(1.0 / 3.0)
    assert ov["cells"] == 3
    assert ov["ws_unserved"] == pytest.approx(5.0 / 3.0)


def test_reduce_metrics_all_inf_column_stays_inf():
    rows = [_row("a", float("inf"), unserved=3),
            _row("b", float("inf"), unserved=4)]
    ov = reduce_metrics(rows)["overall"]
    assert ov["ws_p99_s"] == float("inf")
    assert ov["inf_rate"] == 1.0


def test_reduce_metrics_order_independent():
    rows = [_row(k, p) for k, p in
            (("a", 10.0), ("b", 20.0), ("c", 30.0), ("d", 40.0))]
    fwd = reduce_metrics(list(rows))
    rev = reduce_metrics(list(reversed(rows)))
    assert fwd == rev
