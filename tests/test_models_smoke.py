"""Per-architecture smoke tests on reduced configs (CPU, 1 device).

For each assigned architecture: instantiate a reduced same-family config, run
one forward and one SGD train step, assert output shapes and no NaNs; check
prefill+decode consistency against the full-sequence oracle.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, reduced_config
from repro.models import model as M

ARCH_NAMES = sorted(ARCHS)


def _inputs(cfg, key, B=2, S=24):
    if cfg.input_mode == "embeddings":
        return jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
    return jax.random.randint(key, (B, S), 0, cfg.vocab_size)


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_forward_shapes_no_nan(name, rng):
    cfg = reduced_config(ARCHS[name])
    params = M.init_params(rng, cfg)
    B, S = 2, 24
    x = _inputs(cfg, rng, B, S)
    logits, aux = M.forward(params, x, cfg, moe_groups=2)
    if cfg.num_codebooks:
        assert logits.shape == (B, S, cfg.num_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    for v in aux.values():
        assert not bool(jnp.isnan(v).any())


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_one_train_step(name, rng):
    cfg = reduced_config(ARCHS[name])
    params = M.init_params(rng, cfg)
    B, S = 2, 16
    x = _inputs(cfg, rng, B, S)
    if cfg.num_codebooks:
        labels = jax.random.randint(rng, (B, S, cfg.num_codebooks), 0,
                                    cfg.vocab_size)
    else:
        labels = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)

    def loss_fn(p):
        logits, aux = M.forward(p, x, cfg, moe_groups=2)
        ll = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(ll, labels[..., None], axis=-1).mean()
        return nll + 0.01 * sum(aux.values())

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert jnp.isfinite(loss)
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in flat)
    # grads reach the embedding (or the head for embedding-input archs)
    probe = grads["head"]["kernel"] if cfg.input_mode == "embeddings" \
        else grads["embed"]["table"]
    assert float(jnp.abs(probe).max()) > 0


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_prefill_decode_matches_forward(name, rng):
    cfg = reduced_config(ARCHS[name])
    params = M.init_params(rng, cfg)
    B, S, P = 2, 24, 20
    full = _inputs(cfg, rng, B, S)
    logits_full, _ = M.forward(params, full, cfg, moe_groups=1)
    lp, cache = M.prefill(params, full[:, :P], cfg, moe_groups=1, max_len=S)
    assert float(jnp.max(jnp.abs(lp - logits_full[:, P - 1]))) < 2e-3
    for t in range(P, S):
        ld, cache = M.decode_step(params, cache, full[:, t:t + 1],
                                  jnp.int32(t), cfg, moe_groups=1)
        assert float(jnp.max(jnp.abs(ld - logits_full[:, t]))) < 2e-3, t


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_param_count_analytic_close(name, rng):
    """Analytic param_count (used for MODEL_FLOPS) tracks actual leaves."""
    cfg = reduced_config(ARCHS[name])
    params = M.init_params(rng, cfg)
    actual = sum(x.size for x in jax.tree.leaves(params))
    analytic = cfg.param_count()
    assert abs(actual - analytic) / actual < 0.35, (actual, analytic)
