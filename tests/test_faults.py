"""Fault-injection subsystem (core/faults.py): degenerate bit-for-bit
compatibility, policy-axis-independent fault streams, correlated rack
blasts, flapping nodes, drain windows and fail/repair pairing."""
import pytest

from repro.core.faults import (FAULT_PROFILES, FaultSpec, fault_rng,
                               get_fault_spec)
from repro.core.nodes import DRAIN_POOL, NodeInventory, NodeState
from repro.core.policies import Tenant
from repro.core.provision import TenantProvisionService
from repro.core.simulator import ConsolidationSim
from repro.core.telemetry import (Tracer, check_causal_chains,
                                  summarize_events, validate_events)
from repro.core.traces import synthetic_sdsc_blue
from repro.core.types import SimConfig, TenantSpec

DAY = 86400.0
HORIZON = 7200.0


def _mini_tenants(seed=0):
    jobs_a = synthetic_sdsc_blue(seed=seed, n_jobs=30, horizon=HORIZON,
                                 max_nodes=24)
    jobs_b = synthetic_sdsc_blue(seed=seed + 7, n_jobs=30, horizon=HORIZON,
                                 max_nodes=24)
    dem_a = [(t * 300.0, 10 + (t % 4) * 6) for t in range(24)]
    dem_b = [(t * 240.0, 8 + (t % 3) * 5) for t in range(30)]
    return [
        TenantSpec("ws-0", "latency", priority=0, demand=dem_a),
        TenantSpec("ws-1", "latency", priority=1, demand=dem_b),
        TenantSpec("hpc-0", "batch", priority=2, jobs=jobs_a),
        TenantSpec("hpc-1", "batch", priority=3, weight=0.5, jobs=jobs_b),
    ]


def _run_traced(profile, policy="paper", seed=0, total=64):
    tr = Tracer()
    cfg = SimConfig(total_nodes=total, seed=seed,
                    faults=get_fault_spec(profile))
    sim = ConsolidationSim(cfg, horizon=HORIZON, tenants=_mini_tenants(seed),
                           policy=policy, tracer=tr)
    res = sim.run()
    return sim, res, tr


def _fault_seq(tr):
    return [(e["ts"], e["node"]) for e in tr.events
            if e["type"] == "node_fail"]


# ------------------------------------------------ degenerate bit-for-bit

def test_independent_profile_reproduces_legacy_mtbf_bit_for_bit():
    """FaultSpec('independent', seed=None) IS the legacy node_mtbf path:
    same shared RNG stream, same draw order, same pool-proportional
    attribution — identical results down to the util timeline."""
    def run(cfg):
        jobs = synthetic_sdsc_blue(seed=3, n_jobs=120, horizon=2 * DAY,
                                   max_nodes=64)
        dem = [(t * 600.0, 20 + (t % 7) * 5) for t in range(200)]
        return ConsolidationSim(cfg, jobs, dem, horizon=2 * DAY).run()

    legacy = run(SimConfig(total_nodes=160, node_mtbf=50 * DAY,
                           node_repair_time=3600.0, seed=3))
    spec = run(SimConfig(total_nodes=160, seed=3,
                         faults=FaultSpec(profile="independent",
                                          mtbf_s=50 * DAY,
                                          repair_time_s=3600.0)))
    for k in ("completed", "killed", "avg_turnaround", "st_avg_alloc",
              "ws_avg_alloc", "ws_unmet_node_seconds"):
        assert getattr(legacy, k) == getattr(spec, k), k
    assert legacy.util_timeline == spec.util_timeline


# -------------------------------------------- policy-axis determinism

# pinned fault sequences for seed=0, 64 nodes, 7200 s, _mini_tenants:
# regenerate ONLY if the fault-stream contract (fault_rng seeding or
# victim selection over up_ids) deliberately changes
PINNED_FIRST3 = {
    "rack_corr": [(597.7305059015397, 61), (597.7305059015397, 48),
                  (597.7305059015397, 49)],
    "flapping": [(1023.4472573226027, 46), (1193.5976445022438, 46),
                 (1392.1563694393838, 20)],
}


@pytest.mark.parametrize("profile", ["rack_corr", "flapping"])
def test_fault_sequence_pinned_and_policy_independent(profile):
    """Changing --policy (or any allocation knob) must not perturb the
    injected (ts, node) fault sequence within a cell: injectors draw from
    an isolated stream and select victims over the inventory's up set,
    which only past faults can change."""
    seqs = {}
    for policy in ("paper", "slo_headroom", "budget_auction"):
        _, _, tr = _run_traced(profile, policy=policy)
        seqs[policy] = _fault_seq(tr)
    ref = seqs["paper"]
    assert ref[:3] == PINNED_FIRST3[profile]
    for policy, seq in seqs.items():
        assert seq == ref, policy


def test_fault_rng_isolated_from_sim_stream():
    spec = get_fault_spec("rack_corr")
    a = fault_rng(spec, 42).random()
    b = fault_rng(spec, 42).random()
    c = fault_rng(spec, 43).random()
    d = fault_rng(get_fault_spec("flapping"), 42).random()
    assert a == b          # deterministic in (profile, seed)
    assert a != c          # seed-sensitive
    assert a != d          # profile-namespaced


def test_get_fault_spec_rejects_unknown_profile():
    with pytest.raises(ValueError, match="unknown fault profile"):
        get_fault_spec("meteor_strike")
    assert get_fault_spec("none") is None
    assert set(FAULT_PROFILES) >= {"none", "independent", "rack_corr",
                                   "flapping"}


# ----------------------------------------------------- injector behavior

def test_rack_blast_victims_cluster_in_one_domain():
    sim, _, tr = _run_traced("rack_corr")
    fails = [e for e in tr.events if e["type"] == "node_fail"]
    assert fails
    rack = sim.inventory.rack_size
    by_ts = {}
    for e in fails:
        by_ts.setdefault(e["ts"], []).append(e["node"])
    blasts = [nodes for nodes in by_ts.values() if len(nodes) > 1]
    assert blasts, "expected at least one multi-node blast"
    for nodes in blasts:
        assert len({n // rack for n in nodes}) == 1, nodes
        assert len(nodes) <= get_fault_spec("rack_corr").blast_radius


def test_flapping_nodes_cycle_and_stay_flappers():
    sim, _, tr = _run_traced("flapping")
    fails = [e for e in tr.events if e["type"] == "node_fail"]
    repairs = [e for e in tr.events if e["type"] == "node_repair"]
    assert fails and all(e["cause"] == "flap" for e in fails)
    # only designated flappers ever fail, and they fail repeatedly
    flappers = {n.id for n in sim.inventory.nodes if n.flapper}
    assert {e["node"] for e in fails} <= flappers
    assert len(fails) > len(flappers) - len(FAULT_PROFILES)
    # a repaired flapper returns to FLAPPING, never HEALTHY
    repaired = {e["node"] for e in repairs}
    for nid in repaired:
        assert sim.inventory.state_of(nid) in (NodeState.FLAPPING,
                                               NodeState.REPAIRING)
    back_up = [e for e in tr.events if e["type"] == "node_state"
               and e["from"] == "repairing"]
    assert back_up and all(e["to"] == "flapping" for e in back_up)


def test_suppressed_faults_traced_and_repairs_never_overshoot():
    """Satellite: when the cluster is at its one-node minimum a fault is
    traced as fault_suppressed (not silently dropped), consumes no victim
    draw, schedules no repair — so fail/repair events stay paired and
    node_repaired can never push total past the configured size."""
    tr = Tracer()
    cfg = SimConfig(total_nodes=2, seed=1,
                    faults=FaultSpec(profile="independent", mtbf_s=300.0,
                                     repair_time_s=50_000.0))
    jobs = synthetic_sdsc_blue(seed=1, n_jobs=5, horizon=HORIZON,
                               max_nodes=2)
    sim = ConsolidationSim(cfg, jobs, [(0.0, 1)], horizon=HORIZON,
                           tracer=tr)
    sim.run()
    s = summarize_events([tr.header()] + tr.events)["faults"]
    assert s["suppressed"] > 0
    assert s["failures"] == 1          # every later fault was suppressed
    assert s["failures"] - s["repairs"] == 2 - sim.svc.total
    assert sim.svc.total >= 1
    assert validate_events([tr.header()] + tr.events) == []


def test_fail_repair_spans_pair_causally():
    _, _, tr = _run_traced("independent")
    evs = [tr.header()] + tr.events
    assert validate_events(evs) == []
    assert check_causal_chains(evs) == []
    fails = {e["span"]: e for e in tr.events if e["type"] == "node_fail"}
    repairs = [e for e in tr.events if e["type"] == "node_repair"]
    assert repairs
    for r in repairs:
        parent = fails[r["parent"]]            # KeyError = orphaned repair
        assert parent["node"] == r["node"]     # same node, same outage


# -------------------------------------------------------- drain windows

def _drained_service(drain_s=30.0):
    """Service + inventory with a manual drain scheduler: the test owns
    the clock and fires drain completions explicitly."""
    fired = []
    svc = TenantProvisionService(12, policy="paper", tracer=Tracer())
    inv = NodeInventory(12)
    svc.attach_inventory(inv)
    svc.configure_drain(drain_s, lambda dt, fn: fired.append((dt, fn)))
    st = svc.register(Tenant("st", "batch", priority=1))
    svc.register(Tenant("ws", "latency", priority=0))
    st.on_force_release = lambda n: n
    svc.provision_idle()                       # all 12 -> st
    return svc, inv, fired


def test_drain_window_delays_claimant_credit():
    svc, inv, fired = _drained_service()
    got = svc.claim("ws", 5)
    # reclaimed nodes sit in the drain pool: the claim returns only what
    # was granted immediately (free pool), the rest is pending
    assert got == 0
    assert svc.draining == 5 and svc.tenants["ws"].alloc == 0
    assert inv.pool(DRAIN_POOL) == [0, 1, 2, 3, 4]
    assert all(inv.state_of(i) is NodeState.DRAINING for i in range(5))
    inv.audit(svc)
    (dt, fn), = fired
    assert dt == 30.0
    fn()                                       # drain window elapses
    assert svc.draining == 0 and svc.tenants["ws"].alloc == 5
    assert inv.pool("ws") == [0, 1, 2, 3, 4]
    inv.audit(svc)
    # causal chain: drain_complete parents the reclaim_step's span
    evs = svc.tracer.events
    step = next(e for e in evs if e["type"] == "reclaim_step")
    done = next(e for e in evs if e["type"] == "drain_complete")
    assert done["parent"] == step["span"]
    assert done["nodes"] == 5
    assert check_causal_chains([svc.tracer.header()] + evs) == []


def test_drain_node_failure_credits_only_survivors():
    svc, inv, fired = _drained_service()
    svc.claim("ws", 4)
    assert svc.draining == 4
    svc.drain_node_failed(1, cause="rack_blast")   # dies mid-drain
    assert svc.draining == 3 and svc.total == 11
    (dt, fn), = fired
    fn()
    # only the 3 survivors reach the claimant; the dead node is down
    assert svc.tenants["ws"].alloc == 3
    assert inv.pool("ws") == [0, 2, 3]
    assert inv.state_of(1) is NodeState.REPAIRING
    inv.audit(svc)
    svc.node_repaired(node=1)
    assert svc.total == 12
    inv.audit(svc)


def test_sim_level_drain_time_slows_ws_recovery():
    """The same scenario with a drain window must deliver reclaimed nodes
    to WS strictly later (more unmet node-seconds, never less)."""
    def run(drain_s):
        jobs = synthetic_sdsc_blue(seed=2, n_jobs=40, horizon=HORIZON,
                                   max_nodes=48)
        dem = [(t * 600.0, 10 + (t % 3) * 15) for t in range(12)]
        cfg = SimConfig(total_nodes=64, seed=2, drain_time_s=drain_s,
                        faults=FaultSpec(profile="independent", mtbf_s=0.0))
        return ConsolidationSim(cfg, jobs, dem, horizon=HORIZON).run()

    instant = run(0.0)
    drained = run(120.0)
    assert drained.ws_unmet_node_seconds > instant.ws_unmet_node_seconds
    assert sum(drained.policy_state["victim_nodes"].values()) > 0


# ------------------------------------------------------- campaign axis

def test_campaign_fault_axis_changes_cell_identity():
    from repro.workloads.campaign import ScenarioCell
    base = dict(preempt="kill", scheduler="first_fit", arrival="poisson",
                total_nodes=96, slo_target_s=30.0)
    plain = ScenarioCell(**base)
    faulty = ScenarioCell(**base, fault_profile="rack_corr")
    assert plain.cell_key() != faulty.cell_key()
    assert plain.cell_id() != faulty.cell_id()
    assert "frack_corr" in faulty.cell_id()
    assert "fnone" not in plain.cell_id()      # default stays unadorned


def test_campaign_traced_cell_with_faults_validates():
    from repro.workloads.campaign import ScenarioCell, run_cell
    import json, os, tempfile
    cell = ScenarioCell(preempt="kill", scheduler="first_fit",
                        arrival="poisson", total_nodes=48,
                        slo_target_s=30.0, horizon_s=1800.0, n_jobs=16,
                        rate_rps=1.0, policy="slo_headroom", mix="2hpc2ws",
                        fault_profile="rack_corr")
    with tempfile.TemporaryDirectory() as td:
        row = run_cell(cell, trace_dir=td)
        assert row["fault_profile"] == "rack_corr"
        faults = row["trace_summary"]["faults"]
        assert faults["failures"] > 0
        with open(row["trace_file"]) as f:
            evs = [json.loads(line) for line in f]
        assert validate_events(evs) == []
        assert check_causal_chains(evs) == []
