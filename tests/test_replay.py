"""Trace-driven replay + bisection tests (core/replay.py, repro.trace).

The PR-10 contract:

  * a trace is a COMPLETE causal record — re-applying its decision
    sequence against fresh count books reproduces the live sim's free
    pool, per-tenant allocs and market spend at every ``metrics``
    checkpoint, for every registry engine and under fault injection
    (drains, node failures, repairs);
  * tampering with the record (a dropped decision, a forged grant) makes
    replay diverge loudly;
  * ``bisect_traces`` localizes the first *behavioral* divergence
    between two traces of the same scenario under different engines,
    and ignores cosmetic differences (span ids, engine labels).
"""
import json

import pytest

from repro.core.policies import POLICIES
from repro.core.replay import (bisect_traces, decision_stream,
                               normalize_decision, replay_events)
from repro.core.telemetry import load_events
from test_telemetry import paper_two_tenant_trace, request_level_trace

ENGINES = sorted(POLICIES)


def _events(tr):
    return [tr.header()] + tr.events


# ------------------------------------------------------------- replay

def test_replay_pinned_paper_trace():
    """The golden 2009 two-tenant trace replays to the exact final books
    the live sim recorded: st=7, ws=3, free=0 on 10 nodes."""
    res = replay_events(_events(paper_two_tenant_trace()))
    assert res.ok, res.problems
    assert res.checkpoints == 4
    assert res.books() == {
        "total": 10, "free": 0, "draining": 0,
        "alloc": {"st": 7, "ws": 3},
        "spend": {}, "demand": {"ws": 3},
    }


@pytest.mark.parametrize("policy", ENGINES)
def test_replay_every_engine_request_level(policy):
    """Every registry engine's decision stream is a complete causal
    record: replay matches all live metrics checkpoints exactly."""
    res = replay_events(_events(request_level_trace(policy=policy)))
    assert res.ok, (policy, res.problems[:5])
    assert res.decisions > 10
    assert res.checkpoints > 5
    assert sum(res.alloc.values()) + res.free + res.draining == res.total


def test_replay_pinned_mix_tiny_cell(tmp_path):
    """A pinned mix_tiny campaign cell (acceptance criterion): the
    spooled trace replays with count books matching the live sim at
    every checkpoint, and the final books conserve the fleet."""
    from repro.workloads.campaign import ScenarioCell, run_cell
    cell = ScenarioCell(preempt="kill", scheduler="first_fit",
                        arrival="poisson", total_nodes=96,
                        slo_target_s=30.0, horizon_s=7200.0,
                        n_jobs=20, rate_rps=2.0, mix="2hpc2ws",
                        policy="slo_headroom")
    row = run_cell(cell, trace_dir=str(tmp_path))
    res = replay_events(load_events(row["trace_file"]))
    assert res.ok, res.problems[:5]
    assert res.checkpoints >= 10      # periodic samples + closing sample
    assert res.total <= 96            # unrepaired failures only shrink it


def test_replay_under_fault_injection(tmp_path):
    """Drain windows, node failures and repairs all round-trip through
    the books (draining pool, owner attribution, total shrink/grow)."""
    from repro.workloads.campaign import ScenarioCell, run_cell
    cell = ScenarioCell(preempt="kill", scheduler="first_fit",
                        arrival="poisson", total_nodes=48,
                        slo_target_s=30.0, horizon_s=7200.0,
                        n_jobs=15, rate_rps=1.0, mix="2hpc2ws",
                        policy="paper", fault_profile="rack_corr")
    row = run_cell(cell, trace_dir=str(tmp_path))
    events = load_events(row["trace_file"])
    assert any(e["type"] == "node_fail" for e in events), \
        "fault profile produced no failures; test scenario too quiet"
    res = replay_events(events)
    assert res.ok, res.problems[:5]


def test_replay_detects_dropped_decision():
    """Deleting one decision from the record breaks checkpoint match —
    the trace is no longer a complete causal record."""
    events = _events(paper_two_tenant_trace())
    tampered = [e for e in events if e["type"] != "release"]
    assert len(tampered) < len(events)
    res = replay_events(tampered)
    assert not res.ok
    assert any("free" in p or "alloc" in p for p in res.problems)


def test_replay_detects_forged_grant():
    events = [dict(e) for e in _events(paper_two_tenant_trace())]
    grant = next(e for e in events if e["type"] == "idle_grant")
    grant["nodes"] += 1
    res = replay_events(events)
    assert not res.ok


def test_replay_flags_claim_arithmetic():
    """A claim whose granted count disagrees with from_free + reclaim
    steps is reported even when checkpoints still happen to pass."""
    events = [dict(e) for e in _events(paper_two_tenant_trace())]
    claim = next(e for e in events if e["type"] == "claim")
    claim["granted"] += 1
    res = replay_events(events)
    assert any("claim arithmetic" in p for p in res.problems)


# ------------------------------------------------------------- bisect

def test_bisect_identical_traces_is_none():
    tr = request_level_trace(policy="paper")
    assert bisect_traces(_events(tr), _events(tr)) is None


def test_bisect_ignores_cosmetic_span_ids():
    """Renumbering spans (allocation-order artifacts) is not a
    behavioral divergence."""
    a = _events(paper_two_tenant_trace())
    b = []
    for e in a:
        e = dict(e)
        for k in ("span", "parent"):
            if k in e:
                e[k] = e[k] + 100
        b.append(e)
    assert bisect_traces(a, b) is None


def test_bisect_localizes_engine_divergence():
    """paper vs slo_headroom on the same scenario (acceptance
    criterion): the report pins sim-time, tenants, both events, and the
    planned victim lists when a reclaim is involved."""
    a = _events(request_level_trace(policy="paper"))
    b = _events(request_level_trace(policy="slo_headroom"))
    rep = bisect_traces(a, b)
    assert rep is not None, "engines produced identical decision streams"
    assert rep["common_decisions"] == rep["decision_index"]
    for side in ("a", "b"):
        s = rep[side]
        assert not s["exhausted"]
        assert s["ts"] is not None
        assert s["type"] in {e["type"] for e in (a if side == "a" else b)}
    # the divergence is real: the normalized events differ
    assert normalize_decision(rep["a"]["event"]) \
        != normalize_decision(rep["b"]["event"])
    # and everything before it matches
    sa, sb = decision_stream(a), decision_stream(b)
    k = rep["decision_index"]
    assert [normalize_decision(e) for _, e in sa[:k]] \
        == [normalize_decision(e) for _, e in sb[:k]]


def test_bisect_prefix_trace_reports_exhaustion():
    events = _events(paper_two_tenant_trace())
    stream = decision_stream(events)
    cut_idx = stream[len(stream) // 2][0]       # truncate mid-stream
    rep = bisect_traces(events, events[:cut_idx])
    assert rep is not None
    assert rep["b"]["exhausted"] and not rep["a"]["exhausted"]


# ----------------------------------------------------------------- CLI

def test_replay_and_bisect_cli(tmp_path):
    from repro.trace import main
    pa = str(tmp_path / "a.trace.jsonl")
    pb = str(tmp_path / "b.trace.jsonl")
    request_level_trace(policy="paper").to_jsonl(pa)
    request_level_trace(policy="slo_headroom").to_jsonl(pb)
    assert main(["replay", pa]) == 0
    assert main(["replay", pa, "--json"]) == 0
    assert main(["bisect", pa, pa]) == 0
    assert main(["bisect", pa, pb]) == 1
    # tampered trace: replay exits non-zero
    events = load_events(pa)
    bad = [e for e in events if e["type"] != "idle_grant"]
    pbad = str(tmp_path / "bad.trace.jsonl")
    with open(pbad, "w") as f:
        for e in bad:
            f.write(json.dumps(e) + "\n")
    assert main(["replay", pbad]) == 1
