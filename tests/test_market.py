"""Budget-constrained market engines: goldens, Vickrey properties, plumbing.

Covers the market subsystem built on the two-phase PolicyEngine:
  * golden regression: `budget_auction` spend ledgers and `second_price`
    clearing prices pinned on a small fixed scenario (the market analogue
    of the paper-engine goldens in tests/test_tenancy.py);
  * Vickrey properties: second-price payments <= first-price on identical
    bids, and a fully served winner's payment is independent of its own
    bid (truthful bid_weights dominant);
  * budget semantics: broke tenants fall back to their floor on both the
    idle-purchase and urgent-claim side; budgets are never overspent;
  * slo_elastic bids rise as latency headroom shrinks (and are capped);
  * MarketState reaches SimResult.policy_state / TenantResult and the
    runtime orchestrator's market_state().
"""
import math
import random

import pytest

from repro.core.policies import (BudgetAuctionEngine, POLICIES,
                                 SecondPriceEngine, Tenant, compute_bid,
                                 get_policy, unit_bid)
from repro.core.provision import TenantProvisionService
from repro.core.types import MarketState, TenantSignals, TenantSpec


def _hook(svc, name):
    """Standard batch release hook: give up to what we hold."""
    return lambda n: min(n, svc.tenants[name].alloc)


def _market_svc(policy, total=10, *, a_bid=3.0, a_budget=20.0,
                b_budget=5.0, c_budget=10.0):
    """The fixed golden scenario: two batch buyers + one latency claimant.

    A bids 3/node with 20 tokens, B bids 1/node with 5 tokens, C (latency,
    floor 1) holds 10 tokens for urgent claims.
    """
    svc = TenantProvisionService(total, policy=policy)
    svc.register(Tenant("A", "batch", priority=1, weight=2.0,
                        bid_weight=a_bid, budget=a_budget,
                        on_force_release=_hook(svc, "A")))
    svc.register(Tenant("B", "batch", priority=2, weight=1.0,
                        bid_weight=1.0, budget=b_budget,
                        on_force_release=_hook(svc, "B")))
    svc.register(Tenant("C", "latency", priority=0, floor=1,
                        budget=c_budget))
    return svc


# ---------------------------------------------------------------- goldens

def test_budget_auction_golden_spend_ledger():
    """Pinned first-price run: idle sale clears at the lowest winning bid,
    the urgent claim pays each victim's bid beyond the floor entitlement."""
    svc = _market_svc("budget_auction")
    svc.set_demand("A", 6, provision=False)
    svc.set_demand("B", 4, provision=False)
    svc.provision_idle()
    m = svc.policy.market
    # A (bid 3) is served first, B (bid 1) second; clearing = lowest
    # winning bid = 1; both pay 1/node
    assert svc.tenants["A"].alloc == 6 and svc.tenants["B"].alloc == 4
    assert svc.policy.price_samples == [pytest.approx(1.0)]
    assert m.spend == {"A": pytest.approx(6.0), "B": pytest.approx(4.0)}
    assert m.remaining["A"] == pytest.approx(14.0)
    assert m.remaining["B"] == pytest.approx(1.0)
    # urgent claim: victims ascending bid (B@1 first, then A@3); C's first
    # node is its free floor entitlement, the rest debit its budget
    got = svc.claim("C", 5)
    assert got == 5
    assert svc.tenants["B"].alloc == 0 and svc.tenants["A"].alloc == 5
    # 1 free + 3 nodes @ B's bid 1 + 1 node @ A's bid 3 = 6 tokens
    assert m.spend["C"] == pytest.approx(6.0)
    assert m.remaining["C"] == pytest.approx(4.0)
    kinds = [e["kind"] for e in m.ledger]
    assert kinds == ["idle", "idle", "reclaim", "reclaim"]
    assert [(e["tenant"], e["nodes"], e["unit_price"]) for e in m.ledger] \
        == [("A", 6, 1.0), ("B", 4, 1.0), ("C", 3, 1.0), ("C", 1, 3.0)]
    svc.check()
    # the whole run lands JSON-safe in the snapshot
    snap = svc.policy.state_snapshot()
    assert snap["engine"] == "budget_auction"
    assert snap["market"]["spend"]["C"] == pytest.approx(6.0)
    assert snap["market"]["clearing_prices"] == [pytest.approx(1.0)]


def test_second_price_golden_clearing_prices():
    """Pinned Vickrey run: with a rejected third bidder the clearing price
    is the highest LOSING bid; with no losers it is zero."""
    # all demand fits: no losers -> price 0, nobody pays
    svc = _market_svc("second_price")
    svc.set_demand("A", 6, provision=False)
    svc.set_demand("B", 4, provision=False)
    svc.provision_idle()
    m = svc.policy.market
    assert svc.tenants["A"].alloc == 6 and svc.tenants["B"].alloc == 4
    assert svc.policy.price_samples == [pytest.approx(0.0)]
    assert m.spend == {"A": 0.0, "B": 0.0}

    # a losing bidder sets the price: D bids 0.5 and is fully rejected
    svc = _market_svc("second_price")
    svc.register(Tenant("D", "batch", priority=3, bid_weight=0.5,
                        budget=5.0, on_force_release=_hook(svc, "D")))
    svc.set_demand("A", 6, provision=False)
    svc.set_demand("B", 4, provision=False)
    svc.set_demand("D", 4, provision=False)
    svc.provision_idle()
    m = svc.policy.market
    assert svc.tenants["A"].alloc == 6 and svc.tenants["B"].alloc == 4
    assert svc.tenants["D"].alloc == 0
    assert svc.policy.price_samples == [pytest.approx(0.5)]
    assert m.spend == {"A": pytest.approx(3.0), "B": pytest.approx(2.0),
                       "D": 0.0}
    # reclaim pricing is inherited from budget_auction unchanged
    got = svc.claim("C", 5)
    assert got == 5
    assert m.spend["C"] == pytest.approx(6.0)
    svc.check()


def test_second_price_payment_independent_of_own_bid():
    """Truthfulness: a fully served Vickrey winner pays the best rejected
    bid whatever it bid itself; under first-price its own bid can set the
    clearing price (single-winner case)."""
    def spend_a(policy, a_bid):
        svc = TenantProvisionService(6, policy=policy)
        svc.register(Tenant("A", "batch", priority=1, bid_weight=a_bid,
                            budget=10_000.0))
        svc.register(Tenant("B", "batch", priority=2, bid_weight=1.0,
                            budget=10_000.0))
        svc.set_demand("A", 6, provision=False)
        svc.set_demand("B", 4, provision=False)   # B fully rejected
        svc.provision_idle()
        assert svc.tenants["A"].alloc == 6
        return svc.policy.market.spend["A"]

    # Vickrey: A pays B's bid (1.0/node) whether it bid 3 or 300
    assert spend_a("second_price", 3.0) == pytest.approx(6.0)
    assert spend_a("second_price", 300.0) == pytest.approx(6.0)
    # first-price: A is the only (hence lowest) winner — its own bid is
    # the clearing price, so inflating it costs real tokens
    assert spend_a("budget_auction", 3.0) == pytest.approx(18.0)
    assert spend_a("budget_auction", 300.0) == pytest.approx(1800.0)


def test_second_price_payments_leq_first_price_on_identical_bids():
    """Property: on one idle auction with identical bids/budgets/demands,
    every tenant's Vickrey payment is <= its first-price payment."""
    for seed in range(30):
        rng = random.Random(9000 + seed)
        total = rng.randint(4, 80)
        n = rng.randint(2, 5)
        rows = [(f"t{i}", i, round(rng.uniform(0.0, 5.0), 2),
                 rng.randint(0, 40), round(rng.uniform(10.0, 500.0), 1))
                for i in range(n)]
        spends = {}
        for policy in ("budget_auction", "second_price"):
            svc = TenantProvisionService(total, policy=policy)
            for name, prio, bw, demand, budget in rows:
                svc.register(Tenant(name, "batch", priority=prio,
                                    bid_weight=bw, budget=budget))
                svc.set_demand(name, demand, provision=False)
            svc.provision_idle()
            svc.check()
            spends[policy] = dict(svc.policy.market.spend)
        for name, _, _, _, _ in rows:
            assert spends["second_price"][name] <= \
                spends["budget_auction"][name] + 1e-9, (seed, name, spends)


# ------------------------------------------------------- budget semantics

def test_broke_batch_tenant_stops_buying_idle():
    svc = TenantProvisionService(20, policy="budget_auction")
    svc.register(Tenant("rich", "batch", priority=1, bid_weight=2.0,
                        budget=1000.0))
    svc.register(Tenant("poor", "batch", priority=2, bid_weight=2.0,
                        budget=3.0))          # can afford exactly 1 node
    svc.set_demand("rich", 5, provision=False)
    svc.set_demand("poor", 10, provision=False)
    svc.provision_idle()
    assert svc.tenants["rich"].alloc == 5
    assert svc.tenants["poor"].alloc == 1     # affordability-capped
    assert svc.free == 14                     # unmet demand but no money
    m = svc.policy.market
    assert m.remaining["poor"] >= 0.0
    svc.check()                               # relaxed satiation invariant


def test_broke_latency_claimant_falls_back_to_floor():
    svc = TenantProvisionService(10, policy="budget_auction")
    svc.register(Tenant("hpc", "batch", priority=2, bid_weight=2.0,
                        budget=1000.0, on_force_release=_hook(svc, "hpc")))
    svc.register(Tenant("ws", "latency", priority=0, floor=2, budget=0.0))
    svc.set_demand("hpc", 10)                 # hpc buys the whole cluster
    assert svc.tenants["hpc"].alloc == 10
    # ws is broke: an urgent claim only reaches its free floor entitlement
    got = svc.claim("ws", 8)
    assert got == 2 and svc.tenants["ws"].alloc == 2
    assert svc.policy.market.spend["ws"] == 0.0
    # with tokens, the same claim digs further (2 free + affordable 3)
    svc2 = TenantProvisionService(10, policy="budget_auction")
    svc2.register(Tenant("hpc", "batch", priority=2, bid_weight=2.0,
                         budget=1000.0, on_force_release=_hook(svc2, "hpc")))
    svc2.register(Tenant("ws", "latency", priority=0, floor=2, budget=6.0))
    svc2.set_demand("hpc", 10)
    got = svc2.claim("ws", 8)
    assert got == 5 and svc2.tenants["ws"].alloc == 5
    assert svc2.policy.market.spend["ws"] == pytest.approx(6.0)
    assert svc2.policy.market.remaining["ws"] == pytest.approx(0.0)


def test_budgets_never_overspent_under_partial_releases():
    """A victim refusing to release must neither let the plan walk into
    charges beyond the claimant's budget NOR starve affordable victims
    later in the plan (affordability is enforced live at apply time)."""
    svc = TenantProvisionService(12, policy="budget_auction")
    # cheap victim refuses to release; expensive one complies
    svc.register(Tenant("cheap", "batch", priority=3, bid_weight=1.0,
                        budget=100.0, on_force_release=lambda n: 0))
    svc.register(Tenant("dear", "batch", priority=2, bid_weight=4.0,
                        budget=100.0, on_force_release=_hook(svc, "dear")))
    svc.register(Tenant("ws", "latency", priority=0, budget=8.0))
    svc.set_demand("cheap", 6, provision=False)
    svc.set_demand("dear", 6, provision=False)
    svc.provision_idle()
    got = svc.claim("ws", 12)
    m = svc.policy.market
    # the stuck cheap victim gave nothing; the claim still reached `dear`
    # and bought exactly what 8 tokens afford at dear's price (2 @ 4.0)
    assert got == 2 and svc.tenants["ws"].alloc == 2
    assert m.spend["ws"] == pytest.approx(8.0)
    assert m.remaining["ws"] == pytest.approx(0.0)
    svc.check()


def test_over_releasing_victim_never_overcharges_claimant():
    """A victim releasing MORE than asked (DP-group rounding) hands the
    surplus back to the free pool — the claimant is charged only for the
    nodes it received, and the surplus is sold through the idle market
    instead of being paid for twice."""
    svc = TenantProvisionService(10, policy="budget_auction")
    # trainer-style victim: always releases in whole groups of 8
    svc.register(Tenant("train", "batch", priority=1, bid_weight=1.0,
                        budget=1000.0, on_force_release=lambda n: 8))
    svc.register(Tenant("ws", "latency", priority=0, budget=100.0))
    svc.set_demand("train", 10)               # buys all 10 @ own bid 1.0
    m = svc.policy.market
    assert svc.tenants["train"].alloc == 10
    spend_before = m.spend["train"]
    got = svc.claim("ws", 2)
    assert got == 2 and svc.tenants["ws"].alloc == 2
    # charged for the 2 nodes received, NOT the 8 the victim released
    assert m.spend["ws"] == pytest.approx(2.0)
    # the 6 surplus nodes reflowed and were re-sold to train through the
    # idle market (its demand is still 10), not double-charged to ws
    assert svc.tenants["train"].alloc == 8
    assert m.spend["train"] > spend_before
    svc.check()


# ------------------------------------------------------- slo_elastic bids

def test_slo_elastic_bid_rises_as_headroom_shrinks_and_caps():
    t = Tenant("ws", "latency", priority=0, bid_weight=2.0,
               bid_policy="slo_elastic")

    def sig(headroom):
        return TenantSignals(name="ws", kind="latency", alloc=2, demand=4,
                             latency_headroom_s=headroom, slo_target_s=30.0)

    assert unit_bid(t, sig(30.0)) == pytest.approx(2.0)    # full headroom
    assert unit_bid(t, sig(15.0)) == pytest.approx(3.0)
    assert unit_bid(t, sig(0.0)) == pytest.approx(4.0)     # at the target
    assert unit_bid(t, sig(-30.0)) == pytest.approx(6.0)   # violating
    assert unit_bid(t, sig(-1e9)) == pytest.approx(8.0)    # capped at 4x
    # compute_bid is the same price times unmet demand
    assert compute_bid(t, sig(0.0)) == pytest.approx(8.0)
    # linear tenants and tenants without an SLO target are unaffected
    lin = Tenant("ws", "latency", priority=0, bid_weight=2.0)
    assert unit_bid(lin, sig(-30.0)) == pytest.approx(2.0)
    no_slo = TenantSignals(name="ws", kind="latency", alloc=2, demand=4,
                           latency_headroom_s=-5.0, slo_target_s=0.0)
    assert unit_bid(t, no_slo) == pytest.approx(2.0)


# ------------------------------------------------------------- plumbing

def test_market_state_registry_and_snapshot_roundtrip():
    m = MarketState()
    m.register("a", 10.0)
    m.register("a", 99.0)                     # later registration ignored
    m.register("b", None)
    assert m.budgets == {"a": 10.0, "b": None}
    assert m.affordable_nodes("a", 3.0) == 3
    assert m.affordable_nodes("b", 3.0) > 10**6
    assert m.affordable_nodes("a", 0.0) > 10**6
    m.debit("a", 2, 3.0, "idle", 1)
    assert m.remaining["a"] == pytest.approx(4.0)
    snap = m.snapshot()
    assert snap["remaining"]["b"] is None     # inf is JSON-safe
    assert snap["spend"]["a"] == pytest.approx(6.0)
    import json
    json.dumps(snap)


def test_market_engines_registered_and_resolvable():
    assert get_policy("budget_auction").name == "budget_auction"
    assert get_policy("second_price").name == "second_price"
    assert isinstance(get_policy("second_price"), BudgetAuctionEngine)
    assert isinstance(get_policy(SecondPriceEngine), SecondPriceEngine)
    assert {"budget_auction", "second_price"} <= set(POLICIES)


def test_market_state_reaches_sim_results():
    from repro.core.simulator import ConsolidationSim
    from repro.core.traces import synthetic_sdsc_blue, worldcup_demand_events
    from repro.core.types import SimConfig

    horizon = 6 * 3600.0
    specs = [
        TenantSpec("ws-a", "latency", priority=0, floor=2, budget=5000.0,
                   bid_policy="slo_elastic",
                   demand=worldcup_demand_events(seed=0, horizon=horizon)),
        TenantSpec("hpc-a", "batch", priority=2, weight=2.0, budget=3000.0,
                   jobs=synthetic_sdsc_blue(seed=0, n_jobs=60,
                                            horizon=horizon, max_nodes=32)),
        TenantSpec("hpc-b", "batch", priority=3, weight=1.0, budget=500.0,
                   jobs=synthetic_sdsc_blue(seed=1, n_jobs=60,
                                            horizon=horizon, max_nodes=32)),
    ]
    sim = ConsolidationSim(SimConfig(total_nodes=96), horizon=horizon,
                           tenants=specs, policy="budget_auction")
    res = sim.run()
    market = res.policy_state["market"]
    assert market["transactions"] > 0
    assert market["budgets"] == {"ws-a": 5000.0, "hpc-a": 3000.0,
                                 "hpc-b": 500.0}
    for name, t in res.tenants.items():
        assert t.spend >= 0.0
        assert t.budget_remaining == pytest.approx(
            market["budgets"][name] - t.spend)
        assert t.budget_remaining >= -1e-6    # never overspent
    assert sum(t.spend for t in res.tenants.values()) > 0.0
    # clearing prices recorded and each <= the interval's max unit bid cap
    assert market["clearing_prices"]
    import json
    json.dumps(res.policy_state)


class _StubTrainer:
    """Duck-typed ElasticTrainer: counts device moves, no JAX."""

    def __init__(self, model_size=1, global_batch=8):
        self.model_size = model_size
        self.global_batch = global_batch
        self.step = 0
        self.devices = []
        self.resizes = 0

    def start(self, devices):
        self.devices = list(devices)

    def resize(self, devices):
        self.devices = list(devices)
        self.resizes += 1


class _StubPool:
    """Duck-typed ServingPool: one replica per device."""

    def __init__(self):
        self.replicas = []

    def scale_to(self, devices):
        self.replicas = list(devices)

    def desired_replicas(self, load):
        return int(load)


def test_orchestrator_exposes_market_state():
    """MultiTenantOrchestrator passes budgets through and market_state()
    shows the serving department throttling as its budget drains."""
    from repro.runtime.orchestrator import MultiTenantOrchestrator

    devices = [f"dev{i}" for i in range(8)]
    orch = MultiTenantOrchestrator(devices=devices, policy="budget_auction")
    pool = _StubPool()
    tr = _StubTrainer(model_size=1, global_batch=8)
    orch.add_latency("serve", pool, priority=0, floor=1, budget=4.0,
                     bid_policy="slo_elastic")
    orch.add_batch("train", tr, priority=1, bid_weight=2.0, min_devices=1)
    orch.start()
    assert orch.market_state() is not None
    # spike: the claim debits serve's budget at train's per-node bid (2):
    # 1 free floor node + 2 paid nodes exhaust the 4-token budget
    orch.latency_tick("serve", 8.0)
    state = orch.market_state()
    assert state["spend"]["serve"] == pytest.approx(4.0)
    assert state["remaining"]["serve"] == pytest.approx(0.0)
    replicas_when_broke = len(pool.replicas)
    # broke: a second, bigger spike cannot buy anything further
    orch.latency_tick("serve", 0.0)
    orch.latency_tick("serve", 8.0)
    assert len(pool.replicas) <= replicas_when_broke
    assert orch.market_state()["remaining"]["serve"] == pytest.approx(0.0)
    orch.devs.check()
    orch.svc.check()
