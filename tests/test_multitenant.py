"""Multi-tenant provision service: N departments, strict priorities."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.policies import MultiTenantProvisionService, Tenant


def make_service(total=100):
    svc = MultiTenantProvisionService(total)
    freed = {"st1": 0, "st2": 0}

    def releaser(name):
        def f(n):
            freed[name] += n
            return n
        return f

    svc.register(Tenant("ws1", "latency", priority=0))
    svc.register(Tenant("ws2", "latency", priority=1))
    svc.register(Tenant("st1", "batch", priority=2,
                        on_force_release=releaser("st1")))
    svc.register(Tenant("st2", "batch", priority=3,
                        on_force_release=releaser("st2")))
    return svc, freed


def test_idle_flows_to_highest_priority_batch():
    svc, _ = make_service()
    svc.tenants["st1"].demand = 30
    svc.tenants["st2"].demand = 50
    svc.provision_idle()
    # st1 gets its demand, st2 gets its demand, leftover -> st1 (greedy)
    assert svc.tenants["st1"].alloc == 30 + 20
    assert svc.tenants["st2"].alloc == 50
    assert svc.free == 0


def test_two_tenant_special_case_matches_paper():
    """With one WS + one ST this reduces to the paper's three rules."""
    svc = MultiTenantProvisionService(10)
    svc.register(Tenant("ws", "latency", priority=0))
    svc.register(Tenant("st", "batch", priority=1,
                        on_force_release=lambda n: n))
    svc.provision_idle()
    assert svc.tenants["st"].alloc == 10          # rule 2: all idle to ST
    got = svc.claim("ws", 4)                      # rule 3: forced reclaim
    assert got == 4
    assert svc.tenants["ws"].alloc == 4 and svc.tenants["st"].alloc == 6
    svc.release("ws", 2)                          # WS releases immediately
    assert svc.tenants["st"].alloc == 8           # ... and idle goes to ST


def test_reclaim_order_reverse_priority():
    svc, freed = make_service()
    svc.tenants["st1"].demand = 60
    svc.tenants["st2"].demand = 40
    svc.provision_idle()
    # claim more than st2 (lowest priority) holds: st2 drained before st1
    got = svc.claim("ws1", 50)
    assert got == 50
    assert freed["st2"] == 40
    assert freed["st1"] == 10
    assert svc.tenants["st2"].alloc == 0


def test_latency_tenants_preempt_lower_priority_latency():
    svc, _ = make_service()
    svc.claim("ws2", 100)          # ws2 grabs everything
    got = svc.claim("ws1", 30)     # higher-priority ws1 preempts ws2
    assert got == 30
    assert svc.tenants["ws1"].alloc == 30
    assert svc.tenants["ws2"].alloc == 70


def test_lower_priority_latency_cannot_preempt_higher():
    svc, _ = make_service()
    svc.claim("ws1", 100)
    got = svc.claim("ws2", 10)     # nothing reclaimable below ws2
    assert got == 0
    assert svc.tenants["ws1"].alloc == 100


@given(total=st.integers(10, 200),
       ops=st.lists(st.tuples(st.sampled_from(["claim1", "claim2", "rel1",
                                               "rel2", "demand1", "demand2"]),
                              st.integers(0, 80)), max_size=40))
@settings(max_examples=80, deadline=None)
def test_conservation_under_arbitrary_ops(total, ops):
    svc, _ = make_service(total)
    for op, n in ops:
        if op == "claim1":
            svc.claim("ws1", n)
        elif op == "claim2":
            svc.claim("ws2", n)
        elif op == "rel1":
            svc.release("ws1", n)
        elif op == "rel2":
            svc.release("ws2", n)
        elif op == "demand1":
            svc.set_batch_demand("st1", n)
        else:
            svc.set_batch_demand("st2", n)
        svc.check()
        # latency priority invariant: ws1 never starved while ws2 holds
        # (after any claim, ws1's last claim was fully satisfiable unless
        # everything above it was exhausted) — structural check:
        assert svc.free >= 0
