"""Multi-tenant provision service: N departments, strict priorities."""
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:       # container without hypothesis: property tests skip
    HAS_HYPOTHESIS = False

from repro.core.policies import MultiTenantProvisionService, Tenant


def make_service(total=100, greedy_idle=False):
    svc = MultiTenantProvisionService(total, greedy_idle=greedy_idle)
    freed = {"st1": 0, "st2": 0}

    def releaser(name):
        def f(n):
            freed[name] += n
            return n
        return f

    svc.register(Tenant("ws1", "latency", priority=0))
    svc.register(Tenant("ws2", "latency", priority=1))
    svc.register(Tenant("st1", "batch", priority=2,
                        on_force_release=releaser("st1")))
    svc.register(Tenant("st2", "batch", priority=3,
                        on_force_release=releaser("st2")))
    return svc, freed


def test_idle_flows_to_highest_priority_batch_greedy():
    svc, _ = make_service(greedy_idle=True)
    svc.tenants["st1"].demand = 30
    svc.tenants["st2"].demand = 50
    svc.provision_idle()
    # st1 gets its demand, st2 gets its demand, leftover -> st1 (greedy)
    assert svc.tenants["st1"].alloc == 30 + 20
    assert svc.tenants["st2"].alloc == 50
    assert svc.free == 0


def test_idle_demand_capped_by_default():
    """Default mode: grants stop at declared demand, leftover stays free —
    a tenant with zero demand never receives nodes."""
    svc, _ = make_service()
    svc.tenants["st1"].demand = 30
    svc.tenants["st2"].demand = 50
    svc.provision_idle()
    assert svc.tenants["st1"].alloc == 30
    assert svc.tenants["st2"].alloc == 50
    assert svc.free == 20
    svc.check()


def test_zero_demand_tenant_gets_nothing_by_default():
    svc, _ = make_service()
    svc.provision_idle()
    assert svc.tenants["st1"].alloc == 0
    assert svc.tenants["st2"].alloc == 0
    assert svc.free == 100
    svc.check()


def test_two_tenant_special_case_matches_paper():
    """With one WS + one ST and greedy_idle this reduces to the paper's
    three rules."""
    svc = MultiTenantProvisionService(10, greedy_idle=True)
    svc.register(Tenant("ws", "latency", priority=0))
    svc.register(Tenant("st", "batch", priority=1,
                        on_force_release=lambda n: n))
    svc.provision_idle()
    assert svc.tenants["st"].alloc == 10          # rule 2: all idle to ST
    got = svc.claim("ws", 4)                      # rule 3: forced reclaim
    assert got == 4
    assert svc.tenants["ws"].alloc == 4 and svc.tenants["st"].alloc == 6
    svc.release("ws", 2)                          # WS releases immediately
    assert svc.tenants["st"].alloc == 8           # ... and idle goes to ST


def test_reclaim_order_reverse_priority():
    svc, freed = make_service()
    svc.tenants["st1"].demand = 60
    svc.tenants["st2"].demand = 40
    svc.provision_idle()
    # claim more than st2 (lowest priority) holds: st2 drained before st1
    got = svc.claim("ws1", 50)
    assert got == 50
    assert freed["st2"] == 40
    assert freed["st1"] == 10
    assert svc.tenants["st2"].alloc == 0


def test_reclaim_drains_all_batch_before_latency_tenants():
    """Claim ordering: batch tenants (reverse priority) are fully drained
    before any lower-priority latency tenant is touched."""
    svc, freed = make_service()
    svc.set_batch_demand("st1", 20)
    svc.set_batch_demand("st2", 20)
    svc.claim("ws2", 60)               # ws2 takes the free pool
    assert svc.free == 0
    # ws1 needs 50: free(0) -> st2(20) -> st1(20) -> only then ws2(10)
    got = svc.claim("ws1", 50)
    assert got == 50
    assert freed["st2"] == 20 and freed["st1"] == 20
    assert svc.tenants["st1"].alloc == 0 and svc.tenants["st2"].alloc == 0
    assert svc.tenants["ws2"].alloc == 50          # lost exactly the rest
    assert svc.tenants["ws1"].alloc == 50


def test_reclaim_spares_latency_when_batch_suffices():
    svc, freed = make_service()
    svc.set_batch_demand("st1", 30)
    svc.claim("ws2", 40)
    got = svc.claim("ws1", 55)          # free 30 + st1's 30 > 55 - no ws2 hit
    assert got == 55
    assert freed["st1"] == 25
    assert svc.tenants["ws2"].alloc == 40          # untouched
    assert svc.tenants["st1"].alloc == 5


def test_latency_tenants_preempt_lower_priority_latency():
    svc, _ = make_service()
    svc.claim("ws2", 100)          # ws2 grabs everything
    got = svc.claim("ws1", 30)     # higher-priority ws1 preempts ws2
    assert got == 30
    assert svc.tenants["ws1"].alloc == 30
    assert svc.tenants["ws2"].alloc == 70


def test_lower_priority_latency_cannot_preempt_higher():
    svc, _ = make_service()
    svc.claim("ws1", 100)
    got = svc.claim("ws2", 10)     # nothing reclaimable below ws2
    assert got == 0
    assert svc.tenants["ws1"].alloc == 100


if not HAS_HYPOTHESIS:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_conservation_under_arbitrary_ops():
        pass
else:
    @given(total=st.integers(10, 200),
           greedy=st.booleans(),
           ops=st.lists(st.tuples(st.sampled_from(["claim1", "claim2",
                                                   "rel1", "rel2",
                                                   "demand1", "demand2"]),
                                  st.integers(0, 80)), max_size=40))
    @settings(max_examples=80, deadline=None)
    def test_conservation_under_arbitrary_ops(total, greedy, ops):
        svc, _ = make_service(total, greedy_idle=greedy)
        for op, n in ops:
            if op == "claim1":
                svc.claim("ws1", n)
            elif op == "claim2":
                svc.claim("ws2", n)
            elif op == "rel1":
                svc.release("ws1", n)
            elif op == "rel2":
                svc.release("ws2", n)
            elif op == "demand1":
                svc.set_batch_demand("st1", n)
            else:
                svc.set_batch_demand("st2", n)
            svc.check()
            assert svc.free >= 0
