"""Checkpointer: atomic save/restore, async staging, dtype/shape checks."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpointer as ck


def tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 16), jnp.float32),
                   "b": jnp.zeros((16,), jnp.bfloat16)},
        "step": jnp.int32(7),
    }


def test_save_restore_roundtrip(tmp_path):
    t = tree()
    ck.save(str(tmp_path), t, step=7)
    assert ck.latest_step(str(tmp_path)) == 7
    shapes = jax.eval_shape(lambda: t)
    r = ck.restore(str(tmp_path), shapes)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_latest_picks_newest(tmp_path):
    ck.save(str(tmp_path), tree(0), step=1)
    ck.save(str(tmp_path), tree(1), step=5)
    assert ck.latest_step(str(tmp_path)) == 5


def test_restore_shape_mismatch_raises(tmp_path):
    ck.save(str(tmp_path), tree(), step=0)
    bad = jax.eval_shape(lambda: {"params": {
        "w": jnp.zeros((4, 4)), "b": jnp.zeros((16,), jnp.bfloat16)},
        "step": jnp.int32(0)})
    with pytest.raises(ValueError):
        ck.restore(str(tmp_path), bad)


def test_async_checkpointer(tmp_path):
    a = ck.AsyncCheckpointer(str(tmp_path))
    t = tree()
    a.save(t, step=3)
    a.save(tree(1), step=4)
    a.close()
    assert ck.latest_step(str(tmp_path)) == 4
    r = ck.restore(str(tmp_path), jax.eval_shape(lambda: t), step=3)
    np.testing.assert_array_equal(np.asarray(r["params"]["w"]),
                                  np.asarray(t["params"]["w"]))


def test_crash_mid_save_preserves_latest(tmp_path):
    ck.save(str(tmp_path), tree(), step=1)
    # simulate a crashed save: stale tmp dir must not affect restore
    os.makedirs(os.path.join(str(tmp_path), "step_0000000002.tmp"))
    assert ck.latest_step(str(tmp_path)) == 1
    r = ck.restore(str(tmp_path), jax.eval_shape(lambda: tree()))
    assert r is not None
