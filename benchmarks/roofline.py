"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads results/dryrun/<mesh>/<arch>__<shape>.json (written by
repro.launch.dryrun), prints the three-term roofline table, identifies the
dominant bottleneck per cell, and nominates the hillclimb candidates:
worst roofline fraction / most collective-bound / most paper-representative.
"""
from __future__ import annotations

import glob
import json
import os
import time
from typing import Dict, List, Optional, Tuple


def load_cells(out_dir: str = "results/dryrun",
               mesh: str = "single", view: str = "final") -> List[Dict]:
    """Load cell records, re-scored with the current shared roofline model
    (so methodology fixes apply to existing artifacts without recompiling).

    view="baseline": untagged records only (the pre-hillclimb mapping).
    view="final": per-cell best — the __opt record supersedes the baseline
    when present (train cells after §Perf i4).
    """
    import re
    from repro.configs import ARCHS, SHAPES_BY_NAME
    from repro.hlo.roofline import score
    by_cell: Dict[str, Dict] = {}
    for p in sorted(glob.glob(os.path.join(out_dir, mesh, "*.json"))):
        base = os.path.basename(p)[:-5]
        m = re.match(r"(.+?__[a-z0-9_]+?)(__\w+)?$", base)
        cell, tag = m.group(1), (m.group(2) or "")
        if tag not in ("", "__opt"):
            continue
        r = json.load(open(p))
        if r.get("status") != "ok":
            continue
        if view == "baseline" and tag:
            continue
        if tag == "__opt" or cell not in by_cell:
            if view == "final" or not tag:
                by_cell[cell] = r
    cells = []
    for r in by_cell.values():
        r["roofline"] = score(ARCHS[r["arch"]], SHAPES_BY_NAME[r["shape"]],
                              r["devices"], r.get("plan", {}), r["hlo"])
        cells.append(r)
    return cells


def table(cells: List[Dict]) -> str:
    hdr = (f"{'arch':<22} {'shape':<12} {'dom':<13} {'compute_s':>10} "
           f"{'memory_s':>10} {'collect_s':>10} {'frac':>6} {'useful':>7} "
           f"{'HBM_GB':>7} {'fits':>5}")
    lines = [hdr, "-" * len(hdr)]
    for r in sorted(cells, key=lambda r: r["roofline"]["roofline_fraction"]):
        rf = r["roofline"]
        lines.append(
            f"{r['arch']:<22} {r['shape']:<12} {rf['dominant']:<13} "
            f"{rf['compute_s']:>10.4f} {rf['memory_s']:>10.4f} "
            f"{rf['collective_s']:>10.4f} {rf['roofline_fraction']:>6.3f} "
            f"{rf['useful_flops_ratio']:>7.3f} "
            f"{r['memory']['peak_bytes_est']/1e9:>7.2f} "
            f"{'y' if r.get('fits_hbm') else 'N':>5}")
    return "\n".join(lines)


def candidates(cells: List[Dict]) -> Dict[str, str]:
    def key(r):
        return f"{r['arch']}/{r['shape']}"
    worst = min(cells, key=lambda r: r["roofline"]["roofline_fraction"])
    coll = max(cells, key=lambda r: (r["roofline"]["collective_s"]
                                     / max(max(r["roofline"]["compute_s"],
                                               r["roofline"]["memory_s"]),
                                           1e-12)))
    # paper-representative: the consolidation story is train + decode sharing
    # one pool; the train cell of the MoE arch stresses the most machinery
    rep = next((r for r in cells if r["arch"] == "qwen3-moe-30b-a3b"
                and r["shape"] == "train_4k"), cells[0])
    return {"worst_fraction": key(worst), "most_collective_bound": key(coll),
            "paper_representative": key(rep)}


def roofline_report(mesh: str = "single",
                    view: str = "final") -> Tuple[float, Dict]:
    t0 = time.time()
    cells = load_cells(mesh=mesh, view=view)
    us = (time.time() - t0) * 1e6
    if not cells:
        return us, {"error": "no dry-run artifacts; run repro.launch.dryrun"}
    fracs = [r["roofline"]["roofline_fraction"] for r in cells]
    doms = {}
    for r in cells:
        doms[r["roofline"]["dominant"]] = doms.get(
            r["roofline"]["dominant"], 0) + 1
    return us, {
        "view": view,
        "cells": len(cells),
        "fits_hbm": sum(bool(r.get("fits_hbm")) for r in cells),
        "median_fraction": sorted(fracs)[len(fracs) // 2],
        "best_fraction": max(fracs),
        "dominant_hist": doms,
        "hillclimb": candidates(cells),
    }


def main():
    for mesh in ("single", "multi"):
        for view in ("baseline", "final"):
            cells = load_cells(mesh=mesh, view=view)
            if not cells:
                continue
            print(f"\n== roofline table ({mesh}-pod mesh, "
                  f"{cells[0]['devices']} devices, {view} mapping) ==")
            print(table(cells))
        if mesh == "single" and cells:
            print("\nhillclimb candidates:", candidates(cells))


if __name__ == "__main__":
    main()
