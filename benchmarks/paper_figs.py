"""Paper-figure benchmarks (one function per paper table/figure).

fig5  — WS resource consumption under the World-Cup-like trace (§III-C)
fig7  — completed jobs + avg turnaround vs cluster size, SC vs DC (§III-D)
fig8  — killed jobs vs cluster size (§III-D)
summary — the 76.9%-cost consolidation claim + validation booleans
request_level_slo — beyond-paper: p99 latency + SLO violations under the
    request-level WS workload (repro.workloads), DC vs dedicated WS nodes
campaign_tiny — the tiny scenario campaign grid; also the source of the
    BENCH_campaign.json artifact written by benchmarks/run.py
"""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

import numpy as np

from repro.core.experiment import (DC_SIZES, SC_TOTAL, run_experiment,
                                   validate_claims)
from repro.core.traces import (WS_CAPACITY_RPS, synthetic_worldcup_load,
                               worldcup_demand_events)
from repro.core.types import SimConfig
from repro.core.ws_cms import demand_from_load

_CACHE: Dict = {}


def _experiment(seed=0, preempt="kill"):
    key = (seed, preempt)
    if key not in _CACHE:
        _CACHE[key] = run_experiment(
            seed=seed, cfg=SimConfig(preempt_mode=preempt))
    return _CACHE[key]


def fig5_ws_consumption() -> Tuple[float, Dict]:
    t0 = time.time()
    load, dt = synthetic_worldcup_load(seed=0)
    demand = demand_from_load(load, dt, WS_CAPACITY_RPS)
    events = worldcup_demand_events(seed=0)
    us = (time.time() - t0) * 1e6
    derived = {
        "peak_instances": int(demand.max()),
        "mean_instances": float(demand.mean()),
        "p50_instances": float(np.median(demand)),
        "demand_change_events": len(events),
        "peak_to_normal_load": float(load.max() / np.median(load)),
    }
    return us, derived


def fig7_completed_turnaround(preempt="kill") -> Tuple[float, Dict]:
    t0 = time.time()
    res = _experiment(0, preempt)
    us = (time.time() - t0) * 1e6
    sc = res["SC"]
    rows = {"SC_144": {"completed": sc.completed,
                       "turnaround_s": round(sc.avg_turnaround)}}
    for size in sorted(res["DC"], reverse=True):
        r = res["DC"][size]
        rows[f"DC_{size}"] = {"completed": r.completed,
                              "turnaround_s": round(r.avg_turnaround)}
    return us, rows


def fig8_killed_jobs(preempt="kill") -> Tuple[float, Dict]:
    t0 = time.time()
    res = _experiment(0, preempt)
    us = (time.time() - t0) * 1e6
    return us, {f"DC_{size}": res["DC"][size].killed
                for size in sorted(res["DC"], reverse=True)}


def consolidation_summary() -> Tuple[float, Dict]:
    t0 = time.time()
    res = _experiment(0, "kill")
    claims = validate_claims(res)
    us = (time.time() - t0) * 1e6
    dc = res["DC"][160]
    sc = res["SC"]
    return us, {
        "sc_nodes": SC_TOTAL, "dc_nodes": 160,
        "cost_ratio": round(claims["cost_ratio_at_160"], 3),
        "dc_completed": dc.completed, "sc_completed": sc.completed,
        "dc_turnaround": round(dc.avg_turnaround),
        "sc_turnaround": round(sc.avg_turnaround),
        "all_claims_hold": all(v for k, v in claims.items()
                               if isinstance(v, bool)),
    }


def request_level_slo() -> Tuple[float, Dict]:
    """Beyond-paper: request-level WS latency, consolidated vs dedicated.

    One 2-hour scenario: flash-crowd arrivals + SLO autoscaler feeding the
    consolidation sim (64 shared nodes) vs the same trace pinned to a
    16-node dedicated WS partition.
    """
    from repro.core.simulator import ConsolidationSim
    from repro.core.traces import synthetic_sdsc_blue
    from repro.core.types import SLOConfig
    from repro.serving.batching import ServiceTimeModel
    from repro.workloads import RequestWorkload, make_trace

    t0 = time.time()
    horizon = 7200.0
    trace = make_trace("flash_crowd", 2.0, horizon, seed=0)
    workload = RequestWorkload(trace=trace, model=ServiceTimeModel(),
                               slo=SLOConfig(latency_target_s=30.0))
    jobs = synthetic_sdsc_blue(seed=0, n_jobs=80, horizon=horizon,
                               max_nodes=32)
    res = ConsolidationSim(SimConfig(total_nodes=64), jobs, workload,
                           horizon=horizon).run()
    dedicated = workload.realized_metrics([(0.0, 16)], horizon=horizon)
    us = (time.time() - t0) * 1e6
    dc = res.ws_latency or {}
    return us, {
        "requests": len(trace),
        "dc_p99_s": round(dc.get("p99_s", 0.0), 2),
        "dc_violation_rate": round(dc.get("violation_rate", 0.0), 5),
        "dc_slo_met": bool(dc.get("slo_met", False)),
        "dedicated16_p99_s": round(dedicated["p99_s"], 2),
        "dedicated16_violation_rate":
            round(dedicated["violation_rate"], 5),
        "st_completed_alongside": res.completed,
    }


def campaign_tiny(out_path: str = "BENCH_campaign.json"
                  ) -> Tuple[float, Dict]:
    """Tiny scenario campaign (8 cells); writes the JSON artifact."""
    from repro.workloads.campaign import make_grid, run_campaign

    t0 = time.time()
    art = run_campaign(make_grid("tiny"), workers=2, out_path=out_path,
                       grid_name="tiny")
    us = (time.time() - t0) * 1e6
    ov = art["reductions"]["overall"]
    tp = art["throughput"]
    return us, {
        "n_cells": art["n_cells"],
        "wall_s": round(art["wall_s"], 2),
        "cells_per_s": round(tp["cells_per_s"], 2),
        "queue_requests_per_s": round(tp["queue_requests_per_s"]),
        "slo_met_rate": ov["slo_met_rate"],
        "mean_ws_p99_s": round(ov["ws_p99_s"], 2),
        "mean_violation_rate": round(ov["ws_violation_rate"], 5),
        "mean_completed": ov["completed"],
        "inf_rate": ov["inf_rate"],
        "artifact": out_path,
    }


def campaign_throughput() -> Tuple[float, Dict]:
    """Perf-regression bench for the queueing core + campaign pipeline.

    Workload set = the exact (trace, capacity-events) pairs the `small`
    campaign grid feeds ``simulate_queue``: the realized WS allocation of
    every cell (replayed from the consolidation sim) plus each unique
    trace's planned (autoscaler-granted) capacity. The pre-vectorization
    reference loop and the new dispatch run the identical set, interleaved
    min-of-3; ``speedup_x`` is the hot-path speedup the dense sweep claims.
    ``pw_*`` is the batched-device headline: a piecewise-heavy department
    grid (every cell carries many capacity changes, the worst case for the
    dense formulation) run through ``simulate_queue_batch`` shape buckets
    vs the per-cell numpy event sweep, min-of-3 hot. Also reports the
    constant-capacity batched core and end-to-end cells/sec for the small
    grid through the chunked campaign pipeline.
    """
    from repro.core.simulator import ConsolidationSim
    from repro.core.traces import synthetic_sdsc_blue
    from repro.core.types import SLOConfig
    from repro.serving.batching import ServiceTimeModel
    from repro.workloads import (QueueJob, RequestWorkload, make_trace,
                                 simulate_queue, simulate_queue_batch,
                                 simulate_queue_many)
    from repro.workloads.campaign import make_grid, run_campaign

    t0 = time.time()
    model = ServiceTimeModel()
    cells = make_grid("small")
    work = []                        # (trace, capacity_events, slo, horizon)
    planned_done = set()
    for cell in cells:
        slo = SLOConfig(latency_target_s=cell.slo_target_s)
        trace = make_trace(cell.arrival, cell.rate_rps, cell.horizon_s,
                           cell.seed)
        wl = RequestWorkload(trace=trace, model=model, slo=slo)
        jobs = synthetic_sdsc_blue(seed=cell.seed, n_jobs=cell.n_jobs,
                                   horizon=cell.horizon_s,
                                   max_nodes=cell.st_max_nodes)
        sim = ConsolidationSim(
            SimConfig(total_nodes=cell.total_nodes,
                      preempt_mode=cell.preempt, scheduler=cell.scheduler,
                      seed=cell.seed),
            jobs, wl, horizon=cell.horizon_s)
        sim.run()
        work.append((trace, list(sim.ws.alloc_events), slo, cell.horizon_s))
        pk = (cell.arrival, cell.slo_target_s, cell.rate_rps,
              cell.horizon_s, cell.seed)
        if pk not in planned_done:
            planned_done.add(pk)
            work.append((trace, wl.demand_events(cell.horizon_s), slo,
                         cell.horizon_s))
    n_req = sum(len(tr) for tr, _, _, _ in work)

    def sweep(impl: str) -> float:
        s = time.perf_counter()
        for tr, ev, slo, hz in work:
            simulate_queue(tr, ev, model, slo, horizon=hz, impl=impl)
        return time.perf_counter() - s

    ref_s = new_s = float("inf")
    for _ in range(3):
        ref_s = min(ref_s, sweep("reference"))
        new_s = min(new_s, sweep("auto"))

    # batched constant-capacity core (one jax scan/vmap call over all
    # dedicated-nodes baselines; numpy fallback when jax is unavailable)
    ded = {}
    for tr, _, _, _ in work:
        ded[(tr.kind, len(tr))] = tr
    mtraces, mcaps = [], []
    for tr in ded.values():
        for nodes in (8, 12, 16):
            mtraces.append(tr)
            mcaps.append([(0.0, nodes)])
    slo30 = SLOConfig(latency_target_s=30.0)
    s = time.perf_counter()
    simulate_queue_many(mtraces, mcaps, model, slo30, horizon=7200.0)
    compile_s = time.perf_counter() - s
    s = time.perf_counter()
    simulate_queue_many(mtraces, mcaps, model, slo30, horizon=7200.0)
    batched_s = time.perf_counter() - s
    batched_req = sum(len(tr) for tr in mtraces)

    # piecewise-heavy department grid: the k(t)-aware batched core vs the
    # per-cell numpy event sweep on cells with 5-20 capacity changes each
    import numpy as _np
    rng = _np.random.default_rng(7)
    pw_horizon = 7200.0
    pw_jobs = []
    arrivals = ("poisson", "mmpp", "diurnal", "flash_crowd")
    for seed in range(192):
        tr = make_trace(arrivals[seed % 4], float(rng.uniform(0.1, 0.5)),
                        pw_horizon, 500 + seed)
        ev = [(0.0, int(rng.integers(1, 5)))]
        for _ in range(int(rng.integers(5, 21))):
            ev.append((float(rng.uniform(0.0, pw_horizon)),
                       int(rng.integers(0, 5))))
        pw_jobs.append(QueueJob(tr, tuple(ev), model, slo30,
                                horizon=pw_horizon))
    pw_req = sum(len(j.trace) for j in pw_jobs)
    simulate_queue_batch(pw_jobs)                          # compile
    pw_batched_s = pw_event_s = float("inf")
    for _ in range(5):
        s = time.perf_counter()
        simulate_queue_batch(pw_jobs)
        pw_batched_s = min(pw_batched_s, time.perf_counter() - s)
        s = time.perf_counter()
        for j in pw_jobs:
            simulate_queue(j.trace, j.capacity_events, model, slo30,
                           horizon=pw_horizon, impl="event")
        pw_event_s = min(pw_event_s, time.perf_counter() - s)

    # end-to-end cells/sec through the full new pipeline
    art = run_campaign(cells, workers=1, grid_name="small")
    tp = art["throughput"]

    us = (time.time() - t0) * 1e6
    return us, {
        "queue_workloads": len(work),
        "queue_requests": n_req,
        "ref_requests_per_s": round(n_req / ref_s),
        "new_requests_per_s": round(n_req / new_s),
        "speedup_x": round(ref_s / new_s, 2),
        "batched_requests_per_s": round(batched_req / batched_s),
        "batched_compile_s": round(compile_s, 2),
        "pw_cells": len(pw_jobs),
        "pw_requests": pw_req,
        "pw_batched_requests_per_s": round(pw_req / pw_batched_s),
        "pw_event_requests_per_s": round(pw_req / pw_event_s),
        "pw_batched_cells_per_s": round(len(pw_jobs) / pw_batched_s, 1),
        "pw_event_cells_per_s": round(len(pw_jobs) / pw_event_s, 1),
        "pw_speedup_x": round(pw_event_s / pw_batched_s, 2),
        "small_cells_per_s": round(tp["cells_per_s"], 2),
        "small_queue_requests_per_s": round(tp["queue_requests_per_s"]),
        "queue_impls": tp.get("queue_impls", {}),
    }


def multi_department() -> Tuple[float, Dict]:
    """Beyond-paper: the N-department tenancy framework.

    One 2-hour scenario consolidating 2 HPC + 2 request-level WS + 1
    best-effort batch department on 96 shared nodes, run under each
    cooperative policy; reports per-department benefit metrics so the
    policy x department trade-off is visible in one row.
    """
    from repro.core.policies import POLICIES
    from repro.core.simulator import ConsolidationSim
    from repro.workloads.campaign import ScenarioCell, make_tenants

    t0 = time.time()
    out: Dict = {}
    for policy in sorted(POLICIES):
        cell = ScenarioCell(preempt="kill", scheduler="first_fit",
                            arrival="flash_crowd", total_nodes=96,
                            slo_target_s=30.0, policy=policy,
                            mix="2hpc2ws1be", seed=0)
        sim = ConsolidationSim(
            SimConfig(total_nodes=96, seed=0), horizon=cell.horizon_s,
            tenants=make_tenants(cell), policy=policy)
        res = sim.run()
        out[policy] = {
            name: {"avg_alloc": round(t.avg_alloc, 1),
                   **{k: round(v, 5) for k, v in t.benefit.items()}}
            for name, t in res.tenants.items()}
        out[policy]["aggregate"] = {
            "completed": res.completed, "killed": res.killed,
            "ws_unmet_node_seconds": round(res.ws_unmet_node_seconds, 1)}
    us = (time.time() - t0) * 1e6
    return us, out


def policy_engine() -> Tuple[float, Dict]:
    """Perf-regression gate for the two-phase PolicyEngine refactor.

    The reclaim decision moved from a hard-coded loop in provision.py into
    plan_reclaim() — this bench proves the indirection does not regress
    simulator event throughput. It replays one fixed 5-department
    half-day scenario (plain node-demand timeseries: no queue simulation,
    so the sim core IS the measured path) under every engine, min-of-3,
    and asserts the paper engine stays above a conservative floor of the
    pre-refactor rate recorded in BENCH.md (pre: 56k events/s, post: 52k
    on the reference container — ~7% planner indirection, within run
    jitter; floor set ~3.5x below to ride out CI machine variance).

    Two departments carry finite budgets and ws-b bids slo_elastic, so
    the market engines (budget_auction/second_price) exercise the full
    ledger path — affordability caps, debits, clearing prices — in the
    measured loop; every non-market engine ignores those fields, keeping
    the paper gate's scenario bit-identical.
    """
    from repro.core.simulator import ConsolidationSim
    from repro.core.traces import synthetic_sdsc_blue, worldcup_demand_events
    from repro.core.policies import POLICIES
    from repro.core.types import TenantSpec

    t0 = time.time()
    day = 86400.0
    horizon = day / 2

    def specs():
        return [
            TenantSpec("ws-a", "latency", priority=0,
                       demand=worldcup_demand_events(seed=0,
                                                     horizon=horizon)),
            TenantSpec("ws-b", "latency", priority=1, floor=2,
                       budget=20_000.0, bid_policy="slo_elastic",
                       demand=worldcup_demand_events(seed=7,
                                                     horizon=horizon)),
            TenantSpec("hpc-a", "batch", priority=2, weight=2.0,
                       jobs=synthetic_sdsc_blue(seed=0, n_jobs=400,
                                                horizon=horizon,
                                                max_nodes=32)),
            TenantSpec("hpc-b", "batch", priority=3, weight=1.0,
                       jobs=synthetic_sdsc_blue(seed=1, n_jobs=400,
                                                horizon=horizon,
                                                max_nodes=32)),
            TenantSpec("be", "batch", priority=9, weight=0.5, bid_weight=0.1,
                       budget=2_000.0,
                       jobs=synthetic_sdsc_blue(seed=2, n_jobs=100,
                                                horizon=horizon,
                                                max_nodes=8)),
        ]

    derived: Dict = {}
    for pol in sorted(POLICIES):
        best, events, plans, spend = float("inf"), 0, 0, 0.0
        for _ in range(3):
            sim = ConsolidationSim(SimConfig(total_nodes=160, seed=0),
                                   horizon=horizon, tenants=specs(),
                                   policy=pol)
            s = time.perf_counter()
            res = sim.run()
            dt = time.perf_counter() - s
            if dt < best:
                best, events = dt, len(sim.timeline)
                plans = res.policy_state["reclaim_plans"]
                market = res.policy_state.get("market")
                spend = round(sum(market["spend"].values()), 1) \
                    if market else 0.0
        derived[pol] = {"events": events,
                        "events_per_s": round(events / best),
                        "reclaim_plans": plans,
                        "market_spend": spend}
    paper_eps = derived["paper"]["events_per_s"]
    floor = 15_000
    derived["paper_floor_events_per_s"] = floor
    derived["paper_ok"] = bool(paper_eps >= floor)
    assert paper_eps >= floor, \
        f"policy engine regressed: paper {paper_eps} events/s < {floor}"

    # ---- telemetry overhead gates (PR 6 tentpole contract) -----------
    # Two measurements, both interleaved traced/untraced pairs so machine
    # noise hits both sides alike:
    #
    #  * informational: this bench's own scenario (plain node-demand
    #    timeseries) is a pure control-plane microbench — ~17us of sim
    #    work per event, nothing to amortize against, so full-detail
    #    tracing costs ~12% here (measured on the reference container;
    #    recorded, not asserted — it is the adversarial bound);
    #  * the GATE: a deployment-representative cell (request-level
    #    latency tenants via RequestWorkload + SLO autoscaler, the
    #    configuration every campaign mix cell runs) must stay within 5%
    #    of the untraced rate — true cost ~1-2%. min-of-pairs ratio, so
    #    a single noisy run cannot flake the assert, while a pathology
    #    like the pre-optimization 84% regression still trips it.
    from repro.core.telemetry import Tracer
    from repro.core.types import SLOConfig
    from repro.serving.batching import ServiceTimeModel
    from repro.workloads.arrivals import make_trace
    from repro.workloads.autoscaler import RequestWorkload

    def trace_pairs(mk_sim, n_pairs):
        best_ratio, traced_events = float("inf"), 0
        for _ in range(n_pairs):
            sim = mk_sim(None)
            s = time.perf_counter()
            sim.run()
            base_dt = time.perf_counter() - s
            tr = Tracer()
            sim = mk_sim(tr)
            s = time.perf_counter()
            sim.run()
            best_ratio = min(best_ratio,
                             (time.perf_counter() - s) / base_dt)
            traced_events = len(tr.events)
        return best_ratio - 1.0, traced_events

    ctrl_overhead, ctrl_events = trace_pairs(
        lambda tr: ConsolidationSim(SimConfig(total_nodes=160, seed=0),
                                    horizon=horizon, tenants=specs(),
                                    policy="paper", tracer=tr), 3)
    derived["trace_overhead_ctrlplane_pct"] = round(ctrl_overhead * 100, 2)
    derived["trace_events_ctrlplane"] = ctrl_events

    gate_horizon = day / 4
    def gate_specs():
        out = []
        for i in range(2):
            trace = make_trace("diurnal", 15.0, gate_horizon, seed=101 * i)
            out.append(TenantSpec(
                f"ws-{i}", "latency", priority=i, floor=2 if i else 0,
                slo=SLOConfig(latency_target_s=1.0),
                demand=RequestWorkload(
                    trace=trace, model=ServiceTimeModel(),
                    slo=SLOConfig(latency_target_s=1.0))))
        for i, (nj, mx, w) in enumerate(((200, 24, 2.0), (200, 24, 1.0))):
            out.append(TenantSpec(
                f"hpc-{chr(97 + i)}", "batch", priority=2 + i, weight=w,
                jobs=synthetic_sdsc_blue(seed=i, n_jobs=nj,
                                         horizon=gate_horizon,
                                         max_nodes=mx)))
        out.append(TenantSpec(
            "be", "batch", priority=9, weight=0.5,
            jobs=synthetic_sdsc_blue(seed=2, n_jobs=50,
                                     horizon=gate_horizon, max_nodes=8)))
        return out

    overhead, traced_events = trace_pairs(
        lambda tr: ConsolidationSim(SimConfig(total_nodes=120, seed=0),
                                    horizon=gate_horizon,
                                    tenants=gate_specs(),
                                    policy="paper", tracer=tr), 4)
    derived["trace_overhead_pct"] = round(overhead * 100.0, 2)
    derived["trace_events"] = traced_events
    derived["trace_ok"] = bool(overhead < 0.05)
    assert overhead < 0.05, \
        f"tracing overhead {overhead:.1%} >= 5% on the " \
        f"request-level consolidation cell"
    us = (time.time() - t0) * 1e6
    return us, derived


def beyond_paper_checkpoint_mode() -> Tuple[float, Dict]:
    """Beyond-paper: checkpoint-preemption vs the paper's kill policy."""
    t0 = time.time()
    kill = _experiment(0, "kill")["DC"][160]
    ck = _experiment(0, "checkpoint")["DC"][160]
    us = (time.time() - t0) * 1e6
    return us, {
        "kill_completed": kill.completed, "ckpt_completed": ck.completed,
        "kill_killed": kill.killed, "ckpt_preemptions": ck.preemptions,
        "completed_gain": ck.completed - kill.completed,
        "turnaround_kill": round(kill.avg_turnaround),
        "turnaround_ckpt": round(ck.avg_turnaround),
    }
