"""Paper-figure benchmarks (one function per paper table/figure).

fig5  — WS resource consumption under the World-Cup-like trace (§III-C)
fig7  — completed jobs + avg turnaround vs cluster size, SC vs DC (§III-D)
fig8  — killed jobs vs cluster size (§III-D)
summary — the 76.9%-cost consolidation claim + validation booleans
request_level_slo — beyond-paper: p99 latency + SLO violations under the
    request-level WS workload (repro.workloads), DC vs dedicated WS nodes
campaign_tiny — the tiny scenario campaign grid; also the source of the
    BENCH_campaign.json artifact written by benchmarks/run.py
"""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

import numpy as np

from repro.core.experiment import (DC_SIZES, SC_TOTAL, run_experiment,
                                   validate_claims)
from repro.core.traces import (WS_CAPACITY_RPS, synthetic_worldcup_load,
                               worldcup_demand_events)
from repro.core.types import SimConfig
from repro.core.ws_cms import demand_from_load

_CACHE: Dict = {}


def _experiment(seed=0, preempt="kill"):
    key = (seed, preempt)
    if key not in _CACHE:
        _CACHE[key] = run_experiment(
            seed=seed, cfg=SimConfig(preempt_mode=preempt))
    return _CACHE[key]


def fig5_ws_consumption() -> Tuple[float, Dict]:
    t0 = time.time()
    load, dt = synthetic_worldcup_load(seed=0)
    demand = demand_from_load(load, dt, WS_CAPACITY_RPS)
    events = worldcup_demand_events(seed=0)
    us = (time.time() - t0) * 1e6
    derived = {
        "peak_instances": int(demand.max()),
        "mean_instances": float(demand.mean()),
        "p50_instances": float(np.median(demand)),
        "demand_change_events": len(events),
        "peak_to_normal_load": float(load.max() / np.median(load)),
    }
    return us, derived


def fig7_completed_turnaround(preempt="kill") -> Tuple[float, Dict]:
    t0 = time.time()
    res = _experiment(0, preempt)
    us = (time.time() - t0) * 1e6
    sc = res["SC"]
    rows = {"SC_144": {"completed": sc.completed,
                       "turnaround_s": round(sc.avg_turnaround)}}
    for size in sorted(res["DC"], reverse=True):
        r = res["DC"][size]
        rows[f"DC_{size}"] = {"completed": r.completed,
                              "turnaround_s": round(r.avg_turnaround)}
    return us, rows


def fig8_killed_jobs(preempt="kill") -> Tuple[float, Dict]:
    t0 = time.time()
    res = _experiment(0, preempt)
    us = (time.time() - t0) * 1e6
    return us, {f"DC_{size}": res["DC"][size].killed
                for size in sorted(res["DC"], reverse=True)}


def consolidation_summary() -> Tuple[float, Dict]:
    t0 = time.time()
    res = _experiment(0, "kill")
    claims = validate_claims(res)
    us = (time.time() - t0) * 1e6
    dc = res["DC"][160]
    sc = res["SC"]
    return us, {
        "sc_nodes": SC_TOTAL, "dc_nodes": 160,
        "cost_ratio": round(claims["cost_ratio_at_160"], 3),
        "dc_completed": dc.completed, "sc_completed": sc.completed,
        "dc_turnaround": round(dc.avg_turnaround),
        "sc_turnaround": round(sc.avg_turnaround),
        "all_claims_hold": all(v for k, v in claims.items()
                               if isinstance(v, bool)),
    }


def request_level_slo() -> Tuple[float, Dict]:
    """Beyond-paper: request-level WS latency, consolidated vs dedicated.

    One 2-hour scenario: flash-crowd arrivals + SLO autoscaler feeding the
    consolidation sim (64 shared nodes) vs the same trace pinned to a
    16-node dedicated WS partition.
    """
    from repro.core.simulator import ConsolidationSim
    from repro.core.traces import synthetic_sdsc_blue
    from repro.core.types import SLOConfig
    from repro.serving.batching import ServiceTimeModel
    from repro.workloads import RequestWorkload, make_trace

    t0 = time.time()
    horizon = 7200.0
    trace = make_trace("flash_crowd", 2.0, horizon, seed=0)
    workload = RequestWorkload(trace=trace, model=ServiceTimeModel(),
                               slo=SLOConfig(latency_target_s=30.0))
    jobs = synthetic_sdsc_blue(seed=0, n_jobs=80, horizon=horizon,
                               max_nodes=32)
    res = ConsolidationSim(SimConfig(total_nodes=64), jobs, workload,
                           horizon=horizon).run()
    dedicated = workload.realized_metrics([(0.0, 16)], horizon=horizon)
    us = (time.time() - t0) * 1e6
    dc = res.ws_latency or {}
    return us, {
        "requests": len(trace),
        "dc_p99_s": round(dc.get("p99_s", 0.0), 2),
        "dc_violation_rate": round(dc.get("violation_rate", 0.0), 5),
        "dc_slo_met": bool(dc.get("slo_met", False)),
        "dedicated16_p99_s": round(dedicated["p99_s"], 2),
        "dedicated16_violation_rate":
            round(dedicated["violation_rate"], 5),
        "st_completed_alongside": res.completed,
    }


def campaign_tiny(out_path: str = "BENCH_campaign.json"
                  ) -> Tuple[float, Dict]:
    """Tiny scenario campaign (8 cells); writes the JSON artifact."""
    from repro.workloads.campaign import make_grid, run_campaign

    t0 = time.time()
    art = run_campaign(make_grid("tiny"), workers=2, out_path=out_path,
                       grid_name="tiny")
    us = (time.time() - t0) * 1e6
    ov = art["reductions"]["overall"]
    return us, {
        "n_cells": art["n_cells"],
        "wall_s": round(art["wall_s"], 2),
        "slo_met_rate": ov["slo_met_rate"],
        "mean_ws_p99_s": round(ov["ws_p99_s"], 2),
        "mean_violation_rate": round(ov["ws_violation_rate"], 5),
        "mean_completed": ov["completed"],
        "artifact": out_path,
    }


def multi_department() -> Tuple[float, Dict]:
    """Beyond-paper: the N-department tenancy framework.

    One 2-hour scenario consolidating 2 HPC + 2 request-level WS + 1
    best-effort batch department on 96 shared nodes, run under each
    cooperative policy; reports per-department benefit metrics so the
    policy x department trade-off is visible in one row.
    """
    from repro.core.policies import POLICIES
    from repro.core.simulator import ConsolidationSim
    from repro.workloads.campaign import ScenarioCell, make_tenants

    t0 = time.time()
    out: Dict = {}
    for policy in sorted(POLICIES):
        cell = ScenarioCell(preempt="kill", scheduler="first_fit",
                            arrival="flash_crowd", total_nodes=96,
                            slo_target_s=30.0, policy=policy,
                            mix="2hpc2ws1be", seed=0)
        sim = ConsolidationSim(
            SimConfig(total_nodes=96, seed=0), horizon=cell.horizon_s,
            tenants=make_tenants(cell), policy=policy)
        res = sim.run()
        out[policy] = {
            name: {"avg_alloc": round(t.avg_alloc, 1),
                   **{k: round(v, 5) for k, v in t.benefit.items()}}
            for name, t in res.tenants.items()}
        out[policy]["aggregate"] = {
            "completed": res.completed, "killed": res.killed,
            "ws_unmet_node_seconds": round(res.ws_unmet_node_seconds, 1)}
    us = (time.time() - t0) * 1e6
    return us, out


def beyond_paper_checkpoint_mode() -> Tuple[float, Dict]:
    """Beyond-paper: checkpoint-preemption vs the paper's kill policy."""
    t0 = time.time()
    kill = _experiment(0, "kill")["DC"][160]
    ck = _experiment(0, "checkpoint")["DC"][160]
    us = (time.time() - t0) * 1e6
    return us, {
        "kill_completed": kill.completed, "ckpt_completed": ck.completed,
        "kill_killed": kill.killed, "ckpt_preemptions": ck.preemptions,
        "completed_gain": ck.completed - kill.completed,
        "turnaround_kill": round(kill.avg_turnaround),
        "turnaround_ckpt": round(ck.avg_turnaround),
    }
