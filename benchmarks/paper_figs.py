"""Paper-figure benchmarks (one function per paper table/figure).

fig5  — WS resource consumption under the World-Cup-like trace (§III-C)
fig7  — completed jobs + avg turnaround vs cluster size, SC vs DC (§III-D)
fig8  — killed jobs vs cluster size (§III-D)
summary — the 76.9%-cost consolidation claim + validation booleans
"""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

import numpy as np

from repro.core.experiment import (DC_SIZES, SC_TOTAL, run_experiment,
                                   validate_claims)
from repro.core.traces import (WS_CAPACITY_RPS, synthetic_worldcup_load,
                               worldcup_demand_events)
from repro.core.types import SimConfig
from repro.core.ws_cms import demand_from_load

_CACHE: Dict = {}


def _experiment(seed=0, preempt="kill"):
    key = (seed, preempt)
    if key not in _CACHE:
        _CACHE[key] = run_experiment(
            seed=seed, cfg=SimConfig(preempt_mode=preempt))
    return _CACHE[key]


def fig5_ws_consumption() -> Tuple[float, Dict]:
    t0 = time.time()
    load, dt = synthetic_worldcup_load(seed=0)
    demand = demand_from_load(load, dt, WS_CAPACITY_RPS)
    events = worldcup_demand_events(seed=0)
    us = (time.time() - t0) * 1e6
    derived = {
        "peak_instances": int(demand.max()),
        "mean_instances": float(demand.mean()),
        "p50_instances": float(np.median(demand)),
        "demand_change_events": len(events),
        "peak_to_normal_load": float(load.max() / np.median(load)),
    }
    return us, derived


def fig7_completed_turnaround(preempt="kill") -> Tuple[float, Dict]:
    t0 = time.time()
    res = _experiment(0, preempt)
    us = (time.time() - t0) * 1e6
    sc = res["SC"]
    rows = {"SC_144": {"completed": sc.completed,
                       "turnaround_s": round(sc.avg_turnaround)}}
    for size in sorted(res["DC"], reverse=True):
        r = res["DC"][size]
        rows[f"DC_{size}"] = {"completed": r.completed,
                              "turnaround_s": round(r.avg_turnaround)}
    return us, rows


def fig8_killed_jobs(preempt="kill") -> Tuple[float, Dict]:
    t0 = time.time()
    res = _experiment(0, preempt)
    us = (time.time() - t0) * 1e6
    return us, {f"DC_{size}": res["DC"][size].killed
                for size in sorted(res["DC"], reverse=True)}


def consolidation_summary() -> Tuple[float, Dict]:
    t0 = time.time()
    res = _experiment(0, "kill")
    claims = validate_claims(res)
    us = (time.time() - t0) * 1e6
    dc = res["DC"][160]
    sc = res["SC"]
    return us, {
        "sc_nodes": SC_TOTAL, "dc_nodes": 160,
        "cost_ratio": round(claims["cost_ratio_at_160"], 3),
        "dc_completed": dc.completed, "sc_completed": sc.completed,
        "dc_turnaround": round(dc.avg_turnaround),
        "sc_turnaround": round(sc.avg_turnaround),
        "all_claims_hold": all(v for k, v in claims.items()
                               if isinstance(v, bool)),
    }


def beyond_paper_checkpoint_mode() -> Tuple[float, Dict]:
    """Beyond-paper: checkpoint-preemption vs the paper's kill policy."""
    t0 = time.time()
    kill = _experiment(0, "kill")["DC"][160]
    ck = _experiment(0, "checkpoint")["DC"][160]
    us = (time.time() - t0) * 1e6
    return us, {
        "kill_completed": kill.completed, "ckpt_completed": ck.completed,
        "kill_killed": kill.killed, "ckpt_preemptions": ck.preemptions,
        "completed_gain": ck.completed - kill.completed,
        "turnaround_kill": round(kill.avg_turnaround),
        "turnaround_ckpt": round(ck.avg_turnaround),
    }
