"""Kernel microbenchmarks: wall time of the jnp reference path on CPU plus
interpret-mode correctness deltas for each Pallas kernel.

NOTE: this container is CPU-only; Pallas interpret mode executes the kernel
body in Python, so its wall time is NOT meaningful TPU performance — the
honest number on this host is the XLA-CPU reference timing plus the
max-abs-error of the kernel against its oracle. TPU timings come from the
roofline model in benchmarks/roofline.py.
"""
from __future__ import annotations

import time
from typing import Dict, Tuple

import jax
import jax.numpy as jnp


def _time(fn, *args, iters=3) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
        (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.time() - t0) / iters * 1e6


def bench_flash_attention() -> Tuple[float, Dict]:
    from repro.kernels.flash_attention.ops import flash_attention
    key = jax.random.PRNGKey(0)
    B, S, H, K, hd = 1, 1024, 8, 2, 128
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, K, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, K, hd), jnp.float32)
    us = _time(lambda a, b, c: flash_attention(a, b, c, impl="ref"), q, k, v)
    o_ref = flash_attention(q, k, v, impl="ref")
    o_pal = flash_attention(q, k, v, impl="interpret", block_q=256,
                            block_k=256)
    err = float(jnp.max(jnp.abs(o_ref - o_pal)))
    flops = 2 * 2 * B * H * S * S * hd / 2  # causal
    return us, {"max_err_vs_oracle": err,
                "ref_gflops_cpu": round(flops / us / 1e3, 2)}


def bench_decode_attention() -> Tuple[float, Dict]:
    from repro.kernels.decode_attention.ops import decode_attention
    key = jax.random.PRNGKey(1)
    B, H, K, hd, L = 4, 8, 4, 128, 8192
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, H, hd), jnp.float32)
    ck = jax.random.normal(ks[1], (B, L, K, hd), jnp.float32)
    cv = jax.random.normal(ks[2], (B, L, K, hd), jnp.float32)
    sp = jnp.arange(L)
    us = _time(lambda a, b, c: decode_attention(a, b, c, sp, L - 1,
                                                impl="ref"), q, ck, cv)
    o_ref = decode_attention(q, ck, cv, sp, L - 1, impl="ref")
    o_pal = decode_attention(q, ck, cv, sp, L - 1, impl="interpret",
                             block_k=512)
    err = float(jnp.max(jnp.abs(o_ref - o_pal)))
    bytes_moved = 2 * B * L * K * hd * 4
    return us, {"max_err_vs_oracle": err,
                "ref_gbps_cpu": round(bytes_moved / us / 1e3, 2)}


def bench_rglru_scan() -> Tuple[float, Dict]:
    from repro.kernels.rglru_scan.ops import rglru_scan
    key = jax.random.PRNGKey(2)
    B, S, W = 2, 2048, 2560
    ks = jax.random.split(key, 3)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (B, S, W))) * 0.2 + 0.79
    b = jax.random.normal(ks[1], (B, S, W)) * 0.1
    h0 = jax.random.normal(ks[2], (B, W))
    us = _time(lambda x, y, z: rglru_scan(x, y, z, impl="ref"), a, b, h0)
    h_ref = rglru_scan(a, b, h0, impl="ref")
    h_pal = rglru_scan(a, b, h0, impl="interpret", block_s=256, block_w=512)
    err = float(jnp.max(jnp.abs(h_ref - h_pal)))
    return us, {"max_err_vs_oracle": err,
                "ref_gbps_cpu": round(3 * B * S * W * 4 / us / 1e3, 2)}


def bench_mlstm_chunk() -> Tuple[float, Dict]:
    from repro.kernels.mlstm_chunk.ops import mlstm_chunk
    from repro.kernels.mlstm_chunk.ref import mlstm_chunk_reference
    key = jax.random.PRNGKey(3)
    B, S, H, dqk, dv = 1, 512, 4, 128, 256
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (B, S, H, dqk), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, dqk), jnp.float32) / dqk ** 0.5
    v = jax.random.normal(ks[2], (B, S, H, dv), jnp.float32)
    il = jax.random.normal(ks[3], (B, S, H), jnp.float32)
    fl = jax.nn.log_sigmoid(jax.random.normal(ks[4], (B, S, H)) + 2.0)
    us = _time(lambda *xs: mlstm_chunk(*xs, impl="ref"), q, k, v, il, fl)
    o_ref = mlstm_chunk(q, k, v, il, fl, impl="ref")
    o_pal = mlstm_chunk(q, k, v, il, fl, impl="interpret", chunk=128)
    err = float(jnp.max(jnp.abs(o_ref - o_pal)))
    return us, {"max_err_vs_oracle": err}
