# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness entry point.

    PYTHONPATH=src python -m benchmarks.run [--only NAME]

Sections:
  paper figures  — fig5 / fig7 / fig8 / consolidation summary (§III)
  beyond paper   — checkpoint-preemption vs kill ablation
  kernels        — Pallas kernels vs oracles (CPU: oracle timing + max err)
  roofline       — dry-run-derived roofline summary (needs results/dryrun)
"""
from __future__ import annotations

import argparse
import json
import sys


def _fmt(derived) -> str:
    return json.dumps(derived, separators=(",", ":"), default=str)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()

    from benchmarks import kernel_bench, paper_figs, roofline

    benches = [
        ("fig5_ws_consumption", paper_figs.fig5_ws_consumption),
        ("fig7_completed_turnaround", paper_figs.fig7_completed_turnaround),
        ("fig8_killed_jobs", paper_figs.fig8_killed_jobs),
        ("consolidation_summary", paper_figs.consolidation_summary),
        ("beyond_paper_checkpoint_mode",
         paper_figs.beyond_paper_checkpoint_mode),
        ("request_level_slo", paper_figs.request_level_slo),
        ("multi_department", paper_figs.multi_department),
        ("policy_engine", paper_figs.policy_engine),
        ("campaign_tiny", paper_figs.campaign_tiny),
        ("campaign_throughput", paper_figs.campaign_throughput),
        ("kernel_flash_attention", kernel_bench.bench_flash_attention),
        ("kernel_decode_attention", kernel_bench.bench_decode_attention),
        ("kernel_rglru_scan", kernel_bench.bench_rglru_scan),
        ("kernel_mlstm_chunk", kernel_bench.bench_mlstm_chunk),
        ("roofline_single_pod_baseline",
         lambda: roofline.roofline_report("single", "baseline")),
        ("roofline_single_pod_final",
         lambda: roofline.roofline_report("single", "final")),
        ("roofline_multi_pod_final",
         lambda: roofline.roofline_report("multi", "final")),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in benches:
        if args.only and args.only not in name:
            continue
        try:
            us, derived = fn()
            print(f"{name},{us:.1f},{_fmt(derived)}", flush=True)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name},NaN,{_fmt({'error': repr(e)})}", flush=True)
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
