"""Re-run the HLO cost model over saved dry-run artifacts (no recompile).

Updates each cell JSON's `hlo` / `collective_detail` / `roofline` fields in
place from the stored .hlo.gz — used whenever the cost-model methodology
changes (EXPERIMENTS.md records which model version scored each table).
"""
from __future__ import annotations

import glob
import json
import os
import sys


def rescore(out_dir: str = "results/dryrun"):
    sys.path.insert(0, "src")
    from repro.configs import ARCHS, SHAPES_BY_NAME
    from repro.hlo.analysis import analyze_file
    from repro.hlo.roofline import score

    n = 0
    for mesh in ("single", "multi"):
        for p in sorted(glob.glob(os.path.join(out_dir, mesh, "*.json"))):
            r = json.load(open(p))
            if r.get("status") != "ok":
                continue
            tag = ""
            base = os.path.basename(p)[:-5]
            hlo_path = os.path.join(out_dir, "hlo",
                                    f"{mesh}__{base}.hlo.gz")
            if not os.path.exists(hlo_path):
                continue
            totals = analyze_file(hlo_path)
            r["hlo"] = {k: v for k, v in totals.items()
                        if k != "collective_detail"}
            r["collective_detail"] = totals["collective_detail"]
            r["roofline"] = score(ARCHS[r["arch"]],
                                  SHAPES_BY_NAME[r["shape"]],
                                  r["devices"], r.get("plan", {}), totals)
            json.dump(r, open(p, "w"), indent=1)
            n += 1
    print(f"rescored {n} cells")


if __name__ == "__main__":
    rescore(sys.argv[1] if len(sys.argv) > 1 else "results/dryrun")
